//! Serving demo client (paper Fig. 10's host side).
//!
//! Start the server first:
//! ```bash
//! cargo run --release -- serve --model scnn3 --addr 127.0.0.1:7878
//! ```
//! then:
//! ```bash
//! cargo run --release --example serve_client -- --addr 127.0.0.1:7878
//! ```

use sti_snn::server::Client;
use sti_snn::util::cli::Args;
use sti_snn::util::json::Json;
use sti_snn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let n = args.get_usize("requests", 8);
    let pixels = args.get_usize("pixels", 28 * 28);

    let mut client = Client::connect(addr)?;
    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let image: Vec<f32> = (0..pixels).map(|_| rng.f32()).collect();
        let resp = client.infer(i as u64, &image)?;
        match resp.get("class") {
            Some(c) => println!("request {i}: class {} ({} us)",
                                c, resp.get("latency_us")
                                    .and_then(|l| l.as_f64())
                                    .unwrap_or(0.0)),
            None => println!("request {i}: error {:?}",
                             resp.get("error")),
        }
    }
    let dt = t0.elapsed();
    println!("\n{n} requests in {:.1} ms ({:.1} req/s)",
             dt.as_secs_f64() * 1e3, n as f64 / dt.as_secs_f64());

    let stats = client.request(&Json::obj(vec![
        ("cmd", Json::str("stats")),
    ]))?;
    println!("server stats: {stats}");
    Ok(())
}
