//! Design-space exploration demo: calibrate the analytical cost models
//! against the simulator, search the joint space of per-layer parallel
//! factors x replica count x compute backend under a PE budget, and
//! print the latency/energy/resource Pareto frontier as a table.
//!
//! ```bash
//! cargo run --release --example explore [-- --model scnn3 \
//!     --pe-budget 144 --max-replicas 4]
//! ```

use sti_snn::arch;
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::dse::{self, CalibrationConfig, CostModel, SearchSpace};
use sti_snn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.get_str("model", "scnn3");
    let net = arch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let budget = args.get_usize("pe-budget", 8 * dse::min_pes(&net));
    let max_replicas = args.get_usize("max-replicas", 4);

    // 1. Calibrate: a handful of simulator probes fit per-term
    //    correction factors (and measure host speed per backend). The
    //    default probe rate is shared with `serve --auto-tune`, so this
    //    example and the CLI fit the same model.
    let timing = ConvLatencyParams::optimized();
    let model = CostModel {
        calibration: dse::calibrate(&net, &timing,
                                    &CalibrationConfig::default()),
        timing,
        ..CostModel::default()
    };
    println!("calibration for {name}:");
    println!("  cycle scales (std/dw/pw): {:.3} / {:.3} / {:.3}",
             model.calibration.cycle_scales[0],
             model.calibration.cycle_scales[1],
             model.calibration.cycle_scales[2]);
    println!("  op activity: {:.3}  weight scale: {:.3}  input scales \
              (DRAM/BRAM): {:.3} / {:.3}",
             model.calibration.op_activity,
             model.calibration.weight_scale,
             model.calibration.input_dram_scale,
             model.calibration.input_bram_scale);
    for (b, ns) in &model.calibration.host_ns_per_frame {
        println!("  host speed [{b}]: {:.2} ms/frame", ns / 1e6);
    }

    // 2. Explore the space and print the frontier.
    let space = SearchSpace::new(net, budget)
        .with_replicas(max_replicas);
    let ex = dse::explore(&space, &model);
    println!("\n{} | PE budget {budget} | {} candidates -> frontier {}",
             space.net.name, ex.candidates, ex.frontier.len());
    print!("{}", dse::frontier_table(&ex));

    // 3. The serving choice `serve --auto-tune` would boot with.
    match &ex.chosen {
        Some(c) => println!("\nserving choice: factors {:?} x{} \
                             replica(s), backend {} -> {:.1} FPS at \
                             {:.2} W",
                            c.candidate.factors, c.candidate.replicas,
                            c.candidate.backend, c.pool_fps, c.power_w),
        None => println!("\nno candidate fits the device"),
    }
    Ok(())
}
