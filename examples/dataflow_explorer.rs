//! Dataflow explorer: sweep timesteps and parallel factors to see the
//! OS-dataflow trade-offs the paper analyses (SectionII-C, SectionIV-E.2).
//!
//! ```bash
//! cargo run --release --example dataflow_explorer [-- --model scnn5]
//! ```

use sti_snn::arch;
use sti_snn::coordinator::scheduler;
use sti_snn::dataflow::{self, ConvLatencyParams};
use sti_snn::sim::cycles_to_ms;
use sti_snn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.get_str("model", "scnn5");
    let net = arch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;

    // --- OS vs WS access counts across timesteps (Table I trend) ------
    println!("== OS vs WS total memory accesses vs timesteps ({name}) ==");
    println!("{:>3} {:>18} {:>18} {:>10}", "T", "OS total", "WS total",
             "OS/WS");
    for t in [1u64, 2, 4, 6] {
        let (mut os_tot, mut ws_tot) = (0u64, 0u64);
        for c in net.accel_convs() {
            os_tot += dataflow::os_access(c, t).total();
            ws_tot += dataflow::ws_access(c, t).total();
        }
        println!("{t:>3} {os_tot:>18} {ws_tot:>18} {:>10.3}",
                 os_tot as f64 / ws_tot as f64);
    }

    // --- Line-buffer reduction per layer (Table III) -------------------
    println!("\n== line buffer + spike-vector input-access reduction ==");
    for (i, c) in net.accel_convs().iter().enumerate() {
        println!("conv{}: {:.0}x fewer off-chip input reads",
                 i + 1, dataflow::access::input_access_reduction(c, 1));
    }

    // --- PE budget sweep (the scheduler's latency/area frontier) -------
    println!("\n== parallel-factor optimiser: PE budget sweep ==");
    println!("{:>8} {:>20} {:>10}", "budget", "factors", "t_max ms");
    let timing = ConvLatencyParams::optimized();
    let min_pes: usize =
        net.accel_convs().iter().map(|c| c.kh * c.kw).sum();
    let budgets: Vec<usize> =
        [1, 2, 3, 4, 8, 16].iter().map(|m| min_pes * m).collect();
    for choice in scheduler::budget_sweep(&net, &budgets, &timing) {
        println!("{:>8} {:>20} {:>10.3}",
                 choice.pes, format!("{:?}", choice.factors),
                 cycles_to_ms(choice.t_max));
    }

    println!("\n(the paper's hand-picked profiles — SCNN3 (4,2) @ 54 PEs, \
              SCNN5 (4,4,2,1) @ 99 PEs — sit on this frontier)");
    Ok(())
}
