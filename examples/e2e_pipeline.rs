//! End-to-end driver (the DESIGN.md validation workload).
//!
//! Proves all three layers compose on a real small workload:
//!
//!   1. Load the **trained + quantised** SCNN3 artifacts built by the
//!      python compile path (`make artifacts`): net.json, int8 weights,
//!      and the AOT HLO graphs lowered from the jax model whose layers
//!      are the L1 Pallas kernels.
//!   2. Generate a held-out synthetic-MNIST test set (same generator +
//!      held-out seed as training).
//!   3. For every image: run the PJRT **encoder** graph (L2/L1) to get
//!      the input spike frame, then push it through the cycle-level
//!      **simulator pipeline** (L3) for the class prediction — and run
//!      the PJRT **full-model** graph as the functional reference.
//!   4. Report: accuracy (sim vs reference vs labels), agreement rate,
//!      and the Table-IV row (FPS / GOPS / W / GOPS/W/PE) for this
//!      design point.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use sti_snn::metrics::PerfRow;
use sti_snn::model::Artifact;
use sti_snn::runtime::{artifacts_dir, Runtime};
use sti_snn::session::{Session, Weights};
use sti_snn::util::cli::Args;
use sti_snn::util::rng::Rng;

/// Synthetic-MNIST glyph generator — a rust port of
/// `python/compile/data.py::synth_mnist` (seven-segment digit strokes
/// with affine jitter + noise). Shares the class structure, not the
/// exact pixels: the e2e claim is that the *trained model* classifies
/// freshly-drawn samples, end to end, through the accelerator.
mod synth {
    use super::Rng;

    const SEGS: [((f64, f64), (f64, f64)); 7] = [
        ((0.25, 0.20), (0.75, 0.20)), // a: top
        ((0.75, 0.20), (0.75, 0.50)), // b: top-right
        ((0.75, 0.50), (0.75, 0.80)), // c: bottom-right
        ((0.25, 0.80), (0.75, 0.80)), // d: bottom
        ((0.25, 0.50), (0.25, 0.80)), // e: bottom-left
        ((0.25, 0.20), (0.25, 0.50)), // f: top-left
        ((0.25, 0.50), (0.75, 0.50)), // g: middle
    ];
    const DIGIT_SEGS: [&str; 10] = [
        "abcdef", "bc", "abged", "abgcd", "fgbc", "afgcd", "afgedc",
        "abc", "abcdefg", "abcdfg",
    ];

    fn seg_index(c: char) -> usize {
        (c as u8 - b'a') as usize
    }

    pub fn glyph(digit: usize, rng: &mut Rng, size: usize) -> Vec<f32> {
        let mut img = vec![0f32; size * size];
        let tx = rng.f64() * 0.16 - 0.08;
        let ty = rng.f64() * 0.16 - 0.08;
        let sc = 0.9 + rng.f64() * 0.2;
        let shear = rng.f64() * 0.24 - 0.12;
        let width = 0.05 + rng.f64() * 0.04;
        let jmap = |x: f64, y: f64| -> (f64, f64) {
            let (x, y) = ((x - 0.5) * sc + 0.5, (y - 0.5) * sc + 0.5);
            (x + shear * (y - 0.5) + tx, y + ty)
        };
        for ch in DIGIT_SEGS[digit % 10].chars() {
            let ((x0, y0), (x1, y1)) = SEGS[seg_index(ch)];
            let p0 = jmap(x0, y0);
            let p1 = jmap(x1, y1);
            draw(&mut img, size, p0, p1, width);
        }
        // Gaussian-ish noise from the PRNG (sum of uniforms).
        for v in img.iter_mut() {
            let n: f64 = (0..4).map(|_| rng.f64()).sum::<f64>() / 2.0 - 1.0;
            *v = (*v + 0.08 * n as f32).clamp(0.0, 1.0);
        }
        img
    }

    fn draw(img: &mut [f32], size: usize, p0: (f64, f64), p1: (f64, f64),
            width: f64) {
        let (x0, y0) = p0;
        let (dx, dy) = (p1.0 - x0, p1.1 - y0);
        let len2 = dx * dx + dy * dy + 1e-12;
        for yy in 0..size {
            for xx in 0..size {
                let x = (xx as f64 + 0.5) / size as f64;
                let y = (yy as f64 + 0.5) / size as f64;
                let t = (((x - x0) * dx + (y - y0) * dy) / len2)
                    .clamp(0.0, 1.0);
                let (px, py) = (x0 + t * dx, y0 + t * dy);
                let d = ((x - px).powi(2) + (y - py).powi(2)).sqrt();
                let stroke = (1.0 - d / width).clamp(0.0, 1.0) as f32;
                let i = yy * size + xx;
                img[i] = img[i].max(stroke);
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_str("model", "scnn3");
    let n_samples = args.get_usize("samples", 64);

    // --- 1. Load artifacts ---------------------------------------------
    let dir = artifacts_dir().join(model);
    let art = Artifact::load(&dir).map_err(|e| {
        anyhow::anyhow!("{e:#}\nrun `make artifacts` first")
    })?;
    println!("loaded artifact {} (input {:?}, T={})",
             art.net.name, art.net.input, art.timesteps);

    let mut rt = Runtime::new()?;
    rt.load_hlo("encoder", &art.encoder_hlo(), art.net.input)?;
    rt.load_hlo("model", &art.model_hlo(), art.net.input)?;
    println!("PJRT platform: {} | encoder + full-model HLO compiled",
             rt.platform());

    let mut session = Session::builder()
        .weights(Weights::Artifact(dir.clone()))
        .timesteps(1)
        .build()?;
    let enc_shape = art.encoder_out_shape();

    // --- 2. Held-out synthetic test set --------------------------------
    let mut rng = Rng::new(777);
    let samples: Vec<(usize, Vec<f32>)> = (0..n_samples)
        .map(|_| {
            let digit = rng.below(10);
            (digit, synth::glyph(digit, &mut rng, art.net.input.0))
        })
        .collect();

    // --- 3. Run every sample through all three layers ------------------
    let mut sim_correct = 0;
    let mut ref_correct = 0;
    let mut agree = 0;
    let mut last_rep = None;
    for (label, image) in &samples {
        let frame = rt.encode("encoder", image, enc_shape)?;
        let rep = session.infer_batch(std::slice::from_ref(&frame));
        let sim_class = rep.predictions[0];

        let logits = rt.logits("model", image)?;
        let ref_class = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();

        sim_correct += usize::from(sim_class == *label);
        ref_correct += usize::from(ref_class == *label);
        agree += usize::from(sim_class == ref_class);
        last_rep = Some(rep);
    }

    let n = samples.len() as f64;
    println!("\n=== end-to-end results ({n} held-out samples) ===");
    println!("simulator accuracy:       {:.1}%",
             100.0 * sim_correct as f64 / n);
    println!("PJRT reference accuracy:  {:.1}%",
             100.0 * ref_correct as f64 / n);
    println!("sim vs reference agree:   {:.1}%  (int8 PE array vs \
              fake-quant float graph)", 100.0 * agree as f64 / n);

    // --- 4. Table-IV row for this design point --------------------------
    let rep = last_rep.expect("at least one sample");
    let row = rep.perf_row(&format!("e2e {model}"));
    println!("\n{}", PerfRow::header());
    println!("{row}");
    Ok(())
}
