//! DSC flexibility demo (paper SectionIV-D): the same PE array architecture
//! runs standard, depthwise, and pointwise convolution by switching
//! modes — compare vMobileNet (DSC) against an equivalent standard-conv
//! network on ops, latency, weight storage, and energy.
//!
//! ```bash
//! cargo run --release --example dsc_flexibility
//! ```

use sti_snn::arch::{self, NetBuilder};
use sti_snn::codec::SpikeFrame;
use sti_snn::session::Session;
use sti_snn::sim::cycles_to_ms;
use sti_snn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // vMobileNet (DSC) vs a standard-conv twin with the same channel
    // progression (what MobileNet replaces).
    let dsc = arch::vmobilenet();
    let standard = NetBuilder::new("vmobilenet-std", (28, 28, 1))
        .encoder(16, 3)
        .conv(32, 3)
        .pool()
        .conv(64, 3)
        .conv(64, 3)
        .pool()
        .conv(128, 3)
        .fc(10)
        .build();

    println!("{:<16} {:>12} {:>12} {:>12} {:>12} {:>12}",
             "network", "MOPs/frame", "weights KB", "t_max ms",
             "uJ/frame", "PEs");
    for net in [dsc, standard] {
        let name = net.name.clone();
        let mops = net.ops_per_frame() as f64 / 1e6;
        let wkb = net.weight_bytes() as f64 / 1024.0;
        let pes = net.total_pes();
        let mut session = Session::builder().network(net).build()?;
        let shape = session.input_shape();
        let mut rng = Rng::new(3);
        let frames: Vec<SpikeFrame> = (0..2)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                        &mut rng))
            .collect();
        let rep = session.infer_batch(&frames);
        println!("{:<16} {:>12.2} {:>12.1} {:>12.3} {:>12.1} {:>12}",
                 name, mops, wkb, cycles_to_ms(rep.t_max),
                 rep.energy_per_frame_j * 1e6, pes);
    }

    println!("\nDSC wins on parameters + ops; the multi-mode PE array \
              (Fig. 8) makes both run on the same hardware.");
    Ok(())
}
