//! Quickstart: build the SCNN3 accelerator through the `Session`
//! facade, run synthetic spike frames through the layer-wise pipeline,
//! print throughput + energy from the unified report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — weights are deterministic-random (cycle and
//! traffic counts are weight-independent; see DESIGN.md).

use sti_snn::codec::SpikeFrame;
use sti_snn::session::{Session, Weights};
use sti_snn::sim::cycles_to_ms;
use sti_snn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. One builder for the whole stack: network, design point
    //    (paper SCNN3 at factors (4,2)), weights, backend.
    let mut session = Session::builder()
        .model("scnn3")
        .parallel_factors(&[4, 2])
        .weights(Weights::Random { seed: 1000 })
        .build()?;
    println!("network: {} | {} PEs | {:.2} MOPs/frame",
             session.net().name, session.net().total_pes(),
             session.net().ops_per_frame() as f64 / 1e6);

    // 2. Feed 8 synthetic post-encoder spike frames at ~20% firing
    //    rate.
    let shape = session.input_shape();
    let mut rng = Rng::new(42);
    let frames: Vec<SpikeFrame> = (0..8)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                    &mut rng))
        .collect();
    let rep = session.infer_batch(&frames);

    // 3. Report — cycles, energy, power, and throughput come from the
    //    one unified `session::Report`.
    println!("\nper-layer cycles (frame 0):");
    for (name, cycles) in rep.layer_names.iter().zip(&rep.layer_cycles) {
        println!("  {name:<22} {cycles:>10} ({:.3} ms)",
                 cycles_to_ms(*cycles));
    }
    println!("\npipeline interval (T_max): {} cycles = {:.3} ms",
             rep.t_max, cycles_to_ms(rep.t_max));
    println!("steady-state throughput:   {:.0} FPS", rep.fps_steady);
    println!("dynamic energy:            {:.1} uJ/frame",
             rep.energy_per_frame_j * 1e6);
    println!("average power:             {:.2} W", rep.power_w);
    println!("efficiency:                {:.2} GOPS/W ({:.3} GOPS/W/PE)",
             rep.gops_per_w, rep.gops_per_w_per_pe);
    println!("predictions:               {:?}", rep.predictions);
    Ok(())
}
