//! Quickstart: build the SCNN3 accelerator, run synthetic spike frames
//! through the layer-wise pipeline, print throughput + energy.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — weights are deterministic-random (cycle and
//! traffic counts are weight-independent; see DESIGN.md).

use sti_snn::arch;
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::sim::{cycles_to_ms, EnergyModel, CLK_HZ};
use sti_snn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Pick a network and a design point (paper SCNN3 at factors (4,2)).
    let net = arch::scnn3().with_parallel_factors(&[4, 2]);
    println!("network: {} | {} PEs | {:.2} MOPs/frame",
             net.name, net.total_pes(),
             net.ops_per_frame() as f64 / 1e6);

    // 2. Build the streaming pipeline (one engine per layer, T = 1).
    let mut pipe = Pipeline::random(net, PipelineConfig::default())?;

    // 3. Feed 8 synthetic post-encoder spike frames at ~20% firing rate.
    let shape = pipe.input_shape();
    let mut rng = Rng::new(42);
    let frames: Vec<SpikeFrame> = (0..8)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                    &mut rng))
        .collect();
    let rep = pipe.run(&frames);

    // 4. Report.
    println!("\nper-layer cycles (frame 0):");
    for (name, cycles) in rep.layer_names.iter().zip(&rep.layer_cycles) {
        println!("  {name:<22} {cycles:>10} ({:.3} ms)",
                 cycles_to_ms(*cycles));
    }
    println!("\npipeline interval (T_max): {} cycles = {:.3} ms",
             rep.t_max, cycles_to_ms(rep.t_max));
    println!("steady-state throughput:   {:.0} FPS",
             CLK_HZ / rep.t_max as f64);
    println!("dynamic energy:            {:.1} uJ/frame",
             rep.dynamic_energy_per_frame_j() * 1e6);
    let power = EnergyModel::default().avg_power(
        rep.dynamic_energy_per_frame_j(), CLK_HZ / rep.t_max as f64,
        rep.pes, rep.resources.bram36);
    println!("average power:             {power:.2} W");
    println!("predictions:               {:?}", rep.predictions);
    Ok(())
}
