//! CI smoke for `serve --online-tune`: an in-process online-tuned
//! serving session over real TCP, driven through a density shift
//! until the controller hot-swaps the replica pool.
//!
//! Asserts the release-mode serving invariants end to end:
//! * at least one generation swap happens (`sti_retune_total >= 1`),
//! * nothing is shed across the swap (`sti_shed_total == 0`),
//! * every request gets a classification before, through, and after
//!   the swap,
//! * the retune event log is written on shutdown and records the
//!   swap (uploaded as a CI artifact).
//!
//! ```bash
//! cargo run --release --example retune_smoke
//! ```

use std::time::{Duration, Instant};

use sti_snn::autotune::RetunePolicy;
use sti_snn::server::Client;
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::util::json::Json;
use sti_snn::util::rng::Rng;

/// Read one un-labelled sample from a Prometheus-style exposition.
fn counter(text: &str, name: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| {
            let mut it = l.split_whitespace();
            if it.next() != Some(name) {
                return None;
            }
            it.next().and_then(|v| v.parse().ok())
        })
        .unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    let log_path = "retune_events.json";
    // Boot deliberately weak (one replica, event-driven backend) under
    // a fast-reacting policy: the first eligible re-plan finds a
    // strictly better design point, so the swap fires quickly.
    let session = Session::builder()
        .model("scnn3")
        .replicas(1)
        .backend(BackendKind::Accurate)
        .queue(4, Duration::from_millis(2))
        .online_tune(RetunePolicy {
            interval: Duration::from_millis(50),
            min_frames: 8,
            hysteresis: 0.01,
            cooldown: Duration::ZERO,
            max_density_spread: 10.0,
            headroom: 1.25,
        })
        .retune_log(log_path)
        .build()?;
    let (h, w, c) = session.input_shape();
    let input_len = h * w * c;

    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        session.serve("127.0.0.1:0", move |addr| {
            tx.send(addr).unwrap();
        })
    });
    let addr = rx.recv()?.to_string();
    println!("online-tune smoke serving scnn3 on {addr}");

    let mut client = Client::connect(&addr)?;
    let mut rng = Rng::new(11);
    let mut image = |rate: f64, rng: &mut Rng| -> Vec<f32> {
        (0..input_len)
            .map(|_| if rng.bernoulli(rate) { 0.9 } else { 0.1 })
            .collect()
    };

    let deadline = Instant::now() + Duration::from_secs(120);
    let mut sent = 0u64;
    let mut swaps = 0.0;
    while swaps < 1.0 {
        anyhow::ensure!(Instant::now() < deadline,
                        "no generation swap within 120 s ({sent} \
                         requests served)");
        // The measured-workload shift: sparse traffic first, then
        // dense — the controller re-plans against what it observes.
        let rate = if sent < 32 { 0.05 } else { 0.6 };
        for _ in 0..4 {
            let img = image(rate, &mut rng);
            let resp = client.infer(sent, &img)?;
            anyhow::ensure!(resp.get("class").is_some(),
                            "request {sent} failed: {resp}");
            sent += 1;
        }
        swaps = counter(&client.metrics()?, "sti_retune_total");
    }

    // The new generation keeps serving the same connection.
    for _ in 0..8 {
        let img = image(0.6, &mut rng);
        let resp = client.infer(sent, &img)?;
        anyhow::ensure!(resp.get("class").is_some(),
                        "post-swap request {sent} failed: {resp}");
        sent += 1;
    }
    let text = client.metrics()?;
    let shed = counter(&text, "sti_shed_total");
    let generation = counter(&text, "sti_retune_generation");
    anyhow::ensure!(shed == 0.0,
                    "{shed} request(s) shed across the swap");
    anyhow::ensure!(generation >= 1.0,
                    "metrics report generation {generation}");
    println!("swap observed: sti_retune_total {swaps}, generation \
              {generation}, shed {shed}, {sent} requests served");

    client.shutdown()?;
    server.join().expect("server thread")?;

    // The shutdown path wrote the event log; it must parse and record
    // the swap (CI uploads it as an artifact).
    let logged = std::fs::read_to_string(log_path)?;
    let json = Json::parse(logged.trim())?;
    let retunes =
        json.get("retunes").and_then(Json::as_f64).unwrap_or(0.0);
    anyhow::ensure!(retunes >= 1.0,
                    "retune log {log_path} records no swaps");
    println!("retune log written to {log_path} ({retunes} swap(s) \
              recorded)");
    Ok(())
}
