//! Event-streaming demo client: the DVS-style host side of the binary
//! events protocol (paper's event-driven single-timestep workload).
//!
//! Start the server first (events mode needs the synthetic simulator
//! path; --events bounds the queue so overload sheds explicitly):
//! ```bash
//! cargo run --release -- serve --model scnn3 --synthetic --events \
//!     --addr 127.0.0.1:7878
//! ```
//! then:
//! ```bash
//! cargo run --release --example events_client -- \
//!     --addr 127.0.0.1:7878 --windows 16 --rate 0.15
//! ```

use sti_snn::codec::stream::{synth_events, WindowPolicy};
use sti_snn::server::{Client, EventReply, RetryPolicy};
use sti_snn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let windows = args.get_usize("windows", 16);
    let rate = args.get_f64("rate", 0.15);
    let window_us = args.get_u64("window-us", 1000) as u32;

    let mut client = Client::connect(addr)?;
    let (h, w, c) = client
        .start_events(WindowPolicy::TimeUs(window_us))?;
    println!("events mode: server windows into ({h}, {w}, {c})");

    // Warm-up probe on a second dense connection, retried through
    // transient shed/timeout replies (pool still restarting a replica,
    // queue momentarily full) so the stream below starts against a
    // server that is actually serving.
    let mut probe = Client::connect(addr)?;
    let reply = probe.submit_with_retry(0, &vec![0.0; h * w * c],
                                        &RetryPolicy::default())?;
    match reply.get("error").and_then(|e| e.as_str()) {
        None => println!("warm-up probe ok (class {})",
                         reply.get("class")
                              .and_then(|v| v.as_f64())
                              .unwrap_or(-1.0)),
        Some(e) => anyhow::bail!("warm-up probe kept failing: {e}"),
    }
    drop(probe);

    let events = synth_events(h, w, c, windows, rate, window_us, 1);
    println!("streaming {} events ({windows} windows of {window_us} µs \
              at rate {rate})",
             events.len());

    fn show(r: &EventReply) {
        match r {
            EventReply::Window { window_id, class, latency_us,
                                 replica, .. } => {
                println!("  window {window_id:>4}: class {class} \
                          ({latency_us} µs, replica {replica})");
            }
            EventReply::Shed { window_id } => {
                println!("  window {window_id:>4}: shed (queue full)");
            }
            EventReply::Error { window_id, msg } => {
                println!("  window {window_id:>4}: error: {msg}");
            }
            EventReply::Summary(_) => unreachable!("finish keeps it"),
        }
    }

    // Stream window by window, draining replies past a bounded
    // in-flight depth — the server drops clients that never read
    // (its reply channel stalls once both TCP buffers fill), so a
    // load tester must consume as it produces.
    const MAX_IN_FLIGHT: usize = 8;
    let t0 = std::time::Instant::now();
    let mut outstanding = 0usize;
    let mut sent = 0usize;
    for wi in 0..windows {
        let end_t = (wi as u32 + 1).saturating_mul(window_us);
        let end = events[sent..]
            .iter()
            .position(|e| e.t >= end_t)
            .map_or(events.len(), |p| sent + p);
        let batch = &events[sent..end];
        sent = end;
        if batch.is_empty() {
            continue; // window had no activity: the server never sees it
        }
        client.send_events(batch)?;
        // All but the newest (still-open) window are complete
        // server-side, so a reply is guaranteed once the depth is hit.
        if outstanding == MAX_IN_FLIGHT {
            show(&client.read_event_reply()?);
        } else {
            outstanding += 1;
        }
    }
    let (replies, summary) = client.finish_events()?;
    let dt = t0.elapsed().as_secs_f64();
    for r in &replies {
        show(r);
    }
    println!("{} events -> {} windows: {} served, {} shed, {:.1} \
              windows/s end-to-end",
             summary.events, summary.windows, summary.served,
             summary.shed, summary.windows as f64 / dt.max(1e-9));
    Ok(())
}
