#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON produced by `sti-snn run --trace`.

Usage: check_trace.py TRACE.json [MIN_LAYERS]

Checks that the file parses as JSON, that `traceEvents` is a non-empty
array of complete ("ph": "X") events each carrying name/cat/ts/dur,
and that at least MIN_LAYERS distinct layer indices appear among the
layer spans (`layer` / `stream.layer`) — i.e. every layer of the net
actually emitted a span. Exits non-zero with a message on any failure
so CI can gate on it.
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if len(sys.argv) < 2:
        fail("usage: check_trace.py TRACE.json [MIN_LAYERS]")
    path = sys.argv[1]
    min_layers = int(sys.argv[2]) if len(sys.argv) > 2 else 1

    try:
        with open(path, encoding="utf-8") as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")

    layers = set()
    cats = set()
    for i, ev in enumerate(events):
        for key in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            if key not in ev:
                fail(f"event {i} missing {key!r}: {ev}")
        if ev["ph"] != "X":
            fail(f"event {i}: expected complete event ph=X, got "
                 f"{ev['ph']!r}")
        cats.add(ev["cat"])
        if ev["name"] in ("layer", "stream.layer"):
            layers.add(ev.get("args", {}).get("layer"))

    if len(layers) < min_layers:
        fail(f"{path}: {len(layers)} distinct layer span(s), "
             f"expected >= {min_layers} (layers seen: {sorted(layers)})")

    print(f"check_trace: OK: {len(events)} events, "
          f"{len(layers)} layer(s), categories {sorted(cats)}, "
          f"{trace.get('otherData', {}).get('dropped', 0)} dropped")


if __name__ == "__main__":
    main()
