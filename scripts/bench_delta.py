#!/usr/bin/env python3
"""Advisory bench delta: compare fresh bench results against the
committed baseline.

Usage: bench_delta.py BASELINE.json FRESH.json

Both files are the JSON arrays the rust bench harness
(`util::bench::BenchSet`, via STI_SNN_BENCH_JSON) emits: a list of
{"title", "results": [{"name", "median_ns", ...}]} sets. Entries are
matched by result name across all sets; frames/s = 1e9 / median_ns.

Always exits 0 — this is an advisory CI step (machine-to-machine
deltas are noisy); the table is for eyeballing regressions, the
committed baseline for tracking the optimisation history.

Refreshing the committed baseline (BENCH_sim.json at the repo root)
---------------------------------------------------------------------
The baseline must describe the CURRENT main, not a historical one —
a stale baseline makes this step report the same "improvement"
forever, which hides real regressions. Refresh it whenever a PR
intentionally moves hot-path performance:

    rm -f BENCH_sim.json          # BenchSet::write_json appends
    STI_SNN_BENCH_JSON=$PWD/BENCH_sim.json \
        cargo bench --bench bench_sim_engine

Run on a quiet machine (no STI_SNN_BENCH_SMOKE — smoke runs are
single-iteration and too noisy to be a baseline), eyeball the printed
table against the previous baseline, note the provenance (which
change, which box) in the set's "title" field, and commit the file in
the same PR that moved the numbers. CI compares every push against it
(build-test-bench job, "Bench delta vs committed baseline" step) but
never gates on it.
"""

import json
import sys


def flatten(path):
    """name -> median_ns over every set in the file."""
    with open(path) as f:
        sets = json.load(f)
    out = {}
    for s in sets:
        for r in s.get("results", []):
            if r.get("median_ns"):
                out[r["name"]] = float(r["median_ns"])
    return out


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    try:
        base = flatten(base_path)
        fresh = flatten(fresh_path)
    except (OSError, ValueError) as e:
        print(f"bench delta skipped: {e}")
        return

    common = [n for n in base if n in fresh]
    print(f"bench delta vs {base_path} "
          f"({len(common)} comparable, {len(fresh) - len(common)} new, "
          f"{len(base) - len(common)} missing)\n")
    print(f"{'bench':<52} {'base fr/s':>12} {'now fr/s':>12} {'delta':>8}")
    for name in common:
        b, n = 1e9 / base[name], 1e9 / fresh[name]
        delta = (n - b) / b * 100.0
        print(f"{name:<52} {b:>12.1f} {n:>12.1f} {delta:>+7.1f}%")
    for name in sorted(set(fresh) - set(base)):
        print(f"{name:<52} {'-':>12} {1e9 / fresh[name]:>12.1f}      new")


if __name__ == "__main__":
    main()
