"""Quantisation + synthetic dataset tests."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import model as M
from compile import quant as Q


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(3, 3, 8, 16)).astype(np.float32)
    qt = Q.quantize_tensor(w)
    assert qt.q.dtype == np.int8
    # Symmetric int8: max error is half a quant step.
    step = np.abs(w).max() / 127.0
    err = np.abs(np.asarray(qt.deq()) - w).max()
    assert err <= step / 2 + 1e-7


def test_quantize_zero_tensor():
    qt = Q.quantize_tensor(np.zeros((4, 4), np.float32))
    assert (qt.q == 0).all()
    assert qt.scale == 1.0


def test_quantize_params_preserves_biases():
    specs = M.scnn3(10, width=0.25)
    params, _ = M.init_params(specs, (28, 28, 1))
    qp = Q.quantize_params(params)
    for p, q in zip(params, qp):
        for k in p:
            if k.startswith("b"):
                np.testing.assert_array_equal(np.asarray(p[k]), q[k])
            else:
                assert isinstance(q[k], Q.QuantTensor)


def test_quantization_error_metric():
    specs = M.scnn3(10, width=0.25)
    params, _ = M.init_params(specs, (28, 28, 1))
    err = Q.quantization_error(params)
    assert 0 < err < 0.05  # small weights -> small absolute error


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_quant_property_roundtrip(seed, scale):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(16,)) * scale).astype(np.float32)
    qt = Q.quantize_tensor(w)
    err = np.abs(np.asarray(qt.deq()) - w).max()
    assert err <= np.abs(w).max() / 127.0 / 2 + 1e-6 * scale


def test_int8_accuracy_close_to_float():
    """Quantisation must not destroy a trained model (ablation)."""
    from compile import train as T
    cfg = T.TrainConfig(model="scnn3", timesteps=1, loss="tet", epochs=2,
                        n_train=192, n_test=96, batch_size=16, width=0.25,
                        lr=3e-3)
    res = T.train(cfg, verbose=False)
    (_, _), (xte, yte), _, _ = D.load(cfg.dataset, cfg.n_train,
                                      cfg.n_test, seed=cfg.seed)
    facc, qacc = Q.accuracy_drop(res.specs, res.shapes, res.params,
                                 xte, yte, 1)
    assert qacc >= facc - 0.08, f"float {facc} vs int8 {qacc}"


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synth_mnist_shapes_and_range():
    x, y = D.synth_mnist(32, seed=1)
    assert x.shape == (32, 28, 28, 1)
    assert x.dtype == np.float32
    assert 0.0 <= x.min() and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_synth_cifar_shapes():
    x, y = D.synth_cifar(16, seed=2)
    assert x.shape == (16, 32, 32, 3)
    assert (y >= 0).all() and (y < 10).all()


def test_dataset_determinism():
    a = D.synth_mnist(8, seed=3)
    b = D.synth_mnist(8, seed=3)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = D.synth_mnist(8, seed=4)
    assert np.abs(a[0] - c[0]).max() > 0


def test_classes_are_distinguishable():
    """Mean intra-class pixel distance must be well below inter-class —
    the dataset actually encodes its labels."""
    x, y = D.synth_mnist(200, seed=5)
    x = x.reshape(len(x), -1)
    intra, inter = [], []
    for c in range(10):
        xc = x[y == c]
        if len(xc) < 2:
            continue
        mu = xc.mean(axis=0)
        intra.append(np.linalg.norm(xc - mu, axis=1).mean())
        rest = x[y != c]
        inter.append(np.linalg.norm(rest - mu, axis=1).mean())
    assert np.mean(intra) < np.mean(inter)


def test_batches_cover_and_shuffle():
    x = np.arange(40, dtype=np.float32).reshape(40, 1)
    y = np.arange(40, dtype=np.int32)
    rng = np.random.default_rng(0)
    seen = []
    for xb, yb in D.batches(x, y, 8, rng):
        assert xb.shape == (8, 1)
        seen.extend(yb.tolist())
    assert len(seen) == 40
    assert sorted(seen) == list(range(40))
    assert seen != list(range(40))  # shuffled


def test_load_returns_held_out_test():
    (xtr, ytr), (xte, yte), shape, n_cls = D.load("synth-mnist", 32, 16)
    assert xtr.shape[0] == 32 and xte.shape[0] == 16
    assert shape == (28, 28, 1) and n_cls == 10
    # Train and test sets must not be identical.
    assert np.abs(xtr[:16] - xte).max() > 0
