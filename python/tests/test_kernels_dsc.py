"""Depthwise / pointwise Pallas kernels vs oracles (multi-mode PE)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dsc, ref


def rand_spikes(rng, h, w, c, rate=0.3):
    return jnp.asarray((rng.random((h, w, c)) < rate).astype(np.float32))


def rand_weights(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("h,w,c", [(8, 8, 4), (28, 28, 16), (6, 10, 3)])
def test_depthwise_matches_ref(h, w, c):
    rng = np.random.default_rng(h * w * c)
    x, wgt = rand_spikes(rng, h, w, c), rand_weights(rng, 3, 3, c)
    np.testing.assert_allclose(
        np.asarray(dsc.depthwise_psum(x, wgt)),
        np.asarray(ref.depthwise_psum(x, wgt)), rtol=1e-5, atol=1e-5)


def test_depthwise_no_channel_mixing():
    """The defining property of depthwise mode (paper Fig. 8c): output
    channel c must not depend on input channel c' != c."""
    rng = np.random.default_rng(3)
    x = rand_spikes(rng, 8, 8, 4)
    wgt = rand_weights(rng, 3, 3, 4)
    base = np.asarray(dsc.depthwise_psum(x, wgt))
    # Perturb channel 2 of the input; channels 0,1,3 must be unchanged.
    x2 = x.at[:, :, 2].set(1.0 - x[:, :, 2])
    pert = np.asarray(dsc.depthwise_psum(x2, wgt))
    for c in (0, 1, 3):
        np.testing.assert_array_equal(base[:, :, c], pert[:, :, c])
    assert np.abs(base[:, :, 2] - pert[:, :, 2]).max() > 0


@pytest.mark.parametrize("h,w,ci,co", [(8, 8, 4, 8), (14, 14, 16, 32),
                                       (7, 7, 64, 128)])
def test_pointwise_matches_ref(h, w, ci, co):
    rng = np.random.default_rng(h + ci)
    x, wgt = rand_spikes(rng, h, w, ci), rand_weights(rng, ci, co)
    np.testing.assert_allclose(
        np.asarray(dsc.pointwise_psum(x, wgt)),
        np.asarray(ref.pointwise_psum(x, wgt)), rtol=1e-4, atol=1e-4)


def test_pointwise_preserves_hw_shape():
    rng = np.random.default_rng(5)
    x, wgt = rand_spikes(rng, 9, 13, 8), rand_weights(rng, 8, 24)
    assert dsc.pointwise_psum(x, wgt).shape == (9, 13, 24)


@pytest.mark.parametrize("vth", [0.1, 1.0])
def test_fused_dsc_matches_ref(vth):
    rng = np.random.default_rng(11)
    x = rand_spikes(rng, 10, 10, 6)
    wd, wp = rand_weights(rng, 3, 3, 6), rand_weights(rng, 6, 12)
    assert (np.asarray(dsc.depthwise_if_fused(x, wd, vth)) ==
            np.asarray(ref.depthwise_if_fused(x, wd, vth))).all()
    assert (np.asarray(dsc.pointwise_if_fused(x, wp, vth)) ==
            np.asarray(ref.pointwise_if_fused(x, wp, vth))).all()


def test_dsc_approximates_standard_conv_structure():
    """DSC = depthwise then pointwise composes to the same shapes as a
    standard conv — the substitution vMobileNet relies on."""
    rng = np.random.default_rng(13)
    x = rand_spikes(rng, 12, 12, 8)
    wd, wp = rand_weights(rng, 3, 3, 8), rand_weights(rng, 8, 16)
    mid = dsc.depthwise_if_fused(x, wd, 0.5)
    out = dsc.pointwise_psum(mid, wp)
    assert out.shape == (12, 12, 16)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(4, 14), w=st.integers(4, 14), c=st.integers(1, 8),
       rate=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_depthwise_property_sweep(h, w, c, rate, seed):
    rng = np.random.default_rng(seed)
    x, wgt = rand_spikes(rng, h, w, c, rate), rand_weights(rng, 3, 3, c)
    np.testing.assert_allclose(
        np.asarray(dsc.depthwise_psum(x, wgt)),
        np.asarray(ref.depthwise_psum(x, wgt)), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 12), ci=st.integers(1, 16), co=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_pointwise_property_sweep(h, ci, co, seed):
    rng = np.random.default_rng(seed)
    x, wgt = rand_spikes(rng, h, h, ci), rand_weights(rng, ci, co)
    np.testing.assert_allclose(
        np.asarray(dsc.pointwise_psum(x, wgt)),
        np.asarray(ref.pointwise_psum(x, wgt)), rtol=1e-4, atol=1e-4)
