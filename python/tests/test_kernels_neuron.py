"""IF/LIF neuron kernels vs oracles + dynamics invariants (Eq. (2)-(4))."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lif, ref


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("vth", [0.5, 1.0, 2.0])
def test_if_step_matches_ref(vth):
    rng = np.random.default_rng(int(vth * 10))
    p, v = rand(rng, 8, 8, 6), rand(rng, 8, 8, 6)
    s1, v1 = lif.if_step(p, v, vth)
    s2, v2 = ref.if_step(p, v, vth)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("leak", [0.5, 0.75, 1.0])
def test_lif_step_matches_ref(leak):
    rng = np.random.default_rng(int(leak * 100))
    p, v = rand(rng, 6, 6, 4), rand(rng, 6, 6, 4)
    s1, v1 = lif.lif_step(p, v, 1.0, leak)
    s2, v2 = ref.lif_step(p, v, 1.0, leak)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)


def test_fired_neurons_reset_to_zero():
    """Hard reset (Eq. 4, u_r = 0): v_next == 0 exactly where spiking."""
    rng = np.random.default_rng(2)
    p, v = rand(rng, 8, 8, 3), rand(rng, 8, 8, 3)
    s, v_next = lif.if_step(p, v, 0.5)
    s, v_next = np.asarray(s), np.asarray(v_next)
    assert (v_next[s > 0] == 0.0).all()
    # Non-fired neurons keep their sub-threshold integration.
    integ = np.asarray(p) + np.asarray(v)
    np.testing.assert_allclose(v_next[s == 0], integ[s == 0], rtol=1e-6)


def test_subthreshold_never_fires():
    p = jnp.full((4, 4, 2), -1.0)
    v = jnp.zeros((4, 4, 2))
    s, _ = lif.if_step(p, v, 0.5)
    assert np.asarray(s).sum() == 0


def test_bias_shifts_current():
    """Eq. (2): bias adds to the input current before integration."""
    rng = np.random.default_rng(4)
    p, v = rand(rng, 4, 4, 3), jnp.zeros((4, 4, 3))
    b = jnp.asarray([10.0, -10.0, 0.0])
    s, _ = lif.if_step(p, v, 0.5, bias=b)
    s = np.asarray(s)
    assert (s[:, :, 0] == 1).all()       # huge positive bias: always fires
    assert (s[:, :, 1] == 0).all()       # huge negative bias: never fires


def test_multi_timestep_accumulation():
    """Integration across timesteps: constant sub-threshold current fires
    after ceil(vth/I) steps — the temporal dependency T=1 removes."""
    p = jnp.full((1, 1, 1), 0.4)
    v = jnp.zeros((1, 1, 1))
    fired_at = None
    for t in range(5):
        s, v = lif.if_step(p, v, 1.0)
        if np.asarray(s).sum() > 0 and fired_at is None:
            fired_at = t
    assert fired_at == 2   # 0.4, 0.8, 1.2 -> fires on 3rd step (t=2)


def test_leak_slows_integration():
    """LIF leak (Eq. 3): same current, leaky neuron fires later/never."""
    p = jnp.full((1, 1, 1), 0.4)
    v_if = jnp.zeros((1, 1, 1))
    v_lif = jnp.zeros((1, 1, 1))
    if_spikes = lif_spikes = 0
    for _ in range(10):
        s, v_if = lif.if_step(p, v_if, 1.0)
        if_spikes += float(np.asarray(s).sum())
        s, v_lif = lif.lif_step(p, v_lif, 1.0, 0.5)
        lif_spikes += float(np.asarray(s).sum())
    assert if_spikes > lif_spikes
    # leak=0.5, I=0.4 -> v converges to 0.8 < vth: never fires.
    assert lif_spikes == 0


@settings(max_examples=25, deadline=None)
@given(h=st.integers(1, 10), c=st.integers(1, 8),
       vth=st.floats(0.1, 3.0), leak=st.floats(0.0, 1.0),
       seed=st.integers(0, 2**31 - 1))
def test_lif_property_sweep(h, c, vth, leak, seed):
    rng = np.random.default_rng(seed)
    p, v = rand(rng, h, h, c), rand(rng, h, h, c)
    s1, v1 = lif.lif_step(p, v, vth, leak)
    s2, v2 = ref.lif_step(p, v, vth, leak)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-5)
    # Binary output invariant.
    assert set(np.unique(np.asarray(s1))) <= {0.0, 1.0}
