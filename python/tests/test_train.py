"""Training tests: losses, Adam, learning progress, Algorithm 1 wiring."""

import numpy as np
import jax.numpy as jnp

from compile import train as T
from compile import model as M


def test_sdt_loss_uses_time_average():
    # Two timesteps that cancel: SDT sees the mean.
    o = jnp.asarray([[[10.0, 0.0], [-10.0, 0.0]]])  # (B=1, T=2, C=2)
    y = jnp.asarray([0])
    # mean logits = (0,0) -> CE = log(2)
    loss = T.sdt_loss(o, y)
    np.testing.assert_allclose(float(loss), np.log(2.0), rtol=1e-5)


def test_tet_loss_penalises_each_timestep():
    o = jnp.asarray([[[10.0, 0.0], [-10.0, 0.0]]])
    y = jnp.asarray([0])
    # t0 is confidently right (CE ~ 0), t1 confidently wrong (CE ~ 10).
    loss = float(T.tet_loss(o, y))
    assert loss > 4.0
    # SDT on the same outputs is much smaller — the TET difference.
    assert loss > float(T.sdt_loss(o, y)) + 3.0


def test_losses_equal_at_t1():
    """At a single timestep SDT == TET by definition."""
    rng = np.random.default_rng(0)
    o = jnp.asarray(rng.normal(size=(4, 1, 10)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, 4))
    np.testing.assert_allclose(float(T.sdt_loss(o, y)),
                               float(T.tet_loss(o, y)), rtol=1e-6)


def test_adam_converges_on_quadratic():
    opt = T.Adam(lr=0.1)
    params = [{"w": jnp.asarray([5.0, -3.0])}]
    state = opt.init(params)
    import jax
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params[0]["w"]).max()) < 1e-2


def test_training_reduces_loss():
    cfg = T.TrainConfig(model="scnn3", timesteps=2, loss="tet", epochs=2,
                        n_train=128, n_test=64, batch_size=16, width=0.25,
                        lr=3e-3)
    res = T.train(cfg, verbose=False)
    first_loss = res.history[0][1]
    last_loss = res.history[-1][1]
    assert last_loss < first_loss, f"{first_loss} -> {last_loss}"


def test_evaluate_returns_sfr_per_layer():
    cfg = T.TrainConfig(model="scnn3", timesteps=1, loss="tet", epochs=1,
                        n_train=64, n_test=64, batch_size=16, width=0.25)
    res = T.train(cfg, verbose=False)
    n_spiking = sum(1 for s in res.specs
                    if isinstance(s, (M.Conv, M.DWConv, M.PWConv,
                                      M.Residual)))
    assert res.sfr.shape == (n_spiking,)
    assert (res.sfr >= 0).all() and (res.sfr <= 1).all()


def test_temporal_pruning_pipeline_runs():
    cfg = T.TrainConfig(model="scnn3", timesteps=3, loss="tet", epochs=1,
                        n_train=96, n_test=64, batch_size=16, width=0.25)
    pr = T.temporal_pruning(cfg, t_de=1, finetune_epochs=1,
                            eval_timesteps=(3, 1), verbose=False)
    assert set(pr.reduced_acc) == {3, 1}
    assert 0.0 <= pr.finetuned.test_acc <= 1.0
    # Fine-tuned weights must differ from base (training happened).
    w0 = np.asarray(pr.base.params[0]["w"])
    w1 = np.asarray(pr.finetuned.params[0]["w"])
    assert np.abs(w0 - w1).max() > 0


def test_finetune_warm_start_uses_base_weights():
    cfg = T.TrainConfig(model="scnn3", timesteps=1, loss="tet", epochs=0,
                        n_train=64, n_test=64, batch_size=16, width=0.25)
    base = T.train(cfg, verbose=False)
    # 0-epoch "training" from a warm start returns exactly the start.
    again = T.train(cfg, init_params=base.params, verbose=False)
    for p, q in zip(base.params, again.params):
        for k in p:
            np.testing.assert_array_equal(np.asarray(p[k]),
                                          np.asarray(q[k]))
