"""AOT export tests: HLO text contract + weight layout contract."""

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M
from compile import quant as Q


def test_hlo_text_has_full_constants():
    """Regression for the silent-zero-weights bug: large weight
    constants must be printed in full, never elided as '{...}' (the
    rust-side text parser reads elided constants back as zeros)."""
    w = jnp.asarray(np.arange(256, dtype=np.float32).reshape(16, 16))

    def fn(x):
        return (x @ w,)

    txt = aot.lower_fn(fn, jax.ShapeDtypeStruct((4, 16), jnp.float32))
    assert "{...}" not in txt
    assert "HloModule" in txt
    assert "ROOT" in txt


def test_hlo_is_tuple_rooted():
    """rust Runtime::run_image unconditionally untuples the result."""
    txt = aot.lower_fn(lambda x: (x + 1.0,),
                       jax.ShapeDtypeStruct((2, 2), jnp.float32))
    root_lines = [l for l in txt.splitlines() if "ROOT" in l]
    assert any("tuple" in l for l in root_lines), root_lines


def test_conv_taps_engine_layout():
    """(Kh,Kw,Ci,Co) -> [co][ci][tap] transpose matches the rust
    ConvWeights::of_channel indexing."""
    kh, kw, ci, co = 3, 3, 2, 4
    q = np.arange(kh * kw * ci * co, dtype=np.int8).reshape(kh, kw, ci, co)
    taps = aot._conv_taps_engine_layout(q)
    assert taps.shape == (co, ci, kh * kw)
    # Spot-check: output channel 1, input channel 0, tap (r=2, c=1).
    assert taps[1, 0, 2 * kw + 1] == q[2, 1, 0, 1]


def test_export_weights_manifest(tmp_path: pathlib.Path):
    specs = M.scnn3(10, width=0.25)
    params, shapes = M.init_params(specs, (28, 28, 1))
    qparams = Q.quantize_params(params)
    manifest = aot.export_weights(specs, qparams, tmp_path)
    blob = (tmp_path / "weights.bin").read_bytes()

    # Encoder conv exports nothing; conv2, conv3, fc export w + b.
    layers = sorted({m["layer"] for m in manifest})
    assert 0 not in layers, "encoder must not be exported"
    assert len([m for m in manifest if m["name"] == "w"]) == 3

    # Offsets tile the blob exactly.
    end = 0
    for m in sorted(manifest, key=lambda m: m["offset"]):
        assert m["offset"] == end
        end += m["len"]
    assert end == len(blob)

    # int8 tensors round-trip through the blob.
    wrec = next(m for m in manifest if m["name"] == "w")
    raw = np.frombuffer(blob[wrec["offset"]:wrec["offset"] + wrec["len"]],
                        dtype=np.int8)
    expected = aot._conv_taps_engine_layout(
        qparams[wrec["layer"]]["w"].q).ravel()
    np.testing.assert_array_equal(raw, expected)

    # Manifest serialises to valid JSON consumable by the rust side.
    json.dumps(manifest)


def test_outputs_exist_logic(tmp_path: pathlib.Path):
    assert not aot.outputs_exist(tmp_path)
    for f in ("net.json", "weights.bin", "encoder.hlo.txt",
              "model.hlo.txt"):
        (tmp_path / f).write_text("x")
    assert aot.outputs_exist(tmp_path)


def test_generate_rust_smoke_fixtures():
    """Lower a tiny Pallas model + reference outputs for the rust-side
    integration test (rust/tests/rt_smoke.rs reads these)."""
    out = pathlib.Path("/tmp/sti_snn_fixture")
    out.mkdir(exist_ok=True)
    specs = M.scnn3(width=0.25)
    params, shapes = M.init_params(specs, (28, 28, 1), seed=0)
    params = [{k: v * 6.0 for k, v in p.items()} for p in params]

    def full(x):
        o, _ = M.forward(specs, params, shapes, x, 1, use_pallas=True)
        return (o[0],)

    txt = aot.lower_fn(full, jax.ShapeDtypeStruct((28, 28, 1),
                                                  jnp.float32))
    assert "{...}" not in txt
    (out / "model.hlo.txt").write_text(txt)

    rng = np.random.default_rng(0)
    img = rng.random((28, 28, 1)).astype(np.float32)
    logits = np.asarray(full(jnp.asarray(img))[0])
    assert np.isfinite(logits).all()
    assert np.abs(logits).max() > 0, "degenerate fixture (all zero)"
    img.ravel().astype("<f4").tofile(out / "img.f32")
    logits.astype("<f4").tofile(out / "logits.f32")
