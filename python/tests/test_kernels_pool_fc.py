"""OR-pooling and FC Pallas kernels vs oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fc, pooling, ref


def rand_spikes(rng, *shape, rate=0.3):
    return jnp.asarray((rng.random(shape) < rate).astype(np.float32))


@pytest.mark.parametrize("h,w,c", [(8, 8, 4), (28, 28, 16), (4, 12, 3)])
def test_or_pool_matches_ref(h, w, c):
    rng = np.random.default_rng(h * 7 + c)
    x = rand_spikes(rng, h, w, c)
    got, want = pooling.or_pool2(x), ref.or_pool2(x)
    assert got.shape == (h // 2, w // 2, c)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_or_pool_is_logical_or():
    """Any spike in the 2x2 window -> pooled spike (paper Fig. 7b)."""
    x = np.zeros((4, 4, 1), np.float32)
    x[1, 0, 0] = 1.0           # one spike in top-left window
    got = np.asarray(pooling.or_pool2(jnp.asarray(x)))
    assert got[0, 0, 0] == 1.0
    assert got.sum() == 1.0


def test_or_pool_all_zero_and_all_one():
    z = jnp.zeros((6, 6, 2), jnp.float32)
    o = jnp.ones((6, 6, 2), jnp.float32)
    assert np.asarray(pooling.or_pool2(z)).sum() == 0
    assert (np.asarray(pooling.or_pool2(o)) == 1).all()


@settings(max_examples=20, deadline=None)
@given(ho=st.integers(1, 10), wo=st.integers(1, 10), c=st.integers(1, 8),
       rate=st.floats(0, 1), seed=st.integers(0, 2**31 - 1))
def test_or_pool_property_sweep(ho, wo, c, rate, seed):
    rng = np.random.default_rng(seed)
    x = rand_spikes(rng, 2 * ho, 2 * wo, c, rate=rate)
    got = np.asarray(pooling.or_pool2(x))
    want = np.asarray(ref.or_pool2(x))
    assert (got == want).all()
    # Monotone invariant: pooled firing rate >= input firing rate.
    assert got.mean() >= np.asarray(x).mean() - 1e-7


@pytest.mark.parametrize("n_in,n_out", [(16, 10), (128, 10), (512, 100)])
def test_fc_matches_ref(n_in, n_out):
    rng = np.random.default_rng(n_in + n_out)
    s = rand_spikes(rng, n_in)
    w = jnp.asarray(rng.normal(size=(n_in, n_out)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n_out,)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fc.fc_psum(s, w, b)),
                               np.asarray(ref.fc_psum(s, w, b)),
                               rtol=1e-4, atol=1e-4)


def test_fc_spike_gating():
    """Zero spikes -> output is exactly the bias (gather-accumulate)."""
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.normal(size=(32, 10)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(10,)).astype(np.float32))
    out = fc.fc_psum(jnp.zeros((32,), jnp.float32), w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(b), rtol=1e-6)


def test_fc_single_spike_selects_row():
    rng = np.random.default_rng(10)
    w = jnp.asarray(rng.normal(size=(32, 10)).astype(np.float32))
    s = jnp.zeros((32,), jnp.float32).at[5].set(1.0)
    out = fc.fc_psum(s, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w)[5],
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n_in=st.integers(1, 64), n_out=st.integers(1, 32),
       seed=st.integers(0, 2**31 - 1))
def test_fc_property_sweep(n_in, n_out, seed):
    rng = np.random.default_rng(seed)
    s = rand_spikes(rng, n_in)
    w = jnp.asarray(rng.normal(size=(n_in, n_out)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fc.fc_psum(s, w)),
                               np.asarray(ref.fc_psum(s, w)),
                               rtol=1e-4, atol=1e-4)
