"""Pallas standard-conv kernel vs pure-jnp oracle (the core L1 signal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, spike_conv

RTOL, ATOL = 1e-5, 1e-5


def rand_spikes(rng, h, w, c, rate=0.3):
    return jnp.asarray((rng.random((h, w, c)) < rate).astype(np.float32))


def rand_weights(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("h,w,ci,co,k,p", [
    (8, 8, 4, 8, 3, 1),      # small square
    (28, 28, 1, 16, 3, 1),   # SCNN3 encoder shape
    (14, 14, 16, 32, 3, 1),  # SCNN3 mid layer
    (6, 10, 3, 5, 3, 1),     # non-square
    (8, 8, 4, 4, 1, 0),      # 1x1 via standard path
    (9, 9, 2, 3, 3, 0),      # valid padding
    (5, 5, 7, 11, 5, 2),     # 5x5 kernel
])
def test_conv_psum_matches_ref(h, w, ci, co, k, p):
    rng = np.random.default_rng(42 + h + w + ci + co + k)
    x, wgt = rand_spikes(rng, h, w, ci), rand_weights(rng, k, k, ci, co)
    got = spike_conv.conv2d_psum(x, wgt, padding=p)
    want = ref.conv2d_psum(x, wgt, padding=p)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("vth", [0.0, 0.5, 1.0, 2.5])
def test_conv_if_fused_matches_ref(vth):
    rng = np.random.default_rng(7)
    x, wgt = rand_spikes(rng, 12, 12, 8), rand_weights(rng, 3, 3, 8, 16)
    b = rand_weights(rng, 16)
    got = spike_conv.conv_if_fused(x, wgt, vth, padding=1, bias=b)
    want = ref.conv_if_fused(x, wgt, vth, padding=1, bias=b)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert set(np.unique(np.asarray(got))) <= {0.0, 1.0}


def test_conv_zero_input_gives_zero_psum():
    rng = np.random.default_rng(0)
    x = jnp.zeros((8, 8, 4), jnp.float32)
    wgt = rand_weights(rng, 3, 3, 4, 8)
    got = spike_conv.conv2d_psum(x, wgt)
    assert np.abs(np.asarray(got)).max() == 0.0


def test_conv_all_ones_equals_weight_sums():
    """Dense spikes: every output pixel (away from borders) is the full
    tap sum — the add-network interpretation of the spike matmul."""
    rng = np.random.default_rng(1)
    x = jnp.ones((8, 8, 4), jnp.float32)
    wgt = rand_weights(rng, 3, 3, 4, 8)
    got = np.asarray(spike_conv.conv2d_psum(x, wgt, padding=1))
    full = np.asarray(wgt).sum(axis=(0, 1, 2))
    np.testing.assert_allclose(got[1:-1, 1:-1, :],
                               np.broadcast_to(full, got[1:-1, 1:-1].shape),
                               rtol=1e-4, atol=1e-4)


def test_line_buffer_view_windows():
    x = jnp.arange(5 * 4 * 2, dtype=jnp.float32).reshape(5, 4, 2)
    lb = spike_conv.line_buffer_view(x, 3)
    assert lb.shape == (3, 3, 4, 2)
    for r in range(3):
        np.testing.assert_array_equal(np.asarray(lb[r]),
                                      np.asarray(x[r:r + 3]))


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(4, 16), w=st.integers(4, 16),
    ci=st.integers(1, 8), co=st.integers(1, 8),
    rate=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1),
)
def test_conv_property_sweep(h, w, ci, co, rate, seed):
    """Hypothesis sweep: arbitrary shapes/firing rates, kernel == oracle."""
    rng = np.random.default_rng(seed)
    x = rand_spikes(rng, h, w, ci, rate)
    wgt = rand_weights(rng, 3, 3, ci, co)
    got = spike_conv.conv2d_psum(x, wgt, padding=1)
    want = ref.conv2d_psum(x, wgt, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), vth=st.floats(-1.0, 3.0))
def test_fused_equals_unfused_then_threshold(seed, vth):
    """Invariant: fused conv+IF == conv followed by threshold."""
    rng = np.random.default_rng(seed)
    x, wgt = rand_spikes(rng, 10, 10, 4), rand_weights(rng, 3, 3, 4, 6)
    fused = np.asarray(spike_conv.conv_if_fused(x, wgt, vth))
    psum = np.asarray(spike_conv.conv2d_psum(x, wgt))
    # Guard against threshold-boundary float ties: perturb check only
    # where |psum - vth| is comfortably non-zero.
    mask = np.abs(psum - vth) > 1e-4
    assert (fused[mask] == (psum[mask] >= vth).astype(np.float32)).all()
