"""L2 model tests: shapes, semantics, batched==per-sample, pallas==ref."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


def setup_net(name="scnn3", width=0.25, shape=(28, 28, 1), seed=0):
    specs = M.MODELS[name](10, width=width)
    params, shapes = M.init_params(specs, shape, seed=seed)
    return specs, params, shapes


@pytest.mark.parametrize("name,shape", [
    ("scnn3", (28, 28, 1)),
    ("vmobilenet", (28, 28, 1)),
    ("scnn5", (32, 32, 3)),
    ("vgg_small", (32, 32, 3)),
    ("resnet_small", (32, 32, 3)),
])
def test_forward_shapes(name, shape):
    specs, params, shapes = setup_net(name, 0.25, shape)
    x = jnp.zeros(shape, jnp.float32)
    o, sfr = M.forward(specs, params, shapes, x, 2)
    assert o.shape == (2, 10)
    assert sfr.shape[0] == 2
    assert np.isfinite(np.asarray(o)).all()


def test_spike_fn_forward_is_heaviside():
    v = jnp.asarray([-1.0, 0.0, 0.999, 1.0, 5.0])
    s = np.asarray(M.spike_fn(v))
    assert (s == np.array([0, 0, 0, 1, 1], np.float32)).all()


def test_spike_fn_gradient_is_surrogate():
    import jax
    g = jax.grad(lambda v: M.spike_fn(v).sum())(jnp.asarray([1.0, 9.0]))
    g = np.asarray(g)
    assert g[0] > 0.5            # at threshold: max surrogate slope
    assert g[1] < g[0]           # far from threshold: small slope
    assert (g > 0).all()         # never exactly zero (no dead gradient)


def test_batched_forward_matches_per_sample():
    """forward_batch (lax.conv fast path) must equal vmap of the
    reference per-sample step — the §Perf L2 rewrite's safety net."""
    specs, params, shapes = setup_net("scnn3", 0.25)
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.random((3, 28, 28, 1)).astype(np.float32))
    scaled = [{k: v * 6.0 for k, v in p.items()} for p in params]
    batched = M.forward_batch(specs, scaled, shapes, xb, 3)
    for i in range(3):
        o, _ = M.forward(specs, scaled, shapes, xb[i], 3)
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_batched_forward_matches_per_sample_dsc():
    specs, params, shapes = setup_net("vmobilenet", 0.25)
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.random((2, 28, 28, 1)).astype(np.float32))
    scaled = [{k: v * 6.0 for k, v in p.items()} for p in params]
    batched = M.forward_batch(specs, scaled, shapes, xb, 2)
    for i in range(2):
        o, _ = M.forward(specs, scaled, shapes, xb[i], 2)
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_pallas_forward_matches_ref_forward():
    """The AOT path (use_pallas=True) equals the ref-op path — the
    L1-in-L2 integration check."""
    specs, params, shapes = setup_net("scnn3", 0.25)
    scaled = [{k: v * 6.0 for k, v in p.items()} for p in params]
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.random((28, 28, 1)).astype(np.float32))
    o_ref, _ = M.forward(specs, scaled, shapes, x, 1, use_pallas=False)
    o_pal, _ = M.forward(specs, scaled, shapes, x, 1, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_pallas_forward_matches_ref_forward_dsc():
    specs, params, shapes = setup_net("vmobilenet", 0.25)
    scaled = [{k: v * 6.0 for k, v in p.items()} for p in params]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.random((28, 28, 1)).astype(np.float32))
    o_ref, _ = M.forward(specs, scaled, shapes, x, 1, use_pallas=False)
    o_pal, _ = M.forward(specs, scaled, shapes, x, 1, use_pallas=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_membrane_state_carries_across_timesteps():
    """Same input twice: second step sees accumulated potential, so
    logits differ from the first step unless everything fired/reset."""
    specs, params, shapes = setup_net("scnn3", 0.25, seed=4)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.random((28, 28, 1)).astype(np.float32))
    o, _ = M.forward(specs, params, shapes, x, 2)
    # With He-init (sub-threshold) weights, step 2 integrates more and
    # cannot be identical to step 1 everywhere.
    assert not np.allclose(np.asarray(o[0]), np.asarray(o[1]))


def test_spec_dicts_cover_all_layers():
    specs, params, shapes = setup_net("vmobilenet", 0.25)
    ds = M.spec_dicts(specs, shapes, params)
    kinds = [d["kind"] for d in ds]
    assert kinds.count("dwconv") == 4
    assert kinds.count("pwconv") == 4
    assert kinds.count("pool") == 2
    assert kinds[-1] == "fc"
    # Geometry fields present and consistent.
    for d in ds:
        assert d["in_h"] > 0 and d["in_c"] > 0


def test_width_scaling():
    s1 = M.scnn3(10, width=1.0)
    s2 = M.scnn3(10, width=0.5)
    assert s1[0].co == 16 and s2[0].co == 8
