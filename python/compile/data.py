"""Synthetic image-classification datasets (offline substitute).

The paper evaluates on MNIST / CIFAR10 / CIFAR100 / Tiny ImageNet.  This
environment has no network access, so we procedurally generate datasets
of the same shapes and a comparable task character (DESIGN.md
Substitutions):

  * ``synth_mnist``  — 28x28x1, 10 classes: parametric digit-like stroke
    glyphs with random affine jitter, stroke-width variation and noise.
  * ``synth_cifar``  — 32x32x3, ``n_classes`` classes: colored oriented
    texture/shape compositions with per-sample color jitter and noise.

The claims under reproduction (SDT accuracy collapse at T=1, TET/SFR
stability, fine-tuning recovery) are about *training dynamics*, which
these tasks exercise; absolute accuracies are not comparable to the
paper's and are reported as such in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Digit-like glyphs: 7-segment-style strokes on a 28x28 canvas
# ---------------------------------------------------------------------------

# Segment layout (like a 7-seg display), in normalised canvas coords:
#   a: top bar, b: top-right col, c: bottom-right col, d: bottom bar,
#   e: bottom-left col, f: top-left col, g: middle bar
_SEGS = {
    "a": ((0.25, 0.20), (0.75, 0.20)),
    "b": ((0.75, 0.20), (0.75, 0.50)),
    "c": ((0.75, 0.50), (0.75, 0.80)),
    "d": ((0.25, 0.80), (0.75, 0.80)),
    "e": ((0.25, 0.50), (0.25, 0.80)),
    "f": ((0.25, 0.20), (0.25, 0.50)),
    "g": ((0.25, 0.50), (0.75, 0.50)),
}

_DIGIT_SEGS = {
    0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
    5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abcdfg",
}


def _draw_segment(img: np.ndarray, p0, p1, width: float):
    """Rasterise a thick line segment onto img (in-place, max-blend)."""
    h, w = img.shape
    ys, xs = np.mgrid[0:h, 0:w]
    xs = (xs + 0.5) / w
    ys = (ys + 0.5) / h
    (x0, y0), (x1, y1) = p0, p1
    dx, dy = x1 - x0, y1 - y0
    seg_len2 = dx * dx + dy * dy + 1e-12
    t = np.clip(((xs - x0) * dx + (ys - y0) * dy) / seg_len2, 0.0, 1.0)
    px, py = x0 + t * dx, y0 + t * dy
    dist = np.sqrt((xs - px) ** 2 + (ys - py) ** 2)
    stroke = np.clip(1.0 - dist / width, 0.0, 1.0)
    np.maximum(img, stroke, out=img)


def _glyph(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    img = np.zeros((size, size), np.float32)
    # Random affine jitter: translate +-8%, scale 90-110%, shear.
    tx, ty = rng.uniform(-0.08, 0.08, 2)
    sc = rng.uniform(0.9, 1.1)
    shear = rng.uniform(-0.12, 0.12)
    width = rng.uniform(0.05, 0.09)
    for seg in _DIGIT_SEGS[digit % 10]:
        (x0, y0), (x1, y1) = _SEGS[seg]

        def jmap(x, y):
            x, y = (x - 0.5) * sc + 0.5, (y - 0.5) * sc + 0.5
            return (x + shear * (y - 0.5) + tx, y + ty)

        _draw_segment(img, jmap(x0, y0), jmap(x1, y1), width)
    img += rng.normal(0, 0.08, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synth_mnist(n: int, seed: int = 0, n_classes: int = 10):
    """Generate (images (n,28,28,1) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    imgs = np.stack([_glyph(int(c), rng) for c in labels])[..., None]
    return imgs.astype(np.float32), labels


# ---------------------------------------------------------------------------
# CIFAR-like: colored oriented textures, 32x32x3
# ---------------------------------------------------------------------------

def _texture(cls: int, rng: np.random.Generator, size: int = 32,
             n_classes: int = 10) -> np.ndarray:
    """Class = (orientation, frequency, hue) triple with jitter."""
    ys, xs = np.mgrid[0:size, 0:size] / size
    theta = (cls % 5) * (np.pi / 5) + rng.normal(0, 0.08)
    freq = 3.0 + 2.0 * (cls // 5) + rng.normal(0, 0.2)
    phase = rng.uniform(0, 2 * np.pi)
    wave = 0.5 + 0.5 * np.sin(
        2 * np.pi * freq * (xs * np.cos(theta) + ys * np.sin(theta)) + phase)
    # Class-keyed hue with jitter.
    base_hue = (cls / n_classes + rng.normal(0, 0.02)) % 1.0
    rgb = np.stack([
        wave * (0.5 + 0.5 * np.cos(2 * np.pi * (base_hue + k / 3.0)))
        for k in range(3)
    ], axis=-1).astype(np.float32)
    # A class-dependent blob (shape cue) on top.
    cx, cy = rng.uniform(0.3, 0.7, 2)
    r = 0.12 + 0.05 * ((cls * 7) % 3)
    blob = np.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2) / (r * r)))
    rgb += 0.4 * blob[..., None]
    rgb += rng.normal(0, 0.06, rgb.shape).astype(np.float32)
    return np.clip(rgb, 0.0, 1.0)


def synth_cifar(n: int, seed: int = 0, n_classes: int = 10):
    """Generate (images (n,32,32,3) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    imgs = np.stack([_texture(int(c), rng, n_classes=n_classes)
                     for c in labels])
    return imgs.astype(np.float32), labels


# ---------------------------------------------------------------------------
# Dataset registry + batching
# ---------------------------------------------------------------------------

DATASETS = {
    "synth-mnist": (synth_mnist, (28, 28, 1), 10),
    "synth-cifar10": (synth_cifar, (32, 32, 3), 10),
    "synth-cifar100": (
        lambda n, seed=0: synth_cifar(n, seed, n_classes=100),
        (32, 32, 3), 100),
}


def load(name: str, n_train: int, n_test: int, seed: int = 0):
    """Return ((x_train, y_train), (x_test, y_test), input_shape, classes)."""
    gen, shape, n_classes = DATASETS[name]
    xtr, ytr = gen(n_train, seed=seed)
    xte, yte = gen(n_test, seed=seed + 10_000)
    return (xtr, ytr), (xte, yte), shape, n_classes


def batches(x: np.ndarray, y: np.ndarray, batch_size: int,
            rng: np.random.Generator):
    """Shuffled minibatch iterator (drops the ragged tail)."""
    idx = rng.permutation(len(x))
    for i in range(0, len(x) - batch_size + 1, batch_size):
        sel = idx[i:i + batch_size]
        yield x[sel], y[sel]
