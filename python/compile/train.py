"""STBP training with SDT / TET losses + Algorithm 1 temporal pruning.

Implements the paper's algorithm contribution (SectionIII):

  * **STBP** (spatio-temporal backprop) — jax autodiff through the
    T-step rollout; the non-differentiable Heaviside is replaced by the
    ATan surrogate gradient (``model.spike_fn``).
  * **SDT** (Eq. 6)  — ``CE(mean_t O(t), y)``: optimise only the
    time-averaged logits.
  * **TET** (Eq. 8)  — ``mean_t CE(O(t), y)``: optimise *every* timestep,
    which keeps per-layer spike-firing rates stable when the inference
    timestep count is later reduced (Fig. 4) — the property the
    single-timestep accelerator relies on.
  * **Algorithm 1** — train at T timesteps, measure per-layer SFR at the
    reduced timestep count, fine-tune at T_de = 1.

Optimiser: Adam (hand-rolled; no optax in this offline environment).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod


# ---------------------------------------------------------------------------
# Losses (paper Eq. (6) and Eq. (8))
# ---------------------------------------------------------------------------

def _ce(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; logits (B, C), labels (B,) int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def sdt_loss(outputs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Standard direct training, Eq. (6): CE of time-averaged logits.

    outputs: (B, T, C).
    """
    return _ce(outputs.mean(axis=1), labels)


def tet_loss(outputs: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Temporal efficient training, Eq. (8): mean over t of CE(O(t), y)."""
    b, t, c = outputs.shape
    flat = outputs.reshape(b * t, c)
    rep = jnp.repeat(labels, t)
    return _ce(flat, rep)


LOSSES: dict[str, Callable] = {"sdt": sdt_loss, "tet": tet_loss}


# ---------------------------------------------------------------------------
# Adam (hand-rolled — optax is not vendored in this environment)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Adam:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
            state["v"], grads)
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)
        new_params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - self.lr * (m_ / bc1) /
            (jnp.sqrt(v_ / bc2) + self.eps),
            params, m, v)
        return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainConfig:
    model: str = "scnn3"
    dataset: str = "synth-mnist"
    timesteps: int = 6
    loss: str = "tet"            # "sdt" | "tet"
    epochs: int = 3
    batch_size: int = 32
    lr: float = 1e-3
    n_train: int = 1024
    n_test: int = 256
    width: float = 1.0
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    params: list
    specs: list
    shapes: list
    test_acc: float
    history: list            # (epoch, loss, test_acc)
    sfr: np.ndarray          # (n_spiking_layers,) mean firing rate @ T


def make_train_step(specs, shapes, loss_name: str, timesteps: int,
                    opt: Adam):
    loss_fn = LOSSES[loss_name]

    def loss_of(params, xb, yb):
        out = model_mod.forward_batch(specs, params, shapes, xb, timesteps)
        return loss_fn(out, yb)

    @jax.jit
    def train_step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(loss_of)(params, xb, yb)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_eval(specs, shapes, timesteps: int):
    @jax.jit
    def eval_batch(params, xb):
        o, sfr = model_mod.forward_batch_sfr(specs, params, shapes, xb,
                                             timesteps)
        pred = jnp.argmax(o.mean(axis=1), axis=-1)
        return pred, sfr.mean(axis=0)
    return eval_batch


def evaluate(specs, shapes, params, x, y, timesteps: int,
             batch_size: int = 64):
    """Returns (accuracy, mean per-layer SFR) at the given timestep count."""
    eval_batch = make_eval(specs, shapes, timesteps)
    correct, sfrs, n = 0, [], 0
    for i in range(0, len(x) - batch_size + 1, batch_size):
        xb = jnp.asarray(x[i:i + batch_size])
        pred, sfr = eval_batch(params, xb)
        correct += int((np.asarray(pred) == y[i:i + batch_size]).sum())
        sfrs.append(np.asarray(sfr))
        n += batch_size
    if n == 0:  # dataset smaller than one batch
        xb = jnp.asarray(x)
        pred, sfr = eval_batch(params, xb)
        return float((np.asarray(pred) == y).mean()), np.asarray(sfr)
    return correct / n, np.mean(sfrs, axis=0)


def train(cfg: TrainConfig, init_params=None, verbose: bool = True
          ) -> TrainResult:
    """Train one model per ``cfg``; optionally warm-start (fine-tune)."""
    (xtr, ytr), (xte, yte), shape, n_classes = data_mod.load(
        cfg.dataset, cfg.n_train, cfg.n_test, seed=cfg.seed)
    specs = model_mod.MODELS[cfg.model](n_classes, width=cfg.width)
    params, shapes = model_mod.init_params(specs, shape, seed=cfg.seed)
    if init_params is not None:
        params = init_params
    opt = Adam(lr=cfg.lr)
    opt_state = opt.init(params)
    train_step = make_train_step(specs, shapes, cfg.loss, cfg.timesteps, opt)

    rng = np.random.default_rng(cfg.seed)
    history = []
    for epoch in range(cfg.epochs):
        t0, losses = time.time(), []
        for xb, yb in data_mod.batches(xtr, ytr, cfg.batch_size, rng):
            params, opt_state, loss = train_step(
                params, opt_state, jnp.asarray(xb), jnp.asarray(yb))
            losses.append(float(loss))
        acc, _ = evaluate(specs, shapes, params, xte, yte, cfg.timesteps)
        history.append((epoch, float(np.mean(losses)), acc))
        if verbose:
            print(f"[{cfg.model}/{cfg.loss} T={cfg.timesteps}] "
                  f"epoch {epoch}: loss={np.mean(losses):.4f} "
                  f"acc={acc:.4f} ({time.time() - t0:.1f}s)")
    acc, sfr = evaluate(specs, shapes, params, xte, yte, cfg.timesteps)
    return TrainResult(params, specs, shapes, acc, history, sfr)


# ---------------------------------------------------------------------------
# Algorithm 1: SDT/TET-based temporal pruning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PruningResult:
    base: TrainResult            # trained at T
    reduced_acc: dict            # T' -> accuracy with base weights
    reduced_sfr: dict            # T' -> per-layer SFR with base weights
    finetuned: TrainResult       # fine-tuned at T_de


def temporal_pruning(cfg: TrainConfig, t_de: int = 1,
                     finetune_epochs: int | None = None,
                     eval_timesteps=(6, 2, 1), verbose: bool = True
                     ) -> PruningResult:
    """Paper Algorithm 1.

    1. Train at ``cfg.timesteps`` with ``cfg.loss`` (SDT or TET).
    2. Directly reduce the inference timesteps; record accuracy + SFR.
    3. Fine-tune at ``t_de`` starting from the trained weights.
    """
    base = train(cfg, verbose=verbose)
    (_, _), (xte, yte), _, _ = data_mod.load(
        cfg.dataset, cfg.n_train, cfg.n_test, seed=cfg.seed)

    reduced_acc, reduced_sfr = {}, {}
    for t in eval_timesteps:
        acc, sfr = evaluate(base.specs, base.shapes, base.params,
                            xte, yte, t)
        reduced_acc[t], reduced_sfr[t] = acc, sfr
        if verbose:
            print(f"  reduce to T={t}: acc={acc:.4f} "
                  f"sfr={np.round(sfr, 3).tolist()}")

    ft_cfg = dataclasses.replace(
        cfg, timesteps=t_de,
        epochs=finetune_epochs if finetune_epochs is not None
        else max(1, cfg.epochs // 2))
    finetuned = train(ft_cfg, init_params=base.params, verbose=verbose)
    return PruningResult(base, reduced_acc, reduced_sfr, finetuned)
