"""Layer-2: spiking CNN model zoo (STI-SNN algorithm side).

Architecture conventions follow the paper (SectionV-A):

  * **Direct encoding** — the first conv layer receives the analog image
    every timestep and its IF neurons produce the spike trains ("the
    first convolution layer is used for spike encoding").
  * **IF neurons** with hard reset-to-zero and Vth = 1 (Table V).
  * **OR pooling** (2x2, Fig. 7b) between blocks.
  * **Classifier head** — the FC output neurons never fire; ``O(t)`` is
    the head's partial-sum at timestep t (standard direct-training
    readout; SDT/TET losses consume the per-timestep O(t)).

Each layer has two implementations selected by ``use_pallas``:

  * ``use_pallas=False`` — pure-jnp oracle ops from ``kernels.ref``
    (differentiable, fast under jit; used for STBP training).
  * ``use_pallas=True``  — L1 Pallas kernels (``interpret=True``); used
    when AOT-lowering the T=1 inference graph so the kernels end up in
    the shipped HLO artifact.

Models (paper SectionV-A):
  * ``scnn3``      — 28x28: 16c3-32c3-p2-32c3-p2-fc          (MNIST-class)
  * ``scnn5``      — 32x32: 64c3-p2-128c3-p2-256c3-p2-256c3-p2-512c3-p2-fc
  * ``vmobilenet`` — 28x28: 16c3-[16dwc3/32c1]-[32dwc3/64c1]-p2-
                     [64dwc3/64c1]-[64dwc3/128c1]-p2-fc
                     (pooling inserted to keep the head small; the paper
                     does not spell out its downsampling — DESIGN.md)
  * ``vgg_small``  / ``resnet_small`` — scaled-down stand-ins for the
    paper's VGG16 / ResNet19 accuracy studies (DESIGN.md Substitutions).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import dsc as k_dsc
from .kernels import fc as k_fc
from .kernels import pooling as k_pool
from .kernels import ref
from .kernels import spike_conv as k_conv

VTH = 1.0  # firing threshold (paper: IF neurons, fixed threshold)


# ---------------------------------------------------------------------------
# Surrogate gradient (SectionII-B): ATan, SpikingJelly's default
# ---------------------------------------------------------------------------

@jax.custom_vjp
def spike_fn(v: jnp.ndarray) -> jnp.ndarray:
    """Heaviside(v - VTH) with ATan surrogate gradient (alpha = 2)."""
    return (v >= VTH).astype(jnp.float32)


def _spike_fwd(v):
    return spike_fn(v), v


def _spike_bwd(v, g):
    alpha = 2.0
    x = v - VTH
    sg = alpha / 2.0 / (1.0 + (jnp.pi / 2.0 * alpha * x) ** 2)
    return (g * sg,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


# ---------------------------------------------------------------------------
# Layer specs — shared vocabulary with the Rust simulator (rust/src/arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Conv:
    """Standard conv: co filters of k x k, stride 1, zero pad."""
    co: int
    k: int = 3
    pad: int = 1
    encoder: bool = False   # True: receives the analog image (no spikes in)


@dataclasses.dataclass(frozen=True)
class DWConv:
    """Depthwise conv (channel count preserved)."""
    k: int = 3
    pad: int = 1


@dataclasses.dataclass(frozen=True)
class PWConv:
    """Pointwise (1x1) conv."""
    co: int


@dataclasses.dataclass(frozen=True)
class Pool:
    """2x2 stride-2 OR pooling."""


@dataclasses.dataclass(frozen=True)
class FC:
    """Classifier head: flatten + linear; output neurons do not fire."""
    out: int


LayerSpec = Any  # Conv | DWConv | PWConv | Pool | FC


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------

def _scale(c: int, width: float) -> int:
    return max(4, int(round(c * width)))


def scnn3(n_classes: int = 10, width: float = 1.0):
    s = functools.partial(_scale, width=width)
    return [
        Conv(s(16), encoder=True),
        Conv(s(32)),
        Pool(),
        Conv(s(32)),
        Pool(),
        FC(n_classes),
    ]


def scnn5(n_classes: int = 10, width: float = 1.0):
    s = functools.partial(_scale, width=width)
    return [
        Conv(s(64), encoder=True), Pool(),
        Conv(s(128)), Pool(),
        Conv(s(256)), Pool(),
        Conv(s(256)), Pool(),
        Conv(s(512)), Pool(),
        FC(n_classes),
    ]


def vmobilenet(n_classes: int = 10, width: float = 1.0):
    s = functools.partial(_scale, width=width)
    return [
        Conv(s(16), encoder=True),
        DWConv(), PWConv(s(32)), Pool(),
        DWConv(), PWConv(s(64)),
        DWConv(), PWConv(s(64)), Pool(),
        DWConv(), PWConv(s(128)),
        FC(n_classes),
    ]


def vgg_small(n_classes: int = 10, width: float = 1.0):
    """Scaled-down spiking VGG (stand-in for the paper's VGG16)."""
    s = functools.partial(_scale, width=width)
    return [
        Conv(s(64), encoder=True), Conv(s(64)), Pool(),
        Conv(s(128)), Conv(s(128)), Pool(),
        Conv(s(256)), Pool(),
        FC(n_classes),
    ]


def resnet_small(n_classes: int = 10, width: float = 1.0):
    """Scaled-down spiking ResNet (stand-in for the paper's ResNet19).

    Residual connections add *partial sums* before the IF neuron (the
    standard tdBN-style spiking residual): see ``Residual`` handling in
    the forward pass.
    """
    s = functools.partial(_scale, width=width)
    return [
        Conv(s(32), encoder=True),
        Residual(s(32)), Pool(),
        Residual(s(64)), Pool(),
        FC(n_classes),
    ]


@dataclasses.dataclass(frozen=True)
class Residual:
    """Spiking residual block: IF(conv2(IF(conv1(x))) + proj(x))."""
    co: int
    k: int = 3


MODELS = {
    "scnn3": scnn3,
    "scnn5": scnn5,
    "vmobilenet": vmobilenet,
    "vgg_small": vgg_small,
    "resnet_small": resnet_small,
}


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(specs, input_shape, seed: int = 0):
    """He-normal init; returns (params list, per-layer shapes list)."""
    rng = np.random.default_rng(seed)
    h, w, c = input_shape
    params, shapes = [], []

    def he(*shape, fan_in):
        return jnp.asarray(
            (rng.normal(size=shape) * np.sqrt(2.0 / fan_in)).astype(
                np.float32))

    for spec in specs:
        shapes.append((h, w, c))
        if isinstance(spec, Conv):
            fan = spec.k * spec.k * c
            params.append({
                "w": he(spec.k, spec.k, c, spec.co, fan_in=fan),
                "b": jnp.zeros((spec.co,), jnp.float32),
            })
            c = spec.co
        elif isinstance(spec, Residual):
            fan = spec.k * spec.k * c
            p = {
                "w1": he(spec.k, spec.k, c, spec.co, fan_in=fan),
                "b1": jnp.zeros((spec.co,), jnp.float32),
                "w2": he(spec.k, spec.k, spec.co, spec.co,
                         fan_in=spec.k * spec.k * spec.co),
                "b2": jnp.zeros((spec.co,), jnp.float32),
            }
            if spec.co != c:
                p["wp"] = he(c, spec.co, fan_in=c)
            params.append(p)
            c = spec.co
        elif isinstance(spec, DWConv):
            params.append({
                "w": he(spec.k, spec.k, c, fan_in=spec.k * spec.k),
                "b": jnp.zeros((c,), jnp.float32),
            })
        elif isinstance(spec, PWConv):
            params.append({
                "w": he(c, spec.co, fan_in=c),
                "b": jnp.zeros((spec.co,), jnp.float32),
            })
            c = spec.co
        elif isinstance(spec, Pool):
            params.append({})
            h, w = h // 2, w // 2
        elif isinstance(spec, FC):
            n_in = h * w * c
            params.append({
                "w": he(n_in, spec.out, fan_in=n_in),
                "b": jnp.zeros((spec.out,), jnp.float32),
            })
        else:
            raise TypeError(f"unknown spec {spec!r}")
    return params, shapes


# ---------------------------------------------------------------------------
# Single-timestep forward (one sample) — returns (O_t, new_states, sfr)
# ---------------------------------------------------------------------------

def _zeros_states(specs, shapes):
    """Initial membrane potentials for each spiking layer."""
    states = []
    for spec, (h, w, c) in zip(specs, shapes):
        if isinstance(spec, Conv):
            states.append(jnp.zeros((h, w, spec.co), jnp.float32))
        elif isinstance(spec, Residual):
            states.append((jnp.zeros((h, w, spec.co), jnp.float32),
                           jnp.zeros((h, w, spec.co), jnp.float32)))
        elif isinstance(spec, DWConv):
            states.append(jnp.zeros((h, w, c), jnp.float32))
        elif isinstance(spec, PWConv):
            states.append(jnp.zeros((h, w, spec.co), jnp.float32))
        else:
            states.append(None)
    return states


def step(specs, params, shapes, x, states, use_pallas: bool = False):
    """One timestep through the network.

    Args:
      x: (H, W, C) analog image (fed to the encoder layer each step).
      states: per-layer membrane potentials (from ``_zeros_states`` or the
        previous timestep).

    Returns (logits O_t, new_states, sfr) where sfr is the list of
    per-spiking-layer firing rates for Fig. 4 / Algorithm 1.
    """
    act = x
    new_states, sfr = [], []
    for spec, p, st in zip(specs, params, states):
        if isinstance(spec, Conv):
            psum = (k_conv.conv2d_psum(act, p["w"], spec.pad) if use_pallas
                    else ref.conv2d_psum(act, p["w"], spec.pad))
            v = st + psum + p["b"][None, None, :]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, Residual):
            st1, st2 = st
            psum1 = (k_conv.conv2d_psum(act, p["w1"], 1) if use_pallas
                     else ref.conv2d_psum(act, p["w1"], 1))
            v1 = st1 + psum1 + p["b1"][None, None, :]
            s1 = spike_fn(v1)
            psum2 = (k_conv.conv2d_psum(s1, p["w2"], 1) if use_pallas
                     else ref.conv2d_psum(s1, p["w2"], 1))
            short = (ref.pointwise_psum(act, p["wp"]) if "wp" in p else act)
            v2 = st2 + psum2 + short + p["b2"][None, None, :]
            s2 = spike_fn(v2)
            new_states.append((jnp.where(s1 > 0, 0.0, v1),
                               jnp.where(s2 > 0, 0.0, v2)))
            act = s2
            sfr.append((s1.mean() + s2.mean()) / 2.0)
        elif isinstance(spec, DWConv):
            psum = (k_dsc.depthwise_psum(act, p["w"], spec.pad) if use_pallas
                    else ref.depthwise_psum(act, p["w"], spec.pad))
            v = st + psum + p["b"][None, None, :]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, PWConv):
            psum = (k_dsc.pointwise_psum(act, p["w"]) if use_pallas
                    else ref.pointwise_psum(act, p["w"]))
            v = st + psum + p["b"][None, None, :]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, Pool):
            act = (k_pool.or_pool2(act) if use_pallas else ref.or_pool2(act))
            new_states.append(None)
        elif isinstance(spec, FC):
            flat = act.reshape(-1)
            out = (k_fc.fc_psum(flat, p["w"], p["b"]) if use_pallas
                   else ref.fc_psum(flat, p["w"], p["b"]))
            new_states.append(None)
            act = out
        else:
            raise TypeError(f"unknown spec {spec!r}")
    return act, new_states, jnp.stack(sfr)


def forward(specs, params, shapes, x, timesteps: int,
            use_pallas: bool = False):
    """T-timestep rollout of one sample.

    Returns (O: (T, n_classes) per-timestep logits,
             sfr: (T, n_spiking_layers) firing rates).

    Direct encoding: the same analog frame drives the encoder each
    timestep; membrane potentials carry across timesteps (Eq. (3)).
    """
    states = _zeros_states(specs, shapes)
    outs, sfrs = [], []
    for _ in range(timesteps):
        o, states, sfr = step(specs, params, shapes, x, states, use_pallas)
        outs.append(o)
        sfrs.append(sfr)
    return jnp.stack(outs), jnp.stack(sfrs)


# ---------------------------------------------------------------------------
# Batched training forward (performance path — EXPERIMENTS.md §Perf L2)
#
# The per-sample `step` above is the semantic reference (and the AOT
# path, where it runs through the Pallas kernels). Training on a single
# CPU core needs the batched equivalents below: XLA's native conv
# (`lax.conv_general_dilated`) over (B, H, W, C) plus `lax.scan` over
# timesteps. ~8x faster wall-clock than vmap(per-sample einsum taps).
# ---------------------------------------------------------------------------

def _conv_b(x, w, pad):
    """Batched NHWC conv, stride 1: (B,H,W,Ci) x (Kh,Kw,Ci,Co)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _dwconv_b(x, w, pad):
    """Batched depthwise conv: w (Kh, Kw, C) -> HWIO (Kh,Kw,1,C)."""
    c = x.shape[-1]
    return jax.lax.conv_general_dilated(
        x, w[:, :, None, :], window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)


def step_batched(specs, params, shapes, xb, states):
    """One timestep over a batch: xb (B, H, W, C)."""
    act = xb
    new_states, sfr = [], []
    for spec, p, st in zip(specs, params, states):
        if isinstance(spec, Conv):
            v = st + _conv_b(act, p["w"], spec.pad) + p["b"]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, Residual):
            st1, st2 = st
            v1 = st1 + _conv_b(act, p["w1"], 1) + p["b1"]
            s1 = spike_fn(v1)
            short = (jnp.einsum("bhwc,co->bhwo", act, p["wp"])
                     if "wp" in p else act)
            v2 = st2 + _conv_b(s1, p["w2"], 1) + short + p["b2"]
            s2 = spike_fn(v2)
            new_states.append((jnp.where(s1 > 0, 0.0, v1),
                               jnp.where(s2 > 0, 0.0, v2)))
            act = s2
            sfr.append((s1.mean() + s2.mean()) / 2.0)
        elif isinstance(spec, DWConv):
            v = st + _dwconv_b(act, p["w"], spec.pad) + p["b"]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, PWConv):
            v = st + jnp.einsum("bhwc,co->bhwo", act, p["w"]) + p["b"]
            s = spike_fn(v)
            new_states.append(jnp.where(s > 0, 0.0, v))
            act = s
            sfr.append(s.mean())
        elif isinstance(spec, Pool):
            b, h, w, c = act.shape
            act = act.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
            new_states.append(None)
        elif isinstance(spec, FC):
            flat = act.reshape(act.shape[0], -1)
            act = flat @ p["w"] + p["b"]
            new_states.append(None)
        else:
            raise TypeError(f"unknown spec {spec!r}")
    return act, new_states, jnp.stack(sfr)


def _zeros_states_batched(specs, shapes, batch):
    states = []
    for st in _zeros_states(specs, shapes):
        if st is None:
            states.append(None)
        elif isinstance(st, tuple):
            states.append(tuple(
                jnp.zeros((batch,) + s.shape, s.dtype) for s in st))
        else:
            states.append(jnp.zeros((batch,) + st.shape, st.dtype))
    return states


def forward_batch(specs, params, shapes, xb, timesteps: int):
    """Batched training forward: xb (B, H, W, C) -> (B, T, classes).

    `lax.scan` over timesteps keeps the lowered graph one-step-sized
    (compile time and memory stay flat as T grows).
    """
    states = _zeros_states_batched(specs, shapes, xb.shape[0])

    def body(states, _):
        o, states, sfr = step_batched(specs, params, shapes, xb, states)
        return states, (o, sfr)

    # States contain None entries, which scan tolerates as static pytree
    # leaves only if they are not jnp arrays — replace with 0-size
    # placeholders via a tuple filter instead: run a python loop when T
    # is small (<= 2), scan otherwise with None pruned.
    if timesteps <= 2:
        outs = []
        for _ in range(timesteps):
            o, states, _ = step_batched(specs, params, shapes, xb, states)
            outs.append(o)
        return jnp.stack(outs, axis=1)

    carry_idx = [i for i, s in enumerate(states) if s is not None]
    carry = tuple(states[i] for i in carry_idx)

    def body2(carry, _):
        full = list(states)
        for i, c in zip(carry_idx, carry):
            full[i] = c
        o, new_full, _ = step_batched(specs, params, shapes, xb, full)
        return tuple(new_full[i] for i in carry_idx), o

    _, outs = jax.lax.scan(body2, carry, None, length=timesteps)
    return jnp.transpose(outs, (1, 0, 2))


def forward_batch_sfr(specs, params, shapes, xb, timesteps: int):
    """Batched eval forward returning (B,T,classes) and (T, layers) SFR."""
    states = _zeros_states_batched(specs, shapes, xb.shape[0])
    outs, sfrs = [], []
    for _ in range(timesteps):
        o, states, sfr = step_batched(specs, params, shapes, xb, states)
        outs.append(o)
        sfrs.append(sfr)
    return jnp.stack(outs, axis=1), jnp.stack(sfrs)


def predict(specs, params, shapes, x, timesteps: int,
            use_pallas: bool = False) -> jnp.ndarray:
    """Class prediction: argmax of the time-averaged logits."""
    o, _ = forward(specs, params, shapes, x, timesteps, use_pallas)
    return jnp.argmax(o.mean(axis=0))


# ---------------------------------------------------------------------------
# Introspection helpers shared with aot.py / the Rust side
# ---------------------------------------------------------------------------

def spec_dicts(specs, shapes, params) -> list[dict]:
    """JSON-ready per-layer description (consumed by rust/src/model)."""
    out = []
    for spec, (h, w, c) in zip(specs, shapes, strict=True):
        d: dict[str, Any] = {"in_h": h, "in_w": w, "in_c": c}
        if isinstance(spec, Conv):
            d.update(kind="conv", co=spec.co, k=spec.k, pad=spec.pad,
                     encoder=spec.encoder)
        elif isinstance(spec, Residual):
            d.update(kind="residual", co=spec.co, k=spec.k)
        elif isinstance(spec, DWConv):
            d.update(kind="dwconv", co=c, k=spec.k, pad=spec.pad)
        elif isinstance(spec, PWConv):
            d.update(kind="pwconv", co=spec.co, k=1, pad=0)
        elif isinstance(spec, Pool):
            d.update(kind="pool")
        elif isinstance(spec, FC):
            d.update(kind="fc", out=spec.out)
        out.append(d)
    return out
