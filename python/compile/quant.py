"""INT8 weight quantisation (paper SectionIV-A).

The accelerator stores weights as 8-bit integers in the on-chip weight
buffer.  We use symmetric per-tensor quantisation per layer:

    w_q = clip(round(w / s), -127, 127),  s = max|w| / 127

The functional inference graph uses the *dequantised* weights
(``w_q * s``) so the AOT HLO matches the hardware's numerics, while the
raw ``int8`` planes + scales are exported for the Rust simulator (whose
PEs accumulate int8 weights exactly as the FPGA does).

The IF threshold is quantised to the same fixed-point grid so the fire
decision is bit-identical between the float graph and the int8 PE array:
thresholding ``sum(w_q * s) >= vth`` is equivalent to the integer
compare ``sum(w_q) >= vth / s``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import model as model_mod


@dataclasses.dataclass
class QuantTensor:
    """int8 planes + scale; `deq()` gives the float tensor the HLO uses."""
    q: np.ndarray       # int8
    scale: float

    def deq(self) -> jnp.ndarray:
        return jnp.asarray(self.q.astype(np.float32) * self.scale)


def quantize_tensor(w: np.ndarray) -> QuantTensor:
    amax = float(np.abs(w).max())
    scale = amax / 127.0 if amax > 0 else 1.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return QuantTensor(q, scale)


def quantize_params(params: list) -> list:
    """Quantise every weight tensor; biases stay float32 (the FPGA keeps
    biases/thresholds at full accumulator precision)."""
    out = []
    for p in params:
        qp = {}
        for k, v in p.items():
            v = np.asarray(v)
            if k.startswith("w"):
                qp[k] = quantize_tensor(v)
            else:
                qp[k] = v.astype(np.float32)
        out.append(qp)
    return out


def dequantized_params(qparams: list) -> list:
    """Float params whose values lie exactly on the int8 grid."""
    out = []
    for qp in qparams:
        p = {}
        for k, v in qp.items():
            p[k] = v.deq() if isinstance(v, QuantTensor) else jnp.asarray(v)
        out.append(p)
    return out


def quantization_error(params: list) -> float:
    """Max |w - deq(quant(w))| across all weight tensors (diagnostics)."""
    err = 0.0
    for p in params:
        for k, v in p.items():
            if k.startswith("w"):
                v = np.asarray(v)
                d = np.asarray(quantize_tensor(v).deq())
                err = max(err, float(np.abs(v - d).max()))
    return err


def accuracy_drop(specs, shapes, params, x, y, timesteps: int):
    """(float_acc, int8_acc) on the given eval set — the quantisation
    ablation the paper folds into its 'Int8 precision' design point."""
    from . import train as train_mod
    facc, _ = train_mod.evaluate(specs, shapes, params, x, y, timesteps)
    qacc, _ = train_mod.evaluate(
        specs, shapes, dequantized_params(quantize_params(params)),
        x, y, timesteps)
    return facc, qacc
