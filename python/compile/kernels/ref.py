"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *correctness ground truth*: each Pallas kernel in
``spike_conv.py`` / ``dsc.py`` / ``lif.py`` / ``pooling.py`` / ``fc.py``
must match its oracle bit-for-bit (binary spike outputs) or to float
tolerance (membrane potentials / partial sums).

Conventions (shared with the Rust simulator, see rust/src/arch/):
  * Feature maps are ``(H, W, C)`` — channel-last, so one pixel's spike
    vector (all C channels, channel-sorted) is contiguous.  This is the
    paper's "compressed and sorted spike representation" (SectionIV-C): memory
    layout makes a single access fetch the whole spike vector.
  * Spikes are float32 tensors holding exactly {0.0, 1.0}.
  * Conv weights are ``(Kh, Kw, Ci, Co)``; depthwise ``(Kh, Kw, C)``;
    pointwise ``(Ci, Co)``; FC ``(In, Out)``.
  * Convolutions are the paper's: stride 1, symmetric zero padding,
    accumulation over input channels (standard mode only).
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Convolution partial sums (the CU in paper Fig. 5/6)
# ---------------------------------------------------------------------------

def conv2d_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
                padding: int = 1) -> jnp.ndarray:
    """Standard-convolution partial sums.

    Args:
      spikes:  (H, W, Ci) float {0,1}.
      weights: (Kh, Kw, Ci, Co) float.
      padding: symmetric zero padding on H and W.

    Returns:
      (Ho, Wo, Co) partial sums with Ho = H + 2p - Kh + 1 (stride 1).
    """
    kh, kw, ci, co = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    out = jnp.zeros((ho, wo, co), dtype=jnp.float32)
    # Tap-by-tap accumulation — mirrors the weight-broadcast order of the
    # OS dataflow (paper Fig. 6(c)): for each kernel tap the whole output
    # plane accumulates spike-gated weights.
    for i in range(kh):
        for j in range(kw):
            patch = x[i:i + ho, j:j + wo, :]            # (Ho, Wo, Ci)
            out = out + jnp.einsum(
                "hwc,co->hwo", patch, weights[i, j],    # (Ci, Co)
                preferred_element_type=jnp.float32)
    return out


def depthwise_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
                   padding: int = 1) -> jnp.ndarray:
    """Depthwise-convolution partial sums (paper Fig. 8(c)).

    No cross-channel accumulation: channel c of the output only sees
    channel c of the input.

    Args:
      spikes:  (H, W, C) float {0,1}.
      weights: (Kh, Kw, C) float.
    """
    kh, kw, c = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    out = jnp.zeros((ho, wo, c), dtype=jnp.float32)
    for i in range(kh):
        for j in range(kw):
            out = out + x[i:i + ho, j:j + wo, :] * weights[i, j][None, None, :]
    return out


def pointwise_psum(spikes: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Pointwise (1x1) convolution partial sums (paper Fig. 8(d)).

    Args:
      spikes:  (H, W, Ci) float {0,1}.
      weights: (Ci, Co) float.
    """
    return jnp.einsum("hwc,co->hwo", spikes, weights,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Neuron dynamics (paper Section II-A, Eq. (2)-(4))
# ---------------------------------------------------------------------------

def if_step(psum: jnp.ndarray, vmem: jnp.ndarray, vth: float,
            bias: jnp.ndarray | None = None):
    """One IF-neuron timestep: integrate, fire, hard reset-to-zero.

    The accelerator implements IF neurons (paper Table V "Neuron Type:
    IF"); LIF with leak is `lif_step`.

    Returns (spikes, new_vmem).
    """
    cur = psum if bias is None else psum + bias
    v = vmem + cur
    spk = (v >= vth).astype(jnp.float32)
    v_next = jnp.where(spk > 0, 0.0, v)
    return spk, v_next


def lif_step(psum: jnp.ndarray, vmem: jnp.ndarray, vth: float,
             leak: float, bias: jnp.ndarray | None = None):
    """One LIF timestep, Eq. (3)-(4): v <- leak*v + I; fire & hard reset.

    ``leak`` is (1 - 1/tau_m).
    """
    cur = psum if bias is None else psum + bias
    v = leak * vmem + cur
    spk = (v >= vth).astype(jnp.float32)
    v_next = jnp.where(spk > 0, 0.0, v)
    return spk, v_next


# ---------------------------------------------------------------------------
# Pooling (paper Fig. 7(b): logical-OR over a 2x2 window)
# ---------------------------------------------------------------------------

def or_pool2(spikes: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 OR pooling on binary spike maps.

    (H, W, C) -> (H//2, W//2, C); H and W must be even.
    """
    h, w, c = spikes.shape
    x = spikes.reshape(h // 2, 2, w // 2, 2, c)
    return jnp.max(jnp.max(x, axis=3), axis=1)


# ---------------------------------------------------------------------------
# Fully-connected (classifier head)
# ---------------------------------------------------------------------------

def fc_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
            bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """Spike-gated fully-connected partial sums.

    Args:
      spikes:  (In,) float {0,1} — flattened channel-last feature map.
      weights: (In, Out) float.
    """
    out = spikes @ weights
    if bias is not None:
        out = out + bias
    return out


# ---------------------------------------------------------------------------
# Fused layers — what the T=1 hardware actually does (OS dataflow: psum is
# thresholded inside the PE, membrane potential never leaves the register).
# ---------------------------------------------------------------------------

def conv_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray, vth: float,
                  padding: int = 1, bias: jnp.ndarray | None = None):
    """Standard conv + IF fire at T=1 (zero-initialised vmem, discarded)."""
    psum = conv2d_psum(spikes, weights, padding)
    if bias is not None:
        psum = psum + bias
    return (psum >= vth).astype(jnp.float32)


def depthwise_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray, vth: float,
                       padding: int = 1):
    psum = depthwise_psum(spikes, weights, padding)
    return (psum >= vth).astype(jnp.float32)


def pointwise_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray, vth: float):
    psum = pointwise_psum(spikes, weights)
    return (psum >= vth).astype(jnp.float32)
