"""Pallas kernels: IF / LIF neuron update (paper SectionII-A, Eq. (2)-(4)).

The neuron module of the accelerator (Fig. 5 "Neuron"): take the CU's
partial sums, update the membrane potential, compare against the
threshold, fire and hard-reset.  In multi-timestep mode the updated
membrane potential is written back to the Vmem buffer (the memory traffic
that T=1 eliminates); at T=1 callers should prefer the fused
``*_if_fused`` kernels in ``spike_conv``/``dsc`` which never materialise
vmem at all.

Elementwise → VPU work; lane dimension = channels; ``interpret=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _neuron_kernel(p_ref, v_ref, s_out, v_out, *, vth: float, leak: float):
    """Integrate-fire-reset on one row of neurons.

    p_ref, v_ref: (1, W, C) psums and previous membrane potentials.
    s_out, v_out: (1, W, C) output spikes and updated potentials.
    """
    v = leak * v_ref[...] + p_ref[...]
    spk = (v >= vth).astype(jnp.float32)
    s_out[...] = spk
    # Hard reset to u_r = 0 (paper Eq. (4) with u_r = 0).
    v_out[...] = jnp.where(spk > 0, 0.0, v)


def _run(psum: jnp.ndarray, vmem: jnp.ndarray, vth: float, leak: float):
    h, w, c = psum.shape

    import functools
    kern = functools.partial(_neuron_kernel, vth=vth, leak=leak)
    return pl.pallas_call(
        kern,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, w, c), lambda r: (r, 0, 0)),
            pl.BlockSpec((1, w, c), lambda r: (r, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w, c), lambda r: (r, 0, 0)),
            pl.BlockSpec((1, w, c), lambda r: (r, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w, c), jnp.float32),
            jax.ShapeDtypeStruct((h, w, c), jnp.float32),
        ],
        interpret=True,
    )(psum, vmem)


def if_step(psum: jnp.ndarray, vmem: jnp.ndarray, vth: float,
            bias: jnp.ndarray | None = None):
    """IF neuron step on (H, W, C) maps. Returns (spikes, new_vmem)."""
    if bias is not None:
        psum = psum + bias[None, None, :]
    return _run(psum, vmem, vth, leak=1.0)


def lif_step(psum: jnp.ndarray, vmem: jnp.ndarray, vth: float, leak: float,
             bias: jnp.ndarray | None = None):
    """LIF neuron step (leak = 1 - 1/tau_m). Returns (spikes, new_vmem)."""
    if bias is not None:
        psum = psum + bias[None, None, :]
    return _run(psum, vmem, vth, leak=leak)
