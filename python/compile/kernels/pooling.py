"""Pallas kernel: 2x2 stride-2 OR pooling (paper Fig. 7b).

The FPGA implements pooling as a logical OR across a 2x2 spike window,
staged through the line buffer + two register rows.  On binary {0,1}
spike maps OR == max, which is what the kernel computes; the grid walks
output rows and each step consumes two input rows — the two register
rows of Fig. 7(b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, wo: int):
    """x_ref: (2, W, C) two input rows; o_ref: (1, Wo, C)."""
    top = x_ref[0]                       # (W, C)
    bot = x_ref[1]
    rows = jnp.maximum(top, bot)         # vertical OR (register1 | register2)
    left = rows[0::2, :][:wo]            # even columns
    right = rows[1::2, :][:wo]           # odd columns
    o_ref[0, :, :] = jnp.maximum(left, right)   # horizontal OR


def or_pool2(spikes: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 OR pooling: (H, W, C) -> (H//2, W//2, C), H, W even."""
    h, w, c = spikes.shape
    assert h % 2 == 0 and w % 2 == 0, "or_pool2 requires even H and W"
    ho, wo = h // 2, w // 2

    import functools
    kern = functools.partial(_pool_kernel, wo=wo)
    return pl.pallas_call(
        kern,
        grid=(ho,),
        in_specs=[pl.BlockSpec((2, w, c), lambda r: (r, 0, 0))],
        out_specs=pl.BlockSpec((1, wo, c), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.float32),
        interpret=True,
    )(spikes)
