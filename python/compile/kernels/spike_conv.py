"""Pallas kernel: spike-gated standard convolution in OS dataflow.

This is the compute hot-spot of STI-SNN's convolutional layer (paper
Fig. 6), re-thought for a TPU-style memory hierarchy instead of the
paper's FPGA fabric (DESIGN.md "Hardware-Adaptation"):

  * The FPGA keeps one output pixel's membrane potential resident in a PE
    register while weights stream past (output stationary).  Here the
    Pallas grid iterates over **output rows**; each grid step keeps one
    output-row tile ``(Wo, Co)`` resident in VMEM while it accumulates all
    ``Kh*Kw`` taps — the membrane potential never round-trips to HBM.
  * The FPGA line buffer (Kh chained FIFOs x Wi x Ci bits, Fig. 7a) is
    materialised explicitly by ``line_buffer_view``: row r of the view is
    the Kh-row window the r-th output row's receptive fields need.  The
    input BlockSpec then fetches exactly that window HBM->VMEM once per
    output row, reused across all Kw offsets and all Co — the same reuse
    the FPGA line buffer provides.
  * The channel-packed spike vector (Fig. 6, SectionIV-C) maps to keeping C
    innermost (the lane dimension): one VMEM load grabs a whole pixel's
    spike vector.
  * Per tap the accumulation is ``spikes(Wo,Ci) @ weights(Ci,Co)`` — with
    {0,1} spikes the MXU matmul degenerates into exactly the add-network
    the FPGA PE array implements with adders.

``interpret=True`` always: the CPU PJRT backend cannot run Mosaic
custom-calls; numerics are validated against ``ref.conv2d_psum``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def line_buffer_view(x: jnp.ndarray, kh: int) -> jnp.ndarray:
    """(H, W, C) -> (Ho, Kh, W, C): the FPGA line buffer, materialised.

    Row r holds input rows r..r+Kh-1 — the window of ``Kh`` chained FIFOs
    (each depth W, width C bits) feeding the PE rows in paper Fig. 7(a).
    XLA lowers this to Kh shifted views; no Kh-fold copy survives fusion
    into the consuming kernel's gather.
    """
    h = x.shape[0]
    ho = h - kh + 1
    return jnp.stack([x[i:i + ho] for i in range(kh)], axis=1)


def _conv_row_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, wo: int):
    """One output row: accumulate Kh*Kw spike-gated taps into VMEM.

    x_ref: (1, Kh, Wi_pad, Ci) — line-buffer window for this output row.
    w_ref: (Kh, Kw, Ci, Co)    — full filter bank (broadcast, Fig. 6c).
    o_ref: (1, Wo, Co)         — output-stationary accumulator tile.
    """
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
    # Static unroll over taps: Kh*Kw MXU-shaped matmuls, the accumulator
    # (the OS membrane potential) resident in registers/VMEM throughout.
    for i in range(kh):
        for j in range(kw):
            patch = x_ref[0, i, j:j + wo, :]        # (Wo, Ci) spike vectors
            acc = acc + jnp.dot(patch, w_ref[i, j],
                                preferred_element_type=jnp.float32)
    o_ref[0, :, :] = acc


def conv2d_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
                padding: int = 1) -> jnp.ndarray:
    """Standard-convolution partial sums via the OS-dataflow Pallas kernel.

    Args:
      spikes:  (H, W, Ci) float {0,1}.
      weights: (Kh, Kw, Ci, Co) float.
      padding: symmetric zero padding (stride fixed at 1 as in the paper's
               conv layers; downsampling is done by OR-pooling).

    Returns: (Ho, Wo, Co) float32 partial sums.
    """
    kh, kw, ci, co = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    xlb = line_buffer_view(x, kh)                   # (Ho, Kh, W, Ci)

    kern = functools.partial(_conv_row_kernel, kh=kh, kw=kw, wo=wo)
    return pl.pallas_call(
        kern,
        grid=(ho,),
        in_specs=[
            pl.BlockSpec((1, kh, w, ci), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda r: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, wo, co), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, co), jnp.float32),
        interpret=True,
    )(xlb, weights)


def conv_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray, vth: float,
                  padding: int = 1,
                  bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fused conv + IF threshold at T=1 (the paper's headline OS win).

    The threshold compare happens on the VMEM-resident accumulator; the
    membrane potential is *discarded* after the fire decision — exactly
    the T=1 hardware, where the Vmem buffer is absent (paper Fig. 11).
    """
    kh, kw, ci, co = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    xlb = line_buffer_view(x, kh)
    b = jnp.zeros((co,), jnp.float32) if bias is None else bias

    def kern(x_ref, w_ref, b_ref, o_ref):
        acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
        for i in range(kh):
            for j in range(kw):
                patch = x_ref[0, i, j:j + wo, :]
                acc = acc + jnp.dot(patch, w_ref[i, j],
                                    preferred_element_type=jnp.float32)
        acc = acc + b_ref[:][None, :]
        # Fire: the neuron module's threshold compare (paper Fig. 8b,
        # ctrl3) fused into the same kernel — vmem never leaves VMEM.
        o_ref[0, :, :] = (acc >= vth).astype(jnp.float32)

    return pl.pallas_call(
        kern,
        grid=(ho,),
        in_specs=[
            pl.BlockSpec((1, kh, w, ci), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda r: (0, 0, 0, 0)),
            pl.BlockSpec((co,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((1, wo, co), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, co), jnp.float32),
        interpret=True,
    )(xlb, weights, b)
