"""Pallas kernel: spike-gated fully-connected layer (classifier head).

The FC layer receives the flattened, channel-sorted spike vector of the
last feature map and produces class logits (= the output neurons'
membrane potentials; the classifier never fires, the argmax of the
accumulated potential is the prediction — standard direct-encoding SNN
head, and what the FPGA's final layer computes).

With binary spikes the matvec is a gather-accumulate over the rows of W
whose spike bit is set — the FPGA implements it exactly that way; the
MXU sees a (1, In) @ (In, Out) matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fc_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
            bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """Spike-gated FC: (In,) x (In, Out) [+ (Out,)] -> (Out,)."""
    n_in, n_out = weights.shape
    b = jnp.zeros((n_out,), jnp.float32) if bias is None else bias

    def kern(s_ref, w_ref, b_ref, o_ref):
        o_ref[...] = (
            jnp.dot(s_ref[...][None, :], w_ref[...],
                    preferred_element_type=jnp.float32)[0]
            + b_ref[...]
        )

    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec((n_in,), lambda: (0,)),
            pl.BlockSpec((n_in, n_out), lambda: (0, 0)),
            pl.BlockSpec((n_out,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n_out,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.float32),
        interpret=True,
    )(spikes, weights, b)
