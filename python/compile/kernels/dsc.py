"""Pallas kernels: depthwise-separable convolution modes (paper Fig. 8c/d).

STI-SNN's multi-mode PE supports depthwise and pointwise convolution by
reconfiguring the dataflow (SectionIV-D).  The same reconfiguration happens
here at the kernel level:

  * **Depthwise** — no cross-channel accumulation; the PE "directly
    outputs the loaded weight upon receiving a spike" (Fig. 8c).  The
    MXU matmul of the standard mode degenerates into an elementwise
    (VPU) multiply-accumulate over taps, lane dimension = channels.
  * **Pointwise** — 1x1 filters; the spike-generation module skips the
    cross-PE psum adder tree and thresholds PE outputs directly
    (Fig. 8d).  Kernel = one (W,Ci)@(Ci,Co) matmul per row, no taps.

Both use the same output-stationary structure as ``spike_conv``: one
output row resident in VMEM per grid step; ``interpret=True`` throughout
(CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spike_conv import line_buffer_view


def _dw_row_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, wo: int):
    """Depthwise: per-channel tap accumulation, no channel reduction.

    x_ref: (1, Kh, Wi_pad, C); w_ref: (Kh, Kw, C); o_ref: (1, Wo, C).
    """
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
    for i in range(kh):
        for j in range(kw):
            # Spike-gated weight pass-through (Fig. 8c): with binary
            # spikes, x * w is "output the weight iff a spike arrived".
            acc = acc + x_ref[0, i, j:j + wo, :] * w_ref[i, j][None, :]
    o_ref[0, :, :] = acc


def depthwise_psum(spikes: jnp.ndarray, weights: jnp.ndarray,
                   padding: int = 1) -> jnp.ndarray:
    """Depthwise-convolution partial sums.

    Args:
      spikes:  (H, W, C) float {0,1}.
      weights: (Kh, Kw, C) float.

    Returns: (Ho, Wo, C) float32.
    """
    kh, kw, c = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    xlb = line_buffer_view(x, kh)

    kern = functools.partial(_dw_row_kernel, kh=kh, kw=kw, wo=wo)
    return pl.pallas_call(
        kern,
        grid=(ho,),
        in_specs=[
            pl.BlockSpec((1, kh, w, c), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, wo, c), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.float32),
        interpret=True,
    )(xlb, weights)


def depthwise_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray,
                       vth: float, padding: int = 1) -> jnp.ndarray:
    """Depthwise conv + IF fire at T=1 (no vmem register needed at all —
    paper SectionIV-D: "a membrane potential register is not required")."""
    kh, kw, c = weights.shape
    x = jnp.pad(spikes, ((padding, padding), (padding, padding), (0, 0)))
    h, w, _ = x.shape
    ho, wo = h - kh + 1, w - kw + 1
    xlb = line_buffer_view(x, kh)

    def kern(x_ref, w_ref, o_ref):
        acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
        for i in range(kh):
            for j in range(kw):
                acc = acc + x_ref[0, i, j:j + wo, :] * w_ref[i, j][None, :]
        o_ref[0, :, :] = (acc >= vth).astype(jnp.float32)

    return pl.pallas_call(
        kern,
        grid=(ho,),
        in_specs=[
            pl.BlockSpec((1, kh, w, c), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, wo, c), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.float32),
        interpret=True,
    )(xlb, weights)


def pointwise_psum(spikes: jnp.ndarray,
                   weights: jnp.ndarray) -> jnp.ndarray:
    """Pointwise (1x1) convolution partial sums.

    Args:
      spikes:  (H, W, Ci) float {0,1}.
      weights: (Ci, Co) float.

    Returns: (H, W, Co) float32.
    """
    h, w, ci = spikes.shape
    co = weights.shape[1]

    def kern(x_ref, w_ref, o_ref):
        o_ref[0, :, :] = jnp.dot(x_ref[0], w_ref[...],
                                 preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kern,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, w, ci), lambda r: (r, 0, 0)),
            pl.BlockSpec((ci, co), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, co), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, co), jnp.float32),
        interpret=True,
    )(spikes, weights)


def pointwise_if_fused(spikes: jnp.ndarray, weights: jnp.ndarray,
                       vth: float) -> jnp.ndarray:
    """Pointwise conv + IF fire at T=1 (Fig. 8d: threshold PE outputs
    directly, no psum adder tree)."""
    h, w, ci = spikes.shape
    co = weights.shape[1]

    def kern(x_ref, w_ref, o_ref):
        acc = jnp.dot(x_ref[0], w_ref[...],
                      preferred_element_type=jnp.float32)
        o_ref[0, :, :] = (acc >= vth).astype(jnp.float32)

    return pl.pallas_call(
        kern,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, w, ci), lambda r: (r, 0, 0)),
            pl.BlockSpec((ci, co), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, co), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, co), jnp.float32),
        interpret=True,
    )(spikes, weights)
