"""STI-SNN Layer-1 Pallas kernels and their pure-jnp oracles.

Every kernel runs with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is pinned to ``ref`` by the pytest
suite in ``python/tests/``.
"""

from . import dsc, fc, lif, pooling, ref, spike_conv  # noqa: F401
