"""AOT compile path: train -> quantise -> export artifacts.

Emits, per model, into ``artifacts/<model>/``:

  * ``net.json``        — network description + tensor manifest
                          (consumed by rust/src/model).
  * ``weights.bin``     — int8 weights (engine layout) + f32 biases.
  * ``encoder.hlo.txt`` — image -> encoder spike frame (Pallas fused
                          conv+IF), the accelerator's input producer.
  * ``model.hlo.txt``   — image -> (logits,), the full T=1 inference
                          graph with every layer running through the L1
                          Pallas kernels — the functional reference the
                          rust runtime executes via PJRT.

HLO **text**, never ``.serialize()``: jax >= 0.5 emits 64-bit ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Idempotence: ``make artifacts`` skips models whose directory already
contains all outputs (delete ``artifacts/<model>`` to force a rebuild).

Usage:
  python -m compile.aot --models scnn3,vmobilenet,scnn5 [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import pickle

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import quant as quant_mod
from . import train as train_mod

REPO = pathlib.Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"


# ---------------------------------------------------------------------------
# HLO text export (the aot_recipe / xla-example bridge)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big weight tensors as ``constant({...})`` and the rust-side
    text parser silently reads them back as **zeros** — the model would
    run but output all-zero logits.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


# ---------------------------------------------------------------------------
# Training configurations per deployed model (Algorithm 1, scaled to the
# single-CPU budget — DESIGN.md Substitutions)
# ---------------------------------------------------------------------------

TRAIN_CFGS = {
    "scnn3": train_mod.TrainConfig(
        model="scnn3", dataset="synth-mnist", timesteps=6, loss="tet",
        epochs=3, n_train=768, n_test=256, batch_size=32, lr=2e-3),
    "vmobilenet": train_mod.TrainConfig(
        model="vmobilenet", dataset="synth-mnist", timesteps=6, loss="tet",
        epochs=3, n_train=768, n_test=256, batch_size=32, lr=2e-3),
    # SCNN5 trains at reduced width on CPU (hardware experiments use the
    # full-width spec with random weights; cycle counts are
    # weight-independent). The artifact net.json still records the
    # trained (narrow) geometry for functional runs.
    "scnn5": train_mod.TrainConfig(
        model="scnn5", dataset="synth-cifar10", timesteps=6, loss="tet",
        epochs=2, n_train=384, n_test=128, batch_size=16, lr=2e-3,
        width=0.25),
}

FAST_OVERRIDES = dict(epochs=1, n_train=128, n_test=64)


# ---------------------------------------------------------------------------
# Weight export (engine layout — see rust/src/model)
# ---------------------------------------------------------------------------

def _conv_taps_engine_layout(q: np.ndarray) -> np.ndarray:
    """(Kh, Kw, Ci, Co) int8 -> flat [co][ci][kh*kw]."""
    kh, kw, ci, co = q.shape
    return np.transpose(q, (3, 2, 0, 1)).reshape(co, ci, kh * kw)


def export_weights(specs, qparams, out_dir: pathlib.Path) -> list[dict]:
    """Write weights.bin; return the tensor manifest."""
    manifest, blob = [], bytearray()

    def put(layer: int, name: str, kind: str, arr: np.ndarray,
            scale: float):
        data = arr.tobytes()
        manifest.append({
            "layer": layer, "name": name, "kind": kind,
            "shape": list(arr.shape), "scale": scale,
            "offset": len(blob), "len": len(data),
        })
        blob.extend(data)

    for li, (spec, qp) in enumerate(zip(specs, qparams)):
        if isinstance(spec, model_mod.Conv):
            if spec.encoder:
                continue  # encoder runs via PJRT, not the PE array
            qt = qp["w"]
            taps = _conv_taps_engine_layout(qt.q)
            put(li, "w", "int8", taps, qt.scale)
            put(li, "b", "f32", qp["b"].astype(np.float32), 1.0)
        elif isinstance(spec, model_mod.DWConv):
            qt = qp["w"]                       # (Kh, Kw, C)
            kh, kw, c = qt.q.shape
            taps = np.transpose(qt.q, (2, 0, 1)).reshape(c, 1, kh * kw)
            put(li, "w", "int8", taps, qt.scale)
            put(li, "b", "f32", qp["b"].astype(np.float32), 1.0)
        elif isinstance(spec, model_mod.PWConv):
            qt = qp["w"]                       # (Ci, Co)
            ci, co = qt.q.shape
            taps = np.transpose(qt.q, (1, 0)).reshape(co, ci, 1)
            put(li, "w", "int8", taps, qt.scale)
            put(li, "b", "f32", qp["b"].astype(np.float32), 1.0)
        elif isinstance(spec, model_mod.FC):
            qt = qp["w"]                       # (In, Out) — row-major OK
            put(li, "w", "int8", qt.q, qt.scale)
            put(li, "b", "f32", qp["b"].astype(np.float32), 1.0)

    (out_dir / "weights.bin").write_bytes(bytes(blob))
    return manifest


# ---------------------------------------------------------------------------
# Per-model artifact build
# ---------------------------------------------------------------------------

def outputs_exist(out_dir: pathlib.Path) -> bool:
    return all((out_dir / f).exists() for f in
               ("net.json", "weights.bin", "encoder.hlo.txt",
                "model.hlo.txt"))


def build_model(name: str, fast: bool = False, force: bool = False) -> None:
    out_dir = ARTIFACTS / name
    if outputs_exist(out_dir) and not force:
        print(f"[aot] {name}: artifacts up to date, skipping")
        return
    out_dir.mkdir(parents=True, exist_ok=True)

    cfg = TRAIN_CFGS[name]
    if fast:
        cfg = dataclasses.replace(cfg, **FAST_OVERRIDES)

    # --- Algorithm 1: train at T, fine-tune at T=1 (cached) ------------
    ckpt = out_dir / "checkpoint.pkl"
    if ckpt.exists() and not force:
        print(f"[aot] {name}: loading cached checkpoint")
        with open(ckpt, "rb") as f:
            saved = pickle.load(f)
        params, specs, shapes = (saved["params"], saved["specs"],
                                 saved["shapes"])
        acc_t1 = saved["acc_t1"]
    else:
        print(f"[aot] {name}: training (Algorithm 1, budget-scaled)")
        pruning = train_mod.temporal_pruning(
            cfg, t_de=1, finetune_epochs=max(4, cfg.epochs),
            eval_timesteps=(cfg.timesteps, 2, 1), verbose=True)
        params = pruning.finetuned.params
        specs = pruning.finetuned.specs
        shapes = pruning.finetuned.shapes
        acc_t1 = pruning.finetuned.test_acc
        with open(ckpt, "wb") as f:
            pickle.dump({"params": params, "specs": specs,
                         "shapes": shapes, "acc_t1": acc_t1,
                         "reduced_acc": pruning.reduced_acc,
                         "reduced_sfr": {k: v.tolist() for k, v in
                                         pruning.reduced_sfr.items()},
                         "base_acc": pruning.base.test_acc}, f)

    # --- Quantise + export ---------------------------------------------
    qparams = quant_mod.quantize_params(params)
    deq = quant_mod.dequantized_params(qparams)
    manifest = export_weights(specs, qparams, out_dir)

    _, _, input_shape, _ = data_mod.DATASETS[cfg.dataset][0], None, \
        data_mod.DATASETS[cfg.dataset][1], data_mod.DATASETS[cfg.dataset][2]
    input_shape = data_mod.DATASETS[cfg.dataset][1]

    net = {
        "name": name,
        "input": list(input_shape),
        "vth": model_mod.VTH,
        "timesteps": 1,
        "acc_t1": acc_t1,
        "layers": model_mod.spec_dicts(specs, shapes, params),
        "tensors": manifest,
    }
    (out_dir / "net.json").write_text(json.dumps(net, indent=1))

    # --- AOT HLO lowering (Pallas kernels, T=1) ------------------------
    x_spec = jax.ShapeDtypeStruct(input_shape, jnp.float32)

    def encoder_fn(x):
        """Image -> encoder spike frame (first conv layer + IF)."""
        spec = specs[0]
        assert isinstance(spec, model_mod.Conv) and spec.encoder
        from .kernels import spike_conv
        return (spike_conv.conv_if_fused(
            x, deq[0]["w"], model_mod.VTH, spec.pad, deq[0]["b"]),)

    def full_fn(x):
        """Image -> (logits,) through the Pallas kernels at T=1."""
        o, _ = model_mod.forward(specs, deq, shapes, x, 1, use_pallas=True)
        return (o[0],)

    print(f"[aot] {name}: lowering encoder HLO")
    (out_dir / "encoder.hlo.txt").write_text(lower_fn(encoder_fn, x_spec))
    print(f"[aot] {name}: lowering full-model HLO")
    (out_dir / "model.hlo.txt").write_text(lower_fn(full_fn, x_spec))
    print(f"[aot] {name}: done (T=1 accuracy {acc_t1:.4f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="scnn3,vmobilenet,scnn5")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training budget (CI smoke)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for name in args.models.split(","):
        build_model(name.strip(), fast=args.fast, force=args.force)


if __name__ == "__main__":
    main()
