"""Algorithm-level experiments: paper Fig. 2, Fig. 3, Fig. 4/13, Table II.

Each experiment prints the paper-style series/rows and appends a
machine-readable record to ``artifacts/experiments/<name>.json`` for
EXPERIMENTS.md.  Budgets are scaled to the single-CPU environment
(DESIGN.md Substitutions); the claims under test are *trends* (SDT
collapse at T=1 vs TET stability), not absolute accuracies.

Usage:
  python -m compile.experiments fig2 [--fast]
  python -m compile.experiments fig3
  python -m compile.experiments fig4 [--fast]
  python -m compile.experiments table2 [--fast]
  python -m compile.experiments all [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod

REPO = pathlib.Path(__file__).resolve().parents[2]
OUT = REPO / "artifacts" / "experiments"


def record(name: str, payload: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / f"{name}.json", "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[saved artifacts/experiments/{name}.json]")


# ---------------------------------------------------------------------------
# Fig. 2 — accuracy vs inference timesteps under SDT
# ---------------------------------------------------------------------------

def fig2(fast: bool = False) -> None:
    """Train with SDT at T=6, sweep inference T in {6,4,2,1}: accuracy
    collapses at low T (the motivation for the TET-based approach)."""
    print("Fig. 2 — accuracy vs inference timesteps (SDT)\n")
    # Paper Fig. 2: VGG16 on CIFAR10 + CIFAR100, ResNet34 on TinyIN.
    combos = [
        ("vgg_small", "synth-cifar10"),
        ("vgg_small", "synth-cifar100"),
        ("resnet_small", "synth-cifar10"),
    ]
    sweep_t = [6, 4, 2, 1]
    results = {}
    for model, dataset in combos:
        cfg = train_mod.TrainConfig(
            model=model, dataset=dataset, timesteps=6, loss="sdt",
            epochs=2 if fast else 3,
            n_train=256 if fast else 512,
            n_test=128 if fast else 192,
            batch_size=16, lr=2e-3, width=0.25 if fast else 0.4)
        res = train_mod.train(cfg, verbose=False)
        (_, _), (xte, yte), _, _ = data_mod.load(
            cfg.dataset, cfg.n_train, cfg.n_test, seed=cfg.seed)
        accs = []
        for t in sweep_t:
            acc, _ = train_mod.evaluate(res.specs, res.shapes, res.params,
                                        xte, yte, t)
            accs.append(acc)
        key = f"{model}/{dataset}"
        results[key] = dict(zip(map(str, sweep_t), accs))
        print(f"{key:<32} " +
              " ".join(f"T{t}:{a:.3f}" for t, a in zip(sweep_t, accs)))
    record("fig2", {"sweep_t": sweep_t, "results": results,
                    "claim": "SDT accuracy degrades as inference T drops "
                             "below the training T; T=1 is worst"})


# ---------------------------------------------------------------------------
# Fig. 3 — single-neuron sensitivity to timestep reduction
# ---------------------------------------------------------------------------

def fig3() -> None:
    """The paper's micro-example: neuron C integrates spikes from A and
    B over 6 timesteps and fires; cutting inference to 1 timestep starves
    it below threshold — spike disappearance."""
    print("Fig. 3 — neuron activity vs inference timesteps\n")
    # Weights trained so C fires when it has integrated ~4 input spikes.
    w_a, w_b, vth = 0.30, 0.25, 1.0
    # A and B spike trains over 6 timesteps (as in the figure).
    a = [1, 0, 1, 1, 0, 1]
    b = [0, 1, 1, 0, 1, 0]
    rows = {}
    for t_inf in (6, 2, 1):
        v, fired_at = 0.0, []
        for t in range(t_inf):
            v += w_a * a[t] + w_b * b[t]
            if v >= vth:
                fired_at.append(t)
                v = 0.0
        rows[t_inf] = fired_at
        print(f"T={t_inf}: membrane integrates "
              f"{sum(a[:t_inf]) + sum(b[:t_inf])} input spikes -> "
              f"output fires at t={fired_at if fired_at else 'never'}")
    assert rows[6], "neuron must fire at full timesteps"
    assert not rows[1], "neuron must starve at T=1"
    record("fig3", {"fired_at": {str(k): v for k, v in rows.items()},
                    "claim": "directly reducing timesteps silences "
                             "neurons trained at higher T"})


# ---------------------------------------------------------------------------
# Fig. 4 / Fig. 13 — per-layer SFR + accuracy, SDT vs TET, T = 6 -> 2 -> 1
# ---------------------------------------------------------------------------

def fig4(fast: bool = False) -> None:
    print("Fig. 4/13 — per-layer spike firing rates, SDT vs TET\n")
    out = {}
    for loss in ("sdt", "tet"):
        cfg = train_mod.TrainConfig(
            model="vgg_small", dataset="synth-cifar10", timesteps=6,
            loss=loss,
            epochs=2 if fast else 3,
            n_train=256 if fast else 512,
            n_test=128 if fast else 192,
            batch_size=16, lr=2e-3, width=0.25 if fast else 0.4)
        res = train_mod.train(cfg, verbose=False)
        (_, _), (xte, yte), _, _ = data_mod.load(
            cfg.dataset, cfg.n_train, cfg.n_test, seed=cfg.seed)
        per_t = {}
        for t in (6, 2, 1):
            acc, sfr = train_mod.evaluate(res.specs, res.shapes,
                                          res.params, xte, yte, t)
            per_t[t] = {"acc": acc, "sfr": [round(float(s), 4)
                                            for s in sfr]}
            print(f"{loss.upper():>4} T={t}: acc={acc:.3f} "
                  f"sfr={per_t[t]['sfr']}")
        out[loss] = per_t

        # Trend metrics: SFR retention and accuracy retention. At this
        # training budget (few epochs, synthetic data) the T=1 collapse
        # hits both losses for deep nets — the paper's own pipeline also
        # needs the Algorithm-1 fine-tune to hold T=1 (see table2); the
        # budget-robust TET advantage shows at T=2.
        for t_red in (2, 1):
            s6 = np.array(out[loss][6]["sfr"])
            s_r = np.array(out[loss][t_red]["sfr"])
            out[loss][f"sfr_retention_t{t_red}"] = float(
                np.mean(s_r / np.maximum(s6, 1e-6)))
            out[loss][f"acc_retention_t{t_red}"] = (
                out[loss][t_red]["acc"]
                / max(out[loss][6]["acc"], 1e-6))
        print(f"{loss.upper():>4} SFR retention T6->T2: "
              f"{out[loss]['sfr_retention_t2']:.3f}, acc retention "
              f"T6->T2: {out[loss]['acc_retention_t2']:.3f}\n")

    record("fig4", {**out,
                    "claim": "TET keeps firing rates + accuracy stable "
                             "under timestep reduction; SDT degrades "
                             "sooner (full T=1 recovery needs the "
                             "Algorithm-1 fine-tune, see table2)"})
    if not fast:
        # The paper's qualitative claim at the reduction step this
        # budget supports: TET retains more accuracy than SDT at T=2.
        assert out["tet"]["acc_retention_t2"] \
            >= out["sdt"]["acc_retention_t2"], \
            "TET must retain at least as much accuracy as SDT at T=2"


# ---------------------------------------------------------------------------
# Table II — temporal pruning comparison (our rows)
# ---------------------------------------------------------------------------

def table2(fast: bool = False) -> None:
    print("Table II — single-timestep accuracy after Algorithm 1\n")
    combos = [
        ("vgg_small", "synth-cifar10"),
        ("vgg_small", "synth-cifar100"),
        ("resnet_small", "synth-cifar10"),
        ("scnn3", "synth-mnist"),
    ]
    rows = []
    for model, dataset in combos:
        cfg = train_mod.TrainConfig(
            model=model, dataset=dataset, timesteps=6, loss="tet",
            epochs=2 if fast else 3,
            n_train=256 if fast else 512,
            n_test=128 if fast else 192,
            batch_size=16, lr=2e-3, width=0.25 if fast else 0.4)
        pr = train_mod.temporal_pruning(cfg, t_de=1,
                                        eval_timesteps=(6, 1),
                                        verbose=False)
        row = {
            "model": model, "dataset": dataset,
            "acc_T6": pr.base.test_acc,
            "acc_T1_direct": pr.reduced_acc[1],
            "acc_T1_finetuned": pr.finetuned.test_acc,
        }
        rows.append(row)
        print(f"{model:<14} {dataset:<16} "
              f"T6 {row['acc_T6']:.3f} | T1 direct "
              f"{row['acc_T1_direct']:.3f} | T1 fine-tuned "
              f"{row['acc_T1_finetuned']:.3f}")
    print("\npaper rows (real CIFAR10): VGG16 93.76 @T1, ResNet19 93.74 "
          "@T1 (synthetic-data absolute numbers are not comparable; the "
          "claim is T1-finetuned ~ T6 baseline)")
    record("table2", {"rows": rows,
                      "paper": {"VGG16/CIFAR10": 93.76,
                                "ResNet19/CIFAR10": 93.74},
                      "claim": "fine-tuned T=1 accuracy approaches the "
                               "T=6 baseline"})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("experiment",
                    choices=["fig2", "fig3", "fig4", "table2", "all"])
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    fns = {
        "fig2": lambda: fig2(args.fast),
        "fig3": fig3,
        "fig4": lambda: fig4(args.fast),
        "table2": lambda: table2(args.fast),
    }
    if args.experiment == "all":
        for f in fns.values():
            f()
    else:
        fns[args.experiment]()


if __name__ == "__main__":
    main()
