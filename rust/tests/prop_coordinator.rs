//! Property-based tests on coordinator invariants.
//!
//! proptest is not vendored in this offline environment, so this file
//! implements the same discipline by hand: each property runs across
//! many PRNG-generated cases (seeded, deterministic) and asserts an
//! invariant; on failure the seed is printed for reproduction.

use sti_snn::arch::{ConvLayer, ConvMode, NetBuilder, NetworkSpec};
use sti_snn::codec::{EventCodec, SpikeFrame, SpikeVector};
use sti_snn::coordinator::batch::{Batcher, Request};
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::coordinator::scheduler;
use sti_snn::dataflow::{conv_latency, ConvLatencyParams};
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::fifo::Fifo;
use sti_snn::util::rng::Rng;

const CASES: u64 = 40;

/// Random small network with valid geometry.
fn random_net(rng: &mut Rng) -> NetworkSpec {
    let h = 8 + 4 * rng.below(3); // 8, 12, 16
    let c_in = 1 + rng.below(3);
    let mut b = NetBuilder::new("prop", (h, h, c_in))
        .encoder(2 + rng.below(6), 3)
        .conv(2 + rng.below(8), 3); // >= 1 accelerated conv, always
    let layers = rng.below(3);
    let mut cur_h = h;
    for _ in 0..layers {
        match rng.below(3) {
            0 => b = b.conv(2 + rng.below(8), 3),
            1 => {
                b = b.dwconv(3);
                b = b.pwconv(2 + rng.below(8));
            }
            _ => {
                if cur_h >= 4 && cur_h % 2 == 0 {
                    b = b.pool();
                    cur_h /= 2;
                } else {
                    b = b.conv(2 + rng.below(8), 3);
                }
            }
        }
    }
    b.fc(10).build()
}

/// Codec roundtrip: encode/decode is the identity for arbitrary frames.
#[test]
fn prop_codec_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let (h, w, c) = (1 + rng.below(20), 1 + rng.below(20),
                         1 + rng.below(100));
        let rate = rng.f64();
        let f = SpikeFrame::random(h, w, c, rate, &mut rng);
        let codec = EventCodec::new(h, w, c);
        let (events, stats) = codec.encode(&f);
        assert_eq!(codec.decode(&events), f, "seed={seed}");
        // Event count == non-empty pixels; encoded bits formula.
        assert_eq!(stats.encoded_bits,
                   events.len() as u64 * codec.bits_per_event(),
                   "seed={seed}");
    }
}

/// Spike vector algebra: OR is commutative/idempotent; popcount is the
/// sum of active bit iteration.
#[test]
fn prop_spike_vector_algebra() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let c = 1 + rng.below(200);
        let bits_a: Vec<bool> = (0..c).map(|_| rng.bernoulli(0.3)).collect();
        let bits_b: Vec<bool> = (0..c).map(|_| rng.bernoulli(0.3)).collect();
        let a = SpikeVector::from_bits(&bits_a);
        let b = SpikeVector::from_bits(&bits_b);
        assert_eq!(a.or(&b), b.or(&a), "seed={seed}");
        assert_eq!(a.or(&a), a, "seed={seed}");
        assert_eq!(a.iter_active().count(), a.popcount(), "seed={seed}");
        // OR popcount bounds.
        let o = a.or(&b);
        assert!(o.popcount() >= a.popcount().max(b.popcount()));
        assert!(o.popcount() <= a.popcount() + b.popcount());
    }
}

/// FIFO: pop order equals push order; occupancy never exceeds capacity.
#[test]
fn prop_fifo_order_and_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let cap = 1 + rng.below(16);
        let mut f = Fifo::new(cap);
        let mut model: std::collections::VecDeque<u64> =
            Default::default();
        for _ in 0..200 {
            if rng.bernoulli(0.6) {
                let v = rng.next_u64();
                if f.push(v).is_ok() {
                    model.push_back(v);
                }
            } else {
                assert_eq!(f.pop(), model.pop_front(), "seed={seed}");
            }
            assert!(f.len() <= cap, "seed={seed}");
            assert_eq!(f.len(), model.len(), "seed={seed}");
        }
    }
}

/// Batcher: never returns more than max_batch; preserves FIFO order;
/// drains completely.
#[test]
fn prop_batcher_invariants() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let max_batch = 1 + rng.below(8);
        let b = Batcher::new(max_batch,
                             std::time::Duration::from_millis(1));
        let n = rng.below(40);
        for i in 0..n {
            b.push(Request {
                id: i as u64,
                frame: SpikeFrame::zeros(2, 2, 1),
                enqueued_at: std::time::Instant::now(),
            });
        }
        let mut seen = Vec::new();
        loop {
            let batch = b.try_batch();
            if batch.is_empty() {
                break;
            }
            assert!(batch.len() <= max_batch, "seed={seed}");
            seen.extend(batch.iter().map(|r| r.id));
        }
        let expect: Vec<u64> = (0..n as u64).collect();
        assert_eq!(seen, expect, "seed={seed}");
    }
}

/// Scheduler: never exceeds the PE budget; t_max monotonically
/// non-increasing in budget; factors are powers of two within Co.
#[test]
fn prop_scheduler_budget_and_monotonicity() {
    let timing = ConvLatencyParams::optimized();
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let net = random_net(&mut rng);
        let min_pes: usize =
            net.accel_convs().iter().map(|c| c.kh * c.kw).sum();
        let mut last_tmax = u64::MAX;
        for mult in [1usize, 2, 4, 8] {
            let budget = min_pes * mult;
            let choice = scheduler::optimize_factors(&net, budget, &timing);
            assert!(choice.pes <= budget, "seed={seed}");
            assert!(choice.t_max <= last_tmax, "seed={seed}");
            last_tmax = choice.t_max;
            for (c, f) in net.accel_convs().iter().zip(&choice.factors) {
                assert!(f.is_power_of_two(), "seed={seed}");
                assert!(*f <= c.co.max(1), "seed={seed}");
            }
        }
    }
}

/// Engine/model agreement on random standard-conv layers: cycle count
/// within 5% of Eq. (12) for any geometry and parallel factor.
#[test]
fn prop_engine_matches_eq12_on_random_layers() {
    for seed in 0..20 {
        let mut rng = Rng::new(5000 + seed);
        let l = ConvLayer {
            mode: ConvMode::Standard,
            in_h: 6 + rng.below(8),
            in_w: 6 + rng.below(8),
            ci: 1 + rng.below(8),
            co: 1 + rng.below(12),
            kh: 3,
            kw: 3,
            pad: 1,
            encoder: false,
            parallel: 1 << rng.below(3),
        };
        let analytical = conv_latency(&l, &ConvLatencyParams::optimized());
        let input =
            SpikeFrame::random(l.in_h, l.in_w, l.ci, 0.3, &mut rng);
        let w = ConvWeights::random(&l, seed);
        let mut eng =
            ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (_, rep) = eng.run_frame(&input, true);
        let err = (rep.cycles as f64 - analytical as f64).abs()
            / analytical.max(1) as f64;
        assert!(err < 0.05, "seed={seed} engine {} model {analytical}",
                rep.cycles);
    }
}

/// Whole-pipeline functional determinism: same seed -> same predictions
/// regardless of batch split.
#[test]
fn prop_pipeline_batch_split_invariance() {
    for seed in 0..10 {
        let mut rng = Rng::new(6000 + seed);
        let net = random_net(&mut rng);
        let mut pipe =
            Pipeline::random(net.clone(), PipelineConfig::default())
                .unwrap();
        let shape = pipe.input_shape();
        let mut frng = Rng::new(7000 + seed);
        let frames: Vec<SpikeFrame> = (0..4)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.3,
                                        &mut frng))
            .collect();
        let all = pipe.run(&frames).predictions;
        // Re-run frame by frame on a fresh pipeline.
        let mut pipe2 =
            Pipeline::random(net, PipelineConfig::default()).unwrap();
        let mut split = Vec::new();
        for f in &frames {
            split.extend(pipe2.run(std::slice::from_ref(f)).predictions);
        }
        assert_eq!(all, split, "seed={seed}");
    }
}

/// OR-pooling engine: monotone (adding spikes never removes output
/// spikes).
#[test]
fn prop_pooling_monotone() {
    use sti_snn::sim::pool_engine::PoolEngine;
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let (h, w, c) = (2 + 2 * rng.below(6), 2 + 2 * rng.below(6),
                         1 + rng.below(8));
        let f1 = SpikeFrame::random(h, w, c, 0.2, &mut rng);
        // f2 = f1 plus extra spikes.
        let extra = SpikeFrame::random(h, w, c, 0.2, &mut rng);
        let mut f2 = f1.clone();
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if extra.get(y, x, ch) {
                        f2.set(y, x, ch);
                    }
                }
            }
        }
        let mut eng = PoolEngine::new(h, w, c);
        let (o1, _) = eng.run(&f1);
        let (o2, _) = eng.run(&f2);
        for y in 0..h / 2 {
            for x in 0..w / 2 {
                for ch in 0..c {
                    assert!(!o1.get(y, x, ch) || o2.get(y, x, ch),
                            "seed={seed}");
                }
            }
        }
    }
}
