//! End-to-end events-mode serving: client -> binary wire protocol ->
//! `EventStream` windowing -> pipeline -> logits, over a real TCP
//! socket through `Session::serve`.
//!
//! The dense JSON protocol and the events protocol share one port and
//! one backend; a window streamed as events must classify exactly like
//! the same frame sent densely.

use std::time::Duration;

use sti_snn::codec::stream::{frame_events, DvsEvent, WindowPolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::server::{Client, EventReply};
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

const WINDOW_US: u32 = 1000;

fn frames(shape: (usize, usize, usize), n: usize, seed: u64)
          -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.15,
                                    &mut rng))
        .collect()
}

/// Frame i's events at timestamp i*WINDOW_US (frame == window).
fn events_of(fs: &[SpikeFrame]) -> Vec<DvsEvent> {
    fs.iter()
        .enumerate()
        .flat_map(|(i, f)| frame_events(f, i as u32 * WINDOW_US))
        .collect()
}

#[test]
fn events_mode_classifies_like_dense_over_tcp() {
    // Reference results from a local session with the same recipe.
    let build = || {
        Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .queue(4, Duration::from_millis(2))
            .build()
            .unwrap()
    };
    let mut reference = build();
    let shape = reference.input_shape();
    let fs = frames(shape, 3, 42);
    let want: Vec<(usize, Vec<f32>)> = fs
        .iter()
        .map(|f| {
            let inf = reference.infer(f.clone()).unwrap();
            (inf.class, inf.logits)
        })
        .collect();

    // Serve an identical session over TCP.
    let server_session = build();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server_session.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
    });
    let addr = rx.recv().unwrap().to_string();

    // Dense JSON request first: the two protocols share the port.
    // Scoped so the client's connection thread exits before the
    // server's shutdown join.
    {
        let mut dense = Client::connect(&addr).unwrap();
        let resp = dense.infer(1, &fs[0].to_f32()).unwrap();
        assert_eq!(resp.get("class").unwrap().as_usize(),
                   Some(want[0].0), "dense protocol baseline");
    }

    // Events mode: handshake, stream, collect.
    let mut c = Client::connect(&addr).unwrap();
    let got_shape = c
        .start_events(WindowPolicy::TimeUs(WINDOW_US))
        .unwrap();
    assert_eq!(got_shape, shape, "handshake reports the frame shape");
    let events = events_of(&fs);
    // Split the stream across batches mid-window to exercise framing.
    let cut = events.len() / 3 + 1;
    c.send_events(&events[..cut]).unwrap();
    c.send_events(&events[cut..]).unwrap();
    let (replies, summary) = c.finish_events().unwrap();

    assert_eq!(summary.windows, fs.len() as u64);
    assert_eq!(summary.served, fs.len() as u64);
    assert_eq!(summary.shed, 0);
    assert_eq!(summary.events, events.len() as u64);

    let got: Vec<(u32, usize, Vec<f32>)> = replies
        .into_iter()
        .map(|r| match r {
            EventReply::Window { window_id, class, logits, .. } => {
                (window_id, class, logits)
            }
            other => panic!("unexpected reply {other:?}"),
        })
        .collect();
    assert_eq!(got.len(), fs.len());
    for (i, (wid, class, logits)) in got.iter().enumerate() {
        assert_eq!(*wid, i as u32, "window order preserved");
        assert_eq!(*class, want[i].0, "window {i}: class == dense");
        assert_eq!(*logits, want[i].1, "window {i}: logits == dense");
    }

    let mut c = Client::connect(&addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}

/// The replica-pool server path speaks events too (N > 1 replicas
/// behind one port), and results stay identical to a single pipeline.
#[test]
fn events_mode_through_replica_pool() {
    let mut reference = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .build()
        .unwrap();
    let shape = reference.input_shape();
    let fs = frames(shape, 4, 7);
    let want: Vec<usize> = fs
        .iter()
        .map(|f| reference.infer(f.clone()).unwrap().class)
        .collect();

    let server_session = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .replicas(2)
        .queue(2, Duration::from_millis(2))
        .build()
        .unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server_session.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
    });
    let addr = rx.recv().unwrap().to_string();

    let mut c = Client::connect(&addr).unwrap();
    c.start_events(WindowPolicy::TimeUs(WINDOW_US)).unwrap();
    c.send_events(&events_of(&fs)).unwrap();
    let (replies, summary) = c.finish_events().unwrap();
    assert_eq!(summary.served, fs.len() as u64);
    assert_eq!(summary.shed, 0);
    let got: Vec<usize> = replies
        .iter()
        .map(|r| match r {
            EventReply::Window { class, .. } => *class,
            other => panic!("unexpected reply {other:?}"),
        })
        .collect();
    assert_eq!(got, want, "pool replicas answer like one pipeline");

    let mut c = Client::connect(&addr).unwrap();
    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}
