//! Events == dense equivalence for the streaming ingestion path.
//!
//! The paper's pipeline consumes the compressed & sorted spike
//! representation; `codec::stream` builds it straight from sorted
//! address events. These tests pin the contract that makes the
//! event-driven serving path trustworthy: windows ingested event by
//! event are **bit-identical** to the dense `SpikeFrame`s they encode,
//! and therefore produce bit-identical spikes/logits and identical
//! cycle / access / energy reports — for both compute backends, on a
//! standard-conv net (scnn3) and the depthwise-separable vMobileNet.

use sti_snn::arch;
use sti_snn::codec::stream::{frame_events, DvsEvent, EventStream,
                             WindowPolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::session::{Report, Session};
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

const WINDOW_US: u32 = 1000;

fn dense_frames(shape: (usize, usize, usize), n: usize, seed: u64)
                -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.15,
                                    &mut rng))
        .collect()
}

/// Decompose dense frames into a sorted event stream: frame `i`'s
/// events live in `[i*WINDOW_US, (i+1)*WINDOW_US)` with jittered
/// timestamps (first event pinned to the window base so time-policy
/// streaming reproduces the frame boundaries exactly).
fn jittered_events(frames: &[SpikeFrame], seed: u64) -> Vec<DvsEvent> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for (i, f) in frames.iter().enumerate() {
        let base = i as u32 * WINDOW_US;
        let mut evs = frame_events(f, base);
        for e in evs.iter_mut() {
            e.t = base + rng.below(WINDOW_US as usize) as u32;
        }
        evs.sort_by_key(|e| e.t);
        if let Some(first) = evs.first_mut() {
            first.t = base;
        }
        out.extend(evs);
    }
    out
}

/// Stream events through an `EventStream` and collect the windows.
fn windows_of(events: &[DvsEvent], shape: (usize, usize, usize))
              -> Vec<SpikeFrame> {
    let mut s = EventStream::new(shape.0, shape.1, shape.2,
                                 WindowPolicy::TimeUs(WINDOW_US))
        .unwrap();
    let mut out = Vec::new();
    for e in events {
        if s.push(*e).unwrap() {
            out.push(s.window().clone());
        }
    }
    if let Some(f) = s.flush() {
        out.push(f.clone());
    }
    out
}

fn session_for(net: arch::NetworkSpec, backend: BackendKind) -> Session {
    Session::builder()
        .network(net)
        .backend(backend)
        .build()
        .unwrap()
}

/// Every architectural number the dense path reports, the events path
/// must reproduce exactly.
fn assert_reports_identical(dense: &Report, events: &Report,
                            ctx: &str) {
    assert_eq!(dense.predictions, events.predictions, "{ctx}: class");
    assert_eq!(dense.logits, events.logits, "{ctx}: logits");
    assert_eq!(dense.layer_cycles, events.layer_cycles,
               "{ctx}: layer cycles");
    assert_eq!(dense.t_max, events.t_max, "{ctx}: t_max");
    assert_eq!(dense.t_sum, events.t_sum, "{ctx}: t_sum");
    assert_eq!(dense.total_cycles, events.total_cycles,
               "{ctx}: total cycles");
    assert_eq!(dense.ops_per_frame, events.ops_per_frame, "{ctx}: ops");
    assert_eq!(dense.counters, events.counters, "{ctx}: access counters");
    assert_eq!(dense.layer_energy, events.layer_energy, "{ctx}: energy");
    assert_eq!(dense.codec_ratios, events.codec_ratios,
               "{ctx}: codec ratios");
    assert_eq!(dense.energy_per_frame_j, events.energy_per_frame_j,
               "{ctx}: energy/frame");
}

/// The core property: streaming-ingested windows are bit-identical to
/// the dense frames they encode, and the full pipeline report (spikes,
/// logits, cycles, traffic, energy) is identical through either path —
/// both backends x standard/DSC nets.
#[test]
fn event_windows_match_dense_path_bit_exact() {
    for (name, net_fn) in [
        ("scnn3", arch::scnn3 as fn() -> arch::NetworkSpec),
        ("vmobilenet", arch::vmobilenet as fn() -> arch::NetworkSpec),
    ] {
        for backend in [BackendKind::Accurate, BackendKind::WordParallel]
        {
            let ctx = format!("{name}/{backend}");
            let mut dense_sess = session_for(net_fn(), backend);
            let shape = dense_sess.input_shape();
            let frames = dense_frames(shape, 2, 0xD15);
            let events = jittered_events(&frames, 0xA5);

            // 1. Windowing fidelity: the streamed windows ARE the
            //    dense frames, bit for bit.
            let windows = windows_of(&events, shape);
            assert_eq!(windows.len(), frames.len(), "{ctx}: windows");
            for (w, f) in windows.iter().zip(&frames) {
                assert_eq!(w, f, "{ctx}: window bits");
            }

            // 2. Report equivalence end to end: same architectural
            //    numbers whether frames arrived dense or as events.
            let dense_rep = dense_sess.infer_batch(&frames);
            let mut event_sess = session_for(net_fn(), backend);
            let event_rep = event_sess.infer_batch(&windows);
            assert_reports_identical(&dense_rep, &event_rep, &ctx);

            // 3. The session-level API agrees with the manual stream.
            let mut api_sess = session_for(net_fn(), backend);
            let out = api_sess
                .infer_events(&events, WindowPolicy::TimeUs(WINDOW_US))
                .unwrap();
            assert_eq!(out.stats.windows, frames.len() as u64, "{ctx}");
            assert_eq!(out.stats.events, events.len() as u64, "{ctx}");
            let api_classes: Vec<usize> =
                out.windows.iter().map(|i| i.class).collect();
            assert_eq!(api_classes, dense_rep.predictions,
                       "{ctx}: infer_events classes");
            for (inf, logits) in out.windows.iter()
                .zip(&dense_rep.logits)
            {
                assert_eq!(&inf.logits, logits,
                           "{ctx}: infer_events logits");
            }
        }
    }
}

/// Count-policy windowing also reproduces frames exactly when the
/// count matches each frame's event count (per-frame flush semantics).
#[test]
fn count_policy_reproduces_frames() {
    let net = arch::scnn3();
    let mut sess = session_for(net, BackendKind::WordParallel);
    let shape = sess.input_shape();
    let frames = dense_frames(shape, 1, 0xC0);
    let events = frame_events(&frames[0], 0);
    let mut s = EventStream::new(shape.0, shape.1, shape.2,
                                 WindowPolicy::Count(events.len()))
        .unwrap();
    let mut done = false;
    for e in &events {
        done = s.push(*e).unwrap();
    }
    assert!(done);
    assert_eq!(*s.window(), frames[0]);
    let dense = sess.infer(frames[0].clone()).unwrap();
    let via_events = sess.infer(s.window().clone()).unwrap();
    assert_eq!(dense.class, via_events.class);
    assert_eq!(dense.logits, via_events.logits);
}
