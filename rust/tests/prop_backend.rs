//! Property tests: the `word-parallel` and `sparse` compute backends
//! are bit-exact against the `accurate` event walk — identical output
//! spike frames AND identical run reports (cycles, ops, spike counts,
//! memory traffic) — across random layer geometries, conv modes,
//! parallel factors, timestep counts, and sparsity levels. Sparse
//! appendices: occupancy skipping on == off, and the weight-stationary
//! `field_psums_batch` == sequential `field_psums` calls.
//!
//! proptest is not vendored; same hand-rolled discipline as
//! `prop_coordinator.rs`: seeded PRNG cases, seed printed on failure.

use sti_snn::arch::{ConvLayer, ConvMode};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::sim::backend::{sparse_conv_backend, ConvCompute};
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::fc_engine::FcEngine;
use sti_snn::sim::linebuf::LineBuffer;
use sti_snn::sim::pe::Acc;
use sti_snn::sim::{AccessCounter, BackendKind};
use sti_snn::util::rng::Rng;

const CASES: u64 = 30;

/// Random conv layer: all three modes, channel counts crossing the
/// 64-bit word boundary, kernel sizes 1/3/5, odd geometries.
fn random_layer(rng: &mut Rng) -> ConvLayer {
    let mode = match rng.below(3) {
        0 => ConvMode::Standard,
        1 => ConvMode::Depthwise,
        _ => ConvMode::Pointwise,
    };
    let k = match mode {
        ConvMode::Pointwise => 1,
        _ => 1 + 2 * rng.range(1, 2), // 3 or 5
    };
    // Channel counts: bias toward word-boundary-straddling values.
    let ci = match rng.below(4) {
        0 => 1 + rng.below(8),
        1 => 60 + rng.below(10), // straddles 64
        2 => 64,
        _ => 65 + rng.below(80),
    };
    let co = match mode {
        ConvMode::Depthwise => ci,
        _ => 1 + rng.below(12),
    };
    ConvLayer {
        mode,
        in_h: k + rng.below(8),
        in_w: k + rng.below(8),
        ci,
        co,
        kh: k,
        kw: k,
        pad: k / 2,
        encoder: false,
        parallel: 1 << rng.below(3),
    }
}

#[test]
fn prop_conv_backends_identical_frames_and_reports() {
    for seed in 0..CASES {
        let mut rng = Rng::new(9000 + seed);
        let l = random_layer(&mut rng);
        let w = ConvWeights::random(&l, 100 + seed);
        let rate = [0.02, 0.1, 0.25, 0.5, 0.9][rng.below(5)];
        let input =
            SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, &mut rng);
        let timesteps = 1 + rng.below(2); // 1 or 2 (vmem path)
        let timing = if rng.bernoulli(0.5) {
            ConvLatencyParams::optimized()
        } else {
            ConvLatencyParams::baseline()
        };

        let mut acc = ConvEngine::with_backend(
            l.clone(), w.clone(), timing, timesteps,
            BackendKind::Accurate);
        let mut wp = ConvEngine::with_backend(
            l.clone(), w.clone(), timing, timesteps,
            BackendKind::WordParallel);
        let mut sp = ConvEngine::with_backend(
            l.clone(), w, timing, timesteps, BackendKind::Sparse);

        let (frame_a, rep_a) = acc.run_frame(&input, true);
        let (frame_w, rep_w) = wp.run_frame(&input, true);
        let (frame_s, rep_s) = sp.run_frame(&input, true);
        assert_eq!(frame_a, frame_w,
                   "seed={seed} {:?} ci={} co={} k={} p={} rate={rate} \
                    t={timesteps}: frames diverge",
                   l.mode, l.ci, l.co, l.kh, l.parallel);
        assert_eq!(rep_a, rep_w,
                   "seed={seed} {:?} ci={} co={}: reports diverge",
                   l.mode, l.ci, l.co);
        assert_eq!(frame_a, frame_s,
                   "seed={seed} {:?} ci={} co={} k={} p={} rate={rate} \
                    t={timesteps}: sparse frames diverge",
                   l.mode, l.ci, l.co, l.kh, l.parallel);
        assert_eq!(rep_a, rep_s,
                   "seed={seed} {:?} ci={} co={}: sparse reports diverge",
                   l.mode, l.ci, l.co);
    }
}

/// The incremental sliding-window protocol (`begin_row` + `advance`)
/// is bit-exact — frames AND full reports — against the `begin_field`
/// full-repack fallback, across random geometries, both backends, and
/// intra-frame band counts {1, 2, 4}.
#[test]
fn prop_incremental_window_matches_fallback_across_bands() {
    for seed in 0..CASES {
        let mut rng = Rng::new(11_000 + seed);
        let l = random_layer(&mut rng);
        let w = ConvWeights::random(&l, 500 + seed);
        let rate = [0.05, 0.2, 0.5][rng.below(3)];
        let input =
            SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, &mut rng);
        let timesteps = 1 + rng.below(2);
        let timing = ConvLatencyParams::optimized();
        for backend in [BackendKind::Accurate, BackendKind::WordParallel,
                        BackendKind::Sparse] {
            let mut fallback = ConvEngine::with_backend(
                l.clone(), w.clone(), timing, timesteps, backend)
                .with_incremental(false);
            let (frame_f, rep_f) = fallback.run_frame(&input, true);
            for bands in [1usize, 2, 4] {
                let mut inc = ConvEngine::with_backend(
                    l.clone(), w.clone(), timing, timesteps, backend)
                    .with_intra_parallel(bands);
                let (frame_i, rep_i) = inc.run_frame(&input, true);
                assert_eq!(frame_i, frame_f,
                           "seed={seed} {:?} ci={} co={} k={} \
                            backend={backend} bands={bands}: frames",
                           l.mode, l.ci, l.co, l.kh);
                assert_eq!(rep_i, rep_f,
                           "seed={seed} {:?} ci={} co={} \
                            backend={backend} bands={bands}: reports",
                           l.mode, l.ci, l.co);
            }
        }
    }
}

#[test]
fn prop_fc_backends_identical_logits_and_reports() {
    for seed in 0..CASES {
        let mut rng = Rng::new(10_000 + seed);
        let n_in = 1 + rng.below(400);
        let n_out = 1 + rng.below(16);
        let mut acc = FcEngine::random(n_in, n_out, 200 + seed);
        let mut wp = FcEngine::random(n_in, n_out, 200 + seed)
            .with_backend(BackendKind::WordParallel);
        let mut sp = FcEngine::random(n_in, n_out, 200 + seed)
            .with_backend(BackendKind::Sparse);
        assert_eq!(wp.backend_kind(), BackendKind::WordParallel);
        assert_eq!(sp.backend_kind(), BackendKind::Sparse);
        let rate = rng.f64();
        let spikes: Vec<bool> =
            (0..n_in).map(|_| rng.bernoulli(rate)).collect();
        let (logits_a, rep_a) = acc.run(&spikes);
        let (logits_w, rep_w) = wp.run(&spikes);
        let (logits_s, rep_s) = sp.run(&spikes);
        assert_eq!(logits_a, logits_w, "seed={seed} n_in={n_in}");
        assert_eq!(rep_a, rep_w, "seed={seed} n_in={n_in}");
        assert_eq!(logits_a, logits_s, "seed={seed} n_in={n_in} sparse");
        assert_eq!(rep_a, rep_s, "seed={seed} n_in={n_in} sparse");
    }
}

/// Whole-pipeline equivalence on the deployed model geometries:
/// predictions, logits, cycle totals, per-layer cycles, energy inputs
/// (ops) and traffic all identical, so Table IV / Fig. 11 artifacts are
/// backend-independent.
#[test]
fn deployed_models_are_backend_invariant() {
    use sti_snn::arch;
    for (net, rate) in [(arch::scnn3(), 0.2), (arch::vmobilenet(), 0.3)] {
        let shape_seed = 77;
        let mut acc = Pipeline::random(net.clone(),
                                       PipelineConfig::default()).unwrap();
        let mut wp = Pipeline::random(
            net.clone(),
            PipelineConfig {
                backend: BackendKind::WordParallel,
                ..Default::default()
            },
        )
        .unwrap();
        let mut sp = Pipeline::random(
            net.clone(),
            PipelineConfig {
                backend: BackendKind::Sparse,
                ..Default::default()
            },
        )
        .unwrap();
        let shape = acc.input_shape();
        let mut rng = Rng::new(shape_seed);
        let frames: Vec<SpikeFrame> = (0..2)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, rate,
                                        &mut rng))
            .collect();
        let ra = acc.run(&frames);
        for rep in [wp.run(&frames), sp.run(&frames)] {
            assert_eq!(ra.predictions, rep.predictions, "{}", net.name);
            assert_eq!(ra.logits, rep.logits, "{}", net.name);
            assert_eq!(ra.total_cycles, rep.total_cycles, "{}", net.name);
            assert_eq!(ra.layer_cycles, rep.layer_cycles, "{}", net.name);
            assert_eq!(ra.ops_per_frame, rep.ops_per_frame, "{}",
                       net.name);
            assert_eq!(ra.counters, rep.counters, "{}", net.name);
            assert_eq!(ra.layer_energy, rep.layer_energy, "{}", net.name);
        }
    }
}

/// The streamed inter-layer schedule is itself backend-invariant AND
/// bit-identical to the serial layer loop on a deployed geometry:
/// per-layer cycles, traffic, energy, predictions and logits all
/// match; only the batch total differs (Eq. (10) vs N x t_sum).
#[test]
fn deployed_model_streamed_schedule_is_bit_exact_vs_serial() {
    use sti_snn::arch;
    let net = arch::scnn3();
    for backend in [BackendKind::Accurate, BackendKind::WordParallel,
                    BackendKind::Sparse] {
        let mut serial = Pipeline::random(
            net.clone(),
            PipelineConfig {
                pipelined: false,
                backend,
                ..Default::default()
            },
        )
        .unwrap();
        let mut streamed = Pipeline::random(
            net.clone(),
            PipelineConfig {
                pipelined: true,
                channel_capacity: 2,
                backend,
                ..Default::default()
            },
        )
        .unwrap();
        let shape = serial.input_shape();
        let mut rng = Rng::new(77);
        let frames: Vec<SpikeFrame> = (0..3)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                        &mut rng))
            .collect();
        let rs = serial.run(&frames);
        let rp = streamed.run(&frames);
        assert_eq!(rp.predictions, rs.predictions, "{backend}");
        assert_eq!(rp.logits, rs.logits, "{backend}");
        assert_eq!(rp.layer_cycles, rs.layer_cycles, "{backend}");
        assert_eq!(rp.t_max, rs.t_max, "{backend}");
        assert_eq!(rp.t_sum, rs.t_sum, "{backend}");
        assert_eq!(rp.ops_per_frame, rs.ops_per_frame, "{backend}");
        assert_eq!(rp.counters, rs.counters, "{backend}");
        assert_eq!(rp.layer_energy, rs.layer_energy, "{backend}");
        assert_eq!(rp.codec_ratios, rs.codec_ratios, "{backend}");
        let n = frames.len() as u64;
        assert_eq!(rs.total_cycles, n * rs.t_sum, "{backend}");
        assert_eq!(rp.total_cycles,
                   n * rp.t_max + (rp.t_sum - rp.t_max), "{backend}");
    }
}

/// Drive a sparse backend through the full incremental protocol over a
/// primed line buffer, exactly as the engine does. Returns per-field
/// psums `[oy][ox][co]` flattened.
fn drive_sparse(backend: &mut Box<dyn ConvCompute>, l: &ConvLayer,
                w: &ConvWeights, input: &SpikeFrame)
                -> Vec<(Acc, u64)> {
    let (ho, wo) = (l.out_h(), l.out_w());
    let mut lb = LineBuffer::new(l.kh, l.in_w + 2 * l.pad, l.ci);
    let mut counters = AccessCounter::new();
    let mut psums = vec![(0, 0); l.co];
    let mut all = Vec::with_capacity(ho * wo * l.co);
    lb.reset();
    for py in 0..l.kh {
        lb.ingest_row(input, py as isize, l.pad, &mut counters, false,
                      true);
    }
    for oy in 0..ho {
        if oy > 0 {
            lb.ingest_row(input, (oy + l.kh - 1) as isize, l.pad,
                          &mut counters, false, true);
        }
        backend.begin_row();
        for ox in 0..wo {
            backend.advance(&lb, ox);
            backend.field_psums(w, &mut psums);
            all.extend_from_slice(&psums);
        }
    }
    all
}

/// Occupancy skipping only decides which all-zero word groups the
/// plane walk visits: skip-on and skip-off sparse backends are
/// bit-identical (psums AND ops) over the full incremental protocol,
/// including all-zero and single-spike frames.
#[test]
fn prop_sparse_occupancy_skip_on_equals_off() {
    for seed in 0..CASES {
        let mut rng = Rng::new(13_000 + seed);
        let l = random_layer(&mut rng);
        let w = ConvWeights::random(&l, 700 + seed);
        let input = match rng.below(4) {
            0 => SpikeFrame::zeros(l.in_h, l.in_w, l.ci),
            1 => {
                let mut f = SpikeFrame::zeros(l.in_h, l.in_w, l.ci);
                f.set(rng.below(l.in_h), rng.below(l.in_w),
                      rng.below(l.ci));
                f
            }
            _ => {
                let rate = [0.03, 0.2, 0.5][rng.below(3)];
                SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, &mut rng)
            }
        };
        let mut on = sparse_conv_backend(&l, &w, true);
        let mut off = sparse_conv_backend(&l, &w, false);
        assert_eq!(on.kind(), BackendKind::Sparse);
        let a = drive_sparse(&mut on, &l, &w, &input);
        let b = drive_sparse(&mut off, &l, &w, &input);
        assert_eq!(a, b,
                   "seed={seed} {:?} ci={} co={} k={}: skip on != off",
                   l.mode, l.ci, l.co, l.kh);
    }
}

/// `field_psums_batch(N)` over a row of stashed fields equals N
/// sequential `field_psums` calls, bit for bit (the weight-stationary
/// transpose only reorders sums). Depthwise layers must decline the
/// stash (`stash_field` false) — their mask is co-dependent.
#[test]
fn prop_sparse_batch_matches_sequential_psums() {
    for seed in 0..CASES {
        let mut rng = Rng::new(14_000 + seed);
        let l = random_layer(&mut rng);
        let w = ConvWeights::random(&l, 900 + seed);
        let rate = [0.0, 0.05, 0.25, 0.5][rng.below(4)];
        let input =
            SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, &mut rng);
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut backend = sparse_conv_backend(&l, &w, rng.bernoulli(0.5));
        let mut lb = LineBuffer::new(l.kh, l.in_w + 2 * l.pad, l.ci);
        let mut counters = AccessCounter::new();
        let mut seq = vec![(0, 0); wo * l.co];
        let mut batch = vec![(0, 0); wo * l.co];
        lb.reset();
        for py in 0..l.kh {
            lb.ingest_row(&input, py as isize, l.pad, &mut counters,
                          false, true);
        }
        for oy in 0..ho {
            if oy > 0 {
                lb.ingest_row(&input, (oy + l.kh - 1) as isize, l.pad,
                              &mut counters, false, true);
            }
            backend.begin_row();
            let mut stashed = true;
            for ox in 0..wo {
                backend.advance(&lb, ox);
                backend.field_psums(
                    &w, &mut seq[ox * l.co..(ox + 1) * l.co]);
                stashed &= backend.stash_field();
            }
            if l.mode == ConvMode::Depthwise {
                assert!(!stashed, "seed={seed}: depthwise must decline");
                assert_eq!(backend.stashed_fields(), 0);
                continue;
            }
            assert!(stashed, "seed={seed}: packed mode must stash");
            assert_eq!(backend.stashed_fields(), wo, "seed={seed}");
            backend.field_psums_batch(&w, l.co, &mut batch);
            assert_eq!(batch, seq,
                       "seed={seed} {:?} ci={} co={} oy={oy}: \
                        batch != sequential",
                       l.mode, l.ci, l.co);
            assert_eq!(backend.stashed_fields(), 0,
                       "seed={seed}: batch must clear the stash");
        }
    }
}
