//! Property tests for the retune decision policy: hysteresis and
//! cooldown together guarantee the controller cannot flap, no matter
//! how the measured workload alternates.
//!
//! The policy is a pure function of logical time (`Observation` in,
//! `Decision` out — no clocks, no pools), so the no-oscillation claim
//! is checked by simulation: two design points A and B, two workloads
//! under which their measured throughput differs, and a controller
//! loop that swaps whenever the policy says so.

use std::time::Duration;

use sti_snn::autotune::{Decision, Observation, PolicyState,
                        RetunePolicy};
use sti_snn::util::rng::Rng;

/// Throughput of point `p` (0 = A, 1 = B) under workload `w` (0/1).
type FpsTable = [[f64; 2]; 2];

/// Run the controller loop over `ticks` decisions: each tick observes
/// one of the two workloads, compares the serving point against the
/// other, and swaps when the policy allows. Returns the logical swap
/// times (µs).
fn simulate(policy: &RetunePolicy, fps: &FpsTable, ticks: usize,
            tick_us: u64, frames_per_tick: u64, rng: &mut Rng)
            -> Vec<u64> {
    let mut state = PolicyState::default();
    let mut serving = 0usize;
    let mut frames = 0u64;
    let mut swaps = Vec::new();
    for t in 0..ticks {
        let now_us = t as u64 * tick_us;
        frames += frames_per_tick;
        let w = usize::from(rng.bernoulli(0.5));
        let candidate = 1 - serving;
        let obs = Observation {
            now_us,
            frames,
            density_spread: 0.0,
            same_config: false,
            current_fps: fps[serving][w],
            candidate_fps: fps[candidate][w],
        };
        if let Decision::Swap { .. } = policy.decide(&state, &obs) {
            serving = candidate;
            state.record_swap(now_us, frames);
            swaps.push(now_us);
        }
    }
    swaps
}

fn policy(hysteresis: f64, cooldown: Duration) -> RetunePolicy {
    RetunePolicy {
        interval: Duration::from_millis(10),
        min_frames: 8,
        hysteresis,
        cooldown,
        max_density_spread: 0.35,
        headroom: 1.25,
    }
}

/// Workload-dependent winners whose mutual gains stay *inside* the
/// hysteresis margin: the policy must never swap, even with cooldown
/// disabled — hysteresis alone kills the oscillation.
#[test]
fn within_margin_alternation_never_swaps() {
    // A/B winner flips with the workload, but the edge is 100/95
    // (~5.3%) — below the 10% margin in both directions.
    let fps: FpsTable = [[100.0, 95.0], [95.0, 100.0]];
    let p = policy(0.10, Duration::ZERO);
    for seed in 0..32 {
        let mut rng = Rng::new(seed);
        let swaps = simulate(&p, &fps, 10_000, 10_000, 16, &mut rng);
        assert!(swaps.is_empty(),
                "seed {seed}: flapped {} times inside the hysteresis \
                 margin", swaps.len());
    }
}

/// Gains far outside the margin in both directions (the worst-case
/// flap-inducing workload): cooldown bounds the swap rate, and every
/// pair of consecutive swaps is spaced at least one cooldown apart.
#[test]
fn cooldown_spaces_swaps_under_adversarial_alternation() {
    let fps: FpsTable = [[100.0, 50.0], [50.0, 100.0]];
    let cooldown = Duration::from_secs(1);
    let p = policy(0.10, cooldown);
    let tick_us = 10_000; // 10 ms ticks, 10 s simulated
    for seed in 0..32 {
        let mut rng = Rng::new(seed);
        let swaps = simulate(&p, &fps, 1_000, tick_us, 16, &mut rng);
        assert!(!swaps.is_empty(),
                "seed {seed}: a >=100% gain must eventually swap");
        let cd_us = cooldown.as_micros() as u64;
        for pair in swaps.windows(2) {
            assert!(pair[1] - pair[0] >= cd_us,
                    "seed {seed}: swaps {} and {} closer than the \
                     cooldown", pair[0], pair[1]);
        }
        // Rate bound: total simulated time / cooldown, plus the first.
        let horizon_us = 1_000 * tick_us;
        assert!(swaps.len() as u64 <= horizon_us / cd_us + 1,
                "seed {seed}: {} swaps in {horizon_us} us",
                swaps.len());
    }
}

/// The min-frames guard: once traffic stops, no amount of predicted
/// gain produces another swap — the EWMAs are stale.
#[test]
fn stalled_traffic_freezes_retuning() {
    let fps: FpsTable = [[100.0, 50.0], [50.0, 100.0]];
    let p = policy(0.10, Duration::ZERO);
    let mut state = PolicyState::default();
    let mut rng = Rng::new(3);
    // Warm up with traffic until one swap lands.
    let mut frames = 0;
    let mut swapped_at = None;
    for t in 0..1_000u64 {
        frames += 16;
        let w = usize::from(rng.bernoulli(0.5));
        let obs = Observation {
            now_us: t * 10_000,
            frames,
            density_spread: 0.0,
            same_config: false,
            current_fps: fps[0][w],
            candidate_fps: fps[1][w],
        };
        if let Decision::Swap { .. } = p.decide(&state, &obs) {
            state.record_swap(t * 10_000, frames);
            swapped_at = Some(t);
            break;
        }
    }
    let start = swapped_at.expect("warm-up must swap once") + 1;
    // Traffic stalls: frames never advance past the swap point.
    for t in start..start + 10_000 {
        let obs = Observation {
            now_us: t * 10_000,
            frames,
            density_spread: 0.0,
            same_config: false,
            current_fps: 50.0,
            candidate_fps: 1e9,
        };
        assert!(matches!(p.decide(&state, &obs), Decision::Hold(_)),
                "stalled traffic at tick {t} must hold");
    }
}
