//! Chaos suite (ISSUE 9 acceptance): deterministic fault-injection
//! sweeps over the serving stack, asserting the supervision
//! invariants end to end:
//!
//! 1. **Zero hangs** — under any seeded [`FaultPlan`], every
//!    submitted frame is answered or errored within a bounded wait;
//!    nothing blocks forever.
//! 2. **Bounded restarts** — replica restart counts respect the
//!    [`RestartPolicy`] budget; exhausting it degrades the pool to
//!    explicit error replies, never silence.
//! 3. **Bit-exact survivors** — frames served around an injected
//!    crash (including by a restarted worker) produce logits
//!    bit-identical to a fault-free reference session.
//! 4. **Transactional retunes** — a replica killed mid-swap (the
//!    health probe panics) triggers a rollback: the pool generation
//!    is unchanged and the rolled-back attempt is logged.
//!
//! The CI `chaos_soak` step sweeps extra seeds in release mode
//! (`STI_SNN_STRESS_ITERS`) and uploads the fault/restart event log
//! written to `STI_SNN_CHAOS_LOG`.

use std::collections::VecDeque;
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use sti_snn::autotune::RetunePolicy;
use sti_snn::codec::SpikeFrame;
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::supervise::{FaultEvent, FaultPlan, RestartPolicy,
                         REPLICA_PROBE};
use sti_snn::util::rng::Rng;

/// Bounded wait for chaos replies: generous for slow CI machines, but
/// finite — a hit means a genuine hang, the one thing the supervision
/// layer must never allow.
const NO_HANG: Duration = Duration::from_secs(60);

/// A restart policy with test-scale backoff (the default 10 ms base is
/// fine too, but the sweep restarts often).
fn fast_restarts() -> RestartPolicy {
    RestartPolicy {
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(8),
        ..RestartPolicy::default()
    }
}

fn test_frames(shape: (usize, usize, usize), n: usize, seed: u64)
               -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                    &mut rng))
        .collect()
}

/// Fault-free reference logits for bit-exactness checks.
fn reference_logits(frames: &[SpikeFrame]) -> Vec<Vec<f32>> {
    let mut s = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .build()
        .unwrap();
    frames
        .iter()
        .map(|f| s.infer(f.clone()).unwrap().logits)
        .collect()
}

/// Append chaos-run evidence to the `STI_SNN_CHAOS_LOG` artifact when
/// CI asks for one (the soak step uploads it).
fn write_chaos_log(lines: &[String]) {
    if let Ok(path) = std::env::var("STI_SNN_CHAOS_LOG") {
        if path.is_empty() {
            return;
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
        {
            for line in lines {
                let _ = writeln!(f, "{line}");
            }
        }
    }
}

/// Invariants 1 + 2 + 3 over a sweep of generated plans: every frame
/// answered-or-errored (zero hangs), restarts within budget, and every
/// successful reply bit-identical to the fault-free reference.
#[test]
fn seeded_fault_sweep_never_hangs() {
    let iters: u64 = std::env::var("STI_SNN_STRESS_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let policy = fast_restarts();
    let mut log = Vec::new();
    for seed in 0..iters {
        let plan = FaultPlan::generate(seed, 2, 8, 3, 6);
        log.push(format!("chaos seed {seed}: plan {}", plan.to_json()));
        let mut s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .replicas(2)
            .queue(4, Duration::from_millis(1))
            .chaos(plan)
            .restart_policy(policy)
            .build()
            .unwrap();
        let frames = test_frames(s.input_shape(), 8, seed ^ 0xF00D);
        let want = reference_logits(&frames);
        s.start_pool().unwrap();
        let rxs: Vec<_> = frames
            .iter()
            .map(|f| s.submit(f.clone()).unwrap())
            .collect();
        let (mut served, mut errored) = (0u64, 0u64);
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv_timeout(NO_HANG) {
                Ok(r) => {
                    if let Some(e) = &r.error {
                        log.push(format!("  frame {i}: error {e}"));
                        errored += 1;
                    } else {
                        assert_eq!(r.logits, want[i],
                                   "seed {seed} frame {i}: survivor \
                                    reply must be bit-identical");
                        served += 1;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // A DropReply fault: the sender is gone, which is
                    // an explicit failure, not a hang.
                    log.push(format!("  frame {i}: reply dropped"));
                    errored += 1;
                }
                Err(RecvTimeoutError::Timeout) => {
                    panic!("seed {seed} frame {i} hung for \
                            {NO_HANG:?} under chaos — supervision \
                            must answer or error every frame");
                }
            }
        }
        assert_eq!(served + errored, 8, "every frame accounted for");
        let snap = s.supervise_stats().snapshot();
        // 2 workers, each restartable at most `max_restarts` times
        // per rolling window.
        let budget = 2 * policy.max_restarts as u64;
        assert!(snap.replica_restarts <= budget,
                "seed {seed}: {} restarts exceed the {budget} budget",
                snap.replica_restarts);
        log.push(format!(
            "  seed {seed}: served {served}, errored {errored}, \
             restarts {}, retired {}, injected {}",
            snap.replica_restarts, snap.replicas_retired,
            s.fault_hooks().unwrap().injected()));
        log.extend(s.fault_hooks().unwrap().log_lines());
        s.shutdown();
    }
    write_chaos_log(&log);
}

/// Invariant 2, exhaustion edge: a replica that keeps panicking runs
/// out of budget, retires, and the pool degrades to *explicit* error
/// replies for queued and future frames — no deadlock, no silence.
#[test]
fn restart_budget_exhaustion_degrades_explicitly() {
    let plan = FaultPlan::new(3, vec![
        FaultEvent::PanicAt { replica: 0, frame: 0 },
        FaultEvent::PanicAt { replica: 0, frame: 1 },
    ]);
    let mut s = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .chaos(plan)
        .restart_policy(RestartPolicy {
            max_restarts: 1,
            window: Duration::from_secs(3600),
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(2),
        })
        .build()
        .unwrap();
    let frames = test_frames(s.input_shape(), 4, 17);
    s.start_pool().unwrap();
    // Serve seq 0 panics (restart #1), seq 1 panics (budget gone →
    // retire); everything after is answered by the bouncer.
    let mut errors = Vec::new();
    for f in &frames {
        match s.infer(f.clone()) {
            Ok(_) => panic!("every frame hits the panicking replica"),
            Err(e) => errors.push(e.to_string()),
        }
    }
    assert!(errors[0].contains("panicked"), "{}", errors[0]);
    assert!(errors[1].contains("panicked"), "{}", errors[1]);
    assert!(errors[2].contains("retired"), "{}", errors[2]);
    assert!(errors[3].contains("retired"), "{}", errors[3]);
    let snap = s.supervise_stats().snapshot();
    assert_eq!(snap.replica_restarts, 1, "budget respected");
    assert_eq!(snap.replicas_retired, 1);
    assert_eq!(s.alive_replicas(), Some(0), "degraded, not deadlocked");
    write_chaos_log(&[format!(
        "exhaustion: restarts {} retired {} errors {:?}",
        snap.replica_restarts, snap.replicas_retired, errors)]);
    s.shutdown();
}

/// Invariant 3, restart edge: the frame a panic kills is errored, and
/// the *restarted* worker (rebuilt from the session recipe) serves
/// every later frame bit-identically to the fault-free reference.
#[test]
fn restarted_replica_serves_bit_identically() {
    let plan = FaultPlan::new(
        11, vec![FaultEvent::PanicAt { replica: 0, frame: 0 }]);
    let mut s = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .chaos(plan)
        .restart_policy(fast_restarts())
        .build()
        .unwrap();
    let frames = test_frames(s.input_shape(), 5, 23);
    let want = reference_logits(&frames);
    s.start_pool().unwrap();
    assert!(s.infer(frames[0].clone()).is_err(),
            "the injected panic surfaces as an explicit error");
    for (f, want) in frames[1..].iter().zip(&want[1..]) {
        let inf = s.infer(f.clone()).unwrap();
        assert_eq!(&inf.logits, want,
                   "post-restart replies must be bit-identical");
    }
    let snap = s.supervise_stats().snapshot();
    assert_eq!(snap.replica_restarts, 1);
    assert_eq!(snap.replicas_retired, 0);
    assert_eq!(s.alive_replicas(), Some(1));
    s.shutdown();
}

/// Invariant 4: a replica killed mid-swap — the candidate's health
/// probe panics — triggers a transactional rollback. The pool
/// generation is unchanged, no retune is counted, the rolled-back
/// attempt is in the event log, and no in-flight frame is lost.
#[test]
fn probe_kill_mid_swap_rolls_back() {
    let plan = FaultPlan::new(
        5, vec![FaultEvent::PanicAt { replica: REPLICA_PROBE,
                                      frame: 0 }]);
    // A deliberately weak boot under a fast-reacting policy (as
    // tests/online_tune.rs) so the first eligible re-plan attempts a
    // swap; the long cooldown keeps the rolled-back attempt the only
    // one the test observes.
    let policy = RetunePolicy {
        interval: Duration::from_millis(50),
        min_frames: 8,
        hysteresis: 0.01,
        cooldown: Duration::from_secs(600),
        max_density_spread: 10.0,
        headroom: 1.25,
    };
    let mut session = Session::builder()
        .model("scnn3")
        .replicas(1)
        .backend(BackendKind::Accurate)
        .queue(4, Duration::from_millis(1))
        .online_tune(policy)
        .chaos(plan)
        .build()
        .unwrap();
    let (h, w, c) = session.input_shape();
    let mut rng = Rng::new(7);
    session.start_pool().unwrap();
    let log = session.retune_log().expect("tuner spawned");
    assert_eq!(session.pool_generation(), Some(0));

    // Live traffic with a density shift until the tuner attempts (and
    // rolls back) a swap.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut pending = VecDeque::new();
    let mut submitted = 0u64;
    while log.rollbacks() == 0 {
        assert!(Instant::now() < deadline,
                "no rollback after 120s: {:?}", log.summary());
        let rate = if submitted < 32 { 0.05 } else { 0.6 };
        for _ in 0..2 {
            let f = SpikeFrame::random(h, w, c, rate, &mut rng);
            pending.push_back(session.submit(f).unwrap());
            submitted += 1;
        }
        while let Some(rx) = pending.front() {
            match rx.try_recv() {
                Ok(r) => {
                    assert!(r.error.is_none(), "{:?}", r.error);
                    pending.pop_front();
                }
                Err(_) => break,
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // The serving generation never moved and no retune was counted.
    assert_eq!(session.pool_generation(), Some(0),
               "rollback must leave the pool generation unchanged");
    assert_eq!(log.retunes(), 0);
    assert_eq!(log.rollbacks(), 1);
    let snap = session.supervise_stats().snapshot();
    assert_eq!(snap.retune_rollbacks, 1);
    let ev = log.events().into_iter().next().expect("attempt logged");
    assert_eq!(ev.outcome, sti_snn::autotune::controller::
               OUTCOME_ROLLED_BACK);
    assert_eq!(ev.generation, 0);

    // Every frame submitted through the aborted swap resolves.
    for rx in pending {
        let r = rx.recv_timeout(NO_HANG)
            .expect("frames in flight across a rollback resolve");
        assert!(r.error.is_none());
    }
    write_chaos_log(&[format!(
        "rollback: from {:?} to {:?} generation {}",
        ev.from, ev.to, ev.generation)]);
    session.shutdown();
}
