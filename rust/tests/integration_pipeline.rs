//! Integration tests: engines x coordinator x analytical models.
//!
//! These cross-check the cycle-level simulator against the paper's
//! closed-form models (Eq. 10-12, Tables I/III) and verify the paper's
//! qualitative claims end-to-end at test-sized geometry.

use sti_snn::arch::{self, NetBuilder};
use sti_snn::codec::{EventCodec, SpikeFrame};
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::coordinator::scheduler;
use sti_snn::dataflow::{self, ConvLatencyParams};
use sti_snn::sim::memory::DataKind;
use sti_snn::sim::EnergyModel;
use sti_snn::util::rng::Rng;

fn frames(shape: (usize, usize, usize), n: usize, rate: f64,
          seed: u64) -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, rate,
                                    &mut rng))
        .collect()
}

fn mini_net() -> arch::NetworkSpec {
    NetBuilder::new("mini", (12, 12, 2))
        .encoder(4, 3)
        .conv(8, 3)
        .pool()
        .conv(8, 3)
        .pool()
        .fc(10)
        .build()
}

/// The engine's cycle count must track Eq. (12) across every conv layer
/// of every deployed model geometry (scaled input).
#[test]
fn engine_cycles_track_eq12_for_all_models() {
    for net in [mini_net(), arch::scnn3()] {
        let model = dataflow::pipeline_latency(
            &net, &ConvLatencyParams::optimized(), 1);
        let mut pipe =
            Pipeline::random(net.clone(), PipelineConfig::default())
                .unwrap();
        let shape = pipe.input_shape();
        let rep = pipe.run(&frames(shape, 1, 0.25, 1));
        let err = (rep.t_max as f64 - model.t_max as f64).abs()
            / model.t_max as f64;
        assert!(err < 0.05, "{}: engine {} vs model {}", net.name,
                rep.t_max, model.t_max);
    }
}

/// Eq. (10): total pipeline cycles for N frames == N*T_max + fill.
#[test]
fn pipeline_total_cycles_follow_eq10() {
    let mut pipe =
        Pipeline::random(mini_net(), PipelineConfig::default()).unwrap();
    let shape = pipe.input_shape();
    for n in [1usize, 3, 7] {
        let rep = pipe.run(&frames(shape, n, 0.25, 2));
        let expect = n as u64 * rep.t_max + (rep.t_sum - rep.t_max);
        assert_eq!(rep.total_cycles, expect, "n={n}");
    }
}

/// Table I claim at the system level: T=1 OS run has ZERO psum/vmem
/// traffic anywhere in the pipeline; T=2 has it.
#[test]
fn t1_eliminates_all_vmem_traffic() {
    let mut p1 =
        Pipeline::random(mini_net(), PipelineConfig::default()).unwrap();
    let shape = p1.input_shape();
    let r1 = p1.run(&frames(shape, 2, 0.3, 3));
    assert_eq!(r1.counters.total_of_kind(DataKind::Vmem), 0);
    assert_eq!(r1.counters.total_of_kind(DataKind::PartialSum), 0);

    let mut p2 = Pipeline::random(
        mini_net(),
        PipelineConfig { timesteps: 2, ..Default::default() },
    )
    .unwrap();
    let r2 = p2.run(&frames(shape, 2, 0.3, 3));
    assert!(r2.counters.total_of_kind(DataKind::Vmem) > 0);
}

/// Fig. 11 energy claim: dynamic energy scales ~linearly in T.
#[test]
fn energy_linear_in_timesteps() {
    let mut p =
        Pipeline::random(mini_net(), PipelineConfig::default()).unwrap();
    let shape = p.input_shape();
    let f = frames(shape, 1, 0.3, 4);
    let mut e = vec![p.run(&f).dynamic_energy_per_frame_j()];
    for t in [2usize, 4] {
        let mut p = Pipeline::random(
            mini_net(),
            PipelineConfig { timesteps: t, ..Default::default() },
        )
        .unwrap();
        e.push(p.run(&f).dynamic_energy_per_frame_j());
    }
    let r21 = e[1] / e[0];
    let r42 = e[2] / e[1];
    assert!((r21 - 2.0).abs() < 0.4, "T2/T1 = {r21}");
    assert!((r42 - 2.0).abs() < 0.4, "T4/T2 = {r42}");
}

/// The scheduler's choice must beat or match every manual profile we
/// try under the same budget.
#[test]
fn scheduler_beats_manual_profiles() {
    let net = arch::scnn3();
    let timing = ConvLatencyParams::optimized();
    let choice = scheduler::optimize_factors(&net, 54, &timing);
    for manual in [[1usize, 1], [2, 1], [2, 2], [4, 2], [1, 4]] {
        let with = arch::scnn3().try_with_parallel_factors(&manual).unwrap();
        let pes = with.total_pes();
        let lat = dataflow::pipeline_latency(&with, &timing, 1);
        if pes <= 54 {
            assert!(choice.t_max <= lat.t_max,
                    "scheduler {} vs manual {manual:?} {}",
                    choice.t_max, lat.t_max);
        }
    }
}

/// Spike-event stream between layers is lossless (codec roundtrip at
/// every inter-layer boundary shape of the deployed models).
#[test]
fn event_stream_lossless_at_all_boundaries() {
    for net in [arch::scnn3(), arch::vmobilenet()] {
        let mut rng = Rng::new(5);
        for layer in &net.layers {
            let (h, w, c) = layer.in_shape();
            if h == 1 {
                continue;
            }
            let f = SpikeFrame::random(h, w, c, 0.2, &mut rng);
            let codec = EventCodec::new(h, w, c);
            let (events, _) = codec.encode(&f);
            assert_eq!(codec.decode(&events), f,
                       "boundary {h}x{w}x{c} of {}", net.name);
        }
    }
}

/// Functional invariance: pipelining mode and parallel factors must not
/// change predictions (only timing).
#[test]
fn timing_knobs_do_not_change_predictions() {
    let f = {
        let p = Pipeline::random(mini_net(), PipelineConfig::default())
            .unwrap();
        frames(p.input_shape(), 3, 0.3, 6)
    };
    let mut preds = Vec::new();
    for (pipelined, factors) in [
        (true, vec![1usize, 1]),
        (false, vec![1, 1]),
        (true, vec![4, 2]),
        (true, vec![8, 8]),
    ] {
        let net = mini_net().try_with_parallel_factors(&factors).unwrap();
        let mut p = Pipeline::random(
            net, PipelineConfig { pipelined, ..Default::default() })
            .unwrap();
        preds.push(p.run(&f).predictions);
    }
    for w in preds.windows(2) {
        assert_eq!(w[0], w[1]);
    }
}

/// Static power model sanity at all three deployed design points.
#[test]
fn power_is_in_paper_band() {
    let m = EnergyModel::default();
    for (pes, bram, paper_w) in [
        (54usize, 11.5, 0.71),
        (99, 527.5, 1.53),
        (40, 13.5, 0.74),
    ] {
        let p = m.static_power(pes, bram);
        assert!((p - paper_w).abs() / paper_w < 0.35,
                "static {p} vs paper {paper_w}");
    }
}

/// WS baseline pays psum traffic that OS avoids, on every conv layer of
/// the mini net at T=1 (the SectionII-C co-design argument, measured).
#[test]
fn os_beats_ws_traffic_at_t1() {
    use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
    use sti_snn::sim::ws_engine::WsEngine;
    let net = mini_net();
    for c in net.accel_convs() {
        let w = ConvWeights::random(c, 7);
        let mut rng = Rng::new(8);
        let input = SpikeFrame::random(c.in_h, c.in_w, c.ci, 0.3, &mut rng);
        let mut os = ConvEngine::new(c.clone(), w.clone(),
                                     ConvLatencyParams::optimized(), 1);
        let (_, os_rep) = os.run_frame(&input, true);
        let mut ws = WsEngine::new(c.clone(), w, 1);
        let (_, ws_rep) = ws.run_frame(&input);
        let os_psum = os_rep.counters.total_of_kind(DataKind::PartialSum)
            + os_rep.counters.total_of_kind(DataKind::Vmem);
        let ws_psum = ws_rep.counters.total_of_kind(DataKind::PartialSum);
        assert_eq!(os_psum, 0);
        assert!(ws_psum > 0);
    }
}
