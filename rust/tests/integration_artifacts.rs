//! Integration tests against the real trained artifacts (the three-
//! layer contract: python-trained + AOT HLO vs rust simulator).
//!
//! These require `make artifacts`; they skip (pass vacuously, with a
//! note) when artifacts are absent so `cargo test` stays green on a
//! fresh checkout.

use std::path::PathBuf;

use sti_snn::model::Artifact;
use sti_snn::runtime::Runtime;
use sti_snn::session::{Session, Weights};
use sti_snn::util::rng::Rng;

fn artifact_dir(name: &str) -> Option<PathBuf> {
    let dir = std::env::var("STI_SNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
        .join(name);
    if dir.join("net.json").exists()
        && dir.join("model.hlo.txt").exists()
    {
        Some(dir)
    } else {
        eprintln!("artifacts/{name} missing — skipping (run `make \
                   artifacts`)");
        None
    }
}

/// Artifact loads and its geometry is self-consistent.
#[test]
fn artifact_loads_and_is_consistent() {
    for name in ["scnn3", "vmobilenet", "scnn5"] {
        let Some(dir) = artifact_dir(name) else { continue };
        let art = Artifact::load(&dir).unwrap();
        assert!(!art.tensors.is_empty(), "{name}: no tensors");
        // Every non-encoder conv/fc layer has weights + bias.
        let sources = art.layer_weights().unwrap();
        assert!(!sources.is_empty(), "{name}: no layer weights");
        // The session facade builds the full stack from the artifact.
        let session = Session::builder()
            .weights(Weights::Artifact(dir.clone()))
            .build();
        assert!(session.is_ok(), "{name}: {:?}", session.err());
    }
}

/// The HLO graphs compile under the rust PJRT client and produce
/// plausible outputs (binary spikes from the encoder; finite logits).
#[test]
fn artifact_hlo_compiles_and_runs() {
    let Some(dir) = artifact_dir("scnn3") else { return };
    let art = Artifact::load(&dir).unwrap();
    let mut rt = Runtime::new().unwrap();
    if let Err(e) = rt.load_hlo("encoder", &art.encoder_hlo(),
                                art.net.input) {
        eprintln!("runtime unavailable ({e:#}); skipping");
        return;
    }
    rt.load_hlo("model", &art.model_hlo(), art.net.input).unwrap();

    let (h, w, c) = art.net.input;
    let mut rng = Rng::new(42);
    let image: Vec<f32> = (0..h * w * c).map(|_| rng.f32()).collect();

    let frame = rt.encode("encoder", &image, art.encoder_out_shape())
        .unwrap();
    let rate = frame.rate();
    assert!(rate > 0.0 && rate < 1.0,
            "encoder produced degenerate rate {rate}");

    let logits = rt.logits("model", &image).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|l| l.is_finite()));
}

/// Three-layer agreement: the int8 simulator pipeline and the PJRT
/// fake-quant float graph must usually agree on the class (they share
/// quantised weights; ties at the int8 grid may flip rare samples).
#[test]
fn simulator_agrees_with_pjrt_reference() {
    let Some(dir) = artifact_dir("scnn3") else { return };
    let art = Artifact::load(&dir).unwrap();
    let mut rt = Runtime::new().unwrap();
    if let Err(e) = rt.load_hlo("encoder", &art.encoder_hlo(),
                                art.net.input) {
        eprintln!("runtime unavailable ({e:#}); skipping");
        return;
    }
    rt.load_hlo("model", &art.model_hlo(), art.net.input).unwrap();
    let mut session = Session::builder()
        .weights(Weights::Artifact(dir.clone()))
        .build()
        .unwrap();

    let (h, w, c) = art.net.input;
    let mut rng = Rng::new(7);
    let n = 16;
    let mut agree = 0;
    for _ in 0..n {
        let image: Vec<f32> = (0..h * w * c).map(|_| rng.f32()).collect();
        let frame = rt
            .encode("encoder", &image, art.encoder_out_shape())
            .unwrap();
        let sim_class = session.infer(frame).unwrap().class;
        let logits = rt.logits("model", &image).unwrap();
        let ref_class = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        agree += usize::from(sim_class == ref_class);
    }
    assert!(agree * 100 >= n * 75,
            "simulator agreed with PJRT on only {agree}/{n} random \
             images");
}

/// Trained accuracy recorded at AOT time is sane (better than chance by
/// a solid margin on the 10-class synthetic set).
#[test]
fn trained_accuracy_recorded() {
    for name in ["scnn3", "vmobilenet"] {
        let Some(dir) = artifact_dir(name) else { continue };
        let txt = std::fs::read_to_string(dir.join("net.json")).unwrap();
        let j = sti_snn::util::json::Json::parse(&txt).unwrap();
        let acc = j.get("acc_t1").and_then(|v| v.as_f64()).unwrap_or(0.0);
        assert!(acc > 0.4, "{name}: T=1 accuracy {acc} too close to \
                chance (0.1)");
    }
}
