//! Steady-state allocation budget for the frame hot path.
//!
//! The §Perf contract: after a warm-up frame, conv inference through
//! the engine-owned workspaces ([`ConvEngine::run_frame_into`])
//! performs **zero** heap allocations per frame — for both compute
//! backends and all three conv modes — and a whole pipeline frame
//! stays within a small O(1) budget (classifier logits and report
//! assembly; nothing proportional to pixels or channels).
//!
//! A counting global allocator pins this: any allocation (or
//! reallocation — buffer growth counts) in the steady-state loop
//! fails the test. Everything lives in ONE `#[test]` so no concurrent
//! test thread pollutes the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use sti_snn::arch::{ConvLayer, ConvMode};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn layer(mode: ConvMode) -> ConvLayer {
    let (ci, co) = match mode {
        ConvMode::Depthwise => (24, 24),
        _ => (24, 16),
    };
    let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
    ConvLayer {
        mode,
        in_h: 12,
        in_w: 12,
        ci,
        co,
        kh: k,
        kw: k,
        pad: k / 2,
        encoder: false,
        parallel: 2,
    }
}

#[test]
fn steady_state_frame_hot_path_allocation_budget() {
    // ---- conv engines: exactly zero allocations per frame ----------
    let mut rng = Rng::new(90);
    for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
        for mode in [ConvMode::Standard, ConvMode::Depthwise,
                     ConvMode::Pointwise] {
            for timesteps in [1usize, 2] {
                let l = layer(mode);
                let w = ConvWeights::random(&l, 7);
                let mut eng = ConvEngine::with_backend(
                    l.clone(), w, ConvLatencyParams::optimized(),
                    timesteps, backend);
                let mut out = SpikeFrame::zeros(1, 1, 1);
                // Frames spanning sparse -> dense so steady state sees
                // MORE window events than the warm-up did (growth of
                // any event buffer would show up as a realloc).
                let frames: Vec<SpikeFrame> = [0.1, 0.4, 0.8, 0.25]
                    .iter()
                    .map(|&r| SpikeFrame::random(l.in_h, l.in_w, l.ci,
                                                 r, &mut rng))
                    .collect();
                eng.run_frame_into(&frames[0], true, &mut out);
                let before = allocs();
                for f in &frames {
                    eng.run_frame_into(f, true, &mut out);
                }
                let grew = allocs() - before;
                assert_eq!(grew, 0,
                           "{mode:?} {backend} T={timesteps}: {grew} \
                            allocations in the steady-state loop");
            }
        }
    }

    // ---- whole pipeline: O(1) per batch, nothing per-pixel ---------
    let net = sti_snn::arch::scnn3();
    let mut p = Pipeline::random(
        net,
        PipelineConfig {
            backend: BackendKind::WordParallel,
            // The zero-allocation contract is the serial schedule's:
            // the streamed executor spawns per-layer workers and row
            // channels per batch by design (still O(layers), never
            // per-pixel — but not zero).
            pipelined: false,
            ..Default::default()
        },
    )
    .unwrap();
    let shape = (28usize, 28usize, 16usize);
    let frame =
        vec![SpikeFrame::random(shape.0, shape.1, shape.2, 0.2, &mut rng)];
    p.run(&frame); // warm-up: sizes every engine workspace + buffer
    let before = allocs();
    p.run(&frame);
    let per_batch = allocs() - before;
    // Report assembly + classifier logits only: far below anything
    // proportional to the 28*28*16 pixel volume.
    assert!(per_batch < 100,
            "pipeline batch made {per_batch} allocations — hot path \
             regressed");
}
