//! Cross-backend differential fuzz harness — the pin for the
//! three-backend contract: `accurate`, `word-parallel`, and `sparse`
//! must produce bit-identical spikes, logits, and architectural
//! reports (cycles, ops, access traffic, energy, Vmem, codec ratios)
//! on every network geometry, schedule, band count, and timestep
//! count.
//!
//! Seeded random `NetworkSpec`s (conv / depthwise-separable /
//! pointwise / pool / FC mixes, odd shapes, 1x1-no-pad and 5x5-pad-2
//! kernel edges) are swept over input densities from all-zero and
//! single-spike frames up to 50% activity; every spec runs the full
//! backend x {serial, streamed} x bands {1, 2, 4} matrix against one
//! serial `accurate` reference (timesteps alternate 1/2 per spec so
//! the Vmem path is covered). `STI_SNN_STRESS_ITERS` repeats the whole
//! sweep with fresh specs (CI soak), like `stream_exec.rs`.

use sti_snn::arch::{NetBuilder, NetworkSpec};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig,
                                     PipelineReport};
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

const SPECS: u64 = 64;

/// Random tiny network: optional-kernel encoder, 1-3 accelerated conv
/// blocks mixing standard / depthwise-separable / pointwise layers,
/// stride-2 pools where the geometry allows, FC head.
fn random_net(rng: &mut Rng, id: u64) -> NetworkSpec {
    let h = 6 + rng.below(6); // 6..11, odd widths included
    let w = 6 + rng.below(6);
    let c = 1 + rng.below(3);
    let enc_k = [1, 3, 5][rng.below(3)];
    let mut b = NetBuilder::new(&format!("diff{id}"), (h, w, c))
        .encoder(2 + rng.below(5), enc_k);
    let (mut cur_h, mut cur_w) = (h, w);
    for _ in 0..1 + rng.below(3) {
        b = match rng.below(3) {
            // Standard conv: 1x1 (pad 0), 3x3 (pad 1), or 5x5 (pad 2).
            0 => b.conv(1 + rng.below(8), [1, 3, 5][rng.below(3)]),
            // Depthwise-separable block.
            1 => b.dwconv([3, 5][rng.below(2)]).pwconv(1 + rng.below(8)),
            _ => b.pwconv(1 + rng.below(8)),
        };
        if cur_h % 2 == 0 && cur_w % 2 == 0 && cur_h >= 6 && cur_w >= 6
            && rng.bernoulli(0.5)
        {
            b = b.pool();
            cur_h /= 2;
            cur_w /= 2;
        }
    }
    b.fc(2 + rng.below(10)).build()
}

/// Input frames at the spec's density point: all-zero, single-spike,
/// or Bernoulli at 5-50%.
fn frames_at(shape: (usize, usize, usize), density: f64, n: usize,
             rng: &mut Rng) -> Vec<SpikeFrame> {
    (0..n)
        .map(|_| {
            if density == 0.0 {
                SpikeFrame::zeros(shape.0, shape.1, shape.2)
            } else if density < 0.0 {
                // Sentinel: exactly one spike somewhere in the frame.
                let mut f = SpikeFrame::zeros(shape.0, shape.1, shape.2);
                f.set(rng.below(shape.0), rng.below(shape.1),
                      rng.below(shape.2));
                f
            } else {
                SpikeFrame::random(shape.0, shape.1, shape.2, density,
                                   rng)
            }
        })
        .collect()
}

fn run_with(net: &NetworkSpec, config: PipelineConfig,
            frames: &[SpikeFrame]) -> PipelineReport {
    let mut p = Pipeline::random(net.clone(), config).unwrap();
    p.run(frames)
}

/// Everything except the batch total (schedule-dependent by design,
/// Eq. (10) vs N x t_sum) must be bit-identical.
fn assert_reports_match(a: &PipelineReport, b: &PipelineReport,
                        ctx: &str) {
    assert_eq!(a.predictions, b.predictions, "{ctx}: predictions");
    assert_eq!(a.logits, b.logits, "{ctx}: logits");
    assert_eq!(a.layer_names, b.layer_names, "{ctx}: layer names");
    assert_eq!(a.layer_cycles, b.layer_cycles, "{ctx}: layer cycles");
    assert_eq!(a.t_max, b.t_max, "{ctx}: t_max");
    assert_eq!(a.t_sum, b.t_sum, "{ctx}: t_sum");
    assert_eq!(a.ops_per_frame, b.ops_per_frame, "{ctx}: ops");
    assert_eq!(a.counters, b.counters, "{ctx}: access counters");
    assert_eq!(a.layer_energy, b.layer_energy, "{ctx}: energy");
    assert_eq!(a.layer_vmem_bytes, b.layer_vmem_bytes, "{ctx}: vmem");
    assert_eq!(a.codec_ratios, b.codec_ratios, "{ctx}: codec ratios");
}

#[test]
fn diff_backends_full_matrix() {
    let iters: u64 = std::env::var("STI_SNN_STRESS_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    // -1.0 is the single-spike sentinel (see frames_at).
    let densities = [0.0, -1.0, 0.05, 0.15, 0.3, 0.5];
    for it in 0..iters {
        for id in 0..SPECS {
            let mut rng = Rng::new(0xd1ff_0000 + it * SPECS + id);
            let net = random_net(&mut rng, id);
            let density = densities[(id % densities.len() as u64) as usize];
            let timesteps = 1 + (id % 2) as usize;
            let shape =
                Pipeline::random(net.clone(), PipelineConfig::default())
                    .unwrap()
                    .input_shape();
            let frames = frames_at(shape, density, 2, &mut rng);
            let reference = run_with(&net,
                                     PipelineConfig {
                                         pipelined: false,
                                         timesteps,
                                         ..Default::default()
                                     },
                                     &frames);
            for backend in [BackendKind::Accurate,
                            BackendKind::WordParallel,
                            BackendKind::Sparse] {
                for pipelined in [false, true] {
                    for bands in [1usize, 2, 4] {
                        if backend == BackendKind::Accurate && !pipelined
                            && bands == 1
                        {
                            continue; // the reference itself
                        }
                        let rep = run_with(
                            &net,
                            PipelineConfig {
                                pipelined,
                                channel_capacity: 2,
                                backend,
                                timesteps,
                                intra_parallel: bands,
                                ..Default::default()
                            },
                            &frames,
                        );
                        assert_reports_match(
                            &rep, &reference,
                            &format!("it={it} spec={id} ({}) \
                                      d={density} T={timesteps} \
                                      {backend} pipelined={pipelined} \
                                      bands={bands}",
                                     net.name));
                    }
                }
            }
        }
    }
}
