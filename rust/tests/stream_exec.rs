//! Integration tests for the streamed inter-layer executor: per-layer
//! workers connected by bounded row channels (`coordinator::pipeline`).
//!
//! The contract under test is twofold:
//!
//! 1. **Bit-exactness** — the streamed schedule must reproduce every
//!    architectural report of the serial layer loop (per-layer cycles,
//!    ops, access traffic, energy, Vmem, compression ratios,
//!    predictions, logits). Only `total_cycles` may differ, and only
//!    by the documented accounting: Eq. (10) when pipelined, N x t_sum
//!    when serial.
//! 2. **Progress** — any channel capacity >= 1 completes (the recycle
//!    leg guarantees the consumer never holds more than one buffer, so
//!    a blocked producer always unblocks).
//!
//! A stress loop sweeps channel capacities around the interesting
//! boundaries (1 row in flight, ~Kh rows, more rows than the frame
//! has) x timesteps x intra-frame band counts. `STI_SNN_STRESS_ITERS`
//! scales the iteration count for CI soak runs (default 1).

use sti_snn::arch::{NetBuilder, NetworkSpec};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig,
                                     PipelineReport};
use sti_snn::dataflow::PipelineLatency;
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

fn mini_net() -> NetworkSpec {
    NetBuilder::new("mini", (12, 12, 2))
        .encoder(4, 3)
        .conv(8, 3)
        .pool()
        .conv(8, 3)
        .pool()
        .fc(10)
        .build()
}

fn random_frames(shape: (usize, usize, usize), n: usize, seed: u64)
                 -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.25,
                                    &mut rng))
        .collect()
}

fn run_with(net: &NetworkSpec, config: PipelineConfig,
            frames: &[SpikeFrame]) -> PipelineReport {
    let mut p = Pipeline::random(net.clone(), config).unwrap();
    p.run(frames)
}

/// Everything except the batch total (and its derived figures) must be
/// bit-identical between the two schedules.
fn assert_reports_match(a: &PipelineReport, b: &PipelineReport,
                        ctx: &str) {
    assert_eq!(a.predictions, b.predictions, "{ctx}: predictions");
    assert_eq!(a.logits, b.logits, "{ctx}: logits");
    assert_eq!(a.layer_names, b.layer_names, "{ctx}: layer names");
    assert_eq!(a.layer_cycles, b.layer_cycles, "{ctx}: layer cycles");
    assert_eq!(a.t_max, b.t_max, "{ctx}: t_max");
    assert_eq!(a.t_sum, b.t_sum, "{ctx}: t_sum");
    assert_eq!(a.ops_per_frame, b.ops_per_frame, "{ctx}: ops");
    assert_eq!(a.counters, b.counters, "{ctx}: access counters");
    assert_eq!(a.layer_energy, b.layer_energy, "{ctx}: energy");
    assert_eq!(a.layer_vmem_bytes, b.layer_vmem_bytes, "{ctx}: vmem");
    assert_eq!(a.codec_ratios, b.codec_ratios, "{ctx}: codec ratios");
}

/// Streamed cycle accounting is exactly `dataflow`'s Eq. (10) model
/// applied to the measured per-layer cycles; the serial schedule pays
/// the full sum per frame.
#[test]
fn streamed_total_cycles_follow_eq_10() {
    let net = sti_snn::arch::scnn3();
    let n_frames = 4u64;
    let mut p =
        Pipeline::random(net.clone(), PipelineConfig::default()).unwrap();
    let shape = p.input_shape();
    let frames = random_frames(shape, n_frames as usize, 5);
    let rep = p.run(&frames);
    let model = PipelineLatency {
        per_layer: rep.layer_cycles.clone(),
        t_max: rep.t_max,
        t_sum: rep.t_sum,
    };
    assert_eq!(rep.t_max,
               rep.layer_cycles.iter().copied().max().unwrap());
    assert_eq!(rep.t_sum, rep.layer_cycles.iter().sum::<u64>());
    assert_eq!(rep.total_cycles, model.total_cycles(n_frames),
               "streamed batch must follow Eq. (10)");

    let serial = run_with(&net,
                          PipelineConfig {
                              pipelined: false,
                              ..Default::default()
                          },
                          &frames);
    assert_reports_match(&rep, &serial, "eq10 scnn3");
    assert_eq!(serial.total_cycles,
               model.unpipelined_cycles(n_frames),
               "serial batch pays the full per-frame sum");
}

/// The stress sweep: channel capacities {1, Kh, > rows} x timesteps
/// {1, 2} x intra-frame bands {1, 2, 4} x all three backends, every
/// combination bit-identical to the serial schedule and free of
/// deadlock. `STI_SNN_STRESS_ITERS` repeats the sweep with fresh
/// random frames (CI soak).
#[test]
fn streamed_is_bit_exact_at_every_channel_capacity() {
    let iters: u64 = std::env::var("STI_SNN_STRESS_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let net = mini_net();
    for it in 0..iters {
        for backend in [BackendKind::Accurate, BackendKind::WordParallel,
                        BackendKind::Sparse]
        {
            for timesteps in [1usize, 2] {
                let shape = Pipeline::random(net.clone(),
                                             PipelineConfig::default())
                    .unwrap()
                    .input_shape();
                let frames = random_frames(shape, 3, 900 + it);
                let serial = run_with(&net,
                                      PipelineConfig {
                                          pipelined: false,
                                          backend,
                                          timesteps,
                                          ..Default::default()
                                      },
                                      &frames);
                // 1 = tightest possible backpressure; 3 = one kernel
                // height of context; 64 = deeper than any row count in
                // the net (channels never block).
                for cap in [1usize, 3, 64] {
                    for bands in [1usize, 2, 4] {
                        let streamed = run_with(
                            &net,
                            PipelineConfig {
                                pipelined: true,
                                channel_capacity: cap,
                                backend,
                                timesteps,
                                intra_parallel: bands,
                                ..Default::default()
                            },
                            &frames,
                        );
                        assert_reports_match(
                            &streamed, &serial,
                            &format!("it={it} {backend} T={timesteps} \
                                      cap={cap} bands={bands}"));
                    }
                }
            }
        }
    }
}
