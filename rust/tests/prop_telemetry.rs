//! Telemetry-transparency property: attaching a [`TraceSink`] must
//! never change what the simulator computes. Every architectural
//! report field — predictions, logits, cycles, ops, access counters,
//! energy, Vmem, codec ratios — is pinned bit-identical between a
//! traced and an untraced run, for both compute backends and both
//! execution schedules (serial layer loop and streamed per-layer
//! workers). The only report field allowed to differ is
//! `channel_stats`, which is host-timing observability data by
//! declaration.

use std::sync::Arc;

use sti_snn::arch;
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig,
                                     PipelineReport};
use sti_snn::sim::BackendKind;
use sti_snn::telemetry::TraceSink;
use sti_snn::util::rng::Rng;

fn frames(shape: (usize, usize, usize), n: usize, seed: u64)
          -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                    &mut rng))
        .collect()
}

/// Compare every architectural field of two reports. `channel_stats`
/// is deliberately absent: it is host-timing-dependent.
fn assert_reports_identical(a: &PipelineReport, b: &PipelineReport,
                            what: &str) {
    assert_eq!(a.frames, b.frames, "{what}: frames");
    assert_eq!(a.layer_cycles, b.layer_cycles, "{what}: layer_cycles");
    assert_eq!(a.layer_names, b.layer_names, "{what}: layer_names");
    assert_eq!(a.t_max, b.t_max, "{what}: t_max");
    assert_eq!(a.t_sum, b.t_sum, "{what}: t_sum");
    assert_eq!(a.total_cycles, b.total_cycles, "{what}: total_cycles");
    assert_eq!(a.ops_per_frame, b.ops_per_frame, "{what}: ops_per_frame");
    assert_eq!(a.counters, b.counters, "{what}: counters");
    assert_eq!(a.layer_energy, b.layer_energy, "{what}: layer_energy");
    assert_eq!(a.layer_vmem_bytes, b.layer_vmem_bytes,
               "{what}: layer_vmem_bytes");
    assert_eq!(a.codec_ratios, b.codec_ratios, "{what}: codec_ratios");
    assert_eq!(a.predictions, b.predictions, "{what}: predictions");
    assert_eq!(a.logits, b.logits, "{what}: logits");
    assert_eq!(a.resources, b.resources, "{what}: resources");
    assert_eq!(a.pes, b.pes, "{what}: pes");
}

/// backends x schedules: trace-off == trace-on, bit for bit, and the
/// traced run actually recorded spans (the equality must not hold
/// vacuously because tracing was never exercised).
#[test]
fn tracing_never_changes_the_architectural_report() {
    for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
        for pipelined in [false, true] {
            let config = PipelineConfig {
                backend,
                pipelined,
                ..PipelineConfig::default()
            };
            let mut plain =
                Pipeline::random(arch::scnn3(), config.clone()).unwrap();
            let sink = Arc::new(TraceSink::new(1 << 14));
            let traced_config = PipelineConfig {
                trace: Some(sink.clone()),
                ..config
            };
            let mut traced =
                Pipeline::random(arch::scnn3(), traced_config).unwrap();

            let fs = frames(plain.input_shape(), 3, 23);
            let rep_plain = plain.run(&fs);
            let rep_traced = traced.run(&fs);
            let what = format!("{backend:?} pipelined={pipelined}");
            assert_reports_identical(&rep_plain, &rep_traced, &what);
            assert!(!sink.is_empty(),
                    "{what}: traced run recorded no spans");
            let evs = sink.events();
            let expect = if pipelined { "stream.layer" } else { "layer" };
            assert!(evs.iter().any(|e| e.name == expect),
                    "{what}: no {expect:?} span among {} events",
                    evs.len());
        }
    }
}

/// A second traced batch on the same pipeline matches a fresh
/// untraced pipeline run — tracing leaves no state behind between
/// batches either.
#[test]
fn tracing_is_stateless_across_batches() {
    let sink = Arc::new(TraceSink::new(1 << 12));
    let config = PipelineConfig {
        backend: BackendKind::WordParallel,
        trace: Some(sink),
        ..PipelineConfig::default()
    };
    let mut traced = Pipeline::random(arch::scnn3(), config).unwrap();
    let fs = frames(traced.input_shape(), 2, 31);
    let _warmup = traced.run(&fs);
    let rep_again = traced.run(&fs);

    let mut plain = Pipeline::random(
        arch::scnn3(),
        PipelineConfig {
            backend: BackendKind::WordParallel,
            ..PipelineConfig::default()
        })
    .unwrap();
    let _warmup = plain.run(&fs);
    let rep_plain = plain.run(&fs);
    assert_reports_identical(&rep_plain, &rep_again, "second batch");
}
