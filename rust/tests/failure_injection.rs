//! Failure-injection tests: malformed artifacts, bad protocol input,
//! and misuse of the public API must fail loudly and cleanly (no
//! panics on the error paths a user can actually hit).

use std::path::Path;

use sti_snn::arch::NetworkSpec;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::model::Artifact;
use sti_snn::session::{Session, Weights};
use sti_snn::sim::engine::LayerWeights;
use sti_snn::util::json::Json;

fn write(dir: &Path, name: &str, contents: &[u8]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join(name), contents).unwrap();
}

const NET_OK: &str = r#"{
  "name": "t", "input": [4, 4, 1], "vth": 1.0, "timesteps": 1,
  "layers": [
    {"kind":"conv","in_h":4,"in_w":4,"in_c":1,"co":2,"k":3,"pad":1,
     "encoder":false}
  ],
  "tensors": [
    {"layer":0,"name":"w","kind":"int8","shape":[2,1,9],"scale":0.01,
     "offset":0,"len":18},
    {"layer":0,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}
  ]}"#;

#[test]
fn corrupt_net_json_is_an_error_not_a_panic() {
    let dir = std::env::temp_dir().join("sti_fail_json");
    write(&dir, "net.json", b"{ not json ");
    write(&dir, "weights.bin", &[0u8; 26]);
    let err = match Artifact::load(&dir) {
        Err(e) => e,
        Ok(_) => panic!("corrupt json must not load"),
    };
    assert!(err.to_string().contains("net.json")
            || format!("{err:#}").contains("json"),
            "unhelpful error: {err:#}");
}

#[test]
fn truncated_weights_blob_is_detected() {
    let dir = std::env::temp_dir().join("sti_fail_trunc");
    write(&dir, "net.json", NET_OK.as_bytes());
    write(&dir, "weights.bin", &[0u8; 5]); // needs 26
    let art = Artifact::load(&dir).unwrap();
    let err = match art.layer_weights() {
        Err(e) => e,
        Ok(_) => panic!("truncated blob must not load"),
    };
    assert!(format!("{err:#}").contains("bounds"), "{err:#}");
}

#[test]
fn missing_tensor_for_layer_is_detected() {
    let dir = std::env::temp_dir().join("sti_fail_missing");
    let net = NET_OK.replace(r#"{"layer":0,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}"#, r#"{"layer":9,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}"#);
    write(&dir, "net.json", net.as_bytes());
    write(&dir, "weights.bin", &[0u8; 26]);
    let art = Artifact::load(&dir).unwrap();
    assert!(art.layer_weights().is_err());
}

#[test]
fn unknown_layer_kind_rejected() {
    let j = Json::parse(r#"{"name":"x","input":[2,2,1],
        "layers":[{"kind":"transformer","in_h":2,"in_w":2,"in_c":1}]}"#)
        .unwrap();
    assert!(NetworkSpec::from_json(&j).is_err());
}

#[test]
fn pipeline_rejects_wrong_weight_source_count() {
    let net = sti_snn::arch::scnn3();
    // scnn3 needs 3 sources (2 convs + fc); give 1.
    let r = Pipeline::new(net, PipelineConfig::default(),
                          vec![LayerWeights::Random { seed: 1 }]);
    assert!(r.is_err());
    // And too many.
    let net = sti_snn::arch::scnn3();
    let r = Pipeline::new(
        net, PipelineConfig::default(),
        (0..9).map(|s| LayerWeights::Random { seed: s }).collect());
    assert!(r.is_err());
}

#[test]
fn session_builder_surfaces_configuration_errors() {
    // Unknown model name.
    assert!(Session::builder().model("resnet50").build().is_err());
    // No network source at all.
    assert!(Session::builder().build().is_err());
    // Missing artifact directory.
    assert!(Session::builder()
        .weights(Weights::Artifact("/nonexistent/xyz".into()))
        .build()
        .is_err());
    // Invalid parallel factors are rejected at build, not at panic.
    assert!(Session::builder()
        .model("scnn3")
        .parallel_factors(&[3, 2])
        .build()
        .is_err());
}

#[test]
#[should_panic(expected = "input shape mismatch")]
fn engine_rejects_wrong_input_shape() {
    use sti_snn::arch::{ConvLayer, ConvMode};
    use sti_snn::codec::SpikeFrame;
    use sti_snn::dataflow::ConvLatencyParams;
    use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
    let l = ConvLayer {
        mode: ConvMode::Standard, in_h: 8, in_w: 8, ci: 4, co: 4,
        kh: 3, kw: 3, pad: 1, encoder: false, parallel: 1,
    };
    let w = ConvWeights::random(&l, 1);
    let mut e = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    let bad = SpikeFrame::zeros(6, 6, 4); // wrong H, W
    let _ = e.run_frame(&bad, true);
}

#[test]
fn server_survives_malformed_requests() {
    use sti_snn::server::{Backend, Client, Server};

    struct Echo;
    impl Backend for Echo {
        fn infer(&mut self, img: &[f32]) -> anyhow::Result<(usize, Vec<f32>)> {
            Ok((0, img.to_vec()))
        }
        fn input_len(&self) -> usize { 2 }
    }

    let server = Server::new(Echo);
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
    });
    let addr = rx.recv().unwrap().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // Garbage JSON -> error reply, connection + server stay alive.
    let resp = c.request(&Json::Str("not an object".into())).unwrap();
    assert!(resp.get("error").is_some());
    // Missing image field.
    let resp = c.request(&Json::obj(vec![("id", Json::num(1.0))])).unwrap();
    assert!(resp.get("error").is_some());
    // Unknown command.
    let resp = c.request(&Json::obj(vec![("cmd", Json::str("reboot"))]))
        .unwrap();
    assert!(resp.get("error").is_some());
    // Then a good request still works.
    let resp = c.infer(5, &[0.1, 0.2]).unwrap();
    assert_eq!(resp.get("class").unwrap().as_usize(), Some(0));

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}

#[test]
fn runtime_rejects_garbage_hlo() {
    use sti_snn::runtime::Runtime;
    let dir = std::env::temp_dir().join("sti_fail_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.hlo.txt");
    std::fs::write(&p, "this is not hlo").unwrap();
    let mut rt = Runtime::new().unwrap();
    assert!(rt.load_hlo("bad", &p, (1, 1, 1)).is_err());
}

/// Binary events-wire faults: a hostile or broken client gets a clean
/// error reply or a closed connection — never a panicked or hung
/// server thread, and the server keeps accepting new connections.
mod events_wire {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use sti_snn::codec::stream::{encode_events, DvsEvent};
    use sti_snn::codec::SpikeFrame;
    use sti_snn::server::{Backend, Client, Server, ServerStats};

    /// Frame-capable echo backend: events mode needs a frame shape.
    struct FrameEcho;
    impl Backend for FrameEcho {
        fn infer(&mut self, img: &[f32])
                 -> anyhow::Result<(usize, Vec<f32>)> {
            Ok((0, img.to_vec()))
        }
        fn input_len(&self) -> usize {
            32
        }
        fn infer_frame(&mut self, _frame: &SpikeFrame)
                       -> anyhow::Result<(usize, Vec<f32>)> {
            Ok((0, vec![1.0]))
        }
        fn frame_shape(&self) -> Option<(usize, usize, usize)> {
            Some((4, 4, 2))
        }
    }

    fn start_server() -> (String, Arc<ServerStats>,
                          std::thread::JoinHandle<anyhow::Result<()>>) {
        let server = Server::new(FrameEcho);
        let stats = server.stats();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
        });
        (rx.recv().unwrap().to_string(), stats, h)
    }

    /// Raw events-mode connection: JSON handshake, then the binary
    /// wire belongs to the test.
    fn raw_events_conn(addr: &str)
                       -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = stream;
        writeln!(out, r#"{{"cmd": "events", "window": "count:4"}}"#)
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"h\""), "handshake refused: {line}");
        (out, reader)
    }

    /// Read one length-prefixed reply frame; `None` = closed.
    fn read_reply(reader: &mut BufReader<TcpStream>)
                  -> Option<Vec<u8>> {
        let mut len4 = [0u8; 4];
        reader.read_exact(&mut len4).ok()?;
        let mut buf = vec![0u8; u32::from_le_bytes(len4) as usize];
        reader.read_exact(&mut buf).ok()?;
        Some(buf)
    }

    /// The server is still healthy: a fresh dense connection round
    /// trips, then shuts the server down.
    fn assert_alive_and_shutdown(
        addr: &str, h: std::thread::JoinHandle<anyhow::Result<()>>) {
        let mut c = Client::connect(addr).unwrap();
        let resp = c.infer(1, &[0.0; 32]).unwrap();
        assert!(resp.get("class").is_some(), "{resp}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// An oversized u32 length prefix gets an explicit error frame
    /// and a closed connection — the server never allocates the
    /// claimed buffer or stalls reading it.
    #[test]
    fn oversized_length_prefix_errors_and_closes() {
        let (addr, stats, h) = start_server();
        let (mut out, mut reader) = raw_events_conn(&addr);
        out.write_all(&((1u32 << 20) + 12).to_le_bytes()).unwrap();
        let reply = read_reply(&mut reader).expect("error frame");
        assert_eq!(reply[0], 2, "EV_ERR status, got {reply:?}");
        let msg = String::from_utf8_lossy(&reply[12..]);
        assert!(msg.contains("bad event batch length"), "{msg}");
        assert!(read_reply(&mut reader).is_none(),
                "connection must close after a framing error");
        assert!(stats.protocol_errors.load(Ordering::SeqCst) >= 1);
        assert_alive_and_shutdown(&addr, h);
    }

    /// A length prefix that is not a whole number of wire events is a
    /// framing error, not a desync: error frame, then close.
    #[test]
    fn misaligned_length_prefix_errors_and_closes() {
        let (addr, stats, h) = start_server();
        let (mut out, mut reader) = raw_events_conn(&addr);
        out.write_all(&10u32.to_le_bytes()).unwrap();
        let reply = read_reply(&mut reader).expect("error frame");
        assert_eq!(reply[0], 2, "EV_ERR status, got {reply:?}");
        assert!(read_reply(&mut reader).is_none());
        assert!(stats.protocol_errors.load(Ordering::SeqCst) >= 1);
        assert_alive_and_shutdown(&addr, h);
    }

    /// A client that promises a frame and disconnects mid-payload is
    /// a dropped connection (counted under `reason="io"`), and the
    /// server thread moves on cleanly.
    #[test]
    fn truncated_frame_counts_a_dropped_connection() {
        let (addr, stats, h) = start_server();
        let (mut out, _reader) = raw_events_conn(&addr);
        // Promise two events (24 bytes), deliver one, vanish.
        out.write_all(&24u32.to_le_bytes()).unwrap();
        let one = encode_events(&[DvsEvent { x: 0, y: 0, c: 0, t: 1 }]);
        out.write_all(&one).unwrap();
        drop(out);
        let deadline = Instant::now() + Duration::from_secs(10);
        while stats.dropped().1 == 0 {
            assert!(Instant::now() < deadline,
                    "mid-frame disconnect never surfaced as a drop");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(stats.dropped(), (0, 1));
        assert_alive_and_shutdown(&addr, h);
    }

    /// Disconnecting at a frame boundary (after a complete batch) is a
    /// clean close: no drop is counted and nothing hangs, even with a
    /// window still open in the stream.
    #[test]
    fn boundary_disconnect_closes_cleanly() {
        let (addr, stats, h) = start_server();
        let (mut out, _reader) = raw_events_conn(&addr);
        // One complete 1-event batch leaves a count:4 window open.
        let one = encode_events(&[DvsEvent { x: 1, y: 1, c: 1, t: 5 }]);
        out.write_all(&(one.len() as u32).to_le_bytes()).unwrap();
        out.write_all(&one).unwrap();
        drop(out);
        // The close is clean, so liveness is the whole assertion: the
        // accept loop and a fresh connection still work immediately.
        assert_alive_and_shutdown(&addr, h);
        assert_eq!(stats.dropped(), (0, 0),
                   "a boundary EOF is not a dropped connection");
    }
}
