//! Failure-injection tests: malformed artifacts, bad protocol input,
//! and misuse of the public API must fail loudly and cleanly (no
//! panics on the error paths a user can actually hit).

use std::path::Path;

use sti_snn::arch::NetworkSpec;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::model::Artifact;
use sti_snn::session::{Session, Weights};
use sti_snn::sim::engine::LayerWeights;
use sti_snn::util::json::Json;

fn write(dir: &Path, name: &str, contents: &[u8]) {
    std::fs::create_dir_all(dir).unwrap();
    std::fs::write(dir.join(name), contents).unwrap();
}

const NET_OK: &str = r#"{
  "name": "t", "input": [4, 4, 1], "vth": 1.0, "timesteps": 1,
  "layers": [
    {"kind":"conv","in_h":4,"in_w":4,"in_c":1,"co":2,"k":3,"pad":1,
     "encoder":false}
  ],
  "tensors": [
    {"layer":0,"name":"w","kind":"int8","shape":[2,1,9],"scale":0.01,
     "offset":0,"len":18},
    {"layer":0,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}
  ]}"#;

#[test]
fn corrupt_net_json_is_an_error_not_a_panic() {
    let dir = std::env::temp_dir().join("sti_fail_json");
    write(&dir, "net.json", b"{ not json ");
    write(&dir, "weights.bin", &[0u8; 26]);
    let err = match Artifact::load(&dir) {
        Err(e) => e,
        Ok(_) => panic!("corrupt json must not load"),
    };
    assert!(err.to_string().contains("net.json")
            || format!("{err:#}").contains("json"),
            "unhelpful error: {err:#}");
}

#[test]
fn truncated_weights_blob_is_detected() {
    let dir = std::env::temp_dir().join("sti_fail_trunc");
    write(&dir, "net.json", NET_OK.as_bytes());
    write(&dir, "weights.bin", &[0u8; 5]); // needs 26
    let art = Artifact::load(&dir).unwrap();
    let err = match art.layer_weights() {
        Err(e) => e,
        Ok(_) => panic!("truncated blob must not load"),
    };
    assert!(format!("{err:#}").contains("bounds"), "{err:#}");
}

#[test]
fn missing_tensor_for_layer_is_detected() {
    let dir = std::env::temp_dir().join("sti_fail_missing");
    let net = NET_OK.replace(r#"{"layer":0,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}"#, r#"{"layer":9,"name":"b","kind":"f32","shape":[2],"scale":1.0,
     "offset":18,"len":8}"#);
    write(&dir, "net.json", net.as_bytes());
    write(&dir, "weights.bin", &[0u8; 26]);
    let art = Artifact::load(&dir).unwrap();
    assert!(art.layer_weights().is_err());
}

#[test]
fn unknown_layer_kind_rejected() {
    let j = Json::parse(r#"{"name":"x","input":[2,2,1],
        "layers":[{"kind":"transformer","in_h":2,"in_w":2,"in_c":1}]}"#)
        .unwrap();
    assert!(NetworkSpec::from_json(&j).is_err());
}

#[test]
fn pipeline_rejects_wrong_weight_source_count() {
    let net = sti_snn::arch::scnn3();
    // scnn3 needs 3 sources (2 convs + fc); give 1.
    let r = Pipeline::new(net, PipelineConfig::default(),
                          vec![LayerWeights::Random { seed: 1 }]);
    assert!(r.is_err());
    // And too many.
    let net = sti_snn::arch::scnn3();
    let r = Pipeline::new(
        net, PipelineConfig::default(),
        (0..9).map(|s| LayerWeights::Random { seed: s }).collect());
    assert!(r.is_err());
}

#[test]
fn session_builder_surfaces_configuration_errors() {
    // Unknown model name.
    assert!(Session::builder().model("resnet50").build().is_err());
    // No network source at all.
    assert!(Session::builder().build().is_err());
    // Missing artifact directory.
    assert!(Session::builder()
        .weights(Weights::Artifact("/nonexistent/xyz".into()))
        .build()
        .is_err());
    // Invalid parallel factors are rejected at build, not at panic.
    assert!(Session::builder()
        .model("scnn3")
        .parallel_factors(&[3, 2])
        .build()
        .is_err());
}

#[test]
#[should_panic(expected = "input shape mismatch")]
fn engine_rejects_wrong_input_shape() {
    use sti_snn::arch::{ConvLayer, ConvMode};
    use sti_snn::codec::SpikeFrame;
    use sti_snn::dataflow::ConvLatencyParams;
    use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
    let l = ConvLayer {
        mode: ConvMode::Standard, in_h: 8, in_w: 8, ci: 4, co: 4,
        kh: 3, kw: 3, pad: 1, encoder: false, parallel: 1,
    };
    let w = ConvWeights::random(&l, 1);
    let mut e = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    let bad = SpikeFrame::zeros(6, 6, 4); // wrong H, W
    let _ = e.run_frame(&bad, true);
}

#[test]
fn server_survives_malformed_requests() {
    use sti_snn::server::{Backend, Client, Server};

    struct Echo;
    impl Backend for Echo {
        fn infer(&mut self, img: &[f32]) -> anyhow::Result<(usize, Vec<f32>)> {
            Ok((0, img.to_vec()))
        }
        fn input_len(&self) -> usize { 2 }
    }

    let server = Server::new(Echo);
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
    });
    let addr = rx.recv().unwrap().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // Garbage JSON -> error reply, connection + server stay alive.
    let resp = c.request(&Json::Str("not an object".into())).unwrap();
    assert!(resp.get("error").is_some());
    // Missing image field.
    let resp = c.request(&Json::obj(vec![("id", Json::num(1.0))])).unwrap();
    assert!(resp.get("error").is_some());
    // Unknown command.
    let resp = c.request(&Json::obj(vec![("cmd", Json::str("reboot"))]))
        .unwrap();
    assert!(resp.get("error").is_some());
    // Then a good request still works.
    let resp = c.infer(5, &[0.1, 0.2]).unwrap();
    assert_eq!(resp.get("class").unwrap().as_usize(), Some(0));

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}

#[test]
fn runtime_rejects_garbage_hlo() {
    use sti_snn::runtime::Runtime;
    let dir = std::env::temp_dir().join("sti_fail_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.hlo.txt");
    std::fs::write(&p, "this is not hlo").unwrap();
    let mut rt = Runtime::new().unwrap();
    assert!(rt.load_hlo("bad", &p, (1, 1, 1)).is_err());
}
