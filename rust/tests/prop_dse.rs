//! Property tests for the `dse` subsystem.
//!
//! 1. **Calibration transfer**: on randomly generated `ConvLayer`s,
//!    calibrated analytical latency/access predictions stay within a
//!    pinned tolerance of the simulator's cycle/access counters — for
//!    both the `accurate` and `word-parallel` backends, and at design
//!    points (parallel factors) the probe never saw.
//! 2. **Frontier soundness**: the Pareto frontier is actually
//!    non-dominated, covers every evaluated point, and is
//!    deterministic.
//!
//! proptest is not vendored; same hand-rolled discipline as
//! `prop_coordinator.rs`: seeded PRNG cases, seed printed on failure.

use sti_snn::arch::{ConvLayer, ConvMode, Layer, NetBuilder, NetworkSpec};
use sti_snn::codec::SpikeFrame;
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::dse::{self, dominates, CalibrationConfig, CostModel,
                   SearchSpace};
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::memory::{DataKind, MemLevel};
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

/// Pinned agreement tolerance between calibrated predictions and the
/// simulator's counters (the counters are architectural, so transfer
/// across inputs and parallel factors is tight).
const TOL: f64 = 0.05;

/// Random conv layer with power-of-two channel counts so every
/// power-of-two parallel factor divides `Co`.
fn random_layer(rng: &mut Rng) -> ConvLayer {
    let mode = match rng.below(3) {
        0 => ConvMode::Standard,
        1 => ConvMode::Depthwise,
        _ => ConvMode::Pointwise,
    };
    let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
    let co = 1 << rng.range(2, 4); // 4, 8, or 16
    let ci = match mode {
        ConvMode::Depthwise => co,
        _ => 2 + rng.below(6),
    };
    ConvLayer {
        mode,
        in_h: 6 + rng.below(6),
        in_w: 6 + rng.below(6),
        ci,
        co,
        kh: k,
        kw: k,
        pad: k / 2,
        encoder: false,
        parallel: 1,
    }
}

fn rel_err(pred: f64, sim: u64) -> f64 {
    if sim == 0 {
        pred.abs() // absolute when the counter is zero
    } else {
        (pred - sim as f64).abs() / sim as f64
    }
}

#[test]
fn prop_calibrated_predictions_track_simulator_counters() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(7000 + seed);
        let l = random_layer(&mut rng);
        let net = NetworkSpec {
            name: "probe".into(),
            input: (l.in_h, l.in_w, l.ci),
            layers: vec![Layer::Conv(l.clone())],
        };
        let timesteps = 1 + rng.below(2); // 1 or 2 (vmem path)
        let timing = ConvLatencyParams::optimized();
        // A design point the probe never saw: a dividing parallel
        // factor and a fresh input.
        let mut l2 = l.clone();
        l2.parallel = 1 << rng.below(3); // 1, 2, or 4 — divides Co
        let input =
            SpikeFrame::random(l2.in_h, l2.in_w, l2.ci, 0.3, &mut rng);

        for backend in [BackendKind::Accurate, BackendKind::WordParallel,
                        BackendKind::Sparse] {
            let cal = dse::calibrate(&net, &timing, &CalibrationConfig {
                timesteps,
                backends: vec![backend],
                seed: 5 + seed,
                ..Default::default()
            });
            let w = ConvWeights::random(&l2, 300 + seed);
            let mut eng = ConvEngine::with_backend(
                l2.clone(), w, timing, timesteps, backend);
            let (_, rep) = eng.run_frame(&input, true);

            let ctx = format!(
                "seed={seed} {:?} ci={} co={} p={} t={timesteps} \
                 backend={backend}",
                l2.mode, l2.ci, l2.co, l2.parallel);

            let pred = cal.predict_conv_cycles(&l2, &timing, timesteps);
            assert!(rel_err(pred, rep.cycles) < TOL,
                    "{ctx}: cycles pred {pred} sim {}", rep.cycles);

            let a = cal.predict_access(&l2, timesteps, true);
            let c = &rep.counters;
            assert!(rel_err(a.input_dram,
                            c.reads_of(MemLevel::Dram,
                                       DataKind::InputSpike)) < TOL,
                    "{ctx}: input@DRAM");
            let in_bram = c.reads_of(MemLevel::Bram, DataKind::InputSpike)
                + c.writes_of(MemLevel::Bram, DataKind::InputSpike);
            assert!(rel_err(a.input_bram, in_bram) < TOL,
                    "{ctx}: input@BRAM pred {} sim {in_bram}",
                    a.input_bram);
            assert!(rel_err(a.weight,
                            c.reads_of(MemLevel::Bram, DataKind::Weight))
                    < TOL,
                    "{ctx}: weights");
            assert!(rel_err(a.vmem, c.total_of_kind(DataKind::Vmem))
                    < TOL,
                    "{ctx}: vmem pred {} sim {}", a.vmem,
                    c.total_of_kind(DataKind::Vmem));
            assert!(rel_err(a.output,
                            c.writes_of(MemLevel::Bram,
                                        DataKind::OutputSpike)) < TOL,
                    "{ctx}: outputs");
        }
    }
}

/// The optimised hot path re-fits cleanly: calibrating with
/// intra-frame bands records fresh host-ns/frame figures per backend
/// while the architectural scales stay band-invariant, and calibrated
/// cycle predictions still land within the 5% envelope on unseen
/// design points.
#[test]
fn prop_calibration_refit_with_bands_stays_in_envelope() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(7700 + seed);
        let l = random_layer(&mut rng);
        let net = NetworkSpec {
            name: "probe".into(),
            input: (l.in_h, l.in_w, l.ci),
            layers: vec![Layer::Conv(l.clone())],
        };
        let timing = ConvLatencyParams::optimized();
        let base = dse::calibrate(&net, &timing, &CalibrationConfig {
            seed: 9 + seed,
            ..Default::default()
        });
        let banded = dse::calibrate(&net, &timing, &CalibrationConfig {
            seed: 9 + seed,
            intra_parallel: 2,
            ..Default::default()
        });
        // Architectural fits are band-invariant; host times refit.
        assert_eq!(base.cycle_scales, banded.cycle_scales,
                   "seed={seed}");
        assert_eq!(base.weight_scale, banded.weight_scale,
                   "seed={seed}");
        assert_eq!(base.op_activity, banded.op_activity, "seed={seed}");
        for backend in [BackendKind::Accurate, BackendKind::WordParallel,
                        BackendKind::Sparse] {
            assert!(banded.host_ns(backend).unwrap() > 0.0,
                    "seed={seed} {backend}: host refit missing");
        }
        // Envelope transfer to an unseen parallel factor, banded run.
        let mut l2 = l.clone();
        l2.parallel = 1 << rng.below(3);
        let input =
            SpikeFrame::random(l2.in_h, l2.in_w, l2.ci, 0.3, &mut rng);
        let w = ConvWeights::random(&l2, 800 + seed);
        let mut eng = ConvEngine::with_backend(
            l2.clone(), w, timing, 1, BackendKind::WordParallel)
            .with_intra_parallel(2);
        let (_, rep) = eng.run_frame(&input, true);
        let pred = banded.predict_conv_cycles(&l2, &timing, 1);
        assert!(rel_err(pred, rep.cycles) < TOL,
                "seed={seed}: banded cycles pred {pred} sim {}",
                rep.cycles);
    }
}

/// Random small net for frontier properties (power-of-two channels so
/// factor enumeration has depth).
fn random_net(rng: &mut Rng) -> NetworkSpec {
    let h = 8 + 4 * rng.below(2); // 8 or 12
    let co1 = 1 << rng.range(2, 4);
    let co2 = 1 << rng.range(2, 4);
    NetBuilder::new("prop-dse", (h, h, 2))
        .encoder(4, 3)
        .conv(co1, 3)
        .pool()
        .conv(co2, 3)
        .fc(10)
        .build()
}

#[test]
fn prop_pareto_frontier_is_non_dominated_and_deterministic() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(8000 + seed);
        let net = random_net(&mut rng);
        let budget = dse::min_pes(&net) * (1 + rng.below(6));
        let space = SearchSpace::new(net, budget)
            .with_replicas(1 + rng.below(3));
        let model = CostModel::default();
        let ex = dse::explore(&space, &model);
        assert_eq!(ex.candidates, ex.evaluated, "seed={seed}");
        assert!(!ex.frontier.is_empty(), "seed={seed}");

        // Pairwise non-dominance on the frontier.
        for (i, a) in ex.frontier.iter().enumerate() {
            for (j, b) in ex.frontier.iter().enumerate() {
                if i != j {
                    assert!(!dominates(&a.objectives(), &b.objectives()),
                            "seed={seed}: frontier point {i} dominates \
                             {j}");
                }
            }
        }
        // Coverage: every evaluated point is equalled or dominated by
        // some frontier point.
        for p in &ex.points {
            let o = p.objectives();
            assert!(ex.frontier.iter().any(|f| {
                let fo = f.objectives();
                fo == o || dominates(&fo, &o)
            }), "seed={seed}: {:?} uncovered", p.candidate);
        }
        // Determinism: a second run reproduces the frontier exactly.
        let ex2 = dse::explore(&space, &model);
        assert_eq!(ex.frontier, ex2.frontier, "seed={seed}");
        assert_eq!(ex.chosen, ex2.chosen, "seed={seed}");

        // The chosen serving point fits and maximises pool throughput.
        if let Some(chosen) = &ex.chosen {
            assert!(chosen.fits, "seed={seed}");
            for p in ex.points.iter().filter(|p| p.fits) {
                assert!(chosen.pool_fps >= p.pool_fps, "seed={seed}");
            }
        }
    }
}

/// The scheduler facade and the dse evaluator agree: the greedy
/// optimum is never beaten (on the latency model) by any enumerated
/// single-replica candidate under the same budget.
#[test]
fn prop_greedy_optimum_on_or_above_enumerated_candidates() {
    use sti_snn::coordinator::scheduler;
    for seed in 0..6u64 {
        let mut rng = Rng::new(9000 + seed);
        let net = random_net(&mut rng);
        let budget = dse::min_pes(&net) * (1 + rng.below(4));
        let timing = ConvLatencyParams::optimized();
        let choice = scheduler::optimize_factors(&net, budget, &timing);
        let model = CostModel::default();
        let space = SearchSpace::new(net, budget);
        let ex = dse::explore(&space, &model);
        let best_enum = ex
            .points
            .iter()
            .filter(|p| p.candidate.replicas == 1)
            .map(|p| p.t_max_cycles)
            .fold(f64::INFINITY, f64::min);
        assert!(choice.t_max as f64 <= best_enum * 1.0001,
                "seed={seed}: greedy {} vs enumerated best {best_enum}",
                choice.t_max);
    }
}
