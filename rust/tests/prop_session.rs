//! API-equivalence tests for the `Session` facade.
//!
//! The session builder replaced four hand-rolled construction paths
//! (CLI wiring, server backends, `dse` pool boot, bench/example
//! setup). These tests pin the migration: a `Session`-built stack must
//! produce **bit-identical spikes/logits and identical cycle / access
//! / energy reports** to the pre-refactor construction path — the
//! hard-coded engine-enum wiring reproduced here concretely — for
//! both compute backends, with synthetic and artifact weights.

use std::path::{Path, PathBuf};

use sti_snn::arch::{Layer, NetBuilder, NetworkSpec};
use sti_snn::codec::SpikeFrame;
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::session::{Session, Weights};
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::fc_engine::FcEngine;
use sti_snn::sim::pool_engine::PoolEngine;
use sti_snn::sim::{AccessCounter, BackendKind, EnergyModel};
use sti_snn::util::rng::Rng;

/// The pre-refactor per-layer weight source (what `LayerParams` was).
enum LegacySource {
    Random { seed: u64 },
    Conv(ConvWeights),
    Fc { weights: Vec<i8>, scale: f32, bias: Vec<f32> },
}

/// The pre-refactor engine enum (what `coordinator::Pipeline` held).
enum LegacyEngine {
    Conv(ConvEngine),
    Pool(PoolEngine),
    Fc(FcEngine),
}

/// What the pre-refactor pipeline reported (the fields the migration
/// must preserve bit-for-bit).
struct LegacyReport {
    predictions: Vec<usize>,
    logits: Vec<Vec<f32>>,
    layer_cycles: Vec<u64>,
    t_max: u64,
    t_sum: u64,
    total_cycles: u64,
    ops_per_frame: u64,
    counters: AccessCounter,
    energy_per_frame_j: f64,
}

/// Reproduce the pre-refactor construction + run loop exactly: build
/// one concrete engine per accelerated layer from the enum, run frames
/// sequentially with the old per-kind arms, apply Eq. (10) pipelining.
fn legacy_run(net: &NetworkSpec, backend: BackendKind, timesteps: usize,
              mut sources: Vec<LegacySource>, frames: &[SpikeFrame])
              -> LegacyReport {
    let timing = ConvLatencyParams::optimized();
    let mut engines = Vec::new();
    sources.reverse();
    for layer in &net.layers {
        match layer {
            Layer::Conv(c) if c.encoder => continue,
            Layer::Conv(c) => {
                let w = match sources.pop().expect("conv source") {
                    LegacySource::Random { seed } => {
                        ConvWeights::random(c, seed)
                    }
                    LegacySource::Conv(w) => w,
                    LegacySource::Fc { .. } => panic!("want conv"),
                };
                engines.push(LegacyEngine::Conv(ConvEngine::with_backend(
                    c.clone(), w, timing, timesteps, backend)));
            }
            Layer::Pool { in_h, in_w, c } => {
                engines.push(LegacyEngine::Pool(PoolEngine::new(
                    *in_h, *in_w, *c)));
            }
            Layer::Fc { n_in, n_out } => {
                let eng = match sources.pop().expect("fc source") {
                    LegacySource::Random { seed } => {
                        FcEngine::random(*n_in, *n_out, seed)
                    }
                    LegacySource::Fc { weights, scale, bias } => {
                        FcEngine::new(*n_in, *n_out, weights, scale, bias)
                    }
                    LegacySource::Conv(_) => panic!("want fc"),
                };
                engines.push(LegacyEngine::Fc(eng.with_backend(backend)));
            }
        }
    }
    assert!(sources.is_empty(), "unused legacy sources");

    let energy_model = EnergyModel::default();
    let mut layer_cycles = vec![0u64; engines.len()];
    let mut layer_energy_j = vec![0f64; engines.len()];
    let mut counters = AccessCounter::new();
    let mut ops_total = 0u64;
    let mut predictions = Vec::new();
    let mut logits_all = Vec::new();
    for (fi, frame) in frames.iter().enumerate() {
        let mut act = frame.clone();
        for (li, eng) in engines.iter_mut().enumerate() {
            match eng {
                LegacyEngine::Conv(ce) => {
                    let (out, rep) = ce.run_frame(&act, li == 0);
                    if fi == 0 {
                        layer_cycles[li] = rep.cycles;
                        layer_energy_j[li] = energy_model
                            .dynamic(rep.ops, &rep.counters)
                            .total_j();
                    }
                    ops_total += rep.ops;
                    counters.merge(&rep.counters);
                    act = out;
                }
                LegacyEngine::Pool(pe) => {
                    let (out, rep) = pe.run(&act);
                    if fi == 0 {
                        layer_cycles[li] = rep.cycles * timesteps as u64;
                        layer_energy_j[li] = energy_model
                            .dynamic(0, &rep.counters)
                            .total_j();
                    }
                    counters.merge(&rep.counters);
                    act = out;
                }
                LegacyEngine::Fc(fc) => {
                    let flat = FcEngine::flatten(&act);
                    let reps: Vec<Vec<bool>> =
                        (0..timesteps).map(|_| flat.clone()).collect();
                    let (cls, logits, rep) = fc.classify_full(&reps);
                    if fi == 0 {
                        layer_cycles[li] = rep.cycles;
                        layer_energy_j[li] = energy_model
                            .dynamic(rep.ops, &rep.counters)
                            .total_j();
                    }
                    ops_total += rep.ops;
                    counters.merge(&rep.counters);
                    predictions.push(cls);
                    logits_all.push(logits);
                }
            }
        }
    }
    let t_max = layer_cycles.iter().copied().max().unwrap_or(0);
    let t_sum: u64 = layer_cycles.iter().sum();
    let n = frames.len() as u64;
    LegacyReport {
        predictions,
        logits: logits_all,
        layer_cycles,
        t_max,
        t_sum,
        total_cycles: n * t_max + (t_sum - t_max),
        ops_per_frame: ops_total / n,
        counters,
        energy_per_frame_j: layer_energy_j.iter().sum(),
    }
}

fn assert_equivalent(rep: &sti_snn::session::Report, want: &LegacyReport,
                     ctx: &str) {
    assert_eq!(rep.predictions, want.predictions, "{ctx}: predictions");
    assert_eq!(rep.logits, want.logits, "{ctx}: logits");
    assert_eq!(rep.layer_cycles, want.layer_cycles,
               "{ctx}: layer cycles");
    assert_eq!(rep.t_max, want.t_max, "{ctx}: t_max");
    assert_eq!(rep.t_sum, want.t_sum, "{ctx}: t_sum");
    assert_eq!(rep.total_cycles, want.total_cycles,
               "{ctx}: total cycles");
    assert_eq!(rep.ops_per_frame, want.ops_per_frame, "{ctx}: ops");
    assert_eq!(rep.counters, want.counters, "{ctx}: access counters");
    assert!((rep.energy_per_frame_j - want.energy_per_frame_j).abs()
            <= 1e-15 * want.energy_per_frame_j.abs(),
            "{ctx}: energy {} vs {}", rep.energy_per_frame_j,
            want.energy_per_frame_j);
}

fn mini_net() -> NetworkSpec {
    NetBuilder::new("mini", (12, 12, 2))
        .encoder(4, 3)
        .conv(8, 3)
        .pool()
        .conv(8, 3)
        .pool()
        .fc(10)
        .build()
}

/// Small depthwise-separable net: covers all three conv modes.
fn mini_dsc_net() -> NetworkSpec {
    NetBuilder::new("mini-dsc", (12, 12, 2))
        .encoder(6, 3)
        .dwconv(3)
        .pwconv(8)
        .pool()
        .fc(10)
        .build()
}

fn random_frames(shape: (usize, usize, usize), n: usize, seed: u64)
                 -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.25,
                                    &mut rng))
        .collect()
}

/// Seeds matching `Weights::Random { seed: 1000 }`: layer i -> 1000+i.
fn random_sources(net: &NetworkSpec) -> Vec<LegacySource> {
    let n = net
        .layers
        .iter()
        .filter(|l| match l {
            Layer::Conv(c) => !c.encoder,
            Layer::Pool { .. } => false,
            Layer::Fc { .. } => true,
        })
        .count();
    (0..n)
        .map(|i| LegacySource::Random { seed: 1000 + i as u64 })
        .collect()
}

/// Synthetic weights, both backends, T = 1 and T = 2: the session
/// stack is bit-identical to the pre-refactor construction.
#[test]
fn session_matches_legacy_construction_synthetic() {
    for net in [mini_net(), sti_snn::arch::scnn3()] {
        for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
            for timesteps in [1usize, 2] {
                let mut session = Session::builder()
                    .network(net.clone())
                    .weights(Weights::Random { seed: 1000 })
                    .backend(backend)
                    .timesteps(timesteps)
                    .build()
                    .unwrap();
                let frames =
                    random_frames(session.input_shape(), 3, 77);
                let rep = session.infer_batch(&frames);
                let want = legacy_run(&net, backend, timesteps,
                                      random_sources(&net), &frames);
                assert_equivalent(
                    &rep, &want,
                    &format!("{} {backend} T={timesteps}", net.name));
            }
        }
    }
}

/// Intra-frame row bands stay bit-identical to current-main (serial,
/// full-repack) semantics through the facade: both backends x all
/// three conv modes (standard + DSC nets) x band counts {1, 2, 4}.
#[test]
fn session_intra_parallel_matches_legacy_construction() {
    for net in [mini_net(), mini_dsc_net()] {
        for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
            let frames_shape_seed = 78;
            let want = {
                let probe = Session::builder()
                    .network(net.clone())
                    .backend(backend)
                    .build()
                    .unwrap();
                let frames = random_frames(probe.input_shape(), 3,
                                           frames_shape_seed);
                drop(probe);
                legacy_run(&net, backend, 1, random_sources(&net),
                           &frames)
            };
            for bands in [1usize, 2, 4] {
                let mut session = Session::builder()
                    .network(net.clone())
                    .backend(backend)
                    .intra_parallel(bands)
                    .build()
                    .unwrap();
                let frames = random_frames(session.input_shape(), 3,
                                           frames_shape_seed);
                let rep = session.infer_batch(&frames);
                assert_equivalent(
                    &rep, &want,
                    &format!("{} {backend} bands={bands}", net.name));
            }
        }
    }
}

// --------------------------------------------------------------------------
// Artifact weights (synthetic artifact written to a temp dir)
// --------------------------------------------------------------------------

/// tiny net: encoder conv (off-accelerator) + conv + pool + fc, with
/// an int8 weight blob — the same layout `make artifacts` emits.
fn write_tiny_artifact(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    // conv layer 1 (non-encoder): 2 -> 2 channels, 3x3.
    // taps: [co][ci][9] = 2*2*9 = 36 int8 bytes at offset 0.
    // bias: 2 f32 = 8 bytes at offset 36.
    // fc: 8 -> 2, w 16 bytes at 44, b 8 bytes at 60.
    let mut blob: Vec<u8> = Vec::new();
    blob.extend((0..36u8).map(|i| i.wrapping_mul(7)));
    blob.extend(0.5f32.to_le_bytes());
    blob.extend((-0.5f32).to_le_bytes());
    blob.extend((0..16u8).map(|i| i.wrapping_mul(11)));
    blob.extend(1.0f32.to_le_bytes());
    blob.extend(2.0f32.to_le_bytes());
    std::fs::write(dir.join("weights.bin"), &blob).unwrap();

    let net_json = r#"{
      "name": "tiny", "input": [4, 4, 1], "vth": 0.05, "timesteps": 1,
      "layers": [
        {"kind":"conv","in_h":4,"in_w":4,"in_c":1,"co":2,"k":3,
         "pad":1,"encoder":true},
        {"kind":"conv","in_h":4,"in_w":4,"in_c":2,"co":2,"k":3,
         "pad":1,"encoder":false},
        {"kind":"pool","in_h":4,"in_w":4,"in_c":2},
        {"kind":"fc","in_h":2,"in_w":2,"in_c":2,"out":2}
      ],
      "tensors": [
        {"layer":1,"name":"w","kind":"int8","shape":[2,2,9],
         "scale":0.01,"offset":0,"len":36},
        {"layer":1,"name":"b","kind":"f32","shape":[2],
         "scale":1.0,"offset":36,"len":8},
        {"layer":3,"name":"w","kind":"int8","shape":[8,2],
         "scale":0.02,"offset":44,"len":16},
        {"layer":3,"name":"b","kind":"f32","shape":[2],
         "scale":1.0,"offset":60,"len":8}
      ]
    }"#;
    std::fs::write(dir.join("net.json"), net_json).unwrap();
}

/// The legacy sources for the tiny artifact, decoded by hand exactly
/// as the pre-refactor `Artifact::layer_params` did.
fn tiny_artifact_sources(net: &NetworkSpec) -> Vec<LegacySource> {
    let conv = match &net.layers[1] {
        Layer::Conv(c) => c.clone(),
        _ => panic!("layer 1 is the accelerated conv"),
    };
    let taps: Vec<i8> =
        (0..36u8).map(|i| i.wrapping_mul(7) as i8).collect();
    let conv_w = ConvWeights::new(&conv, taps, 0.01, vec![0.5, -0.5],
                                  0.05);
    let fc_w: Vec<i8> =
        (0..16u8).map(|i| i.wrapping_mul(11) as i8).collect();
    vec![
        LegacySource::Conv(conv_w),
        LegacySource::Fc {
            weights: fc_w,
            scale: 0.02,
            bias: vec![1.0, 2.0],
        },
    ]
}

/// Artifact weights, both backends: the session stack loaded via
/// `Weights::Artifact` matches the hand-decoded legacy construction.
#[test]
fn session_matches_legacy_construction_artifact() {
    let dir: PathBuf =
        std::env::temp_dir().join("sti_snn_prop_session_artifact");
    write_tiny_artifact(&dir);
    for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
        let mut session = Session::builder()
            .weights(Weights::Artifact(dir.clone()))
            .backend(backend)
            .build()
            .unwrap();
        assert_eq!(session.net().name, "tiny");
        assert_eq!(session.input_shape(), (4, 4, 2));
        let frames = random_frames((4, 4, 2), 4, 99);
        let rep = session.infer_batch(&frames);
        let want = legacy_run(session.net(), backend, 1,
                              tiny_artifact_sources(session.net()),
                              &frames);
        assert_equivalent(&rep, &want, &format!("artifact {backend}"));
    }
}

/// An explicit network that doesn't describe the artifact is rejected
/// at build — artifact tensors must never be paired with foreign
/// layer geometry.
#[test]
fn session_rejects_network_artifact_mismatch() {
    let dir: PathBuf =
        std::env::temp_dir().join("sti_snn_prop_session_mismatch");
    write_tiny_artifact(&dir);
    let err = Session::builder()
        .network(sti_snn::arch::scnn3())
        .weights(Weights::Artifact(dir))
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("does not match"), "{err:#}");
}

/// The two backends agree with each other through the facade too
/// (bit-exact spikes AND identical reports) — the serving guarantee.
#[test]
fn session_backends_are_bit_exact_through_the_facade() {
    let net = mini_net();
    let mut reports = Vec::new();
    for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
        let mut session = Session::builder()
            .network(net.clone())
            .backend(backend)
            .build()
            .unwrap();
        let frames = random_frames(session.input_shape(), 2, 55);
        reports.push(session.infer_batch(&frames));
    }
    let (a, b) = (&reports[0], &reports[1]);
    assert_eq!(a.predictions, b.predictions);
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.layer_cycles, b.layer_cycles);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.ops_per_frame, b.ops_per_frame);
    assert_eq!(a.counters, b.counters);
}

/// The streamed per-layer-worker schedule (`pipelined(true)`, the
/// default) and the serial layer loop (`pipelined(false)`) produce
/// bit-identical architectural reports through the facade — only
/// `total_cycles` differs, and only by the documented accounting
/// (Eq. (10) streamed, N x t_sum serial) — across backends x conv
/// modes (standard + DSC nets) x intra-frame band counts {1, 2, 4}.
#[test]
fn session_streamed_schedule_matches_serial_bit_exact() {
    for net in [mini_net(), mini_dsc_net()] {
        for backend in [BackendKind::Accurate, BackendKind::WordParallel] {
            for bands in [1usize, 2, 4] {
                let build = |pipelined: bool| {
                    Session::builder()
                        .network(net.clone())
                        .backend(backend)
                        .intra_parallel(bands)
                        .pipelined(pipelined)
                        .build()
                        .unwrap()
                };
                let mut serial = build(false);
                let mut streamed = build(true);
                let frames = random_frames(serial.input_shape(), 3, 81);
                let rs = serial.infer_batch(&frames);
                let rp = streamed.infer_batch(&frames);
                let ctx = format!("{} {backend} bands={bands}",
                                  net.name);
                assert_eq!(rp.predictions, rs.predictions,
                           "{ctx}: predictions");
                assert_eq!(rp.logits, rs.logits, "{ctx}: logits");
                assert_eq!(rp.layer_names, rs.layer_names,
                           "{ctx}: layer names");
                assert_eq!(rp.layer_cycles, rs.layer_cycles,
                           "{ctx}: layer cycles");
                assert_eq!(rp.layer_energy, rs.layer_energy,
                           "{ctx}: energy");
                assert_eq!(rp.layer_vmem_bytes, rs.layer_vmem_bytes,
                           "{ctx}: vmem");
                assert_eq!(rp.codec_ratios, rs.codec_ratios,
                           "{ctx}: codec ratios");
                assert_eq!(rp.t_max, rs.t_max, "{ctx}: t_max");
                assert_eq!(rp.t_sum, rs.t_sum, "{ctx}: t_sum");
                assert_eq!(rp.ops_per_frame, rs.ops_per_frame,
                           "{ctx}: ops");
                assert_eq!(rp.counters, rs.counters, "{ctx}: counters");
                let n = frames.len() as u64;
                assert_eq!(rs.total_cycles, n * rs.t_sum,
                           "{ctx}: serial total");
                assert_eq!(rp.total_cycles,
                           n * rp.t_max + (rp.t_sum - rp.t_max),
                           "{ctx}: streamed total (Eq. 10)");
            }
        }
    }
}
