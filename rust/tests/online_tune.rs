//! End-to-end acceptance test for the online auto-tuner (ISSUE 8):
//! drive a live replica pool through a measured-workload shift, watch
//! the controller hot-swap generations, and assert the three serving
//! invariants:
//!
//! 1. **No dropped frames** — every submitted frame resolves with a
//!    prediction across the swap; zero backend errors.
//! 2. **Reproducible decision** — replaying the logged snapshot
//!    through `autotune::plan` offline picks exactly the candidate the
//!    controller swapped to.
//! 3. **Bit-exact serving** — the same probe frame classifies to the
//!    same logits before and after the swap (the backend/factor
//!    invariance contract extends to hot-swapped generations).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use sti_snn::autotune::{plan, RetunePolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::dse;
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::util::rng::Rng;

/// A deliberately weak boot (one replica, event-driven backend, unit
/// factors) under a fast-reacting policy: the first eligible re-plan
/// finds a strictly better point, so the swap fires deterministically.
fn fast_policy() -> RetunePolicy {
    RetunePolicy {
        interval: Duration::from_millis(50),
        min_frames: 8,
        hysteresis: 0.01,
        cooldown: Duration::ZERO,
        max_density_spread: 10.0,
        headroom: 1.25,
    }
}

#[test]
fn online_tuner_swaps_generations_without_dropping_frames() {
    let policy = fast_policy();
    let mut session = Session::builder()
        .model("scnn3")
        .replicas(1)
        .backend(BackendKind::Accurate)
        .queue(4, Duration::from_millis(1))
        .online_tune(policy.clone())
        .build()
        .unwrap();
    let net = session.net().clone();
    let (h, w, c) = session.input_shape();
    let mut rng = Rng::new(7);

    // Fixed probe frame for the bit-exactness check.
    let probe = SpikeFrame::random(h, w, c, 0.3, &mut rng);
    let pre = session
        .submit(probe.clone())
        .unwrap()
        .recv_timeout(Duration::from_secs(60))
        .unwrap();
    assert!(pre.prediction.is_some(), "boot generation must serve");

    let log = session.retune_log().expect("tuner spawned with the pool");
    assert_eq!(session.pool_generation(), Some(0));

    // Live traffic with a density shift: sparse first, then dense.
    // Keep submitting until the controller completes a swap, draining
    // replies as they arrive so every receiver is accounted for.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut pending = VecDeque::new();
    let mut submitted = 0u64;
    let mut resolved = 0u64;
    while log.retunes() == 0 {
        assert!(Instant::now() < deadline,
                "no swap after 120s: {:?}", log.summary());
        let rate = if submitted < 32 { 0.05 } else { 0.6 };
        for _ in 0..2 {
            let f = SpikeFrame::random(h, w, c, rate, &mut rng);
            pending.push_back(session.submit(f).unwrap());
            submitted += 1;
        }
        while let Some(rx) = pending.front() {
            match rx.try_recv() {
                Ok(r) => {
                    assert!(r.prediction.is_some());
                    resolved += 1;
                    pending.pop_front();
                }
                Err(_) => break,
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    // 1. Every in-flight frame resolves across the swap — nothing is
    //    dropped or shed by the generation handover.
    for rx in pending {
        let r = rx.recv_timeout(Duration::from_secs(60))
            .expect("frame submitted before/through the swap resolves");
        assert!(r.prediction.is_some());
        resolved += 1;
    }
    assert_eq!(resolved, submitted);
    let totals = session.pool_metrics().unwrap().totals();
    assert_eq!(totals.errors, 0, "no errors attributable to the swap");

    // The generation actually advanced, and telemetry agrees.
    let generation = session.pool_generation().unwrap();
    assert!(generation >= 1, "swap must advance the pool generation");
    assert_eq!(log.generation(), generation);
    let snap = session.telemetry();
    let retune = snap.retune.expect("telemetry carries retune summary");
    assert!(retune.retunes >= 1);
    assert_eq!(retune.generation, generation);
    assert!(retune.last_gain.unwrap() >= policy.hysteresis);

    // 2. The logged decision replays offline: the same measured
    //    snapshot, baseline calibration, and search options re-plan to
    //    exactly the candidate the controller swapped to.
    let ev = log.events().into_iter().next().expect("swap logged");
    assert_ne!(ev.from, ev.to, "a swap must change the configuration");
    let baseline = log.baseline().expect("baseline recorded");
    let d = dse::AutoTuneOptions::default();
    let opts = dse::AutoTuneOptions {
        max_replicas: d.max_replicas.max(1),
        timesteps: 1,
        intra_parallel: 1,
        pipelined: true,
        ..d
    };
    let replay = plan(&net, &opts, &baseline.calibration,
                      baseline.reference_density, &ev.from,
                      policy.headroom, &ev.snapshot)
        .unwrap()
        .expect("logged snapshot must be plannable");
    assert_eq!(replay.chosen.candidate, ev.to,
               "offline re-plan of the logged snapshot must pick the \
                swapped-to candidate");

    // 3. Bit-exact across the swap: the same probe frame gets the same
    //    prediction and logits from the new generation.
    let post = session
        .submit(probe)
        .unwrap()
        .recv_timeout(Duration::from_secs(60))
        .unwrap();
    assert_eq!(pre.prediction, post.prediction);
    assert_eq!(pre.logits, post.logits,
               "hot-swap must preserve bit-exact serving");

    session.shutdown();
}
