//! Smoke: execute the jax/Pallas-lowered HLO from rust PJRT and match
//! the python reference numerics — the AOT-bridge integration test.
//!
//! Fixtures are produced by python/tests/test_aot.py::
//! test_generate_rust_smoke_fixtures (run `make test` python side
//! first); the test skips when they are absent.

use std::path::Path;

use sti_snn::runtime::Runtime;

fn read_f32(path: &Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn pallas_lowered_hlo_runs_in_rust() {
    let dir = Path::new("/tmp/sti_snn_fixture");
    if !dir.join("model.hlo.txt").exists() {
        eprintln!("fixtures missing (run pytest first); skipping");
        return;
    }
    let img = read_f32(&dir.join("img.f32"));
    let want = read_f32(&dir.join("logits.f32"));

    let mut rt = Runtime::new().unwrap();
    if let Err(e) = rt.load_hlo("m", &dir.join("model.hlo.txt"),
                                (28, 28, 1)) {
        // Stub runtime (built without the `pjrt` feature): skip.
        eprintln!("runtime unavailable ({e:#}); skipping");
        return;
    }
    let got = rt.logits("m", &img).unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want.iter()) {
        assert!((g - w).abs() < 1e-3, "got {g} want {w}");
    }
    println!("rust PJRT logits match the jax/Pallas reference: {got:?}");
}
