//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin) exactly as the
//! reference wiring in /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (jax >= 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Python never runs at inference time: `make artifacts` lowers the
//! L2 jax graphs (which call the L1 Pallas kernels, interpret mode)
//! once; this module compiles the text on startup and executes from
//! the request path.
//!
//! ## Feature gating
//!
//! The `xla` crate needs the XLA toolchain, which most build hosts do
//! not have. The real implementation is therefore gated behind the
//! `pjrt` cargo feature; without it this module compiles a **stub**
//! with the same API whose entry points return errors at runtime, so
//! `cargo build && cargo test` pass everywhere and callers degrade
//! gracefully (the CLI's `serve --synthetic` path needs no runtime at
//! all).

use std::path::PathBuf;

/// Locate the artifacts directory (env override for tests).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("STI_SNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{Context, Result};

    use crate::codec::SpikeFrame;

    /// A compiled executable plus its I/O geometry.
    pub struct CompiledModel {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
        /// Input shape (H, W, C) of the image the graph expects.
        pub input_shape: (usize, usize, usize),
    }

    /// The runtime: one PJRT CPU client, many compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        models: HashMap<String, CompiledModel>,
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client, models: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile an HLO text file into a named executable.
        pub fn load_hlo(&mut self, name: &str, path: &Path,
                        input_shape: (usize, usize, usize)) -> Result<()> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.models.insert(
                name.to_string(),
                CompiledModel { name: name.to_string(), exe, input_shape },
            );
            Ok(())
        }

        pub fn has(&self, name: &str) -> bool {
            self.models.contains_key(name)
        }

        /// Execute a single-input graph on an (H, W, C) f32 image,
        /// returning the flat f32 outputs of every tuple element.
        pub fn run_image(&self, name: &str, image: &[f32])
                         -> Result<Vec<Vec<f32>>> {
            let m = self
                .models
                .get(name)
                .with_context(|| format!("model {name} not loaded"))?;
            let (h, w, c) = m.input_shape;
            anyhow::ensure!(image.len() == h * w * c,
                            "image size {} != {h}x{w}x{c}", image.len());
            let lit = xla::Literal::vec1(image)
                .reshape(&[h as i64, w as i64, c as i64])?;
            let result = m.exe.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True.
            let elems = result.to_tuple()?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>()?);
            }
            Ok(out)
        }

        /// Run the spike-encoder graph: image -> binary spike frame.
        pub fn encode(&self, name: &str, image: &[f32],
                      out_shape: (usize, usize, usize))
                      -> Result<SpikeFrame> {
            let outs = self.run_image(name, image)?;
            let spikes = &outs[0];
            let (h, w, c) = out_shape;
            anyhow::ensure!(spikes.len() == h * w * c,
                            "encoder output {} != {h}x{w}x{c}",
                            spikes.len());
            Ok(SpikeFrame::from_f32(h, w, c, spikes))
        }

        /// Run the full-net graph: image -> per-class logits.
        pub fn logits(&self, name: &str, image: &[f32])
                      -> Result<Vec<f32>> {
            let outs = self.run_image(name, image)?;
            Ok(outs.last().context("empty output tuple")?.clone())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use std::path::Path;

    use anyhow::Result;

    use crate::codec::SpikeFrame;

    /// API-compatible stub compiled when the `pjrt` feature is off:
    /// construction succeeds (so binaries link and start everywhere);
    /// anything that would need XLA returns a descriptive error.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            Ok(Self { _private: () })
        }

        pub fn platform(&self) -> String {
            "stub (built without the `pjrt` feature)".to_string()
        }

        pub fn load_hlo(&mut self, name: &str, path: &Path,
                        _input_shape: (usize, usize, usize)) -> Result<()> {
            anyhow::bail!(
                "cannot compile HLO {path:?} for model {name}: this \
                 binary was built without the `pjrt` feature (rebuild \
                 with `--features pjrt` and a vendored xla crate, or \
                 use the simulator-only paths, e.g. `serve --synthetic`)"
            )
        }

        pub fn has(&self, _name: &str) -> bool {
            false
        }

        pub fn run_image(&self, name: &str, _image: &[f32])
                         -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("model {name} not loaded (pjrt feature disabled)")
        }

        pub fn encode(&self, name: &str, image: &[f32],
                      _out_shape: (usize, usize, usize))
                      -> Result<SpikeFrame> {
            self.run_image(name, image).map(|_| unreachable!())
        }

        pub fn logits(&self, name: &str, image: &[f32])
                      -> Result<Vec<f32>> {
            self.run_image(name, image).map(|_| unreachable!())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{CompiledModel, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Runtime;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    /// Compiles and runs a hand-written HLO module (no artifacts
    /// needed): f(x) = (x + 1,) over f32[2,3,1].
    #[test]
    fn run_handwritten_hlo() {
        let hlo = r#"
HloModule add_one, entry_computation_layout={(f32[2,3,1]{2,1,0})->(f32[2,3,1]{2,1,0})}

ENTRY main {
  x = f32[2,3,1]{2,1,0} parameter(0)
  one = f32[] constant(1)
  ones = f32[2,3,1]{2,1,0} broadcast(one), dimensions={}
  sum = f32[2,3,1]{2,1,0} add(x, ones)
  ROOT t = (f32[2,3,1]{2,1,0}) tuple(sum)
}
"#;
        let dir = std::env::temp_dir().join("sti_snn_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("add_one.hlo.txt");
        std::fs::write(&path, hlo).unwrap();

        let mut rt = Runtime::new().unwrap();
        rt.load_hlo("add1", &path, (2, 3, 1)).unwrap();
        assert!(rt.has("add1"));
        let img: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let outs = rt.run_image("add1", &img).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0], vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn missing_model_errors() {
        let rt = Runtime::new().unwrap();
        assert!(rt.run_image("nope", &[0.0]).is_err());
    }

    #[test]
    fn wrong_image_size_errors() {
        let hlo_dir = std::env::temp_dir().join("sti_snn_rt_test2");
        std::fs::create_dir_all(&hlo_dir).unwrap();
        // Reuse the add-one module.
        let hlo = r#"
HloModule add_one, entry_computation_layout={(f32[1,1,1]{2,1,0})->(f32[1,1,1]{2,1,0})}

ENTRY main {
  x = f32[1,1,1]{2,1,0} parameter(0)
  ROOT t = (f32[1,1,1]{2,1,0}) tuple(x)
}
"#;
        let path = hlo_dir.join("id.hlo.txt");
        std::fs::write(&path, hlo).unwrap();
        let mut rt = Runtime::new().unwrap();
        rt.load_hlo("id", &path, (1, 1, 1)).unwrap();
        assert!(rt.run_image("id", &[1.0, 2.0]).is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_constructs_and_errors_cleanly() {
        let mut rt = Runtime::new().unwrap();
        assert!(rt.platform().contains("stub"));
        assert!(!rt.has("anything"));
        let err = rt
            .load_hlo("m", std::path::Path::new("/nope.hlo.txt"), (1, 1, 1))
            .unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        assert!(rt.run_image("m", &[0.0]).is_err());
        assert!(rt.logits("m", &[0.0]).is_err());
        assert!(rt.encode("m", &[0.0], (1, 1, 1)).is_err());
    }
}
