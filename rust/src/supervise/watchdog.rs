//! Per-frame deadline monitoring for the streamed executor.
//!
//! The streamed schedule runs one worker per layer connected by
//! bounded row channels; a stalled worker (bug, injected
//! `StallChannel` fault, pathological input) would otherwise block
//! its neighbours forever on `recv`/`acquire`. With a
//! [`WatchdogPolicy`] armed, workers wait on the channels in bounded
//! slices and check a shared [`Deadline`]; whoever notices the
//! deadline first aborts the frame, the abort flag cascades through
//! the other workers, the scoped pipeline tears down, and — policy
//! permitting — the frame batch is retried once on the serial
//! schedule (identical reports, graceful degradation instead of a
//! hang).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deadline policy for one `Pipeline::run` call on the streamed
/// schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WatchdogPolicy {
    /// Wall-clock budget per frame (the heartbeat: every row forward
    /// is progress; a frame that stops progressing past this fires).
    pub deadline: Duration,
    /// Retry the batch once on the serial schedule after a fire
    /// (otherwise the run reports an error).
    pub retry_serial: bool,
}

impl Default for WatchdogPolicy {
    fn default() -> Self {
        Self { deadline: Duration::from_secs(5), retry_serial: true }
    }
}

impl WatchdogPolicy {
    pub fn with_deadline_ms(ms: u64) -> Self {
        Self { deadline: Duration::from_millis(ms), ..Self::default() }
    }
}

/// Shared frame deadline: armed per frame, polled by every layer
/// worker between channel waits.
pub struct Deadline {
    due: Instant,
    aborted: Arc<AtomicBool>,
}

impl Deadline {
    /// Arm a deadline `budget` from now with a shared abort flag.
    pub fn arm(budget: Duration, aborted: Arc<AtomicBool>) -> Self {
        Self { due: Instant::now() + budget, aborted }
    }

    /// True once the budget is spent or any worker already aborted.
    pub fn expired(&self) -> bool {
        self.aborted.load(Ordering::SeqCst) || Instant::now() >= self.due
    }

    /// Mark the whole frame aborted (cascades to every worker).
    pub fn fire(&self) {
        self.aborted.store(true, Ordering::SeqCst);
    }

    /// How long a channel wait may block before re-checking: the
    /// remaining budget, clamped to `slice` so the abort flag is
    /// polled at least that often.
    pub fn wait_slice(&self, slice: Duration) -> Duration {
        self.due
            .saturating_duration_since(Instant::now())
            .min(slice)
            .max(Duration::from_millis(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires_on_time_or_abort() {
        let flag = Arc::new(AtomicBool::new(false));
        let d = Deadline::arm(Duration::from_secs(60), flag.clone());
        assert!(!d.expired());
        d.fire();
        assert!(d.expired(), "abort flag expires every worker's view");
        assert!(flag.load(Ordering::SeqCst));

        let d = Deadline::arm(Duration::from_millis(0),
                              Arc::new(AtomicBool::new(false)));
        assert!(d.expired(), "zero budget is already due");
    }

    #[test]
    fn wait_slice_is_bounded_and_positive() {
        let d = Deadline::arm(Duration::from_secs(60),
                              Arc::new(AtomicBool::new(false)));
        let s = d.wait_slice(Duration::from_millis(20));
        assert!(s <= Duration::from_millis(20));
        assert!(s >= Duration::from_millis(1));

        let d = Deadline::arm(Duration::from_millis(0),
                              Arc::new(AtomicBool::new(false)));
        assert_eq!(d.wait_slice(Duration::from_millis(20)),
                   Duration::from_millis(1),
                   "expired deadline still polls, never busy-spins");
    }
}
