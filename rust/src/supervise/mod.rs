//! Supervision layer: panic isolation, budgeted restarts, watchdogs,
//! and deterministic fault injection.
//!
//! The serving tier (replica pool + streamed executor + online tuner)
//! must run unattended: a panic in any worker thread, a stalled row
//! channel, or a bad retune candidate degrades service instead of
//! silently killing a component for the life of the process.
//!
//! - [`policy`]: [`RestartPolicy`] budgeted exponential backoff and
//!   the [`Supervisor`] that turns worker crashes into
//!   restart-or-retire [`Verdict`]s.
//! - [`watchdog`]: [`WatchdogPolicy`] deadlines over the streamed
//!   executor; an overdue frame tears the pipeline down and retries
//!   once on the serial schedule.
//! - [`faults`]: seeded [`FaultPlan`] schedules injected through
//!   `Option`-based runtime hooks ([`FaultHooks`]) that are `None` in
//!   production — no `#[cfg]`, no hot-path allocation.
//!
//! Every supervision action ticks a counter on [`SuperviseStats`];
//! the server's metrics endpoint exports them as
//! `sti_replica_restarts_total`, `sti_watchdog_fires_total`, and
//! `sti_retune_rollbacks_total`.

pub mod faults;
pub mod policy;
pub mod watchdog;

pub use faults::{FaultEvent, FaultHooks, FaultPlan, ServeFault,
                 REPLICA_PROBE};
pub use policy::{RestartPolicy, Supervisor, Verdict};
pub use watchdog::{Deadline, WatchdogPolicy};

use std::sync::atomic::{AtomicU64, Ordering};

/// Best-effort extraction of a caught panic payload's message (the
/// `&str`/`String` cases `panic!` produces; anything else gets a
/// placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Shared supervision counters (one set per `Session`/pool, exported
/// by the metrics endpoint).
#[derive(Debug, Default)]
pub struct SuperviseStats {
    /// Replica workers restarted after a caught panic.
    pub replica_restarts: AtomicU64,
    /// Replica workers retired after exhausting the restart budget.
    pub replicas_retired: AtomicU64,
    /// Streamed-executor frames aborted by the watchdog (or a worker
    /// crash) and recovered on the serial schedule.
    pub watchdog_fires: AtomicU64,
    /// Retune generations rolled back (failed health probe or panic
    /// during the swap).
    pub retune_rollbacks: AtomicU64,
    /// Online-tuner control loops restarted after a caught panic.
    pub tuner_restarts: AtomicU64,
}

/// Plain-value snapshot of [`SuperviseStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SuperviseSnapshot {
    pub replica_restarts: u64,
    pub replicas_retired: u64,
    pub watchdog_fires: u64,
    pub retune_rollbacks: u64,
    pub tuner_restarts: u64,
}

impl SuperviseStats {
    pub fn snapshot(&self) -> SuperviseSnapshot {
        SuperviseSnapshot {
            replica_restarts: self.replica_restarts.load(Ordering::SeqCst),
            replicas_retired: self.replicas_retired.load(Ordering::SeqCst),
            watchdog_fires: self.watchdog_fires.load(Ordering::SeqCst),
            retune_rollbacks: self.retune_rollbacks.load(Ordering::SeqCst),
            tuner_restarts: self.tuner_restarts.load(Ordering::SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reads_every_counter() {
        let s = SuperviseStats::default();
        s.replica_restarts.fetch_add(2, Ordering::SeqCst);
        s.watchdog_fires.fetch_add(1, Ordering::SeqCst);
        s.retune_rollbacks.fetch_add(3, Ordering::SeqCst);
        let snap = s.snapshot();
        assert_eq!(snap.replica_restarts, 2);
        assert_eq!(snap.replicas_retired, 0);
        assert_eq!(snap.watchdog_fires, 1);
        assert_eq!(snap.retune_rollbacks, 3);
        assert_eq!(snap.tuner_restarts, 0);
    }
}
