//! Restart policies and the [`Supervisor`] bookkeeping behind them.
//!
//! A supervised worker (replica worker thread, tuner control loop)
//! reports each crash to a shared [`Supervisor`]; the verdict is
//! either *restart after a backoff* or *retire*. The budget is a
//! rolling window — `max_restarts` crashes inside `window` retire the
//! worker — so a worker that crashes once a day keeps restarting
//! forever while a crash loop burns its budget in milliseconds and
//! degrades the pool to the survivors instead of spinning.
//!
//! Every decision method takes the clock as an argument
//! ([`Supervisor::decide_at`]) so tests drive the rolling window with
//! a synthetic timeline; [`Supervisor::decide`] is the `Instant::now`
//! convenience used by production callers.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Budgeted exponential-backoff restart policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPolicy {
    /// Crashes tolerated per rolling `window` before retiring.
    pub max_restarts: u32,
    /// Rolling budget window.
    pub window: Duration,
    /// Backoff before the first restart; doubles per consecutive
    /// restart inside the window.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        Self {
            max_restarts: 3,
            window: Duration::from_secs(30),
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(2),
        }
    }
}

impl RestartPolicy {
    /// A policy that never restarts (first crash retires the worker).
    pub fn never() -> Self {
        Self { max_restarts: 0, ..Self::default() }
    }

    /// Exponential backoff for the `attempt`-th restart in the
    /// current window (0-based), capped at `max_backoff`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let mult = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base_backoff
            .checked_mul(mult)
            .map_or(self.max_backoff, |d| d.min(self.max_backoff))
    }
}

/// What a crashed worker should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Sleep `delay`, rebuild, and resume serving.
    Restart { delay: Duration },
    /// Budget exhausted: exit for good; the pool degrades to the
    /// survivors.
    Retire,
}

/// Tracks restarts per worker lane and applies a [`RestartPolicy`].
///
/// Shared across the workers of one generation (or one tuner); cheap
/// enough that contention is irrelevant — it is only locked when a
/// worker crashes.
pub struct Supervisor {
    policy: RestartPolicy,
    /// Restart timestamps per worker, pruned to the rolling window.
    lanes: Mutex<Vec<Vec<Instant>>>,
    /// Total restarts ever granted (survives window pruning).
    granted: std::sync::atomic::AtomicU64,
}

impl Supervisor {
    pub fn new(policy: RestartPolicy, workers: usize) -> Self {
        Self {
            policy,
            lanes: Mutex::new(vec![Vec::new(); workers]),
            granted: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &RestartPolicy {
        &self.policy
    }

    /// Total restarts granted across all lanes since construction.
    pub fn restarts_granted(&self) -> u64 {
        self.granted.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Judge a crash of `worker` at the injected time `now`.
    pub fn decide_at(&self, worker: usize, now: Instant) -> Verdict {
        let mut lanes =
            self.lanes.lock().unwrap_or_else(|e| e.into_inner());
        if worker >= lanes.len() {
            lanes.resize(worker + 1, Vec::new());
        }
        let lane = &mut lanes[worker];
        lane.retain(|t| {
            now.saturating_duration_since(*t) < self.policy.window
        });
        if lane.len() as u32 >= self.policy.max_restarts {
            return Verdict::Retire;
        }
        let attempt = lane.len() as u32;
        lane.push(now);
        self.granted
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        Verdict::Restart { delay: self.policy.backoff(attempt) }
    }

    /// Judge a crash of `worker` right now.
    pub fn decide(&self, worker: usize) -> Verdict {
        self.decide_at(worker, Instant::now())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy {
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(35),
            ..RestartPolicy::default()
        };
        assert_eq!(p.backoff(0), Duration::from_millis(10));
        assert_eq!(p.backoff(1), Duration::from_millis(20));
        assert_eq!(p.backoff(2), Duration::from_millis(35), "capped");
        assert_eq!(p.backoff(40), Duration::from_millis(35),
                   "shift overflow saturates at the cap");
    }

    #[test]
    fn budget_exhausts_then_retires() {
        let p = RestartPolicy {
            max_restarts: 2,
            window: Duration::from_secs(10),
            ..RestartPolicy::default()
        };
        let s = Supervisor::new(p, 1);
        let t0 = Instant::now();
        assert!(matches!(s.decide_at(0, t0), Verdict::Restart { .. }));
        assert!(matches!(s.decide_at(0, t0), Verdict::Restart { .. }));
        assert_eq!(s.decide_at(0, t0), Verdict::Retire);
        assert_eq!(s.restarts_granted(), 2);
    }

    #[test]
    fn window_rolls_the_budget_back() {
        let p = RestartPolicy {
            max_restarts: 1,
            window: Duration::from_secs(5),
            ..RestartPolicy::default()
        };
        let s = Supervisor::new(p, 1);
        let t0 = Instant::now();
        assert!(matches!(s.decide_at(0, t0), Verdict::Restart { .. }));
        assert_eq!(s.decide_at(0, t0 + Duration::from_secs(1)),
                   Verdict::Retire);
        // Past the window the crash record expires: budget refreshed.
        assert!(matches!(s.decide_at(0, t0 + Duration::from_secs(6)),
                         Verdict::Restart { .. }));
    }

    #[test]
    fn lanes_are_independent() {
        let p = RestartPolicy { max_restarts: 1,
                                ..RestartPolicy::default() };
        let s = Supervisor::new(p, 2);
        let t0 = Instant::now();
        assert!(matches!(s.decide_at(0, t0), Verdict::Restart { .. }));
        assert_eq!(s.decide_at(0, t0), Verdict::Retire);
        // Worker 1 still has its own budget.
        assert!(matches!(s.decide_at(1, t0), Verdict::Restart { .. }));
    }

    #[test]
    fn never_policy_retires_immediately() {
        let s = Supervisor::new(RestartPolicy::never(), 1);
        assert_eq!(s.decide(0), Verdict::Retire);
    }

    #[test]
    fn unseen_lane_grows_on_demand() {
        let s = Supervisor::new(RestartPolicy::default(), 1);
        assert!(matches!(s.decide(7), Verdict::Restart { .. }));
    }
}
