//! Deterministic fault injection: seeded [`FaultPlan`] schedules and
//! the runtime [`FaultHooks`] that fire them.
//!
//! A plan is a *pure data* schedule — which replica panics on which
//! frame, which streamed layer stalls for how long — so a chaos run is
//! reproducible from its seed alone. The hooks are `#[cfg]`-free:
//! production wiring passes `None` everywhere (an `Option<Arc<..>>`
//! check on the hot path, no allocation — the `alloc_budget` contract
//! is untouched), and `serve --chaos PLAN.json` or the chaos test
//! suite passes `Some`.
//!
//! Frame indices are **per-replica serve sequence numbers**: replica
//! `r`'s counter ticks once per frame it serves, surviving restarts,
//! so `PanicAt { replica: 1, frame: 2 }` fires on the third frame
//! replica 1 ever serves regardless of how the queue distributes work.
//! The probe sentinel [`REPLICA_PROBE`] targets the retune health
//! probe instead of a pool worker — a plan carrying
//! `PanicAt { replica: REPLICA_PROBE, .. }` kills the candidate
//! generation mid-swap and must yield a rollback.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// `replica` value addressing the retune health probe rather than a
/// pool worker.
pub const REPLICA_PROBE: usize = usize::MAX;

/// One scheduled fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// Panic inside replica `replica`'s worker while serving its
    /// `frame`-th frame (0-based per-replica sequence).
    PanicAt { replica: usize, frame: u64 },
    /// Stall streamed layer `layer`'s worker for `ms` before it
    /// starts its next frame (watchdog fodder).
    StallChannel { layer: usize, ms: u64 },
    /// Delay replica `replica`'s `frame`-th serve by `ms` without
    /// crashing (latency fault).
    SlowReplica { replica: usize, frame: u64, ms: u64 },
    /// Drop the reply channel for replica `replica`'s `frame`-th
    /// serve: the submitter sees a disconnect error, never a hang.
    DropReply { replica: usize, frame: u64 },
}

impl FaultEvent {
    fn kind(&self) -> &'static str {
        match self {
            FaultEvent::PanicAt { .. } => "panic_at",
            FaultEvent::StallChannel { .. } => "stall_channel",
            FaultEvent::SlowReplica { .. } => "slow_replica",
            FaultEvent::DropReply { .. } => "drop_reply",
        }
    }

    fn to_json(&self) -> Json {
        let num = |v: u64| Json::num(v as f64);
        let mut kv = vec![("kind", Json::str(self.kind()))];
        match *self {
            FaultEvent::PanicAt { replica, frame } => {
                kv.push(("replica", num(replica as u64)));
                kv.push(("frame", num(frame)));
            }
            FaultEvent::StallChannel { layer, ms } => {
                kv.push(("layer", num(layer as u64)));
                kv.push(("ms", num(ms)));
            }
            FaultEvent::SlowReplica { replica, frame, ms } => {
                kv.push(("replica", num(replica as u64)));
                kv.push(("frame", num(frame)));
                kv.push(("ms", num(ms)));
            }
            FaultEvent::DropReply { replica, frame } => {
                kv.push(("replica", num(replica as u64)));
                kv.push(("frame", num(frame)));
            }
        }
        Json::obj(kv)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let field = |k: &str| -> Result<u64> {
            v.get(k)
                .and_then(|x| x.as_f64())
                .map(|x| x as u64)
                .ok_or_else(|| {
                    anyhow::anyhow!("fault event missing field {k:?}")
                })
        };
        // `replica` may be the u64-encoded probe sentinel; map it back.
        let replica = |r: u64| -> usize {
            if r == u64::MAX || r == REPLICA_PROBE as u64 {
                REPLICA_PROBE
            } else {
                r as usize
            }
        };
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow::anyhow!("fault event missing kind"))?;
        Ok(match kind {
            "panic_at" => FaultEvent::PanicAt {
                replica: replica(field("replica")?),
                frame: field("frame")?,
            },
            "stall_channel" => FaultEvent::StallChannel {
                layer: field("layer")? as usize,
                ms: field("ms")?,
            },
            "slow_replica" => FaultEvent::SlowReplica {
                replica: replica(field("replica")?),
                frame: field("frame")?,
                ms: field("ms")?,
            },
            "drop_reply" => FaultEvent::DropReply {
                replica: replica(field("replica")?),
                frame: field("frame")?,
            },
            other => anyhow::bail!("unknown fault kind {other:?}"),
        })
    }
}

/// A seeded, pure schedule of faults.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new(seed: u64, events: Vec<FaultEvent>) -> Self {
        Self { seed, events }
    }

    /// Generate `n` faults over `replicas` workers x `frames` frames
    /// x `layers` streamed layers, deterministically from `seed`. The
    /// CI soak sweeps seeds; the same seed always yields the same
    /// plan.
    pub fn generate(seed: u64, replicas: usize, frames: u64,
                    layers: usize, n: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_FA17);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let replica = rng.below(replicas.max(1));
            let frame = rng.below(frames.max(1) as usize) as u64;
            events.push(match rng.below(4) {
                0 => FaultEvent::PanicAt { replica, frame },
                1 => FaultEvent::StallChannel {
                    layer: rng.below(layers.max(1)),
                    ms: 1 + rng.below(20) as u64,
                },
                2 => FaultEvent::SlowReplica {
                    replica,
                    frame,
                    ms: 1 + rng.below(10) as u64,
                },
                _ => FaultEvent::DropReply { replica, frame },
            });
        }
        Self { seed, events }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("events",
             Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let seed = v
            .get("seed")
            .and_then(|s| s.as_f64())
            .unwrap_or(0.0) as u64;
        let events = v
            .get("events")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("fault plan missing events"))?
            .iter()
            .map(FaultEvent::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { seed, events })
    }
}

/// What [`FaultHooks::on_serve`] tells a replica worker to do for the
/// frame it is about to run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeFault {
    /// Panic inside the (caught) serve body.
    pub panic: bool,
    /// Sleep this long before serving.
    pub slow: Option<Duration>,
    /// Drop the reply sender instead of answering.
    pub drop_reply: bool,
}

impl ServeFault {
    pub fn is_none(&self) -> bool {
        *self == Self::default()
    }
}

/// Runtime fault state compiled from a [`FaultPlan`]: each event
/// fires exactly once (consumed flags), every firing is appended to a
/// log line buffer for the chaos artifact.
pub struct FaultHooks {
    plan: FaultPlan,
    consumed: Vec<AtomicBool>,
    injected: AtomicU64,
    log: Mutex<Vec<String>>,
}

impl FaultHooks {
    pub fn from_plan(plan: FaultPlan) -> Self {
        let consumed =
            (0..plan.events.len()).map(|_| AtomicBool::new(false)).collect();
        Self {
            plan,
            consumed,
            injected: AtomicU64::new(0),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Faults fired so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// Human-readable record of every fired fault (chaos artifact).
    pub fn log_lines(&self) -> Vec<String> {
        self.log.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn fire(&self, idx: usize, note: String) {
        self.injected.fetch_add(1, Ordering::SeqCst);
        self.log
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(format!("[{idx}] {note}"));
    }

    /// Claim event `idx` if it has not fired yet.
    fn claim(&self, idx: usize) -> bool {
        !self.consumed[idx].swap(true, Ordering::SeqCst)
    }

    /// Faults scheduled for `replica`'s `frame_seq`-th serve.
    pub fn on_serve(&self, replica: usize, frame_seq: u64) -> ServeFault {
        let mut f = ServeFault::default();
        for (i, ev) in self.plan.events.iter().enumerate() {
            match *ev {
                FaultEvent::PanicAt { replica: r, frame }
                    if r == replica && frame == frame_seq =>
                {
                    if self.claim(i) {
                        f.panic = true;
                        self.fire(i, format!(
                            "panic_at replica={replica} frame={frame_seq}"));
                    }
                }
                FaultEvent::SlowReplica { replica: r, frame, ms }
                    if r == replica && frame == frame_seq =>
                {
                    if self.claim(i) {
                        f.slow = Some(Duration::from_millis(ms));
                        self.fire(i, format!(
                            "slow_replica replica={replica} \
                             frame={frame_seq} ms={ms}"));
                    }
                }
                FaultEvent::DropReply { replica: r, frame }
                    if r == replica && frame == frame_seq =>
                {
                    if self.claim(i) {
                        f.drop_reply = true;
                        self.fire(i, format!(
                            "drop_reply replica={replica} \
                             frame={frame_seq}"));
                    }
                }
                _ => {}
            }
        }
        f
    }

    /// Stall scheduled for streamed layer `layer` (consumed once).
    pub fn stall(&self, layer: usize) -> Option<Duration> {
        for (i, ev) in self.plan.events.iter().enumerate() {
            if let FaultEvent::StallChannel { layer: l, ms } = *ev {
                if l == layer && self.claim(i) {
                    self.fire(i, format!(
                        "stall_channel layer={layer} ms={ms}"));
                    return Some(Duration::from_millis(ms));
                }
            }
        }
        None
    }

    /// A `PanicAt` aimed at [`REPLICA_PROBE`]: the retune health probe
    /// must die (consumed once).
    pub fn probe_panic(&self) -> bool {
        for (i, ev) in self.plan.events.iter().enumerate() {
            if let FaultEvent::PanicAt { replica: REPLICA_PROBE, .. } = *ev
            {
                if self.claim(i) {
                    self.fire(i, "panic_at probe".to_string());
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic_in_the_seed() {
        let a = FaultPlan::generate(7, 4, 32, 5, 12);
        let b = FaultPlan::generate(7, 4, 32, 5, 12);
        let c = FaultPlan::generate(8, 4, 32, 5, 12);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.events.len(), 12);
    }

    #[test]
    fn json_round_trips_every_kind() {
        let plan = FaultPlan::new(3, vec![
            FaultEvent::PanicAt { replica: 1, frame: 4 },
            FaultEvent::PanicAt { replica: REPLICA_PROBE, frame: 0 },
            FaultEvent::StallChannel { layer: 2, ms: 50 },
            FaultEvent::SlowReplica { replica: 0, frame: 9, ms: 5 },
            FaultEvent::DropReply { replica: 3, frame: 2 },
        ]);
        let text = plan.to_json().to_string();
        let back = FaultPlan::from_json(&text).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(FaultPlan::from_json("{\"seed\": 1}").is_err());
        assert!(FaultPlan::from_json(
            "{\"events\": [{\"kind\": \"meteor\"}]}").is_err());
        assert!(FaultPlan::from_json(
            "{\"events\": [{\"kind\": \"panic_at\"}]}").is_err());
    }

    #[test]
    fn each_event_fires_exactly_once() {
        let hooks = FaultHooks::from_plan(FaultPlan::new(0, vec![
            FaultEvent::PanicAt { replica: 0, frame: 1 },
            FaultEvent::StallChannel { layer: 1, ms: 5 },
        ]));
        assert!(hooks.on_serve(0, 0).is_none());
        assert!(hooks.on_serve(1, 1).is_none(), "wrong replica");
        let f = hooks.on_serve(0, 1);
        assert!(f.panic);
        assert!(hooks.on_serve(0, 1).is_none(), "consumed");
        assert_eq!(hooks.stall(0), None);
        assert_eq!(hooks.stall(1), Some(Duration::from_millis(5)));
        assert_eq!(hooks.stall(1), None, "consumed");
        assert_eq!(hooks.injected(), 2);
        assert_eq!(hooks.log_lines().len(), 2);
    }

    #[test]
    fn probe_sentinel_only_fires_the_probe_hook() {
        let hooks = FaultHooks::from_plan(FaultPlan::new(0, vec![
            FaultEvent::PanicAt { replica: REPLICA_PROBE, frame: 0 },
        ]));
        assert!(hooks.on_serve(0, 0).is_none(),
                "pool workers never match the probe sentinel");
        assert!(hooks.probe_panic());
        assert!(!hooks.probe_panic(), "consumed");
    }

    #[test]
    fn combined_faults_on_one_frame_compose() {
        let hooks = FaultHooks::from_plan(FaultPlan::new(0, vec![
            FaultEvent::SlowReplica { replica: 2, frame: 3, ms: 1 },
            FaultEvent::DropReply { replica: 2, frame: 3 },
        ]));
        let f = hooks.on_serve(2, 3);
        assert_eq!(f.slow, Some(Duration::from_millis(1)));
        assert!(f.drop_reply);
        assert!(!f.panic);
    }
}
