//! Compute array: `Kh x Kw` PEs per output-channel lane, `parallel`
//! lanes (paper SectionIV-B + SectionIV-E.2).
//!
//! The array processes one receptive field at a time (the spike-vector
//! window from the line buffer).  For each output channel assigned to a
//! lane, weights stream channel-by-channel past the PEs; each PE gates
//! its tap's weight on its tap's spike bit.  After the `Ci` walk the
//! lane's psums combine in the adder tree and the neuron fires.
//!
//! ## Implementation note (§Perf L3)
//!
//! The behavioural single-PE model lives in [`super::pe`] (with its own
//! tests). The simulator's *hot loop* now lives in the pluggable
//! compute backends ([`super::backend`]): the conv engine calls a
//! backend for each field's psums and reports the lane-aggregate
//! accounting back here via [`PeArray::record`]. The `process_field` /
//! `process_field_active` paths below are the original event-driven
//! implementations, kept as the behavioural oracle the backends (and
//! these unit tests) are pinned against: the psum and the spike-gated
//! op count are identical to stepping the PEs one (spike, weight) pair
//! at a time, while the cycle count stays the *architectural* Eq. (12)
//! walk (the FPGA spends the full `Ci` walk regardless of sparsity;
//! only our host-side simulation exploits it).

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::SpikeVector;

use super::pe::{adder_tree_latency, Acc};

/// One output-channel lane: Kh*Kw PEs + adder tree (logically); the
/// simulator tracks the lane-aggregate op count.
#[derive(Debug, Clone)]
pub struct Lane {
    pub ops: u64,
    pub busy_cycles: u64,
}

/// The per-layer compute array.
#[derive(Debug, Clone)]
pub struct PeArray {
    pub mode: ConvMode,
    pub kh: usize,
    pub kw: usize,
    pub lanes: Vec<Lane>,
    /// Scratch psum-per-tap buffer (reused across fields; §Perf).
    scratch: Vec<Acc>,
}

/// Result of processing one receptive field for one output channel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FieldResult {
    pub psum: Acc,
    /// Cycles consumed: Ci walk + adder tree (mode-dependent).
    pub cycles: u64,
}

impl PeArray {
    pub fn for_layer(l: &ConvLayer) -> Self {
        Self {
            mode: l.mode,
            kh: l.kh,
            kw: l.kw,
            lanes: (0..l.parallel)
                .map(|_| Lane { ops: 0, busy_cycles: 0 })
                .collect(),
            scratch: vec![0; l.kh * l.kw],
        }
    }

    pub fn pe_count(&self) -> usize {
        self.lanes.len() * self.kh * self.kw
    }

    /// Process one receptive field for one output channel on one lane.
    ///
    /// * `rows[r]` — the `Kw` window vectors of tap row r (already
    ///   sliced at the field's x offset by the engine).
    /// * `taps_tm` — this output channel's weights, **tap-major**:
    ///   `taps_tm[t * n_ci + ci]` (depthwise: `taps_tm[t]`; pointwise:
    ///   `taps_tm[ci]`).
    /// * `n_ci` — input channels walked (1 for depthwise).
    /// * `channel` — the spike bit a depthwise lane gates on.
    /// * `t_rw`/`t_pe` — Eq. (12) timing knobs.
    pub fn process_field(
        &mut self,
        lane: usize,
        rows: &[&[SpikeVector]],
        taps_tm: &[i8],
        n_ci: usize,
        channel: usize,
        t_rw: u64,
        t_pe: u64,
    ) -> FieldResult {
        let lane = &mut self.lanes[lane];
        let ntaps = self.kh * self.kw;
        debug_assert_eq!(taps_tm.len(), ntaps * n_ci);

        match self.mode {
            ConvMode::Standard => {
                // Event-driven accumulate: per tap, iterate only the
                // active channels of the window vector.
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                for r in 0..self.kh {
                    let row = rows[r];
                    for c in 0..self.kw {
                        let base = (r * self.kw + c) * n_ci;
                        let taps = &taps_tm[base..base + n_ci];
                        for ci in row[c].iter_active() {
                            psum += taps[ci] as Acc;
                            ops += 1;
                        }
                    }
                }
                lane.ops += ops;
                // Architectural cycles: the full Ci walk + adder tree.
                let cycles = n_ci as u64 * (t_rw + t_pe)
                    + adder_tree_latency(ntaps);
                lane.busy_cycles += cycles;
                FieldResult { psum, cycles }
            }
            ConvMode::Depthwise => {
                // Fig. 8c: pass the tap weight through iff the lane's
                // channel spiked at that tap.
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                for r in 0..self.kh {
                    let row = rows[r];
                    for c in 0..self.kw {
                        if row[c].get(channel) {
                            psum += taps_tm[r * self.kw + c] as Acc;
                            ops += 1;
                        }
                    }
                }
                lane.ops += ops;
                let cycles = ntaps as u64 * (t_rw + t_pe)
                    + adder_tree_latency(ntaps);
                lane.busy_cycles += cycles;
                FieldResult { psum, cycles }
            }
            ConvMode::Pointwise => {
                // Fig. 8d: single tap, Ci walk on one PE, no adder tree.
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                for ci in rows[0][0].iter_active() {
                    psum += taps_tm[ci] as Acc;
                    ops += 1;
                }
                lane.ops += ops;
                let cycles = n_ci as u64 * (t_rw + t_pe);
                lane.busy_cycles += cycles;
                FieldResult { psum, cycles }
            }
        }
    }

    /// Standard-mode variant taking a pre-decoded active list (pairs of
    /// `(tap, ci)` for every set spike bit in the window). The engine
    /// builds the list once per receptive field and reuses it across
    /// all output channels of the Co walk — the decode cost is paid
    /// once instead of `Co` times (§Perf iteration 2).
    pub fn process_field_active(
        &mut self,
        lane: usize,
        active: &[(u16, u16)],
        taps_tm: &[i8],
        n_ci: usize,
        t_rw: u64,
        t_pe: u64,
    ) -> FieldResult {
        debug_assert_eq!(self.mode, ConvMode::Standard);
        let lane = &mut self.lanes[lane];
        let ntaps = self.kh * self.kw;
        debug_assert_eq!(taps_tm.len(), ntaps * n_ci);
        let mut psum: Acc = 0;
        for &(tap, ci) in active {
            psum += taps_tm[tap as usize * n_ci + ci as usize] as Acc;
        }
        lane.ops += active.len() as u64;
        let cycles =
            n_ci as u64 * (t_rw + t_pe) + adder_tree_latency(ntaps);
        lane.busy_cycles += cycles;
        FieldResult { psum, cycles }
    }

    /// Record one field evaluation's lane-aggregate accounting. The
    /// conv engine's compute backends (`sim::backend`) produce the
    /// psum + op count; the array keeps the per-lane books exactly as
    /// the inline `process_field` paths do.
    #[inline]
    pub fn record(&mut self, lane: usize, ops: u64, cycles: u64) {
        let lane = &mut self.lanes[lane];
        lane.ops += ops;
        lane.busy_cycles += cycles;
    }

    pub fn total_ops(&self) -> u64 {
        self.lanes.iter().map(|l| l.ops).sum()
    }

    /// Scratch access for engines needing a per-tap psum buffer.
    pub fn scratch(&mut self) -> &mut Vec<Acc> {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ConvLayer;
    use crate::sim::pe::Pe;

    fn mk_layer(mode: ConvMode, parallel: usize) -> ConvLayer {
        let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
        ConvLayer {
            mode,
            in_h: 8,
            in_w: 8,
            ci: 4,
            co: 8,
            kh: k,
            kw: k,
            pad: k / 2,
            encoder: false,
            parallel,
        }
    }

    fn window_rows(v: &SpikeVector, kw: usize) -> Vec<Vec<SpikeVector>> {
        (0..3).map(|_| vec![v.clone(); kw]).collect()
    }

    #[test]
    fn array_shape_follows_layer() {
        let arr = PeArray::for_layer(&mk_layer(ConvMode::Standard, 4));
        assert_eq!(arr.pe_count(), 36);
        assert_eq!(arr.lanes.len(), 4);
    }

    #[test]
    fn standard_field_computation() {
        let mut arr = PeArray::for_layer(&mk_layer(ConvMode::Standard, 1));
        // Window: all spikes on in channel 0, none in channel 1.
        let v_on = SpikeVector::from_bits(&[true, false]);
        let rows_own = window_rows(&v_on, 3);
        let rows: Vec<&[SpikeVector]> =
            rows_own.iter().map(|r| r.as_slice()).collect();
        // Tap-major: per tap [w_ci0, w_ci1] = [1, 100].
        let taps_tm: Vec<i8> =
            (0..9).flat_map(|_| [1i8, 100]).collect();
        let r = arr.process_field(0, &rows, &taps_tm, 2, 0, 0, 1);
        assert_eq!(r.psum, 9);          // 9 taps x weight 1, ci=1 gated
        // Ci walk (2 cycles) + adder tree over 9 (4 cycles).
        assert_eq!(r.cycles, 2 + 4);
        assert_eq!(arr.total_ops(), 9);
    }

    /// Fast path == stepping the behavioural PE model pair-by-pair.
    #[test]
    fn fast_path_matches_pe_model() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let n_ci = 5;
        let ntaps = 9;
        // Random window + weights.
        let rows_own: Vec<Vec<SpikeVector>> = (0..3)
            .map(|_| {
                (0..3)
                    .map(|_| {
                        let bits: Vec<bool> =
                            (0..n_ci).map(|_| rng.bernoulli(0.4)).collect();
                        SpikeVector::from_bits(&bits)
                    })
                    .collect()
            })
            .collect();
        let taps_tm: Vec<i8> =
            (0..ntaps * n_ci).map(|_| rng.int8()).collect();

        // Behavioural: one PE per tap, step per (spike, weight).
        let mut pes: Vec<Pe> =
            (0..ntaps).map(|_| Pe::new(ConvMode::Standard)).collect();
        for pe in pes.iter_mut() {
            pe.start(0);
        }
        for ci in 0..n_ci {
            for r in 0..3 {
                for c in 0..3 {
                    let t = r * 3 + c;
                    pes[t].step(rows_own[r][c].get(ci),
                                taps_tm[t * n_ci + ci]);
                }
            }
        }
        let want: Acc = pes.iter_mut().map(|p| p.drain()).sum();
        let want_ops: u64 = pes.iter().map(|p| p.ops).sum();

        let mut arr = PeArray::for_layer(&mk_layer(ConvMode::Standard, 1));
        let rows: Vec<&[SpikeVector]> =
            rows_own.iter().map(|r| r.as_slice()).collect();
        let got = arr.process_field(0, &rows, &taps_tm, n_ci, 0, 0, 1);
        assert_eq!(got.psum, want);
        assert_eq!(arr.total_ops(), want_ops);
    }

    #[test]
    fn eq12_cycle_shape() {
        // Standard mode cycles = Ci*(Trw+Tpe) + Tpes — Eq. (12) inner
        // bracket, which the conv engine multiplies by Ho*Wo*Co.
        let mut arr = PeArray::for_layer(&mk_layer(ConvMode::Standard, 1));
        let v = SpikeVector::zeros(4);
        let rows_own = window_rows(&v, 3);
        let rows: Vec<&[SpikeVector]> =
            rows_own.iter().map(|r| r.as_slice()).collect();
        let taps_tm = vec![0i8; 36];
        let r = arr.process_field(0, &rows, &taps_tm, 4, 0, 1, 1);
        assert_eq!(r.cycles, 4 * (1 + 1) + 4);
    }

    #[test]
    fn depthwise_field_computation() {
        let mut arr = PeArray::for_layer(&mk_layer(ConvMode::Depthwise, 1));
        let on = SpikeVector::from_bits(&[true]);
        let off = SpikeVector::from_bits(&[false]);
        // Checkerboard spikes; taps 1..9.
        let rows_own: Vec<Vec<SpikeVector>> = (0..3)
            .map(|r| {
                (0..3)
                    .map(|c| if (r + c) % 2 == 0 { on.clone() }
                         else { off.clone() })
                    .collect()
            })
            .collect();
        let rows: Vec<&[SpikeVector]> =
            rows_own.iter().map(|r| r.as_slice()).collect();
        let taps: Vec<i8> = (1..=9).collect();
        let r = arr.process_field(0, &rows, &taps, 1, 0, 0, 1);
        // Active taps: (0,0)=1,(0,2)=3,(1,1)=5,(2,0)=7,(2,2)=9 -> 25.
        assert_eq!(r.psum, 25);
    }

    #[test]
    fn pointwise_field_computation() {
        let mut arr = PeArray::for_layer(&mk_layer(ConvMode::Pointwise, 1));
        let v = SpikeVector::from_bits(&[true, false, true, true]);
        let rows_own = vec![vec![v]];
        let rows: Vec<&[SpikeVector]> =
            rows_own.iter().map(|r| r.as_slice()).collect();
        let taps: Vec<i8> = vec![10, 20, 30, 40];
        let r = arr.process_field(0, &rows, &taps, 4, 0, 0, 1);
        assert_eq!(r.psum, 10 + 30 + 40);
        assert_eq!(r.cycles, 4); // Ci walk, no tree
    }
}
