//! Functional compute backends for the conv / FC engines.
//!
//! The simulator separates *what the hardware computes* (psums, spikes,
//! op counts) from *what it costs* (cycles, memory traffic). The cost
//! side is weight- and sparsity-independent — Eq. (12) cycles and the
//! Table I/III access counts depend only on layer geometry — so the
//! engines are free to compute the functional side with whatever host
//! algorithm is fastest, as long as it is bit-exact.
//!
//! Two backends implement that contract:
//!
//! * [`BackendKind::Accurate`] — the original event walk: iterate the
//!   active channels of each window vector over tap-major weights,
//!   exactly mirroring the behavioural PE model ([`super::pe::Pe`]).
//! * [`BackendKind::WordParallel`] — sparsity-aware word processing in
//!   the style of SpikeX (arXiv 2505.12292): the receptive field's
//!   spike vectors are packed into one contiguous `ntaps*Ci`-bit string
//!   of `u64` words, int8 weights are decomposed into 8 two's-complement
//!   **bit-planes** over the same bit positions, and the psum is a sum
//!   of shifted popcounts:
//!
//!   ```text
//!   psum = sum_{b=0..6} 2^b * popcount(window & plane_b)
//!          - 128 * popcount(window & plane_7)
//!   ```
//!
//!   64 channel-accumulates collapse into 8 AND+popcount ops, all
//!   branchless and streaming — the word-level win the compressed &
//!   sorted spike-vector layout (paper SectionIV-C) was built for.
//!
//! Both backends produce identical spikes, identical op counts, and the
//! engines charge identical (architectural) cycles and memory accesses
//! regardless of backend — pinned by `tests/prop_backend.rs`.

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::SpikeVector;

use super::conv_engine::ConvWeights;
use super::pe::Acc;

/// Which functional backend an engine computes with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// Event-driven active-channel walk (the behavioural reference).
    #[default]
    Accurate,
    /// Bit-plane popcount over packed spike words (fast host path).
    WordParallel,
}

impl BackendKind {
    /// Parse a CLI spelling of the backend name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "accurate" | "acc" | "event" => Some(Self::Accurate),
            "word-parallel" | "word_parallel" | "wordparallel" | "wp"
                | "word" => Some(Self::WordParallel),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Accurate => "accurate",
            Self::WordParallel => "word-parallel",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Conv backends
// ---------------------------------------------------------------------------

/// Per-layer conv compute backend. The engine feeds it one receptive
/// field at a time ([`ConvCompute::begin_field`], once per output
/// pixel) and then asks for the psum of each output channel of the Co
/// walk — so per-field preprocessing (event decode / word packing) is
/// paid once and amortised over all output channels.
pub trait ConvCompute: Send {
    fn kind(&self) -> BackendKind;

    /// Ingest the receptive field whose top-left input column is `ox`
    /// within the padded rows. `rows[r]` is the full padded row of tap
    /// row `r` (top of the field first).
    fn begin_field(&mut self, rows: &[&[SpikeVector]], ox: usize);

    /// `(psum, spike-gated ops)` of the current field for output
    /// channel `co`. `w` carries the tap-major weights (ignored by
    /// backends that pre-transformed them at construction).
    fn field_psum(&mut self, w: &ConvWeights, co: usize) -> (Acc, u64);
}

/// Build a conv backend for one layer.
pub fn conv_backend(kind: BackendKind, layer: &ConvLayer,
                    weights: &ConvWeights) -> Box<dyn ConvCompute> {
    match kind {
        BackendKind::Accurate => Box::new(AccurateConv::new(layer)),
        BackendKind::WordParallel => {
            Box::new(WordParallelConv::new(layer, weights))
        }
    }
}

/// The original event walk, hoisted out of the engine loop.
struct AccurateConv {
    mode: ConvMode,
    kh: usize,
    kw: usize,
    n_ci: usize,
    /// Standard/pointwise: decoded `(tap, ci)` active list of the field.
    active: Vec<(u16, u16)>,
    /// Depthwise: the field's vectors copied word-wise, tap-major
    /// (`wpc` words per tap), for per-channel bit tests.
    tap_words: Vec<u64>,
    wpc: usize,
}

impl AccurateConv {
    fn new(layer: &ConvLayer) -> Self {
        let n_ci = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let (kh, kw) = match layer.mode {
            ConvMode::Pointwise => (1, 1),
            _ => (layer.kh, layer.kw),
        };
        let wpc = layer.ci.div_ceil(64);
        Self {
            mode: layer.mode,
            kh,
            kw,
            n_ci,
            active: Vec::with_capacity(kh * kw * layer.ci.min(1 << 14)),
            tap_words: vec![0; kh * kw * wpc],
            wpc,
        }
    }
}

impl ConvCompute for AccurateConv {
    fn kind(&self) -> BackendKind {
        BackendKind::Accurate
    }

    fn begin_field(&mut self, rows: &[&[SpikeVector]], ox: usize) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                self.active.clear();
                for (r, row) in rows.iter().take(self.kh).enumerate() {
                    for c in 0..self.kw {
                        let tap = (r * self.kw + c) as u16;
                        for ci in row[ox + c].iter_active() {
                            self.active.push((tap, ci as u16));
                        }
                    }
                }
            }
            ConvMode::Depthwise => {
                for (r, row) in rows.iter().take(self.kh).enumerate() {
                    for c in 0..self.kw {
                        let t = r * self.kw + c;
                        let words = row[ox + c].words();
                        self.tap_words[t * self.wpc..(t + 1) * self.wpc]
                            .copy_from_slice(words);
                    }
                }
            }
        }
    }

    fn field_psum(&mut self, w: &ConvWeights, co: usize) -> (Acc, u64) {
        let taps_tm = w.taps_tm(co);
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                let mut psum: Acc = 0;
                let n_ci = self.n_ci;
                for &(tap, ci) in &self.active {
                    psum += taps_tm[tap as usize * n_ci + ci as usize]
                        as Acc;
                }
                (psum, self.active.len() as u64)
            }
            ConvMode::Depthwise => {
                // Fig. 8c: pass the tap weight through iff the lane's
                // channel spiked at that tap.
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                let (word, bit) = (co / 64, co % 64);
                for t in 0..self.kh * self.kw {
                    if (self.tap_words[t * self.wpc + word] >> bit) & 1 == 1
                    {
                        psum += taps_tm[t] as Acc;
                        ops += 1;
                    }
                }
                (psum, ops)
            }
        }
    }
}

/// Bit-plane popcount backend.
struct WordParallelConv {
    mode: ConvMode,
    kh: usize,
    kw: usize,
    n_ci: usize,
    ntaps: usize,
    /// Words of the packed `ntaps * n_ci`-bit field string
    /// (standard/pointwise) or of the per-co tap mask (depthwise: 1).
    w_words: usize,
    /// Weight bit-planes, laid out `[co][plane][word]` over the same
    /// bit positions as the packed field string (standard/pointwise) or
    /// over tap positions (depthwise).
    planes: Vec<u64>,
    /// Per-co bitmask of planes with at least one set bit (lets the
    /// psum loop skip empty planes — frequent with real quantised
    /// weights whose magnitudes are small).
    plane_nz: Vec<u8>,
    /// Scratch: the packed field string of the current field.
    win: Vec<u64>,
    /// Depthwise scratch: field vectors copied tap-major (wpc per tap).
    tap_words: Vec<u64>,
    wpc: usize,
    /// Active spike count of the current field (standard/pointwise).
    count: u64,
}

impl WordParallelConv {
    fn new(layer: &ConvLayer, weights: &ConvWeights) -> Self {
        let n_ci = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let (kh, kw) = match layer.mode {
            ConvMode::Pointwise => (1, 1),
            _ => (layer.kh, layer.kw),
        };
        let ntaps = kh * kw;
        let wpc = layer.ci.div_ceil(64);
        let w_words = match layer.mode {
            // Tap mask over ntaps bits — one word covers kernels <= 8x8.
            ConvMode::Depthwise => {
                assert!(ntaps <= 64,
                        "word-parallel depthwise supports kernels up to \
                         8x8 ({ntaps} taps)");
                1
            }
            _ => (ntaps * n_ci).div_ceil(64),
        };
        let mut planes = vec![0u64; layer.co * 8 * w_words];
        let mut plane_nz = vec![0u8; layer.co];
        for co in 0..layer.co {
            let taps_tm = weights.taps_tm(co);
            let base = co * 8 * w_words;
            for t in 0..ntaps {
                for ci in 0..n_ci {
                    let byte = taps_tm[t * n_ci + ci] as u8;
                    // Bit position inside the packed field string: the
                    // field packs tap-major, n_ci bits per tap. For
                    // depthwise the position is simply the tap index.
                    let pos = if layer.mode == ConvMode::Depthwise {
                        t
                    } else {
                        t * n_ci + ci
                    };
                    for b in 0..8 {
                        if (byte >> b) & 1 == 1 {
                            planes[base + b * w_words + pos / 64] |=
                                1u64 << (pos % 64);
                            plane_nz[co] |= 1 << b;
                        }
                    }
                }
            }
        }
        Self {
            mode: layer.mode,
            kh,
            kw,
            n_ci,
            ntaps,
            w_words,
            planes,
            plane_nz,
            win: vec![0; w_words],
            tap_words: vec![0; ntaps * wpc],
            wpc,
            count: 0,
        }
    }

    /// Sum of shifted popcounts over the 8 two's-complement bit-planes
    /// of output channel `co`, against the `w_words`-long bit string
    /// `win`.
    #[inline]
    fn plane_psum(&self, win: &[u64], co: usize) -> Acc {
        let ww = self.w_words;
        let nz = self.plane_nz[co];
        let planes = &self.planes[co * 8 * ww..(co + 1) * 8 * ww];
        let mut psum: Acc = 0;
        for (b, plane) in planes.chunks_exact(ww).enumerate() {
            if nz & (1u8 << b) == 0 {
                continue;
            }
            let mut cnt: u32 = 0;
            for (w, p) in win.iter().zip(plane) {
                cnt += (w & p).count_ones();
            }
            if b == 7 {
                // Two's complement: bit 7 weighs -128.
                psum -= (cnt as Acc) << 7;
            } else {
                psum += (cnt as Acc) << b;
            }
        }
        psum
    }
}

/// Append `nbits` bits of `src` (LSB-first words) into `dst` at bit
/// offset `pos`; returns the new offset. `dst` must be pre-zeroed.
#[inline]
fn append_bits(dst: &mut [u64], mut pos: usize, src: &[u64],
               nbits: usize) -> usize {
    let mut remaining = nbits;
    let mut si = 0;
    while remaining > 0 {
        let take = remaining.min(64);
        let mut w = src[si];
        if take < 64 {
            w &= (1u64 << take) - 1;
        }
        let (word, off) = (pos / 64, pos % 64);
        dst[word] |= w << off;
        if off + take > 64 {
            // off >= 1 here (take <= 64), so the shift is in range.
            dst[word + 1] |= w >> (64 - off);
        }
        pos += take;
        remaining -= take;
        si += 1;
    }
    pos
}

impl ConvCompute for WordParallelConv {
    fn kind(&self) -> BackendKind {
        BackendKind::WordParallel
    }

    fn begin_field(&mut self, rows: &[&[SpikeVector]], ox: usize) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                self.win.iter_mut().for_each(|w| *w = 0);
                let mut pos = 0;
                let mut count = 0u64;
                for row in rows.iter().take(self.kh) {
                    for c in 0..self.kw {
                        let v = &row[ox + c];
                        let words = v.words();
                        pos = append_bits(&mut self.win, pos, words,
                                          self.n_ci);
                        count += words
                            .iter()
                            .map(|w| w.count_ones() as u64)
                            .sum::<u64>();
                    }
                }
                self.count = count;
            }
            ConvMode::Depthwise => {
                for (r, row) in rows.iter().take(self.kh).enumerate() {
                    for c in 0..self.kw {
                        let t = r * self.kw + c;
                        self.tap_words[t * self.wpc..(t + 1) * self.wpc]
                            .copy_from_slice(row[ox + c].words());
                    }
                }
            }
        }
    }

    fn field_psum(&mut self, _w: &ConvWeights, co: usize) -> (Acc, u64) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                let psum = self.plane_psum(&self.win, co);
                (psum, self.count)
            }
            ConvMode::Depthwise => {
                let (word, bit) = (co / 64, co % 64);
                let mut mask = 0u64;
                for t in 0..self.ntaps {
                    mask |= ((self.tap_words[t * self.wpc + word] >> bit)
                        & 1)
                        << t;
                }
                let psum = self.plane_psum(&[mask], co);
                (psum, mask.count_ones() as u64)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// FC backends
// ---------------------------------------------------------------------------

/// Classifier-head compute backend: accumulate the int8 weight rows of
/// active inputs into per-class i64 accumulators, returning the active
/// input count (the engines derive ops/traffic from it).
pub trait FcCompute: Send {
    fn kind(&self) -> BackendKind;
    fn accumulate(&mut self, spikes: &[bool], weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64;
}

pub fn fc_backend(kind: BackendKind, n_in: usize, n_out: usize,
                  weights: &[i8]) -> Box<dyn FcCompute> {
    match kind {
        BackendKind::Accurate => Box::new(AccurateFc),
        BackendKind::WordParallel => {
            Box::new(WordParallelFc::new(n_in, n_out, weights))
        }
    }
}

/// Row-gather over active inputs (the event-driven reference).
struct AccurateFc;

impl FcCompute for AccurateFc {
    fn kind(&self) -> BackendKind {
        BackendKind::Accurate
    }

    fn accumulate(&mut self, spikes: &[bool], weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64 {
        let mut active = 0u64;
        for (i, &s) in spikes.iter().enumerate() {
            if !s {
                continue;
            }
            active += 1;
            let row = &weights[i * n_out..(i + 1) * n_out];
            for (a, &w) in acc.iter_mut().zip(row) {
                *a += w as i64;
            }
        }
        active
    }
}

/// Bit-plane popcount over the packed input spike vector. The `[n_in]
/// [n_out]` weight matrix is transposed into per-output-neuron planes
/// at construction.
struct WordParallelFc {
    n_in: usize,
    w_words: usize,
    /// `[o][plane][word]` bit-planes over the n_in input positions.
    planes: Vec<u64>,
    plane_nz: Vec<u8>,
    packed: Vec<u64>,
}

impl WordParallelFc {
    fn new(n_in: usize, n_out: usize, weights: &[i8]) -> Self {
        assert_eq!(weights.len(), n_in * n_out);
        let w_words = n_in.div_ceil(64);
        let mut planes = vec![0u64; n_out * 8 * w_words];
        let mut plane_nz = vec![0u8; n_out];
        for i in 0..n_in {
            for o in 0..n_out {
                let byte = weights[i * n_out + o] as u8;
                let base = o * 8 * w_words;
                for b in 0..8 {
                    if (byte >> b) & 1 == 1 {
                        planes[base + b * w_words + i / 64] |=
                            1u64 << (i % 64);
                        plane_nz[o] |= 1 << b;
                    }
                }
            }
        }
        Self { n_in, w_words, planes, plane_nz, packed: vec![0; w_words] }
    }
}

impl FcCompute for WordParallelFc {
    fn kind(&self) -> BackendKind {
        BackendKind::WordParallel
    }

    fn accumulate(&mut self, spikes: &[bool], _weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64 {
        assert_eq!(spikes.len(), self.n_in);
        self.packed.iter_mut().for_each(|w| *w = 0);
        let mut active = 0u64;
        for (i, &s) in spikes.iter().enumerate() {
            if s {
                self.packed[i / 64] |= 1u64 << (i % 64);
                active += 1;
            }
        }
        let ww = self.w_words;
        for (o, a) in acc.iter_mut().enumerate().take(n_out) {
            let nz = self.plane_nz[o];
            let planes = &self.planes[o * 8 * ww..(o + 1) * 8 * ww];
            let mut sum: i64 = 0;
            for (b, plane) in planes.chunks_exact(ww).enumerate() {
                if nz & (1u8 << b) == 0 {
                    continue;
                }
                let mut cnt: u32 = 0;
                for (w, p) in self.packed.iter().zip(plane) {
                    cnt += (w & p).count_ones();
                }
                if b == 7 {
                    sum -= (cnt as i64) << 7;
                } else {
                    sum += (cnt as i64) << b;
                }
            }
            *a += sum;
        }
        active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("accurate"),
                   Some(BackendKind::Accurate));
        assert_eq!(BackendKind::parse("word-parallel"),
                   Some(BackendKind::WordParallel));
        assert_eq!(BackendKind::parse("WP"),
                   Some(BackendKind::WordParallel));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::WordParallel.to_string(), "word-parallel");
    }

    #[test]
    fn append_bits_packs_across_word_boundaries() {
        // Three 40-bit chunks: bits straddle the first word boundary.
        let mut dst = vec![0u64; 2];
        let mut pos = 0;
        for k in 0..3u64 {
            let src = [0b1011 | (k << 36)];
            pos = append_bits(&mut dst, pos, &src, 40);
        }
        assert_eq!(pos, 120);
        for k in 0..3 {
            let base = k * 40;
            for (bit, want) in [(0, true), (1, true), (2, false),
                                (3, true)] {
                let p = base + bit;
                let got = (dst[p / 64] >> (p % 64)) & 1 == 1;
                assert_eq!(got, want, "chunk {k} bit {bit}");
            }
        }
    }

    /// Bit-plane decomposition identity: for random int8 weights and a
    /// random active set, the shifted-popcount sum equals the direct
    /// signed sum. Exercises the -128 plane.
    #[test]
    fn plane_decomposition_matches_signed_sum() {
        let mut rng = Rng::new(11);
        for trial in 0..50 {
            let n = 1 + rng.below(200);
            let weights: Vec<i8> = (0..n)
                .map(|_| {
                    if rng.bernoulli(0.05) {
                        i8::MIN // hit the -128 corner explicitly
                    } else {
                        rng.int8()
                    }
                })
                .collect();
            let active: Vec<bool> =
                (0..n).map(|_| rng.bernoulli(0.4)).collect();

            // Direct sum.
            let want: i64 = weights
                .iter()
                .zip(&active)
                .filter(|(_, &a)| a)
                .map(|(&w, _)| w as i64)
                .sum();

            // Plane sum (via the FC backend, n_out = 1).
            let mut be = WordParallelFc::new(n, 1, &weights);
            let mut acc = vec![0i64];
            let got_active = be.accumulate(&active, &weights, 1, &mut acc);
            assert_eq!(acc[0], want, "trial {trial}");
            assert_eq!(got_active,
                       active.iter().filter(|&&a| a).count() as u64);
        }
    }
}
