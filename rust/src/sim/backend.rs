//! Functional compute backends for the conv / FC engines.
//!
//! The simulator separates *what the hardware computes* (psums, spikes,
//! op counts) from *what it costs* (cycles, memory traffic). The cost
//! side is weight- and sparsity-independent — Eq. (12) cycles and the
//! Table I/III access counts depend only on layer geometry — so the
//! engines are free to compute the functional side with whatever host
//! algorithm is fastest, as long as it is bit-exact.
//!
//! Three backends implement that contract:
//!
//! * [`BackendKind::Accurate`] — the original event walk: iterate the
//!   active channels of each window vector over tap-major weights,
//!   exactly mirroring the behavioural PE model ([`super::pe::Pe`]).
//! * [`BackendKind::WordParallel`] — sparsity-aware word processing in
//!   the style of SpikeX (arXiv 2505.12292): the receptive field's
//!   spike vectors are packed into one contiguous `ntaps*Ci`-bit string
//!   of `u64` words, int8 weights are decomposed into 8 two's-complement
//!   **bit-planes** over the same bit positions, and the psum is a sum
//!   of shifted popcounts:
//!
//!   ```text
//!   psum = sum_{b=0..6} 2^b * popcount(window & plane_b)
//!          - 128 * popcount(window & plane_7)
//!   ```
//!
//!   64 channel-accumulates collapse into 8 AND+popcount ops, all
//!   branchless and streaming — the word-level win the compressed &
//!   sorted spike-vector layout (paper SectionIV-C) was built for.
//! * [`BackendKind::Sparse`] — the word-parallel plane walk plus
//!   hierarchical occupancy skipping and weight-stationary row
//!   batching ([`sparse`]): a summary `u64` marks which word groups of
//!   the packed field hold any spike, so all-zero regions skip the
//!   plane walk entirely (SpikeX's core observation), and whole rows of
//!   stashed fields evaluate in one pass per weight plane. Unlike
//!   word-parallel, its host cost tracks observed spike density.
//!
//! ## Incremental sliding-window protocol (§Perf)
//!
//! Every backend keeps the decoded/packed window state **per column**:
//! as the engine walks `ox` along an output row, [`ConvCompute::advance`]
//! shifts out the leftmost column and appends one new `Kh x 1` column —
//! O(Ci) incremental work per output pixel — exactly the line-buffer
//! reuse the hardware's Fig. 7a fill pipeline performs.  The packed
//! field string is laid out **column-major** (`pos = (c*Kh + r)*Ci +
//! ci`), so the word-parallel slide is one whole-string shift by
//! `Kh*Ci` bits plus one column pack.  [`ConvCompute::begin_field`] is
//! the full-repack fallback; both paths produce bit-identical state,
//! pinned by `tests/prop_backend.rs`.
//!
//! All backends produce identical spikes, identical op counts, and the
//! engines charge identical (architectural) cycles and memory accesses
//! regardless of backend — pinned by `tests/prop_backend.rs` and the
//! cross-backend differential harness `tests/diff_backends.rs`.

pub mod sparse;

use std::sync::Arc;

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::or_bits;

use super::conv_engine::ConvWeights;
use super::linebuf::LineBuffer;
use super::pe::Acc;

pub use sparse::sparse_conv_backend;

/// Which functional backend an engine computes with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// Event-driven active-channel walk (the behavioural reference).
    #[default]
    Accurate,
    /// Bit-plane popcount over packed spike words (fast host path).
    WordParallel,
    /// Bit-plane popcount with hierarchical occupancy skipping and
    /// weight-stationary row batching (fastest at real SNN sparsity;
    /// host cost tracks density — see [`sparse`]).
    Sparse,
}

impl BackendKind {
    /// Parse a CLI spelling of the backend name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "accurate" | "acc" | "event" => Some(Self::Accurate),
            "word-parallel" | "word_parallel" | "wordparallel" | "wp"
                | "word" => Some(Self::WordParallel),
            "sparse" | "sp" | "sparsity-skip" => Some(Self::Sparse),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Accurate => "accurate",
            Self::WordParallel => "word-parallel",
            Self::Sparse => "sparse",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Conv backends
// ---------------------------------------------------------------------------

/// Per-layer conv compute backend. The engine slides it along each
/// output row ([`ConvCompute::begin_row`] + [`ConvCompute::advance`],
/// once per output pixel) and then asks for the psums of the whole Co
/// walk in one batched call — so per-field preprocessing is O(Ci)
/// incremental and the window state stays register/cache-resident
/// across all output channels.
pub trait ConvCompute: Send {
    fn kind(&self) -> BackendKind;

    /// Clone into an independent instance with the same weights
    /// (intra-frame row bands give every band its own backend; the
    /// word-parallel weight planes are shared read-only).
    fn clone_box(&self) -> Box<dyn ConvCompute>;

    /// Start a new output row: invalidate the incremental column
    /// state so the next [`ConvCompute::advance`] repacks in full.
    fn begin_row(&mut self);

    /// Full-repack fallback: ingest the receptive field whose leftmost
    /// padded input column is `ox`.
    fn begin_field(&mut self, lb: &LineBuffer, ox: usize);

    /// Incremental slide to `ox`: shift out the leftmost window column
    /// and append column `ox + Kw - 1` — O(Ci) work. Requires the
    /// previous call this row to have been `advance(ox - 1)` (or a
    /// fresh row); bit-identical to `begin_field(lb, ox)`.
    fn advance(&mut self, lb: &LineBuffer, ox: usize);

    /// `(psum, spike-gated ops)` of the current field for output
    /// channel `co`. `w` carries the tap-major weights (ignored by
    /// backends that pre-transformed them at construction).
    fn field_psum(&mut self, w: &ConvWeights, co: usize) -> (Acc, u64);

    /// Batched Co walk: fill `out[co]` for every output channel in one
    /// call (amortises dispatch and keeps the packed window hot).
    fn field_psums(&mut self, w: &ConvWeights, out: &mut [(Acc, u64)]) {
        for (co, o) in out.iter_mut().enumerate() {
            *o = self.field_psum(w, co);
        }
    }

    /// Queue the current field's packed window for a deferred,
    /// weight-stationary batch evaluation
    /// ([`ConvCompute::field_psums_batch`]). Returns `false` when this
    /// backend (or conv mode) does not batch — the caller must then
    /// evaluate the field immediately via
    /// [`ConvCompute::field_psums`]. The default never batches.
    fn stash_field(&mut self) -> bool {
        false
    }

    /// Number of fields currently stashed (0 for non-batching
    /// backends).
    fn stashed_fields(&self) -> usize {
        0
    }

    /// Evaluate every stashed field against all `n_co` output channels
    /// in one weight-stationary pass: `out[i * n_co + co]` receives
    /// stashed field `i`'s `(psum, ops)` for channel `co`, in stash
    /// order. Clears the stash. Bit-identical to calling
    /// [`ConvCompute::field_psums`] per field at stash time — pinned by
    /// `tests/prop_backend.rs`. No-op default for non-batching
    /// backends.
    fn field_psums_batch(&mut self, _w: &ConvWeights, _n_co: usize,
                         _out: &mut [(Acc, u64)]) {
    }
}

/// Build a conv backend for one layer.
pub fn conv_backend(kind: BackendKind, layer: &ConvLayer,
                    weights: &ConvWeights) -> Box<dyn ConvCompute> {
    match kind {
        BackendKind::Accurate => Box::new(AccurateConv::new(layer)),
        BackendKind::WordParallel => {
            Box::new(WordParallelConv::new(layer, weights))
        }
        BackendKind::Sparse => {
            Box::new(sparse::SparseConv::new(layer, weights))
        }
    }
}

/// Shift the bit string in `words` right by `s` bits (toward bit 0),
/// zero-filling the top — the word-parallel window slide.
#[inline]
fn shr_bits(words: &mut [u64], s: usize) {
    let n = words.len();
    let (q, r) = (s / 64, s % 64);
    debug_assert!(q <= n);
    if r == 0 {
        words.copy_within(q.., 0);
    } else {
        for i in 0..n - q {
            let lo = words[i + q] >> r;
            let hi = if i + q + 1 < n {
                words[i + q + 1] << (64 - r)
            } else {
                0
            };
            words[i] = lo | hi;
        }
    }
    for w in words[n - q..].iter_mut() {
        *w = 0;
    }
}

/// Ring of `kw` raw-word window columns (`kh * wpc` words per column)
/// — the incremental slide state both depthwise backends share: the
/// oldest column is evicted in place as `advance` walks the row.
#[derive(Clone)]
struct ColRing {
    kh: usize,
    kw: usize,
    wpc: usize,
    cols: Vec<Vec<u64>>,
    head: usize,
    fresh: bool,
}

impl ColRing {
    fn new(kh: usize, kw: usize, wpc: usize) -> Self {
        Self {
            kh,
            kw,
            wpc,
            cols: (0..kw).map(|_| vec![0u64; kh * wpc]).collect(),
            head: 0,
            fresh: true,
        }
    }

    fn begin_row(&mut self) {
        self.fresh = true;
    }

    /// Copy padded input column `x` into ring slot `slot`.
    fn load(&mut self, lb: &LineBuffer, x: usize, slot: usize) {
        let (kh, wpc) = (self.kh, self.wpc);
        let col = &mut self.cols[slot];
        for r in 0..kh {
            col[r * wpc..(r + 1) * wpc]
                .copy_from_slice(lb.at(r, x).words());
        }
    }

    fn begin_field(&mut self, lb: &LineBuffer, ox: usize) {
        self.head = 0;
        for k in 0..self.kw {
            self.load(lb, ox + k, k);
        }
    }

    fn advance(&mut self, lb: &LineBuffer, ox: usize) {
        if self.fresh || ox == 0 || self.kw == 1 {
            self.begin_field(lb, ox);
            self.fresh = false;
            return;
        }
        let slot = self.head;
        self.load(lb, ox + self.kw - 1, slot);
        self.head = (self.head + 1) % self.kw;
    }

    /// Logical window column `k`'s words (0 = leftmost).
    #[inline]
    fn col(&self, k: usize) -> &[u64] {
        &self.cols[(self.head + k) % self.kw]
    }
}

/// The original event walk, hoisted out of the engine loop and kept
/// per window column for the incremental slide.
#[derive(Clone)]
struct AccurateConv {
    mode: ConvMode,
    kh: usize,
    kw: usize,
    n_ci: usize,
    /// Standard/pointwise: ring of `kw` decoded window columns;
    /// `cols[(head + k) % kw]` holds logical column k's active events
    /// as `r * kw * n_ci + ci`, so `taps_tm[entry + k * n_ci]` is the
    /// tap weight — one add per event in the Co walk.
    cols: Vec<Vec<u32>>,
    head: usize,
    fresh: bool,
    /// Depthwise: the shared raw-word column ring.
    ring: ColRing,
}

impl AccurateConv {
    fn new(layer: &ConvLayer) -> Self {
        let n_ci = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let (kh, kw) = match layer.mode {
            ConvMode::Pointwise => (1, 1),
            _ => (layer.kh, layer.kw),
        };
        let wpc = layer.ci.div_ceil(64);
        // A full column decodes to at most kh * n_ci events; clamp the
        // whole product so the hint stays sane for enormous Ci.
        let cap = (kh * n_ci).min(1 << 14);
        Self {
            mode: layer.mode,
            kh,
            kw,
            n_ci,
            cols: match layer.mode {
                ConvMode::Depthwise => Vec::new(),
                _ => (0..kw).map(|_| Vec::with_capacity(cap)).collect(),
            },
            head: 0,
            fresh: true,
            ring: ColRing::new(kh, kw, wpc),
        }
    }

    /// Decode padded input column `x` into event-ring slot `slot`
    /// (standard/pointwise only).
    fn load_col(&mut self, lb: &LineBuffer, x: usize, slot: usize) {
        let stride = (self.kw * self.n_ci) as u32;
        let kh = self.kh;
        let col = &mut self.cols[slot];
        col.clear();
        for r in 0..kh {
            let base = r as u32 * stride;
            for ci in lb.at(r, x).iter_active() {
                col.push(base + ci as u32);
            }
        }
    }
}

impl ConvCompute for AccurateConv {
    fn kind(&self) -> BackendKind {
        BackendKind::Accurate
    }

    fn clone_box(&self) -> Box<dyn ConvCompute> {
        Box::new(self.clone())
    }

    fn begin_row(&mut self) {
        self.fresh = true;
        self.ring.begin_row();
    }

    fn begin_field(&mut self, lb: &LineBuffer, ox: usize) {
        if self.mode == ConvMode::Depthwise {
            self.ring.begin_field(lb, ox);
            return;
        }
        self.head = 0;
        for k in 0..self.kw {
            self.load_col(lb, ox + k, k);
        }
    }

    fn advance(&mut self, lb: &LineBuffer, ox: usize) {
        if self.mode == ConvMode::Depthwise {
            self.ring.advance(lb, ox);
            return;
        }
        if self.fresh || ox == 0 || self.kw == 1 {
            self.begin_field(lb, ox);
            self.fresh = false;
            return;
        }
        let slot = self.head;
        self.load_col(lb, ox + self.kw - 1, slot);
        self.head = (self.head + 1) % self.kw;
    }

    fn field_psum(&mut self, w: &ConvWeights, co: usize) -> (Acc, u64) {
        let taps_tm = w.taps_tm(co);
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                for k in 0..self.kw {
                    let col = &self.cols[(self.head + k) % self.kw];
                    let off = k * self.n_ci;
                    ops += col.len() as u64;
                    for &e in col {
                        psum += taps_tm[e as usize + off] as Acc;
                    }
                }
                (psum, ops)
            }
            ConvMode::Depthwise => {
                // Fig. 8c: pass the tap weight through iff the lane's
                // channel spiked at that tap.
                let (word, bit) = (co / 64, co % 64);
                let wpc = self.ring.wpc;
                let mut psum: Acc = 0;
                let mut ops = 0u64;
                for k in 0..self.kw {
                    let cw = self.ring.col(k);
                    for r in 0..self.kh {
                        if (cw[r * wpc + word] >> bit) & 1 == 1 {
                            psum += taps_tm[r * self.kw + k] as Acc;
                            ops += 1;
                        }
                    }
                }
                (psum, ops)
            }
        }
    }
}

/// Bit-plane popcount backend.
#[derive(Clone)]
struct WordParallelConv {
    mode: ConvMode,
    kh: usize,
    kw: usize,
    n_ci: usize,
    /// Bits per window column in the packed field string (`kh * n_ci`;
    /// depthwise: `kh` tap-mask bits).
    col_bits: usize,
    /// Words of the packed `kw * col_bits`-bit field string
    /// (depthwise: the single tap-mask word).
    w_words: usize,
    /// Weight bit-planes, laid out `[co][plane][word]` over the
    /// column-major packed positions `pos = (c*kh + r)*n_ci + ci`
    /// (depthwise: `pos = c*kh + r`). Shared read-only across band
    /// clones.
    planes: Arc<Vec<u64>>,
    /// Per-co bitmask of planes with at least one set bit (lets the
    /// psum loop skip empty planes — frequent with real quantised
    /// weights whose magnitudes are small).
    plane_nz: Arc<Vec<u8>>,
    /// The packed field string of the current window. Physical order
    /// equals logical order: `advance` shifts the whole string right
    /// by `col_bits` and packs the new column at the top slot.
    win: Vec<u64>,
    /// Spike count per resident window column (front = leftmost).
    col_counts: Vec<u64>,
    /// Active spike count of the current field (standard/pointwise).
    count: u64,
    /// Depthwise: the shared raw-word column ring.
    ring: ColRing,
    fresh: bool,
}

impl WordParallelConv {
    fn new(layer: &ConvLayer, weights: &ConvWeights) -> Self {
        let n_ci = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let (kh, kw) = match layer.mode {
            ConvMode::Pointwise => (1, 1),
            _ => (layer.kh, layer.kw),
        };
        let ntaps = kh * kw;
        let wpc = layer.ci.div_ceil(64);
        let (col_bits, w_words) = match layer.mode {
            // Tap mask over ntaps bits — one word covers kernels <= 8x8.
            ConvMode::Depthwise => {
                assert!(ntaps <= 64,
                        "word-parallel depthwise supports kernels up to \
                         8x8 ({ntaps} taps)");
                (kh, 1)
            }
            _ => {
                let cb = kh * n_ci;
                (cb, (kw * cb).div_ceil(64))
            }
        };
        let mut planes = vec![0u64; layer.co * 8 * w_words];
        let mut plane_nz = vec![0u8; layer.co];
        for co in 0..layer.co {
            let taps_tm = weights.taps_tm(co);
            let base = co * 8 * w_words;
            for r in 0..kh {
                for c in 0..kw {
                    for ci in 0..n_ci {
                        let byte = taps_tm[(r * kw + c) * n_ci + ci] as u8;
                        // Column-major packed position (see win docs).
                        let pos = if layer.mode == ConvMode::Depthwise {
                            c * kh + r
                        } else {
                            c * col_bits + r * n_ci + ci
                        };
                        for b in 0..8 {
                            if (byte >> b) & 1 == 1 {
                                planes[base + b * w_words + pos / 64] |=
                                    1u64 << (pos % 64);
                                plane_nz[co] |= 1 << b;
                            }
                        }
                    }
                }
            }
        }
        Self {
            mode: layer.mode,
            kh,
            kw,
            n_ci,
            col_bits,
            w_words,
            planes: Arc::new(planes),
            plane_nz: Arc::new(plane_nz),
            win: vec![0; w_words],
            col_counts: vec![0; kw],
            count: 0,
            ring: ColRing::new(kh, kw, wpc),
            fresh: true,
        }
    }

    /// Pack padded input column `x` into logical column slot `k` of
    /// the win string; returns its spike count. Target bits must be
    /// zero.
    fn pack_col(&mut self, lb: &LineBuffer, x: usize, k: usize) -> u64 {
        let mut pos = k * self.col_bits;
        let mut cnt = 0u64;
        for r in 0..self.kh {
            let words = lb.at(r, x).words();
            pos = or_bits(&mut self.win, pos, words, self.n_ci);
            cnt += words.iter().map(|w| w.count_ones() as u64).sum::<u64>();
        }
        cnt
    }

    /// Sum of shifted popcounts over the 8 two's-complement bit-planes
    /// of output channel `co`, against the `w_words`-long bit string
    /// `win`.
    #[inline]
    fn plane_psum(&self, win: &[u64], co: usize) -> Acc {
        let ww = self.w_words;
        let nz = self.plane_nz[co];
        let planes = &self.planes[co * 8 * ww..(co + 1) * 8 * ww];
        let mut psum: Acc = 0;
        for (b, plane) in planes.chunks_exact(ww).enumerate() {
            if nz & (1u8 << b) == 0 {
                continue;
            }
            let mut cnt: u32 = 0;
            for (w, p) in win.iter().zip(plane) {
                cnt += (w & p).count_ones();
            }
            if b == 7 {
                // Two's complement: bit 7 weighs -128.
                psum -= (cnt as Acc) << 7;
            } else {
                psum += (cnt as Acc) << b;
            }
        }
        psum
    }
}

impl ConvCompute for WordParallelConv {
    fn kind(&self) -> BackendKind {
        BackendKind::WordParallel
    }

    fn clone_box(&self) -> Box<dyn ConvCompute> {
        Box::new(self.clone())
    }

    fn begin_row(&mut self) {
        self.fresh = true;
        self.ring.begin_row();
    }

    fn begin_field(&mut self, lb: &LineBuffer, ox: usize) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                self.win.iter_mut().for_each(|w| *w = 0);
                self.count = 0;
                for k in 0..self.kw {
                    let cnt = self.pack_col(lb, ox + k, k);
                    self.col_counts[k] = cnt;
                    self.count += cnt;
                }
            }
            ConvMode::Depthwise => self.ring.begin_field(lb, ox),
        }
    }

    fn advance(&mut self, lb: &LineBuffer, ox: usize) {
        if self.mode == ConvMode::Depthwise {
            self.ring.advance(lb, ox);
            return;
        }
        if self.fresh || ox == 0 || self.kw == 1 {
            self.begin_field(lb, ox);
            self.fresh = false;
            return;
        }
        shr_bits(&mut self.win, self.col_bits);
        self.count -= self.col_counts[0];
        self.col_counts.copy_within(1.., 0);
        let cnt = self.pack_col(lb, ox + self.kw - 1, self.kw - 1);
        self.col_counts[self.kw - 1] = cnt;
        self.count += cnt;
    }

    fn field_psum(&mut self, _w: &ConvWeights, co: usize) -> (Acc, u64) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                let psum = self.plane_psum(&self.win, co);
                (psum, self.count)
            }
            ConvMode::Depthwise => {
                let (word, bit) = (co / 64, co % 64);
                let wpc = self.ring.wpc;
                let mut mask = 0u64;
                for k in 0..self.kw {
                    let cw = self.ring.col(k);
                    for r in 0..self.kh {
                        mask |= ((cw[r * wpc + word] >> bit) & 1)
                            << (k * self.kh + r);
                    }
                }
                let psum = self.plane_psum(&[mask], co);
                (psum, mask.count_ones() as u64)
            }
        }
    }

    fn field_psums(&mut self, w: &ConvWeights, out: &mut [(Acc, u64)]) {
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                // One pass over all co with the packed window hot.
                for (co, o) in out.iter_mut().enumerate() {
                    *o = (self.plane_psum(&self.win, co), self.count);
                }
            }
            ConvMode::Depthwise => {
                for (co, o) in out.iter_mut().enumerate() {
                    *o = self.field_psum(w, co);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// FC backends
// ---------------------------------------------------------------------------

/// Classifier-head compute backend: accumulate the int8 weight rows of
/// active inputs into per-class i64 accumulators, returning the active
/// input count (the engines derive ops/traffic from it).
pub trait FcCompute: Send {
    fn kind(&self) -> BackendKind;
    fn accumulate(&mut self, spikes: &[bool], weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64;
}

pub fn fc_backend(kind: BackendKind, n_in: usize, n_out: usize,
                  weights: &[i8]) -> Box<dyn FcCompute> {
    match kind {
        BackendKind::Accurate => Box::new(AccurateFc),
        BackendKind::WordParallel => {
            Box::new(WordParallelFc::new(n_in, n_out, weights))
        }
        BackendKind::Sparse => {
            Box::new(sparse::SparseFc::new(n_in, n_out, weights))
        }
    }
}

/// Row-gather over active inputs (the event-driven reference).
struct AccurateFc;

impl FcCompute for AccurateFc {
    fn kind(&self) -> BackendKind {
        BackendKind::Accurate
    }

    fn accumulate(&mut self, spikes: &[bool], weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64 {
        let mut active = 0u64;
        for (i, &s) in spikes.iter().enumerate() {
            if !s {
                continue;
            }
            active += 1;
            let row = &weights[i * n_out..(i + 1) * n_out];
            for (a, &w) in acc.iter_mut().zip(row) {
                *a += w as i64;
            }
        }
        active
    }
}

/// Bit-plane popcount over the packed input spike vector. The `[n_in]
/// [n_out]` weight matrix is transposed into per-output-neuron planes
/// at construction.
struct WordParallelFc {
    n_in: usize,
    w_words: usize,
    /// `[o][plane][word]` bit-planes over the n_in input positions.
    planes: Vec<u64>,
    plane_nz: Vec<u8>,
    packed: Vec<u64>,
}

impl WordParallelFc {
    fn new(n_in: usize, n_out: usize, weights: &[i8]) -> Self {
        assert_eq!(weights.len(), n_in * n_out);
        let w_words = n_in.div_ceil(64);
        let mut planes = vec![0u64; n_out * 8 * w_words];
        let mut plane_nz = vec![0u8; n_out];
        for i in 0..n_in {
            for o in 0..n_out {
                let byte = weights[i * n_out + o] as u8;
                let base = o * 8 * w_words;
                for b in 0..8 {
                    if (byte >> b) & 1 == 1 {
                        planes[base + b * w_words + i / 64] |=
                            1u64 << (i % 64);
                        plane_nz[o] |= 1 << b;
                    }
                }
            }
        }
        Self { n_in, w_words, planes, plane_nz, packed: vec![0; w_words] }
    }
}

impl FcCompute for WordParallelFc {
    fn kind(&self) -> BackendKind {
        BackendKind::WordParallel
    }

    fn accumulate(&mut self, spikes: &[bool], _weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64 {
        assert_eq!(spikes.len(), self.n_in);
        self.packed.iter_mut().for_each(|w| *w = 0);
        let mut active = 0u64;
        for (i, &s) in spikes.iter().enumerate() {
            if s {
                self.packed[i / 64] |= 1u64 << (i % 64);
                active += 1;
            }
        }
        let ww = self.w_words;
        for (o, a) in acc.iter_mut().enumerate().take(n_out) {
            let nz = self.plane_nz[o];
            let planes = &self.planes[o * 8 * ww..(o + 1) * 8 * ww];
            let mut sum: i64 = 0;
            for (b, plane) in planes.chunks_exact(ww).enumerate() {
                if nz & (1u8 << b) == 0 {
                    continue;
                }
                let mut cnt: u32 = 0;
                for (w, p) in self.packed.iter().zip(plane) {
                    cnt += (w & p).count_ones();
                }
                if b == 7 {
                    sum -= (cnt as i64) << 7;
                } else {
                    sum += (cnt as i64) << b;
                }
            }
            *a += sum;
        }
        active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("accurate"),
                   Some(BackendKind::Accurate));
        assert_eq!(BackendKind::parse("word-parallel"),
                   Some(BackendKind::WordParallel));
        assert_eq!(BackendKind::parse("WP"),
                   Some(BackendKind::WordParallel));
        assert_eq!(BackendKind::parse("sparse"),
                   Some(BackendKind::Sparse));
        assert_eq!(BackendKind::parse("SP"), Some(BackendKind::Sparse));
        assert_eq!(BackendKind::parse("sparsity-skip"),
                   Some(BackendKind::Sparse));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::WordParallel.to_string(), "word-parallel");
        assert_eq!(BackendKind::Sparse.to_string(), "sparse");
    }

    #[test]
    fn shr_bits_shifts_across_word_boundaries() {
        // 150-bit string over 3 words, bit i set iff i % 5 == 0.
        let mut words = vec![0u64; 3];
        for i in (0..150).step_by(5) {
            words[i / 64] |= 1u64 << (i % 64);
        }
        shr_bits(&mut words, 35);
        for i in 0..150 {
            let want = i + 35 < 150 && (i + 35) % 5 == 0;
            let got = (words[i / 64] >> (i % 64)) & 1 == 1;
            assert_eq!(got, want, "bit {i}");
        }
        // Word-aligned shift path.
        let mut words = vec![u64::MAX; 2];
        shr_bits(&mut words, 64);
        assert_eq!(words, vec![u64::MAX, 0]);
    }

    /// Bit-plane decomposition identity: for random int8 weights and a
    /// random active set, the shifted-popcount sum equals the direct
    /// signed sum. Exercises the -128 plane.
    #[test]
    fn plane_decomposition_matches_signed_sum() {
        let mut rng = Rng::new(11);
        for trial in 0..50 {
            let n = 1 + rng.below(200);
            let weights: Vec<i8> = (0..n)
                .map(|_| {
                    if rng.bernoulli(0.05) {
                        i8::MIN // hit the -128 corner explicitly
                    } else {
                        rng.int8()
                    }
                })
                .collect();
            let active: Vec<bool> =
                (0..n).map(|_| rng.bernoulli(0.4)).collect();

            // Direct sum.
            let want: i64 = weights
                .iter()
                .zip(&active)
                .filter(|(_, &a)| a)
                .map(|(&w, _)| w as i64)
                .sum();

            // Plane sum (via the FC backend, n_out = 1).
            let mut be = WordParallelFc::new(n, 1, &weights);
            let mut acc = vec![0i64];
            let got_active = be.accumulate(&active, &weights, 1, &mut acc);
            assert_eq!(acc[0], want, "trial {trial}");
            assert_eq!(got_active,
                       active.iter().filter(|&&a| a).count() as u64);
        }
    }
}
