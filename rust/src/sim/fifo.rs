//! Hardware FIFO model with capacity, backpressure, and occupancy stats.
//!
//! Used for the line buffer rows (Fig. 7a) and the inter-layer buffers
//! of the streaming pipeline (SectionIV-E.1). `push` fails when full — the
//! "request-response" handshake turns that into upstream stall cycles.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Fifo<T> {
    capacity: usize,
    items: VecDeque<T>,
    pub stats: FifoStats,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FifoStats {
    pub pushes: u64,
    pub pops: u64,
    /// Rejected pushes (upstream stalls under the handshake).
    pub full_rejects: u64,
    /// Pops attempted while empty (downstream starvation).
    pub empty_rejects: u64,
    /// High-water mark of occupancy.
    pub max_occupancy: usize,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "fifo capacity must be positive");
        Self {
            capacity,
            items: VecDeque::with_capacity(capacity),
            stats: FifoStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Try to enqueue; `Err(item)` when full (backpressure).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            self.stats.full_rejects += 1;
            return Err(item);
        }
        self.items.push_back(item);
        self.stats.pushes += 1;
        self.stats.max_occupancy = self.stats.max_occupancy.max(self.len());
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        match self.items.pop_front() {
            Some(x) => {
                self.stats.pops += 1;
                Some(x)
            }
            None => {
                self.stats.empty_rejects += 1;
                None
            }
        }
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Tail-to-head chaining (Fig. 7a): pop here, push into `next`.
    pub fn shift_into(&mut self, next: &mut Fifo<T>) -> bool {
        if next.is_full() || self.is_empty() {
            return false;
        }
        let item = self.pop().expect("checked non-empty");
        next.push(item).ok().expect("checked non-full");
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.push(i).unwrap();
        }
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn backpressure_on_full() {
        let mut f = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.stats.full_rejects, 1);
    }

    #[test]
    fn starvation_counted() {
        let mut f: Fifo<u8> = Fifo::new(2);
        assert!(f.pop().is_none());
        assert_eq!(f.stats.empty_rejects, 1);
    }

    #[test]
    fn chained_shift() {
        let mut a = Fifo::new(2);
        let mut b = Fifo::new(2);
        a.push(7).unwrap();
        assert!(a.shift_into(&mut b));
        assert_eq!(b.pop(), Some(7));
        assert!(!a.shift_into(&mut b)); // a now empty
    }

    #[test]
    fn high_water_mark() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        for _ in 0..3 {
            f.pop();
        }
        assert_eq!(f.stats.max_occupancy, 5);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _: Fifo<u8> = Fifo::new(0);
    }
}
