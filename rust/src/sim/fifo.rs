//! Hardware FIFO model with capacity, backpressure, and occupancy stats
//! — plus the host-side bounded SPSC row channel the streamed
//! inter-layer executor runs on.
//!
//! [`Fifo`] is used for the line buffer rows (Fig. 7a) and the
//! inter-layer buffers of the streaming pipeline (SectionIV-E.1).
//! `push` fails when full — the "request-response" handshake turns
//! that into upstream stall cycles.
//!
//! [`row_channel`] is the executed counterpart: a bounded channel of
//! word-packed output rows between two layer workers. It is built from
//! two unbounded `mpsc` legs — a data leg and a recycle leg pre-filled
//! with `capacity` row buffers — so the bound is enforced by the
//! circulating buffer count: a producer must receive a recycled buffer
//! before it can send again. That makes the steady state
//! allocation-free and the acyclic worker topology deadlock-free for
//! any capacity >= 1 (a blocked producer always has a consumer that
//! recycles; nothing waits on the producer to drain first).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender,
                      TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::telemetry::TraceSink;

/// Outcome of a bounded channel wait ([`RowReceiver::recv_timeout`] /
/// [`RowSender::acquire_timeout`]) — the watchdog-aware variants the
/// supervised streamed executor polls with.
#[derive(Debug, PartialEq, Eq)]
pub enum RowWait {
    /// A buffer arrived within the slice.
    Ready(Vec<u64>),
    /// Nothing arrived within the slice; the peer is still alive.
    /// Callers re-check their deadline/abort flag and wait again.
    TimedOut,
    /// The peer hung up (panicked or aborted) — no buffer will ever
    /// arrive.
    Closed,
}

#[derive(Debug, Clone)]
pub struct Fifo<T> {
    capacity: usize,
    items: VecDeque<T>,
    pub stats: FifoStats,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FifoStats {
    pub pushes: u64,
    pub pops: u64,
    /// Rejected pushes (upstream stalls under the handshake).
    pub full_rejects: u64,
    /// Pops attempted while empty (downstream starvation).
    pub empty_rejects: u64,
    /// High-water mark of occupancy.
    pub max_occupancy: usize,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "fifo capacity must be positive");
        Self {
            capacity,
            items: VecDeque::with_capacity(capacity),
            stats: FifoStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Try to enqueue; `Err(item)` when full (backpressure).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            self.stats.full_rejects += 1;
            return Err(item);
        }
        self.items.push_back(item);
        self.stats.pushes += 1;
        self.stats.max_occupancy = self.stats.max_occupancy.max(self.len());
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        match self.items.pop_front() {
            Some(x) => {
                self.stats.pops += 1;
                Some(x)
            }
            None => {
                self.stats.empty_rejects += 1;
                None
            }
        }
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Tail-to-head chaining (Fig. 7a): pop here, push into `next`.
    pub fn shift_into(&mut self, next: &mut Fifo<T>) -> bool {
        if next.is_full() || self.is_empty() {
            return false;
        }
        let item = self.pop().expect("checked non-empty");
        next.push(item).ok().expect("checked non-full");
        true
    }
}

/// Shared occupancy/backpressure counters of one [`row_channel`] —
/// the atomic analogue of [`FifoStats`], readable while the workers
/// run and after the scope joins.
#[derive(Debug, Default)]
pub struct RowChannelStats {
    /// Rows sent downstream.
    pub sends: AtomicU64,
    /// Rows received by the consumer.
    pub recvs: AtomicU64,
    /// Times the producer found no recycled buffer and had to block —
    /// downstream backpressure (the executed analogue of
    /// `FifoStats::full_rejects`).
    pub backpressure_waits: AtomicU64,
    /// High-water mark of rows in flight (<= capacity by construction).
    pub max_occupancy: AtomicU64,
    in_flight: AtomicU64,
}

impl RowChannelStats {
    pub fn sends(&self) -> u64 {
        self.sends.load(Ordering::Relaxed)
    }

    pub fn backpressure_waits(&self) -> u64 {
        self.backpressure_waits.load(Ordering::Relaxed)
    }

    pub fn max_occupancy(&self) -> u64 {
        self.max_occupancy.load(Ordering::Relaxed)
    }

    /// Plain-data copy of the counters (what pipeline reports carry).
    pub fn snapshot(&self) -> ChannelSnapshot {
        ChannelSnapshot {
            sends: self.sends(),
            recvs: self.recvs.load(Ordering::Relaxed),
            backpressure_waits: self.backpressure_waits(),
            max_occupancy: self.max_occupancy(),
        }
    }
}

/// Plain-data snapshot of one row channel's counters, taken after the
/// worker scope joins. Host-timing-dependent (how often the producer
/// blocked depends on thread scheduling), so it rides on reports
/// *next to* the architectural fields, never inside the bit-exact
/// comparisons.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChannelSnapshot {
    pub sends: u64,
    pub recvs: u64,
    pub backpressure_waits: u64,
    pub max_occupancy: u64,
}

/// Producer half of a [`row_channel`].
pub struct RowSender {
    data: Sender<Vec<u64>>,
    recycle: Receiver<Vec<u64>>,
    stats: Arc<RowChannelStats>,
    /// Span recorder for blocking waits (None = no tracing).
    trace: Option<Arc<TraceSink>>,
    /// Channel id carried on wait spans (producer layer index).
    link: u64,
}

impl RowSender {
    /// Record blocking `acquire` waits as `channel.wait` spans on
    /// `trace`, tagged with channel id `link`.
    pub fn set_trace(&mut self, trace: Option<Arc<TraceSink>>,
                     link: u64) {
        self.trace = trace;
        self.link = link;
    }

    /// Take a free row buffer, blocking (and counting backpressure)
    /// until the consumer recycles one. `None` when the consumer is
    /// gone (it panicked — the thread scope will propagate).
    pub fn acquire(&self) -> Option<Vec<u64>> {
        match self.recycle.try_recv() {
            Ok(buf) => Some(buf),
            Err(TryRecvError::Empty) => {
                self.stats
                    .backpressure_waits
                    .fetch_add(1, Ordering::Relaxed);
                // Only the genuinely blocking path records a span —
                // the fast path above stays a single try_recv.
                let t0 = self.trace.as_ref().map(|t| t.start());
                let buf = self.recycle.recv().ok();
                if let (Some(tr), Some(t0)) = (&self.trace, t0) {
                    tr.record("channel.wait", "backpressure", t0,
                              [("link", self.link), ("", 0)]);
                }
                buf
            }
            Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Bounded-wait [`RowSender::acquire`]: block at most `slice` for
    /// a recycled buffer. The backpressure counter ticks on the first
    /// slice of a blocking wait only (retries after `TimedOut` pass
    /// `count_wait = false`), so counters match the unbounded path.
    pub fn acquire_timeout(&self, slice: Duration, count_wait: bool)
                           -> RowWait {
        match self.recycle.try_recv() {
            Ok(buf) => RowWait::Ready(buf),
            Err(TryRecvError::Empty) => {
                if count_wait {
                    self.stats
                        .backpressure_waits
                        .fetch_add(1, Ordering::Relaxed);
                }
                let t0 = self.trace.as_ref().map(|t| t.start());
                let got = self.recycle.recv_timeout(slice);
                if let (Some(tr), Some(t0)) = (&self.trace, t0) {
                    tr.record("channel.wait", "backpressure", t0,
                              [("link", self.link), ("", 0)]);
                }
                match got {
                    Ok(buf) => RowWait::Ready(buf),
                    Err(RecvTimeoutError::Timeout) => RowWait::TimedOut,
                    Err(RecvTimeoutError::Disconnected) => RowWait::Closed,
                }
            }
            Err(TryRecvError::Disconnected) => RowWait::Closed,
        }
    }

    /// Send one filled row buffer downstream.
    pub fn send(&self, buf: Vec<u64>) -> bool {
        let occ = self.stats.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.stats.max_occupancy.fetch_max(occ, Ordering::Relaxed);
        self.stats.sends.fetch_add(1, Ordering::Relaxed);
        self.data.send(buf).is_ok()
    }

    pub fn stats(&self) -> Arc<RowChannelStats> {
        self.stats.clone()
    }
}

/// Consumer half of a [`row_channel`].
pub struct RowReceiver {
    data: Receiver<Vec<u64>>,
    recycle: Sender<Vec<u64>>,
    stats: Arc<RowChannelStats>,
}

impl RowReceiver {
    /// Receive the next row, blocking until the producer sends one.
    /// `None` when the producer is gone.
    pub fn recv(&self) -> Option<Vec<u64>> {
        let buf = self.data.recv().ok()?;
        self.stats.recvs.fetch_add(1, Ordering::Relaxed);
        self.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
        Some(buf)
    }

    /// Bounded-wait [`RowReceiver::recv`]: block at most `slice` for
    /// the next row so a watchdog-supervised worker can re-check its
    /// deadline between slices.
    pub fn recv_timeout(&self, slice: Duration) -> RowWait {
        match self.data.recv_timeout(slice) {
            Ok(buf) => {
                self.stats.recvs.fetch_add(1, Ordering::Relaxed);
                self.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
                RowWait::Ready(buf)
            }
            Err(RecvTimeoutError::Timeout) => RowWait::TimedOut,
            Err(RecvTimeoutError::Disconnected) => RowWait::Closed,
        }
    }

    /// Hand a consumed buffer back to the producer.
    pub fn recycle(&self, buf: Vec<u64>) {
        // A gone producer just drops the buffer — not an error at
        // end-of-stream.
        let _ = self.recycle.send(buf);
    }

    pub fn stats(&self) -> Arc<RowChannelStats> {
        self.stats.clone()
    }
}

/// Build a bounded SPSC row channel: `capacity` circulating buffers
/// of `words` zeroed `u64`s each (see [`crate::codec::SpikeFrame::row_words`]).
pub fn row_channel(capacity: usize, words: usize)
                   -> (RowSender, RowReceiver) {
    let capacity = capacity.max(1);
    let (data_tx, data_rx) = channel();
    let (recycle_tx, recycle_rx) = channel();
    for _ in 0..capacity {
        recycle_tx
            .send(vec![0u64; words])
            .expect("receiver held locally");
    }
    let stats = Arc::new(RowChannelStats::default());
    (
        RowSender { data: data_tx, recycle: recycle_rx,
                    stats: stats.clone(), trace: None, link: 0 },
        RowReceiver { data: data_rx, recycle: recycle_tx, stats },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_channel_bounds_in_flight_rows() {
        let (tx, rx) = row_channel(2, 1);
        // Producer thread pushes 8 rows through a depth-2 channel.
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..8u64 {
                    let mut buf = tx.acquire().unwrap();
                    buf[0] = i;
                    assert!(tx.send(buf));
                }
            });
            for want in 0..8u64 {
                let buf = rx.recv().unwrap();
                assert_eq!(buf[0], want);
                rx.recycle(buf);
            }
        });
        let stats = rx.stats();
        assert_eq!(stats.sends(), 8);
        assert_eq!(stats.recvs.load(Ordering::Relaxed), 8);
        assert!(stats.max_occupancy() <= 2,
                "bound violated: {}", stats.max_occupancy());
    }

    #[test]
    fn row_channel_capacity_one_makes_progress() {
        let (tx, rx) = row_channel(1, 4);
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..100 {
                    let buf = tx.acquire().unwrap();
                    tx.send(buf);
                }
            });
            for _ in 0..100 {
                let buf = rx.recv().unwrap();
                rx.recycle(buf);
            }
        });
        assert_eq!(rx.stats().sends(), 100);
    }

    /// Snapshots are plain copies of the live counters, and a traced
    /// sender records its blocking waits as backpressure spans.
    #[test]
    fn row_channel_snapshot_and_wait_spans() {
        let sink = Arc::new(TraceSink::new(64));
        let (mut tx, rx) = row_channel(1, 1);
        tx.set_trace(Some(sink.clone()), 3);
        // Fill the single slot, then acquire again from another
        // thread: it must block until the consumer recycles.
        let buf = tx.acquire().unwrap();
        assert!(tx.send(buf));
        std::thread::scope(|s| {
            s.spawn(|| {
                let buf = tx.acquire().unwrap();
                tx.send(buf);
            });
            let buf = rx.recv().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
            rx.recycle(buf);
            rx.recv().unwrap();
        });
        let snap = rx.stats().snapshot();
        assert_eq!(snap.sends, 2);
        assert_eq!(snap.recvs, 2);
        assert!(snap.backpressure_waits >= 1);
        assert!(snap.max_occupancy <= 1);
        let evs = sink.events();
        assert!(evs.iter().any(|e| e.name == "channel.wait"
                    && e.cat == "backpressure"
                    && e.args[0] == ("link", 3)),
                "blocking acquire must leave a wait span: {evs:?}");
    }

    /// The bounded-wait variants distinguish "nothing yet" from "peer
    /// gone" and keep the counters identical to the unbounded path.
    #[test]
    fn timeout_variants_report_timeout_and_closure() {
        let (tx, rx) = row_channel(1, 1);
        let slice = Duration::from_millis(5);
        assert_eq!(rx.recv_timeout(slice), RowWait::TimedOut);
        let buf = match tx.acquire_timeout(slice, true) {
            RowWait::Ready(b) => b,
            other => panic!("expected a prefilled buffer, got {other:?}"),
        };
        assert!(tx.send(buf));
        // Channel slot now empty: a second acquire times out...
        assert_eq!(tx.acquire_timeout(slice, true), RowWait::TimedOut);
        match rx.recv_timeout(slice) {
            RowWait::Ready(b) => rx.recycle(b),
            other => panic!("expected the sent row, got {other:?}"),
        }
        // ...and succeeds once the consumer recycles.
        assert!(matches!(tx.acquire_timeout(slice, false),
                         RowWait::Ready(_)));
        let stats = rx.stats().snapshot();
        assert_eq!(stats.sends, 1);
        assert_eq!(stats.recvs, 1);
        assert_eq!(stats.backpressure_waits, 1,
                   "only the counted blocking acquire ticks the counter");
        // Dropped peers read as Closed on both halves.
        drop(rx);
        assert_eq!(tx.acquire_timeout(slice, false), RowWait::Closed);
        let (tx2, rx2) = row_channel(1, 1);
        drop(tx2);
        assert_eq!(rx2.recv_timeout(slice), RowWait::Closed);
    }

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.push(i).unwrap();
        }
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn backpressure_on_full() {
        let mut f = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.stats.full_rejects, 1);
    }

    #[test]
    fn starvation_counted() {
        let mut f: Fifo<u8> = Fifo::new(2);
        assert!(f.pop().is_none());
        assert_eq!(f.stats.empty_rejects, 1);
    }

    #[test]
    fn chained_shift() {
        let mut a = Fifo::new(2);
        let mut b = Fifo::new(2);
        a.push(7).unwrap();
        assert!(a.shift_into(&mut b));
        assert_eq!(b.pop(), Some(7));
        assert!(!a.shift_into(&mut b)); // a now empty
    }

    #[test]
    fn high_water_mark() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        for _ in 0..3 {
            f.pop();
        }
        assert_eq!(f.stats.max_occupancy, 5);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _: Fifo<u8> = Fifo::new(0);
    }
}
