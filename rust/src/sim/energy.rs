//! Energy model: dynamic per-op/per-access energies + static power.
//!
//! ## Calibration (DESIGN.md Substitutions)
//!
//! The FPGA's Vivado power reports are replaced by a first-order model
//! calibrated against the paper's own design points (Table IV):
//!
//! * Dynamic slope: SCNN3 Ours-1 -> Ours-2 adds 5.39 Gop/s for +0.05 W
//!   (~9.3 pJ/op); SCNN5 Ours-3 -> Ours-4 adds 15.4 Gop/s for +0.19 W
//!   (~12.3 pJ/op). We use **10 pJ per synaptic op** (accumulate +
//!   weight-buffer read + control) as the per-op dynamic energy.
//! * Static floor: fitted as `P_base + c_pe*PEs + c_bram*BRAM36` with
//!   P_base = 0.45 W, c_pe = 2.5 mW, c_bram = 1.2 mW, which lands on
//!   the paper's 0.66/0.71 W (SCNN3), 1.34/1.53 W (SCNN5), 0.74 W
//!   (vMobileNet) once the dynamic part is added.
//! * Memory access energies follow the Eyeriss-style hierarchy ratios
//!   (reg 1x : BRAM ~6x : DRAM ~200x), normalised so a BRAM vector
//!   access is 5 pJ.
//!
//! Absolute joules are model-calibrated; **ratios** (T1 vs T2, layer
//! breakdowns, parallel vs not) are structural and are the claims under
//! test (Fig. 11, Table IV).

use super::memory::{AccessCounter, DataKind, MemLevel};

/// Per-event energies in picojoules + static power in watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// One synaptic accumulate (int8 add + weight fetch + control).
    pub pj_per_op: f64,
    /// PE-register access (membrane potential during OS accumulate).
    pub pj_reg: f64,
    /// BRAM vector access (line buffer, weight buffer, Vmem buffer).
    pub pj_bram: f64,
    /// Off-chip DRAM vector access.
    pub pj_dram: f64,
    /// Static power floor of the PS+PL.
    pub static_base_w: f64,
    /// Static increment per instantiated PE.
    pub static_per_pe_w: f64,
    /// Static increment per BRAM36 used.
    pub static_per_bram_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            pj_per_op: 10.0,
            pj_reg: 0.1,
            pj_bram: 5.0,
            pj_dram: 200.0,
            static_base_w: 0.45,
            static_per_pe_w: 2.5e-3,
            static_per_bram_w: 1.2e-3,
        }
    }
}

/// Energy accounting for one run (one layer or a whole pipeline).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyReport {
    pub compute_pj: f64,
    pub input_pj: f64,
    pub weight_pj: f64,
    pub vmem_pj: f64,
    pub output_pj: f64,
}

impl EnergyReport {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.input_pj + self.weight_pj + self.vmem_pj
            + self.output_pj
    }

    pub fn total_j(&self) -> f64 {
        self.total_pj() * 1e-12
    }

    pub fn add(&mut self, other: &EnergyReport) {
        self.compute_pj += other.compute_pj;
        self.input_pj += other.input_pj;
        self.weight_pj += other.weight_pj;
        self.vmem_pj += other.vmem_pj;
        self.output_pj += other.output_pj;
    }
}

impl EnergyModel {
    fn pj_at(&self, level: MemLevel) -> f64 {
        match level {
            MemLevel::Reg => self.pj_reg,
            MemLevel::Bram => self.pj_bram,
            MemLevel::Dram => self.pj_dram,
        }
    }

    /// Dynamic energy of a counted run: `ops` synaptic accumulates plus
    /// every memory access in `counters`.
    pub fn dynamic(&self, ops: u64, counters: &AccessCounter) -> EnergyReport {
        let mut rep = EnergyReport {
            compute_pj: ops as f64 * self.pj_per_op,
            ..Default::default()
        };
        for (level, kind, r, w) in counters.iter() {
            let pj = (r + w) as f64 * self.pj_at(level);
            match kind {
                DataKind::InputSpike => rep.input_pj += pj,
                DataKind::Weight => rep.weight_pj += pj,
                DataKind::PartialSum | DataKind::Vmem => rep.vmem_pj += pj,
                DataKind::OutputSpike => rep.output_pj += pj,
            }
        }
        rep
    }

    /// Static power of a design point (W).
    pub fn static_power(&self, pes: usize, bram36: f64) -> f64 {
        self.static_base_w
            + self.static_per_pe_w * pes as f64
            + self.static_per_bram_w * bram36
    }

    /// Average power at a given throughput: dynamic energy/frame times
    /// FPS plus the static floor.
    pub fn avg_power(&self, dyn_j_per_frame: f64, fps: f64, pes: usize,
                     bram36: f64) -> f64 {
        dyn_j_per_frame * fps + self.static_power(pes, bram36)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_energy_sums_kinds() {
        let m = EnergyModel::default();
        let mut c = AccessCounter::new();
        c.read(MemLevel::Bram, DataKind::Weight, 100);
        c.read(MemLevel::Dram, DataKind::InputSpike, 10);
        c.write(MemLevel::Bram, DataKind::Vmem, 50);
        let rep = m.dynamic(1000, &c);
        assert!((rep.compute_pj - 10_000.0).abs() < 1e-9);
        assert!((rep.weight_pj - 500.0).abs() < 1e-9);
        assert!((rep.input_pj - 2000.0).abs() < 1e-9);
        assert!((rep.vmem_pj - 250.0).abs() < 1e-9);
        assert!(rep.total_pj() > 12_000.0);
    }

    #[test]
    fn dram_dominates_bram_dominates_reg() {
        let m = EnergyModel::default();
        assert!(m.pj_dram > 10.0 * m.pj_bram);
        assert!(m.pj_bram > 10.0 * m.pj_reg);
    }

    /// Static power at the paper's design points lands near Table IV.
    #[test]
    fn static_power_calibration() {
        let m = EnergyModel::default();
        let scnn3 = m.static_power(54, 11.5);
        assert!((scnn3 - 0.66).abs() < 0.12, "scnn3 {scnn3}");
        let scnn5 = m.static_power(99, 527.5);
        assert!((scnn5 - 1.34).abs() < 0.15, "scnn5 {scnn5}");
        let vmob = m.static_power(40, 13.5);
        assert!((vmob - 0.74).abs() < 0.2, "vmobilenet {vmob}");
    }
}
