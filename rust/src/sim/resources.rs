//! FPGA resource model: LUT / FF / BRAM per module vs the ZCU102 budget.
//!
//! ## Calibration (DESIGN.md Substitutions)
//!
//! Vivado synthesis reports are replaced by a first-order area model
//! fitted to the paper's Table V design points:
//!
//! * Per conv layer: `LUT = 40*PEs + 12*P*Ci + 50`, where the `P*Ci`
//!   term is the weight-mux / spike-vector datapath width scaling with
//!   the parallel factor. FF = 1.2 x LUT (register-rich pipeline).
//!   This lands SCNN3@(4,2) ~ 3.5K LUT, SCNN5@(4,4,2,1) ~ 25.5K LUT,
//!   vMobileNet ~ 7.7K LUT region (paper: 3.5 / 25.52 / 7.7).
//! * BRAM36: weight buffers at int8 (`bytes/4608` blocks) + line
//!   buffers (`Kh * Wi * Ci` bits) + Vmem buffer when T > 1 + a block
//!   per inter-layer FIFO.

use crate::arch::{ConvLayer, ConvMode, Layer, NetworkSpec};

/// ZCU102 (xczu9eg) budget — paper Table V "Available".
#[derive(Debug, Clone, Copy)]
pub struct Zcu102;

impl Zcu102 {
    pub const LUT: u64 = 274_000;
    pub const FF: u64 = 548_000;
    pub const BRAM36: f64 = 912.0;
    pub const DSP: u64 = 2_520;
}

/// Resource usage of one module or a whole design.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceReport {
    pub lut: u64,
    pub ff: u64,
    pub bram36: f64,
    pub dsp: u64,
}

impl ResourceReport {
    pub fn add(&mut self, o: &ResourceReport) {
        self.lut += o.lut;
        self.ff += o.ff;
        self.bram36 += o.bram36;
        self.dsp += o.dsp;
    }

    pub fn lut_util(&self) -> f64 {
        self.lut as f64 / Zcu102::LUT as f64 * 100.0
    }

    pub fn bram_util(&self) -> f64 {
        self.bram36 / Zcu102::BRAM36 * 100.0
    }

    pub fn fits(&self) -> bool {
        self.lut <= Zcu102::LUT
            && self.ff <= Zcu102::FF
            && self.bram36 <= Zcu102::BRAM36
            && self.dsp <= Zcu102::DSP
    }
}

/// Area model.
#[derive(Debug, Clone, Copy)]
pub struct ResourceModel {
    pub lut_per_pe: u64,
    pub lut_per_ci_lane: u64,
    pub lut_layer_control: u64,
    pub ff_per_lut: f64,
    /// BRAM36 bytes capacity (36 Kbit = 4608 bytes).
    pub bram_bytes: usize,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self {
            lut_per_pe: 40,
            lut_per_ci_lane: 12,
            lut_layer_control: 50,
            ff_per_lut: 1.2,
            bram_bytes: 4608,
        }
    }
}

impl ResourceModel {
    /// Logic + memory of one conv layer at `timesteps`.
    pub fn conv_layer(&self, l: &ConvLayer, timesteps: usize)
                      -> ResourceReport {
        let lut = self.lut_per_pe * l.pes() as u64
            + self.lut_per_ci_lane * (l.parallel * l.ci) as u64
            + self.lut_layer_control;

        // Line buffer: Kh rows x Wi pixels x Ci bits (only multi-tap
        // modes need it; pointwise streams directly).
        let linebuf_bits = if l.mode == ConvMode::Pointwise {
            0
        } else {
            l.kh * l.in_w * l.ci
        };
        // Weight buffer + Vmem buffer (T > 1 only, Fig. 11).
        let weight_bytes = l.weight_bytes();
        let vmem_bytes = if timesteps > 1 { l.vmem_bytes() } else { 0 };
        let bram_bytes_total =
            weight_bytes + vmem_bytes + linebuf_bits.div_ceil(8);
        let bram36 = bram_bytes_total as f64 / self.bram_bytes as f64;

        ResourceReport {
            lut,
            ff: (lut as f64 * self.ff_per_lut) as u64,
            bram36,
            dsp: 0, // spike-gated adds need no DSP48 (the SNN advantage)
        }
    }

    /// Whole design: conv layers + pooling (negligible logic) + FC
    /// weight storage + one inter-layer FIFO block per boundary.
    pub fn network(&self, net: &NetworkSpec, timesteps: usize)
                   -> ResourceReport {
        let mut total = ResourceReport::default();
        for layer in &net.layers {
            match layer {
                Layer::Conv(c) if !c.encoder => {
                    total.add(&self.conv_layer(c, timesteps))
                }
                Layer::Conv(_) => {}
                Layer::Pool { .. } => total.add(&ResourceReport {
                    lut: 30,
                    ff: 36,
                    bram36: 0.0,
                    dsp: 0,
                }),
                Layer::Fc { n_in, n_out } => total.add(&ResourceReport {
                    lut: 200,
                    ff: 240,
                    bram36: (n_in * n_out) as f64 / self.bram_bytes as f64,
                    dsp: 0,
                }),
            }
        }
        // Inter-layer FIFOs: half a BRAM36 per boundary.
        total.bram36 += (net.layers.len() as f64 - 1.0) * 0.5;
        total
    }

    /// Per-layer reports for Fig. 12 (before/after parallelism).
    pub fn per_conv_layer(&self, net: &NetworkSpec, timesteps: usize)
                          -> Vec<ResourceReport> {
        net.accel_convs()
            .iter()
            .map(|c| self.conv_layer(c, timesteps))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5, vmobilenet};

    /// Table V: used LUT 3.5K / 25.52K / 7.7K; BRAM 11.5 / 527.5 / ~13.
    #[test]
    fn table5_lut_calibration() {
        let m = ResourceModel::default();
        let s3 = m.network(&scnn3().try_with_parallel_factors(&[4, 2]).unwrap(), 1);
        assert!((s3.lut as f64 - 3500.0).abs() / 3500.0 < 0.5,
                "scnn3 lut {}", s3.lut);
        let s5 = m.network(&scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(), 1);
        assert!((s5.lut as f64 - 25520.0).abs() / 25520.0 < 0.3,
                "scnn5 lut {}", s5.lut);
        let vm = m.network(&vmobilenet(), 1);
        assert!((vm.lut as f64 - 7700.0).abs() / 7700.0 < 0.6,
                "vmobilenet lut {}", vm.lut);
    }

    #[test]
    fn table5_bram_calibration() {
        let m = ResourceModel::default();
        let s5 = m.network(&scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(), 1);
        assert!((s5.bram36 - 527.5).abs() / 527.5 < 0.15,
                "scnn5 bram {}", s5.bram36);
        let s3 = m.network(&scnn3().try_with_parallel_factors(&[4, 2]).unwrap(), 1);
        assert!(s3.bram36 > 2.0 && s3.bram36 < 20.0,
                "scnn3 bram {}", s3.bram36);
    }

    #[test]
    fn t2_needs_more_bram_than_t1() {
        let m = ResourceModel::default();
        let net = scnn5();
        let t1 = m.network(&net, 1).bram36;
        let t2 = m.network(&net, 2).bram36;
        // Fig. 11: the delta is the Vmem buffer, ~126 KB ~= 28 BRAM36.
        let delta_kb = (t2 - t1) * 4608.0 / 1024.0;
        assert!((delta_kb - 126.0).abs() < 40.0, "delta {delta_kb} KB");
    }

    #[test]
    fn parallelism_costs_logic_not_bram() {
        let m = ResourceModel::default();
        let base = m.network(&scnn5(), 1);
        let par = m.network(&scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(), 1);
        assert!(par.lut > base.lut);
        assert!((par.bram36 - base.bram36).abs() < 1.0);
    }

    #[test]
    fn everything_fits_zcu102() {
        let m = ResourceModel::default();
        for net in [
            scnn3().try_with_parallel_factors(&[4, 2]).unwrap(),
            scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(),
            vmobilenet(),
        ] {
            assert!(m.network(&net, 2).fits(), "{} does not fit", net.name);
        }
    }
}
