//! Sparsity-skip compute backend: the word-parallel bit-plane walk
//! plus the two optimisations real SNN activity pays for —
//!
//! 1. **Hierarchical occupancy skipping.** A summary `u64` over the
//!    packed field string marks which *word groups* hold any spike
//!    ([`Occupancy`]): bit `g` set iff at least one of group `g`'s
//!    `group_words` consecutive `u64`s is nonzero. The plane walk then
//!    visits only the set groups — an all-zero receptive field costs a
//!    single compare, and a field with one spike cluster touches one
//!    group per plane instead of the whole string. This is the host
//!    mirror of the paper's compressed & sorted spike representation
//!    (Section IV-C stores only active positions) and the core
//!    observation SpikeX builds its accelerator around: most of a dense
//!    AND+popcount walk is against zero words.
//! 2. **Weight-stationary row batching.** Instead of evaluating each
//!    field against all 8 planes of every output channel as the window
//!    slides, the backend can *stash* the packed window
//!    ([`super::ConvCompute::stash_field`]) and later evaluate the
//!    whole row of stashed fields in one pass per output channel
//!    ([`super::ConvCompute::field_psums_batch`]): the channel's planes
//!    stay cache-hot while every window streams past, rather than the
//!    planes streaming past every window. `Session::infer_batch`
//!    benefits directly — queued frames' conv rows all ride this path.
//!
//! Popcounts run over 4-`u64` chunks ([`popcount_and`]) so the
//! AND+popcount chains of neighbouring words stay independent — plain
//! chunked scalar code, no nightly `std::simd`.
//!
//! Everything here is bit-exact against the other two backends (the
//! skipped groups contain only zero words; popcount is exact), pinned
//! by `tests/diff_backends.rs` and `tests/prop_backend.rs`. Unlike
//! word-parallel, the *host* cost tracks observed spike density — the
//! DSE calibrator treats its measured host-ns like the event-driven
//! backend's (see `autotune::measure`).

use crate::arch::{ConvLayer, ConvMode};

use super::{shr_bits, Acc, BackendKind, ConvCompute, ConvWeights,
            FcCompute, LineBuffer, WordParallelConv, WordParallelFc};

/// Hierarchical occupancy bitmap over a packed `w_words`-long bit
/// string: `summary` bit `g` is set iff word group `g` (a run of
/// [`Occupancy::group_words`] consecutive `u64`s) holds any set bit.
#[derive(Clone, Debug)]
struct Occupancy {
    /// Words per summary group: `w_words.div_ceil(64)` so the whole
    /// string always fits the single summary word, floored at 4 so
    /// each visited group feeds the 4-wide chunked popcount.
    group_words: usize,
    /// Bit `g` = "group `g` has any spike".
    summary: u64,
}

impl Occupancy {
    fn new(w_words: usize) -> Self {
        Self { group_words: w_words.div_ceil(64).max(4), summary: 0 }
    }

    /// Recompute the summary from the packed string `win`. O(w_words)
    /// ORs — the same order as the pack that produced `win`, so the
    /// slide protocol stays O(Ci) per output pixel.
    fn rebuild(&mut self, win: &[u64]) {
        let mut summary = 0u64;
        for (g, chunk) in win.chunks(self.group_words).enumerate() {
            let mut any = 0u64;
            for &w in chunk {
                any |= w;
            }
            if any != 0 {
                summary |= 1u64 << g;
            }
        }
        self.summary = summary;
    }
}

/// AND the two equal-length word slices and popcount the result, four
/// words per step with independent counters (the wide-word walk).
#[inline]
fn popcount_and(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for (qa, qb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        c0 += (qa[0] & qb[0]).count_ones();
        c1 += (qa[1] & qb[1]).count_ones();
        c2 += (qa[2] & qb[2]).count_ones();
        c3 += (qa[3] & qb[3]).count_ones();
    }
    for (w, p) in a[n4..].iter().zip(&b[n4..]) {
        c0 += (w & p).count_ones();
    }
    c0 + c1 + c2 + c3
}

/// The sparsity-skip conv backend: wraps the word-parallel packer and
/// weight planes (same slide protocol, same shared `Arc` planes) and
/// replaces the dense plane walk with an occupancy-gated one, plus the
/// stash/batch path.
#[derive(Clone)]
pub(super) struct SparseConv {
    inner: WordParallelConv,
    occ: Occupancy,
    /// Occupancy-skip toggle — `false` walks every group exactly like
    /// word-parallel (test hook proving skip-on == skip-off).
    skip: bool,
    /// Stashed packed windows, flat `[i * w_words ..][w_words]`.
    batch_wins: Vec<u64>,
    /// Per-stash active spike counts (the `ops` half of each psum).
    batch_counts: Vec<u64>,
    /// Per-stash occupancy summaries.
    batch_occs: Vec<u64>,
}

impl SparseConv {
    pub(super) fn new(layer: &ConvLayer, weights: &ConvWeights) -> Self {
        Self::with_skip(layer, weights, true)
    }

    fn with_skip(layer: &ConvLayer, weights: &ConvWeights,
                 skip: bool) -> Self {
        let inner = WordParallelConv::new(layer, weights);
        let occ = Occupancy::new(inner.w_words);
        Self {
            inner,
            occ,
            skip,
            batch_wins: Vec::new(),
            batch_counts: Vec::new(),
            batch_occs: Vec::new(),
        }
    }

    /// Occupancy-gated plane walk: like `WordParallelConv::plane_psum`
    /// but each nonzero plane is popcounted only over the word groups
    /// `groups` marks occupied (all groups when skipping is off).
    fn plane_walk(&self, win: &[u64], groups: u64, co: usize) -> Acc {
        let ww = self.inner.w_words;
        let gw = self.occ.group_words;
        let nz = self.inner.plane_nz[co];
        if self.skip && groups == 0 {
            return 0;
        }
        let planes = &self.inner.planes[co * 8 * ww..(co + 1) * 8 * ww];
        let mut psum: Acc = 0;
        for (b, plane) in planes.chunks_exact(ww).enumerate() {
            if nz & (1u8 << b) == 0 {
                continue;
            }
            let cnt = if self.skip {
                let mut cnt = 0u32;
                let mut g = groups;
                while g != 0 {
                    let i = g.trailing_zeros() as usize;
                    g &= g - 1;
                    let s = i * gw;
                    let e = (s + gw).min(ww);
                    cnt += popcount_and(&win[s..e], &plane[s..e]);
                }
                cnt
            } else {
                popcount_and(win, plane)
            };
            if b == 7 {
                // Two's complement: bit 7 weighs -128.
                psum -= (cnt as Acc) << 7;
            } else {
                psum += (cnt as Acc) << b;
            }
        }
        psum
    }

    #[inline]
    fn packed_mode(&self) -> bool {
        self.inner.mode != ConvMode::Depthwise
    }
}

impl ConvCompute for SparseConv {
    fn kind(&self) -> BackendKind {
        BackendKind::Sparse
    }

    fn clone_box(&self) -> Box<dyn ConvCompute> {
        Box::new(self.clone())
    }

    fn begin_row(&mut self) {
        self.inner.begin_row();
    }

    fn begin_field(&mut self, lb: &LineBuffer, ox: usize) {
        self.inner.begin_field(lb, ox);
        if self.packed_mode() {
            self.occ.rebuild(&self.inner.win);
        }
    }

    fn advance(&mut self, lb: &LineBuffer, ox: usize) {
        self.inner.advance(lb, ox);
        if self.packed_mode() {
            // The slide shifted the whole string; group membership of
            // every surviving bit changed, so rebuild the summary (same
            // O(w_words) order as the shift itself).
            self.occ.rebuild(&self.inner.win);
        }
    }

    fn field_psum(&mut self, w: &ConvWeights, co: usize) -> (Acc, u64) {
        if !self.packed_mode() {
            // Depthwise windows are one co-dependent tap-mask word —
            // nothing for the occupancy hierarchy to skip over.
            return self.inner.field_psum(w, co);
        }
        if self.inner.count == 0 {
            return (0, 0);
        }
        let psum = self.plane_walk(&self.inner.win, self.occ.summary, co);
        (psum, self.inner.count)
    }

    fn field_psums(&mut self, w: &ConvWeights, out: &mut [(Acc, u64)]) {
        if !self.packed_mode() {
            self.inner.field_psums(w, out);
            return;
        }
        if self.inner.count == 0 {
            out.iter_mut().for_each(|o| *o = (0, 0));
            return;
        }
        for (co, o) in out.iter_mut().enumerate() {
            *o = (self.plane_walk(&self.inner.win, self.occ.summary, co),
                  self.inner.count);
        }
    }

    fn stash_field(&mut self) -> bool {
        if !self.packed_mode() {
            return false;
        }
        self.batch_wins.extend_from_slice(&self.inner.win);
        self.batch_counts.push(self.inner.count);
        self.batch_occs.push(self.occ.summary);
        true
    }

    fn stashed_fields(&self) -> usize {
        self.batch_counts.len()
    }

    fn field_psums_batch(&mut self, _w: &ConvWeights, n_co: usize,
                         out: &mut [(Acc, u64)]) {
        let ww = self.inner.w_words;
        let n = self.batch_counts.len();
        debug_assert!(out.len() >= n * n_co);
        // Weight-stationary: hold one output channel's planes hot while
        // every stashed window streams past (the transpose of the
        // per-field Co walk — identical sums, better plane locality).
        for co in 0..n_co {
            for i in 0..n {
                let count = self.batch_counts[i];
                let entry = if count == 0 {
                    (0, 0)
                } else {
                    let win = &self.batch_wins[i * ww..(i + 1) * ww];
                    (self.plane_walk(win, self.batch_occs[i], co), count)
                };
                out[i * n_co + co] = entry;
            }
        }
        self.batch_wins.clear();
        self.batch_counts.clear();
        self.batch_occs.clear();
    }
}

/// Test hook: build a sparse conv backend with occupancy skipping
/// forced on or off (`tests/prop_backend.rs` proves the two walks
/// bit-identical).
pub fn sparse_conv_backend(layer: &ConvLayer, weights: &ConvWeights,
                           skip: bool) -> Box<dyn ConvCompute> {
    Box::new(SparseConv::with_skip(layer, weights, skip))
}

/// FC head with nonzero-word skipping: pack the input spikes like
/// word-parallel, but record which packed words are nonzero once and
/// popcount only those against every output neuron's planes — an
/// all-quiet head returns without touching the planes at all.
pub(super) struct SparseFc {
    inner: WordParallelFc,
    /// Indices of nonzero packed words for the current call.
    nz_words: Vec<u32>,
}

impl SparseFc {
    pub(super) fn new(n_in: usize, n_out: usize, weights: &[i8]) -> Self {
        Self {
            inner: WordParallelFc::new(n_in, n_out, weights),
            nz_words: Vec::new(),
        }
    }
}

impl FcCompute for SparseFc {
    fn kind(&self) -> BackendKind {
        BackendKind::Sparse
    }

    fn accumulate(&mut self, spikes: &[bool], _weights: &[i8],
                  n_out: usize, acc: &mut [i64]) -> u64 {
        assert_eq!(spikes.len(), self.inner.n_in);
        self.inner.packed.iter_mut().for_each(|w| *w = 0);
        let mut active = 0u64;
        for (i, &s) in spikes.iter().enumerate() {
            if s {
                self.inner.packed[i / 64] |= 1u64 << (i % 64);
                active += 1;
            }
        }
        if active == 0 {
            return 0;
        }
        self.nz_words.clear();
        for (i, &w) in self.inner.packed.iter().enumerate() {
            if w != 0 {
                self.nz_words.push(i as u32);
            }
        }
        let ww = self.inner.w_words;
        for (o, a) in acc.iter_mut().enumerate().take(n_out) {
            let nz = self.inner.plane_nz[o];
            let planes = &self.inner.planes[o * 8 * ww..(o + 1) * 8 * ww];
            let mut sum: i64 = 0;
            for (b, plane) in planes.chunks_exact(ww).enumerate() {
                if nz & (1u8 << b) == 0 {
                    continue;
                }
                let mut cnt: u32 = 0;
                for &i in &self.nz_words {
                    let i = i as usize;
                    cnt += (self.inner.packed[i] & plane[i]).count_ones();
                }
                if b == 7 {
                    sum -= (cnt as i64) << 7;
                } else {
                    sum += (cnt as i64) << b;
                }
            }
            *a += sum;
        }
        active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn occupancy_empty_and_full() {
        let mut occ = Occupancy::new(10);
        assert_eq!(occ.group_words, 4);
        occ.rebuild(&[0u64; 10]);
        assert_eq!(occ.summary, 0);
        occ.rebuild(&[!0u64; 10]);
        // 10 words / 4 per group -> groups {0, 1, 2} all occupied.
        assert_eq!(occ.summary, 0b111);
    }

    #[test]
    fn occupancy_single_bit_word0_and_last_word() {
        let mut occ = Occupancy::new(10);
        let mut win = vec![0u64; 10];
        win[0] = 1;
        occ.rebuild(&win);
        assert_eq!(occ.summary, 0b001);
        win[0] = 0;
        win[9] = 1u64 << 63;
        occ.rebuild(&win);
        assert_eq!(occ.summary, 0b100);
    }

    #[test]
    fn occupancy_single_bit_at_group_boundary() {
        let mut occ = Occupancy::new(10);
        let mut win = vec![0u64; 10];
        // Word 3 is the last word of group 0; word 4 the first of
        // group 1.
        win[3] = 1u64 << 17;
        occ.rebuild(&win);
        assert_eq!(occ.summary, 0b001);
        win[3] = 0;
        win[4] = 1;
        occ.rebuild(&win);
        assert_eq!(occ.summary, 0b010);
    }

    #[test]
    fn occupancy_group_count_always_fits_summary_word() {
        for w_words in [1usize, 4, 64, 256, 257, 4096, 5000] {
            let occ = Occupancy::new(w_words);
            assert!(w_words.div_ceil(occ.group_words) <= 64,
                    "w_words={w_words} gw={}", occ.group_words);
        }
    }

    #[test]
    fn occupancy_summary_tracks_shr_bits_slide() {
        let mut occ = Occupancy::new(12);
        let mut win = vec![0u64; 12];
        // One spike in the top group; slide it down 5 whole words —
        // same protocol the incremental window uses between fields.
        win[11] = 1u64 << 3;
        occ.rebuild(&win);
        assert_eq!(occ.summary, 0b100);
        shr_bits(&mut win, 5 * 64);
        occ.rebuild(&win);
        assert_eq!(win[6], 1u64 << 3);
        assert_eq!(occ.summary, 0b010);
        shr_bits(&mut win, 5 * 64);
        occ.rebuild(&win);
        assert_eq!(win[1], 1u64 << 3);
        assert_eq!(occ.summary, 0b001);
    }

    #[test]
    fn wide_popcount_matches_scalar() {
        let mut rng = Rng::new(0x5eed);
        for len in [0usize, 1, 3, 4, 5, 7, 8, 13, 64, 100] {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> =
                (0..len).map(|_| rng.next_u64() & rng.next_u64()).collect();
            let scalar: u32 = a.iter()
                .zip(&b)
                .map(|(x, y)| (x & y).count_ones())
                .sum();
            assert_eq!(popcount_and(&a, &b), scalar, "len={len}");
        }
    }
}
