//! Cycle-level OS-dataflow convolution layer engine (paper Fig. 6).
//!
//! Walks receptive fields through the line buffer, drives the PE array
//! per output channel (grouped by the layer's parallel factor), fires
//! neurons, and emits the output spike frame — while counting cycles,
//! memory accesses, and synaptic ops.  The cycle count realises
//! Eq. (12); the integration tests cross-check it against the
//! analytical `dataflow::latency` model, and the functional output is
//! bit-exact against the python L1/L2 semantics.
//!
//! The *functional* psum computation is delegated to a pluggable
//! [`ComputeBackend`](super::backend::ConvCompute): the event-driven
//! `Accurate` walk or the bit-plane `WordParallel` popcount path. Both
//! are bit-exact; cycle / op / access reports are identical by
//! construction (they depend only on layer geometry and the spike
//! pattern, never on the host algorithm — see `sim::backend`).

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::SpikeFrame;
use crate::dataflow::ConvLatencyParams;

use super::array::PeArray;
use super::backend::{conv_backend, BackendKind, ConvCompute};
use super::linebuf::{padded_rows, LineBuffer};
use super::memory::{DataKind, MemLevel};
use super::neuron::NeuronUnit;
use super::pe::adder_tree_latency;

/// int8 weights of one conv layer, laid out `[co][ci][tap]`
/// (depthwise: `[c][0][tap]`; pointwise: `[co][ci][0]`).
#[derive(Debug, Clone)]
pub struct ConvWeights {
    pub scale: f32,
    pub bias: Vec<f32>,
    pub vth: f32,
    taps: Vec<i8>,
    /// Tap-major mirror `[co][tap][ci]` — the hot-path layout
    /// (the backends walk active channels per tap; §Perf).
    taps_tm: Vec<i8>,
    ci: usize,
    ntaps: usize,
}

impl ConvWeights {
    /// Build from a flat `[co][ci][tap]` int8 array.
    pub fn new(layer: &ConvLayer, taps: Vec<i8>, scale: f32, bias: Vec<f32>,
               vth: f32) -> Self {
        let ci_eff = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let ntaps = match layer.mode {
            ConvMode::Pointwise => 1,
            _ => layer.kh * layer.kw,
        };
        assert_eq!(taps.len(), layer.co * ci_eff * ntaps,
                   "weight tap count mismatch");
        assert_eq!(bias.len(), layer.co);
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self { scale, bias, vth, taps, taps_tm, ci: ci_eff, ntaps }
    }

    fn to_tap_major(taps: &[i8], co: usize, ci: usize, ntaps: usize)
                    -> Vec<i8> {
        let mut tm = vec![0i8; taps.len()];
        for o in 0..co {
            for c in 0..ci {
                for t in 0..ntaps {
                    tm[(o * ntaps + t) * ci + c] =
                        taps[(o * ci + c) * ntaps + t];
                }
            }
        }
        tm
    }

    /// Deterministic random weights (benches / hardware-only runs —
    /// cycle counts do not depend on weight values).
    pub fn random(layer: &ConvLayer, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let ci_eff = if layer.mode == ConvMode::Depthwise { 1 } else { layer.ci };
        let ntaps = if layer.mode == ConvMode::Pointwise {
            1
        } else {
            layer.kh * layer.kw
        };
        let n = layer.co * ci_eff * ntaps;
        let taps: Vec<i8> = (0..n).map(|_| rng.int8()).collect();
        // Scale/vth chosen so ~half the psums cross threshold.
        let fanin = (ci_eff * ntaps) as f32;
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self {
            scale: 1.0 / 127.0 / fanin.sqrt(),
            bias: vec![0.0; layer.co],
            vth: 0.05,
            taps,
            taps_tm,
            ci: ci_eff,
            ntaps,
        }
    }

    /// Tap-major taps of output channel `co` (hot-path layout).
    #[inline]
    pub fn taps_tm(&self, co: usize) -> &[i8] {
        let n = self.ci * self.ntaps;
        &self.taps_tm[co * n..(co + 1) * n]
    }

    /// Input channels walked per output channel (1 for depthwise).
    pub fn n_ci(&self) -> usize {
        self.ci
    }

    /// Kernel taps walked per (co, ci) pair (1 for pointwise).
    pub fn n_taps(&self) -> usize {
        self.ntaps
    }

    /// The `[tap]` slice of one (output, input) channel pair — a
    /// borrowed view into the canonical `[co][ci][tap]` layout (no
    /// per-call allocation; §Perf).
    #[inline]
    pub fn taps_of(&self, co: usize, ci: usize) -> &[i8] {
        let s = (co * self.ci + ci) * self.ntaps;
        &self.taps[s..s + self.ntaps]
    }
}

/// Per-run report of the engine — the unified
/// [`LayerStep`](super::engine::LayerStep) every layer engine shares.
pub type ConvRunReport = super::engine::LayerStep;

/// The engine itself. One instance per conv layer of the pipeline.
pub struct ConvEngine {
    pub layer: ConvLayer,
    pub weights: ConvWeights,
    pub timing: ConvLatencyParams,
    pub array: PeArray,
    pub neuron: NeuronUnit,
    backend: Box<dyn ConvCompute>,
    timesteps: usize,
}

impl ConvEngine {
    /// Engine with the default (event-driven `Accurate`) backend.
    pub fn new(layer: ConvLayer, weights: ConvWeights,
               timing: ConvLatencyParams, timesteps: usize) -> Self {
        Self::with_backend(layer, weights, timing, timesteps,
                           BackendKind::Accurate)
    }

    /// Engine with an explicit compute backend.
    pub fn with_backend(layer: ConvLayer, weights: ConvWeights,
                        timing: ConvLatencyParams, timesteps: usize,
                        kind: BackendKind) -> Self {
        let n_neurons = layer.out_h() * layer.out_w() * layer.co;
        let neuron = NeuronUnit::new(
            weights.vth,
            weights.scale,
            weights.bias.clone(),
            n_neurons,
            timesteps,
        );
        let array = PeArray::for_layer(&layer);
        let backend = conv_backend(kind, &layer, &weights);
        Self { layer, weights, timing, array, neuron, backend, timesteps }
    }

    /// Which functional backend this engine computes with.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Architectural Vmem buffer size (18-bit potentials — the BRAM18
    /// word width; see `arch::ConvLayer::vmem_bytes`). The simulator
    /// stores f32 internally for convenience; what the FPGA provisions
    /// is the 18-bit figure, so that is what we report.
    pub fn vmem_bytes(&self) -> usize {
        if self.neuron.vmem_bytes() == 0 {
            0
        } else {
            self.layer.vmem_bytes()
        }
    }

    /// Architectural cycles of one (receptive field, output channel)
    /// evaluation — Eq. (12)'s inner bracket. The FPGA spends the full
    /// `Ci` walk regardless of sparsity or weights, so this is constant
    /// per layer and identical across functional backends.
    fn field_cycles(&self) -> u64 {
        let l = &self.layer;
        let (t_rw, t_pe) = (self.timing.t_rw, self.timing.t_pe);
        let ntaps = l.kh * l.kw;
        match l.mode {
            ConvMode::Standard => {
                self.weights.n_ci() as u64 * (t_rw + t_pe)
                    + adder_tree_latency(ntaps)
            }
            ConvMode::Depthwise => {
                ntaps as u64 * (t_rw + t_pe) + adder_tree_latency(ntaps)
            }
            ConvMode::Pointwise => {
                self.weights.n_ci() as u64 * (t_rw + t_pe)
            }
        }
    }

    /// Run one timestep of one frame. `off_chip_input` marks whether
    /// the input arrives from DRAM (first layer) or an on-chip FIFO.
    pub fn run_timestep(&mut self, input: &SpikeFrame,
                        off_chip_input: bool) -> (SpikeFrame, ConvRunReport) {
        let l = &self.layer;
        assert_eq!((input.h, input.w, input.c), (l.in_h, l.in_w, l.ci),
                   "input shape mismatch for {:?}", l.mode);
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut out = SpikeFrame::zeros(ho, wo, l.co);
        let mut rep = ConvRunReport::default();
        let ops_before = self.array.total_ops();

        let rows = padded_rows(input, l.pad);
        let wi_pad = l.in_w + 2 * l.pad;
        let mut lb = LineBuffer::new(l.kh, wi_pad, l.ci);
        let mut row_iter = rows.into_iter();
        // Prime the line buffer with the first Kh rows.
        for _ in 0..l.kh {
            lb.push_row(row_iter.next().expect("input taller than kernel"),
                        &mut rep.counters, off_chip_input);
        }

        let groups = l.co.div_ceil(l.parallel);
        let n_ci = self.weights.n_ci();
        let field_cycles = self.field_cycles();
        // One weight-buffer read per input channel per output channel
        // walked — charged once per field (hoisted out of the Co loop;
        // identical totals, far fewer counter-map touches. §Perf).
        let weight_reads_per_field = (n_ci * l.co) as u64;

        for oy in 0..ho {
            if oy > 0 {
                // Shift one new input row in (overlapped with compute —
                // the fill pipeline of Fig. 7a; no cycle charge here).
                lb.push_row(row_iter.next().expect("row count"),
                            &mut rep.counters, off_chip_input);
            }
            let full_rows = lb.resident_rows();
            for ox in 0..wo {
                lb.count_window_read(l.kw, &mut rep.counters);
                // One decode / pack per receptive field, shared across
                // the whole Co walk (§Perf).
                self.backend.begin_field(&full_rows, ox);
                rep.counters.read(MemLevel::Bram, DataKind::Weight,
                                  weight_reads_per_field);
                // Output channels in groups of `parallel` lanes; lanes
                // run concurrently so the group costs one lane's time.
                for g in 0..groups {
                    for lane in 0..l.parallel {
                        let co = g * l.parallel + lane;
                        if co >= l.co {
                            break;
                        }
                        let (psum, ops) =
                            self.backend.field_psum(&self.weights, co);
                        self.array.record(lane, ops, field_cycles);
                        let idx = (oy * wo + ox) * l.co + co;
                        if self.neuron.fire(idx, co, psum,
                                            &mut rep.counters) {
                            out.set(oy, ox, co);
                        }
                    }
                    rep.cycles += field_cycles;
                }
                rep.counters.write(MemLevel::Bram, DataKind::OutputSpike, 1);
            }
        }
        rep.ops = self.array.total_ops() - ops_before;
        rep.out_spikes = out.count() as u64;
        (out, rep)
    }

    /// Run all `timesteps` of one frame (same input each step — direct
    /// encoding upstream), merging reports.
    pub fn run_frame(&mut self, input: &SpikeFrame, off_chip_input: bool)
                     -> (SpikeFrame, ConvRunReport) {
        self.neuron.reset();
        let mut merged = ConvRunReport::default();
        let mut last_out = None;
        for _ in 0..self.timesteps {
            let (out, rep) = self.run_timestep(input, off_chip_input);
            merged.cycles += rep.cycles;
            merged.ops += rep.ops;
            merged.out_spikes += rep.out_spikes;
            merged.counters.merge(&rep.counters);
            last_out = Some(out);
        }
        (last_out.expect("timesteps >= 1"), merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ConvLayer, ConvMode};
    use crate::dataflow::{conv_latency, ConvLatencyParams};
    use crate::util::rng::Rng;

    fn layer(mode: ConvMode, parallel: usize) -> ConvLayer {
        let (ci, co) = match mode {
            ConvMode::Depthwise => (6, 6),
            _ => (6, 8),
        };
        let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
        ConvLayer {
            mode,
            in_h: 10,
            in_w: 10,
            ci,
            co,
            kh: k,
            kw: k,
            pad: k / 2,
            encoder: false,
            parallel,
        }
    }

    /// Reference conv + IF in plain rust (mirrors kernels/ref.py).
    fn ref_conv_if(input: &SpikeFrame, l: &ConvLayer, w: &ConvWeights)
                   -> SpikeFrame {
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut out = SpikeFrame::zeros(ho, wo, l.co);
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..l.co {
                    let mut acc: i64 = 0;
                    match l.mode {
                        ConvMode::Standard | ConvMode::Depthwise => {
                            for r in 0..l.kh {
                                for c in 0..l.kw {
                                    let iy = oy as isize + r as isize
                                        - l.pad as isize;
                                    let ix = ox as isize + c as isize
                                        - l.pad as isize;
                                    if iy < 0 || ix < 0
                                        || iy >= l.in_h as isize
                                        || ix >= l.in_w as isize {
                                        continue;
                                    }
                                    let (iy, ix) = (iy as usize, ix as usize);
                                    match l.mode {
                                        ConvMode::Standard => {
                                            for ci in 0..l.ci {
                                                if input.get(iy, ix, ci) {
                                                    acc += w.taps_of(co, ci)
                                                        [r * l.kw + c]
                                                        as i64;
                                                }
                                            }
                                        }
                                        _ => {
                                            if input.get(iy, ix, co) {
                                                acc += w.taps_of(co, 0)
                                                    [r * l.kw + c]
                                                    as i64;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        ConvMode::Pointwise => {
                            for ci in 0..l.ci {
                                if input.get(oy, ox, ci) {
                                    acc += w.taps_of(co, ci)[0] as i64;
                                }
                            }
                        }
                    }
                    let v = acc as f32 * w.scale + w.bias[co];
                    if v >= w.vth {
                        out.set(oy, ox, co);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn standard_engine_matches_reference() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 3);
        let mut rng = Rng::new(1);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, rep) = eng.run_frame(&input, true);
        assert_eq!(got, want);
        assert!(rep.cycles > 0 && rep.ops > 0);
    }

    #[test]
    fn depthwise_engine_matches_reference() {
        let l = layer(ConvMode::Depthwise, 1);
        let w = ConvWeights::random(&l, 5);
        let mut rng = Rng::new(2);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    #[test]
    fn pointwise_engine_matches_reference() {
        let l = layer(ConvMode::Pointwise, 2);
        let w = ConvWeights::random(&l, 7);
        let mut rng = Rng::new(3);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    /// The word-parallel backend matches the reference semantics and
    /// the accurate backend's full report on every conv mode.
    #[test]
    fn word_parallel_backend_is_bit_exact() {
        for mode in [ConvMode::Standard, ConvMode::Depthwise,
                     ConvMode::Pointwise] {
            let l = layer(mode, 2);
            let w = ConvWeights::random(&l, 31);
            let mut rng = Rng::new(9);
            let input = SpikeFrame::random(10, 10, 6, 0.35, &mut rng);
            let want = ref_conv_if(&input, &l, &w);
            let mut acc = ConvEngine::new(
                l.clone(), w.clone(), ConvLatencyParams::optimized(), 1);
            let mut wp = ConvEngine::with_backend(
                l, w, ConvLatencyParams::optimized(), 1,
                BackendKind::WordParallel);
            let (got_a, rep_a) = acc.run_frame(&input, true);
            let (got_w, rep_w) = wp.run_frame(&input, true);
            assert_eq!(got_w, want, "{mode:?}");
            assert_eq!(got_a, got_w, "{mode:?}");
            assert_eq!(rep_a, rep_w, "{mode:?} reports diverge");
        }
    }

    #[test]
    fn cycles_match_analytical_model() {
        for parallel in [1, 2, 4] {
            let l = layer(ConvMode::Standard, parallel);
            let w = ConvWeights::random(&l, 11);
            let timing = ConvLatencyParams::optimized();
            let analytical = conv_latency(&l, &timing);
            let mut eng = ConvEngine::new(l, w, timing, 1);
            let mut rng = Rng::new(4);
            let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
            let (_, rep) = eng.run_frame(&input, true);
            let err = (rep.cycles as f64 - analytical as f64).abs()
                / analytical as f64;
            assert!(err < 0.05,
                    "p={parallel}: engine {} vs model {analytical}",
                    rep.cycles);
        }
    }

    #[test]
    fn parallelism_reduces_cycles() {
        let mut rng = Rng::new(5);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut cycles = Vec::new();
        for p in [1, 2, 4] {
            let l = layer(ConvMode::Standard, p);
            let w = ConvWeights::random(&l, 13);
            let mut eng =
                ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
            let (_, rep) = eng.run_frame(&input, true);
            cycles.push(rep.cycles);
        }
        assert!(cycles[0] > cycles[1] && cycles[1] > cycles[2],
                "{cycles:?}");
        let ratio = cycles[0] as f64 / cycles[2] as f64;
        assert!(ratio > 3.0, "4x lanes gave only {ratio}x");
    }

    #[test]
    fn parallelism_preserves_function() {
        let mut rng = Rng::new(6);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l1 = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l1, 17);
        let mut e1 =
            ConvEngine::new(l1, w.clone(), ConvLatencyParams::optimized(), 1);
        let (out1, _) = e1.run_frame(&input, true);
        let l4 = layer(ConvMode::Standard, 4);
        let mut e4 =
            ConvEngine::new(l4, w, ConvLatencyParams::optimized(), 1);
        let (out4, _) = e4.run_frame(&input, true);
        assert_eq!(out1, out4);
    }

    #[test]
    fn t1_has_zero_vmem_traffic_t2_does_not() {
        let mut rng = Rng::new(7);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 19);
        let mut e1 = ConvEngine::new(l.clone(), w.clone(),
                                     ConvLatencyParams::optimized(), 1);
        let (_, r1) = e1.run_frame(&input, true);
        assert_eq!(r1.counters.total_of_kind(DataKind::Vmem), 0);
        assert_eq!(e1.vmem_bytes(), 0);

        let mut e2 = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 2);
        let (_, r2) = e2.run_frame(&input, true);
        assert!(r2.counters.total_of_kind(DataKind::Vmem) > 0);
        assert!(e2.vmem_bytes() > 0);
        // Two timesteps => ~2x cycles and ~2x ops.
        assert!((r2.cycles as f64 / r1.cycles as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn input_vector_fetched_once_per_pixel() {
        // Table III: off-chip input reads = Hi*Wi (padded rows included
        // as zero vectors are on-chip constants; we count pushed rows).
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 23);
        let mut rng = Rng::new(8);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (_, rep) = eng.run_frame(&input, true);
        let dram_reads =
            rep.counters.reads_of(MemLevel::Dram, DataKind::InputSpike);
        // Padded geometry: (Hi+2p) rows of (Wi+2p) vectors pushed, but
        // only Kh + (Ho-1) rows enter the buffer.
        let rows_pushed = (l_kh() + (10 - 1)) as u64;
        assert_eq!(dram_reads, rows_pushed * 12);
        fn l_kh() -> usize { 3 }
    }

    #[test]
    fn taps_of_matches_tap_major_mirror() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 29);
        for co in 0..l.co {
            let tm = w.taps_tm(co);
            for ci in 0..l.ci {
                let row = w.taps_of(co, ci);
                assert_eq!(row.len(), l.kh * l.kw);
                for (t, &v) in row.iter().enumerate() {
                    assert_eq!(v, tm[t * l.ci + ci], "co={co} ci={ci} t={t}");
                }
            }
        }
    }
}
