//! Cycle-level OS-dataflow convolution layer engine (paper Fig. 6).
//!
//! Walks receptive fields through the line buffer, drives the PE array
//! per output channel (grouped by the layer's parallel factor), fires
//! neurons, and emits the output spike frame — while counting cycles,
//! memory accesses, and synaptic ops.  The cycle count realises
//! Eq. (12); the integration tests cross-check it against the
//! analytical `dataflow::latency` model, and the functional output is
//! bit-exact against the python L1/L2 semantics.
//!
//! The *functional* psum computation is delegated to a pluggable
//! [`ComputeBackend`](super::backend::ConvCompute): the event-driven
//! `Accurate` walk, the bit-plane `WordParallel` popcount path, or the
//! occupancy-skipping `Sparse` walk (which may also defer a whole
//! row's fields to one weight-stationary batch pass). All three are
//! bit-exact; cycle / op / access reports are identical by
//! construction (they depend only on layer geometry and the spike
//! pattern, never on the host algorithm — see `sim::backend`), pinned
//! by `tests/diff_backends.rs`.
//!
//! ## Zero-allocation frame hot path (§Perf)
//!
//! All per-frame scratch — line buffer, backend window state, psum
//! buffer, band-local output rows — lives in engine-owned per-band
//! workspaces, and the window walk uses the backend's incremental
//! sliding protocol (`begin_row` + `advance`: O(Ci) per output pixel).
//! Steady-state inference through [`ConvEngine::run_frame_into`]
//! performs zero heap allocations (pinned by `tests/alloc_budget.rs`).
//!
//! ## Intra-frame row parallelism
//!
//! [`ConvEngine::with_intra_parallel`] splits the output rows into
//! contiguous bands processed by scoped worker threads. Each band owns
//! its line buffer, backend clone, counter block, and output rows;
//! results merge deterministically in band order, so spikes, cycles,
//! ops, and access counters are bit-identical to the serial run (they
//! are architectural quantities — only host wall-clock changes).

use std::sync::Arc;

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::SpikeFrame;
use crate::dataflow::ConvLatencyParams;
use crate::telemetry::TraceSink;

use super::array::PeArray;
use super::backend::{conv_backend, BackendKind, ConvCompute};
use super::engine::LayerStep;
use super::linebuf::LineBuffer;
use super::memory::{DataKind, MemLevel};
use super::neuron::{NeuronBand, NeuronUnit};
use super::pe::{adder_tree_latency, Acc};

/// int8 weights of one conv layer, laid out `[co][ci][tap]`
/// (depthwise: `[c][0][tap]`; pointwise: `[co][ci][0]`).
#[derive(Debug, Clone)]
pub struct ConvWeights {
    pub scale: f32,
    pub bias: Vec<f32>,
    pub vth: f32,
    taps: Vec<i8>,
    /// Tap-major mirror `[co][tap][ci]` — the hot-path layout
    /// (the backends walk active channels per tap; §Perf).
    taps_tm: Vec<i8>,
    ci: usize,
    ntaps: usize,
}

impl ConvWeights {
    /// Build from a flat `[co][ci][tap]` int8 array.
    pub fn new(layer: &ConvLayer, taps: Vec<i8>, scale: f32, bias: Vec<f32>,
               vth: f32) -> Self {
        let ci_eff = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let ntaps = match layer.mode {
            ConvMode::Pointwise => 1,
            _ => layer.kh * layer.kw,
        };
        assert_eq!(taps.len(), layer.co * ci_eff * ntaps,
                   "weight tap count mismatch");
        assert_eq!(bias.len(), layer.co);
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self { scale, bias, vth, taps, taps_tm, ci: ci_eff, ntaps }
    }

    fn to_tap_major(taps: &[i8], co: usize, ci: usize, ntaps: usize)
                    -> Vec<i8> {
        let mut tm = vec![0i8; taps.len()];
        for o in 0..co {
            for c in 0..ci {
                for t in 0..ntaps {
                    tm[(o * ntaps + t) * ci + c] =
                        taps[(o * ci + c) * ntaps + t];
                }
            }
        }
        tm
    }

    /// Deterministic random weights (benches / hardware-only runs —
    /// cycle counts do not depend on weight values).
    pub fn random(layer: &ConvLayer, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let ci_eff = if layer.mode == ConvMode::Depthwise { 1 } else { layer.ci };
        let ntaps = if layer.mode == ConvMode::Pointwise {
            1
        } else {
            layer.kh * layer.kw
        };
        let n = layer.co * ci_eff * ntaps;
        let taps: Vec<i8> = (0..n).map(|_| rng.int8()).collect();
        // Scale/vth chosen so ~half the psums cross threshold.
        let fanin = (ci_eff * ntaps) as f32;
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self {
            scale: 1.0 / 127.0 / fanin.sqrt(),
            bias: vec![0.0; layer.co],
            vth: 0.05,
            taps,
            taps_tm,
            ci: ci_eff,
            ntaps,
        }
    }

    /// Tap-major taps of output channel `co` (hot-path layout).
    #[inline]
    pub fn taps_tm(&self, co: usize) -> &[i8] {
        let n = self.ci * self.ntaps;
        &self.taps_tm[co * n..(co + 1) * n]
    }

    /// Input channels walked per output channel (1 for depthwise).
    pub fn n_ci(&self) -> usize {
        self.ci
    }

    /// Kernel taps walked per (co, ci) pair (1 for pointwise).
    pub fn n_taps(&self) -> usize {
        self.ntaps
    }

    /// The `[tap]` slice of one (output, input) channel pair — a
    /// borrowed view into the canonical `[co][ci][tap]` layout (no
    /// per-call allocation; §Perf).
    #[inline]
    pub fn taps_of(&self, co: usize, ci: usize) -> &[i8] {
        let s = (co * self.ci + ci) * self.ntaps;
        &self.taps[s..s + self.ntaps]
    }
}

/// Per-run report of the engine — the unified
/// [`LayerStep`](super::engine::LayerStep) every layer engine shares.
pub type ConvRunReport = super::engine::LayerStep;

/// One intra-frame band: reusable per-band workspace covering output
/// rows `[y0, y1)`. Every buffer the frame hot path touches lives
/// here, so steady-state inference allocates nothing.
struct Band {
    y0: usize,
    y1: usize,
    lb: LineBuffer,
    backend: Box<dyn ConvCompute>,
    /// Per-co `(psum, ops)` of the current field (batched Co walk).
    psums: Vec<(Acc, u64)>,
    /// Row-batch psum buffer `[ox][co]` for backends that stash whole
    /// rows of fields and evaluate them weight-stationary
    /// (`ConvCompute::field_psums_batch`); empty for the others.
    batch: Vec<(Acc, u64)>,
    /// Per-lane op / busy-cycle totals, merged into the [`PeArray`]
    /// after the run (bands must not touch the shared array
    /// concurrently).
    lane_ops: Vec<u64>,
    lane_cycles: Vec<u64>,
    /// Band-local output rows (multi-band runs only; the single-band
    /// run writes the caller's frame directly).
    out: SpikeFrame,
    /// Report of this band's last run (filled by worker threads,
    /// merged in band order).
    step: LayerStep,
    /// Telemetry span recorder (None = tracing off, the default;
    /// spans record host wall-clock only — `step` never changes).
    trace: Option<Arc<TraceSink>>,
}

impl Band {
    /// Zero the accumulated run state ([`Band::run`] adds into it, so
    /// a whole frame's timesteps can run inside one thread scope).
    fn clear_run_state(&mut self) {
        self.step = LayerStep::default();
        self.lane_ops.iter_mut().for_each(|v| *v = 0);
        self.lane_cycles.iter_mut().for_each(|v| *v = 0);
    }

    /// Run `timesteps` passes over this band's rows, accumulating into
    /// `self.step` (the band-worker body: one thread spawn covers the
    /// whole frame, not one per timestep).
    fn run_steps(&mut self, layer: &ConvLayer, weights: &ConvWeights,
                 neuron: &mut NeuronBand<'_>, input: &SpikeFrame,
                 off_chip: bool, field_cycles: u64, incremental: bool,
                 timesteps: usize) {
        for _ in 0..timesteps {
            self.run(layer, weights, neuron, input, off_chip,
                     field_cycles, incremental, None);
        }
    }

    /// Run one timestep over output rows `[y0, y1)`: prime the band's
    /// line buffer, slide the backend window along each row, fire
    /// neurons, and **accumulate** every architectural cost into
    /// `self.step` (callers zero it via [`Band::clear_run_state`]).
    /// Writes into the caller's frame when `external_out` is given
    /// (single-band path), otherwise into the band-local rows
    /// (overwritten per timestep — the last timestep's spikes remain).
    #[allow(clippy::too_many_arguments)]
    fn run(&mut self, layer: &ConvLayer, weights: &ConvWeights,
           neuron: &mut NeuronBand<'_>, input: &SpikeFrame,
           off_chip: bool, field_cycles: u64, incremental: bool,
           mut external_out: Option<&mut SpikeFrame>) {
        if external_out.is_none() {
            self.out.reset(self.y1 - self.y0, layer.out_w(), layer.co);
        }
        self.prime(layer, input, off_chip);
        for oy in self.y0..self.y1 {
            self.compute_row(layer, weights, neuron, input, off_chip,
                             field_cycles, incremental, oy,
                             external_out.as_deref_mut());
        }
        let spikes = match &external_out {
            Some(o) => o.count(),
            None => self.out.count(),
        };
        self.step.out_spikes += spikes as u64;
    }

    /// Prime the band's line buffer: reset + the first Kh padded rows.
    /// Charging mirrors the serial row schedule exactly: band 0
    /// charges its whole prime (the serial prime); a later band
    /// charges only its last prime row — serially that is the push
    /// for output row y0 — and refills the Kh-1 overlap rows
    /// uncharged, so each padded row is charged exactly once across
    /// bands.
    fn prime(&mut self, layer: &ConvLayer, input: &SpikeFrame,
             off_chip: bool) {
        let Band { y0, lb, step, trace, .. } = self;
        let t0 = trace.as_ref().map(|t| t.start());
        let y0 = *y0;
        lb.reset();
        for py in y0..y0 + layer.kh {
            let charge = y0 == 0 || py + 1 == y0 + layer.kh;
            lb.ingest_row(input, py as isize, layer.pad,
                          &mut step.counters, off_chip, charge);
        }
        if let (Some(tr), Some(t0)) = (trace.as_ref(), t0) {
            tr.record("conv.prime", "band", t0,
                      [("y0", y0 as u64), ("", 0)]);
        }
    }

    /// Compute one output row `oy` of the band — ingest the row's new
    /// input row (when past the primed window), slide the backend
    /// window along the row, fire neurons, accumulate every
    /// architectural cost into `self.step`. The loop body of
    /// [`Band::run`], also driven row-at-a-time by the inter-layer
    /// streaming executor (identical charge order either way).
    #[allow(clippy::too_many_arguments)]
    fn compute_row(&mut self, layer: &ConvLayer, weights: &ConvWeights,
                   neuron: &mut NeuronBand<'_>, input: &SpikeFrame,
                   off_chip: bool, field_cycles: u64, incremental: bool,
                   oy: usize, external_out: Option<&mut SpikeFrame>) {
        let Band { y0, lb, backend, psums, batch, lane_ops, lane_cycles,
                   out, step, trace, .. } = self;
        let t0 = trace.as_ref().map(|t| t.start());
        let y0 = *y0;
        let wo = layer.out_w();
        let (out, out_y0): (&mut SpikeFrame, usize) = match external_out {
            Some(o) => (o, 0),
            None => (out, y0),
        };

        let n_ci = weights.n_ci();
        // One weight-buffer read per input channel per output channel
        // walked — charged once per field (hoisted out of the Co loop;
        // identical totals, far fewer counter touches. §Perf).
        let weight_reads_per_field = (n_ci * layer.co) as u64;

        if oy > y0 {
            // Shift one new input row in (overlapped with compute —
            // the fill pipeline of Fig. 7a; no cycle charge here).
            lb.ingest_row(input, (oy + layer.kh - 1) as isize,
                          layer.pad, &mut step.counters, off_chip,
                          true);
        }
        backend.begin_row();
        let mut deferred = false;
        for ox in 0..wo {
            lb.count_window_read(layer.kw, &mut step.counters);
            // One incremental slide (or full repack on the
            // fallback path) per receptive field, shared across
            // the whole Co walk (§Perf).
            if incremental {
                backend.advance(lb, ox);
            } else {
                backend.begin_field(lb, ox);
            }
            step.counters.read(MemLevel::Bram, DataKind::Weight,
                               weight_reads_per_field);
            // A batching backend stashes the packed window here and
            // evaluates the whole row weight-stationary below. Every
            // report field is a sum, so deferring the evaluation and
            // firing pass cannot change spikes, cycles, ops, or
            // counters (pinned by tests/prop_backend.rs).
            if backend.stash_field() {
                deferred = true;
                continue;
            }
            backend.field_psums(weights, psums);
            fire_field(layer, neuron, psums, lane_ops, lane_cycles,
                       out, step, field_cycles, oy, out_y0, ox, wo);
        }
        if deferred {
            let n = backend.stashed_fields();
            debug_assert_eq!(n, wo);
            batch.resize(n * layer.co, (0, 0));
            backend.field_psums_batch(weights, layer.co, batch);
            for ox in 0..n {
                let psums = &batch[ox * layer.co..(ox + 1) * layer.co];
                fire_field(layer, neuron, psums, lane_ops, lane_cycles,
                           out, step, field_cycles, oy, out_y0, ox, wo);
            }
        }
        if let (Some(tr), Some(t0)) = (trace.as_ref(), t0) {
            tr.record("conv.row", "band", t0,
                      [("oy", oy as u64), ("", 0)]);
        }
    }
}

/// Fire the Co walk of one field from its `(psum, ops)` slice: charge
/// ops/cycles per lane group, fire neurons, set output spikes, and
/// write the field's output-spike word. Shared by the immediate path
/// and the deferred weight-stationary batch path of
/// [`Band::compute_row`] — all charges are sums, so the two call
/// orders produce bit-identical reports.
#[allow(clippy::too_many_arguments)]
fn fire_field(layer: &ConvLayer, neuron: &mut NeuronBand<'_>,
              psums: &[(Acc, u64)], lane_ops: &mut [u64],
              lane_cycles: &mut [u64], out: &mut SpikeFrame,
              step: &mut LayerStep, field_cycles: u64, oy: usize,
              out_y0: usize, ox: usize, wo: usize) {
    let groups = layer.co.div_ceil(layer.parallel);
    // Output channels in groups of `parallel` lanes; lanes run
    // concurrently so the group costs one lane's time.
    for g in 0..groups {
        for lane in 0..layer.parallel {
            let co = g * layer.parallel + lane;
            if co >= layer.co {
                break;
            }
            let (psum, ops) = psums[co];
            step.ops += ops;
            lane_ops[lane] += ops;
            lane_cycles[lane] += field_cycles;
            let idx = (oy * wo + ox) * layer.co + co;
            if neuron.fire(idx, co, psum, &mut step.counters) {
                out.set(oy - out_y0, ox, co);
            }
        }
        step.cycles += field_cycles;
    }
    step.counters.write(MemLevel::Bram, DataKind::OutputSpike, 1);
}

/// Split `ho` output rows into `n` contiguous bands (clamped to
/// `[1, ho]`; earlier bands take the remainder rows).
fn band_ranges(ho: usize, n: usize) -> Vec<(usize, usize)> {
    let n = n.clamp(1, ho.max(1));
    let base = ho / n;
    let rem = ho % n;
    let mut out = Vec::with_capacity(n);
    let mut y = 0;
    for b in 0..n {
        let h = base + usize::from(b < rem);
        out.push((y, y + h));
        y += h;
    }
    out
}

/// Row-granular streaming progress — the inter-layer pipeline
/// executor drives [`ConvEngine::stream_begin`] /
/// [`ConvEngine::stream_row`] / [`ConvEngine::stream_finish`].
#[derive(Default)]
struct StreamState {
    /// Whether this streamed frame's input arrives from DRAM.
    off_chip: bool,
    /// Line buffer primed (single-band row mode).
    primed: bool,
    /// Completed output-row prefix (single-band row mode).
    next_oy: usize,
    /// Next band to run (multi-band mode).
    next_band: usize,
}

/// The engine itself. One instance per conv layer of the pipeline.
pub struct ConvEngine {
    pub layer: ConvLayer,
    pub weights: ConvWeights,
    pub timing: ConvLatencyParams,
    pub array: PeArray,
    pub neuron: NeuronUnit,
    timesteps: usize,
    backend_kind: BackendKind,
    /// Incremental sliding-window protocol on (default); off falls
    /// back to full per-field repacking (the equivalence oracle for
    /// `tests/prop_backend.rs`).
    incremental: bool,
    bands: Vec<Band>,
    stream: StreamState,
    /// Telemetry span recorder, mirrored into every band (None = off).
    trace: Option<Arc<TraceSink>>,
}

impl ConvEngine {
    /// Engine with the default (event-driven `Accurate`) backend.
    pub fn new(layer: ConvLayer, weights: ConvWeights,
               timing: ConvLatencyParams, timesteps: usize) -> Self {
        Self::with_backend(layer, weights, timing, timesteps,
                           BackendKind::Accurate)
    }

    /// Engine with an explicit compute backend.
    pub fn with_backend(layer: ConvLayer, weights: ConvWeights,
                        timing: ConvLatencyParams, timesteps: usize,
                        kind: BackendKind) -> Self {
        let n_neurons = layer.out_h() * layer.out_w() * layer.co;
        let neuron = NeuronUnit::new(
            weights.vth,
            weights.scale,
            weights.bias.clone(),
            n_neurons,
            timesteps,
        );
        let array = PeArray::for_layer(&layer);
        let proto = conv_backend(kind, &layer, &weights);
        let bands = Self::build_bands(&layer, proto,
                                      band_ranges(layer.out_h(), 1));
        Self {
            layer,
            weights,
            timing,
            array,
            neuron,
            timesteps,
            backend_kind: kind,
            incremental: true,
            bands,
            stream: StreamState::default(),
            trace: None,
        }
    }

    fn build_bands(layer: &ConvLayer, proto: Box<dyn ConvCompute>,
                   ranges: Vec<(usize, usize)>) -> Vec<Band> {
        let wo = layer.out_w();
        let wi_pad = layer.in_w + 2 * layer.pad;
        let n = ranges.len();
        let multi = n > 1;
        // The last band consumes the prototype; earlier bands clone it
        // (word-parallel clones share the weight planes read-only).
        let mut proto = Some(proto);
        let mut bands = Vec::with_capacity(n);
        for (i, (y0, y1)) in ranges.into_iter().enumerate() {
            let backend = if i + 1 == n {
                proto.take().expect("prototype consumed once")
            } else {
                proto.as_ref().expect("prototype present").clone_box()
            };
            bands.push(Band {
                y0,
                y1,
                lb: LineBuffer::new(layer.kh, wi_pad, layer.ci),
                backend,
                psums: vec![(0, 0); layer.co],
                batch: Vec::new(),
                lane_ops: vec![0; layer.parallel],
                lane_cycles: vec![0; layer.parallel],
                out: if multi {
                    SpikeFrame::zeros(y1 - y0, wo, layer.co)
                } else {
                    SpikeFrame::zeros(0, 0, 0)
                },
                step: LayerStep::default(),
                trace: None,
            });
        }
        bands
    }

    /// Split the frame into `n` row bands processed by scoped worker
    /// threads (clamped to the output height; 1 = serial). Reports
    /// stay bit-identical — only host wall-clock changes.
    pub fn with_intra_parallel(mut self, n: usize) -> Self {
        let ranges = band_ranges(self.layer.out_h(), n);
        if ranges.len() != self.bands.len() {
            let proto = self.bands[0].backend.clone_box();
            self.bands = Self::build_bands(&self.layer, proto, ranges);
            let trace = self.trace.clone();
            self.set_trace_sink(trace);
        }
        self
    }

    /// Install (or clear) the telemetry span recorder on the engine
    /// and every band worker — band `prime` / row computations record
    /// `conv.prime` / `conv.row` spans while it is set. Purely
    /// observational: reports and spikes are unchanged.
    pub(crate) fn set_trace_sink(&mut self,
                                 trace: Option<Arc<TraceSink>>) {
        for band in self.bands.iter_mut() {
            band.trace = trace.clone();
        }
        self.trace = trace;
    }

    /// Toggle the incremental sliding-window protocol (tests pin the
    /// incremental path bit-exact against this fallback).
    pub fn with_incremental(mut self, on: bool) -> Self {
        self.incremental = on;
        self
    }

    /// Which functional backend this engine computes with.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend_kind
    }

    /// Configured intra-frame band count.
    pub fn intra_parallel(&self) -> usize {
        self.bands.len()
    }

    /// Architectural Vmem buffer size (18-bit potentials — the BRAM18
    /// word width; see `arch::ConvLayer::vmem_bytes`). The simulator
    /// stores f32 internally for convenience; what the FPGA provisions
    /// is the 18-bit figure, so that is what we report.
    pub fn vmem_bytes(&self) -> usize {
        if self.neuron.vmem_bytes() == 0 {
            0
        } else {
            self.layer.vmem_bytes()
        }
    }

    /// Architectural cycles of one (receptive field, output channel)
    /// evaluation — Eq. (12)'s inner bracket. The FPGA spends the full
    /// `Ci` walk regardless of sparsity or weights, so this is constant
    /// per layer and identical across functional backends.
    fn field_cycles(&self) -> u64 {
        let l = &self.layer;
        let (t_rw, t_pe) = (self.timing.t_rw, self.timing.t_pe);
        let ntaps = l.kh * l.kw;
        match l.mode {
            ConvMode::Standard => {
                self.weights.n_ci() as u64 * (t_rw + t_pe)
                    + adder_tree_latency(ntaps)
            }
            ConvMode::Depthwise => {
                ntaps as u64 * (t_rw + t_pe) + adder_tree_latency(ntaps)
            }
            ConvMode::Pointwise => {
                self.weights.n_ci() as u64 * (t_rw + t_pe)
            }
        }
    }

    /// Run one timestep of one frame into the caller-owned `out`
    /// frame (reshaped as needed — the zero-allocation hot path).
    /// `off_chip_input` marks whether the input arrives from DRAM
    /// (first layer) or an on-chip FIFO.
    pub fn run_timestep_into(&mut self, input: &SpikeFrame,
                             off_chip_input: bool, out: &mut SpikeFrame)
                             -> ConvRunReport {
        let l = &self.layer;
        assert_eq!((input.h, input.w, input.c), (l.in_h, l.in_w, l.ci),
                   "input shape mismatch for {:?}", l.mode);
        let (ho, wo) = (l.out_h(), l.out_w());
        out.reset(ho, wo, l.co);
        let field_cycles = self.field_cycles();
        let incremental = self.incremental;

        let mut rep;
        if self.bands.len() == 1 {
            let mut nb = self.neuron.band_all();
            let band = &mut self.bands[0];
            band.clear_run_state();
            band.run(&self.layer, &self.weights, &mut nb, input,
                     off_chip_input, field_cycles, incremental,
                     Some(out));
            rep = std::mem::take(&mut band.step);
        } else {
            self.run_bands(input, off_chip_input, field_cycles,
                           incremental, 1);
            rep = ConvRunReport::default();
            for band in &mut self.bands {
                let step = std::mem::take(&mut band.step);
                rep.merge(&step);
                out.or_rows_from(&band.out, band.y0);
            }
        }
        self.record_lanes();
        rep
    }

    /// Run `timesteps` band passes inside ONE thread scope (a spawn
    /// per band per frame, not per timestep). Bands accumulate into
    /// their `step`s; the caller merges and collects outputs.
    fn run_bands(&mut self, input: &SpikeFrame, off_chip_input: bool,
                 field_cycles: u64, incremental: bool, timesteps: usize) {
        let l = &self.layer;
        let wo_co = l.out_w() * l.co;
        let ranges: Vec<(usize, usize)> = self
            .bands
            .iter()
            .map(|b| (b.y0 * wo_co, b.y1 * wo_co))
            .collect();
        let mut views = self.neuron.bands(&ranges);
        let layer = &self.layer;
        let weights = &self.weights;
        for band in self.bands.iter_mut() {
            band.clear_run_state();
        }
        std::thread::scope(|s| {
            for (band, nb) in
                self.bands.iter_mut().zip(views.iter_mut())
            {
                s.spawn(move || {
                    band.run_steps(layer, weights, nb, input,
                                   off_chip_input, field_cycles,
                                   incremental, timesteps);
                });
            }
        });
    }

    /// Merge the bands' lane bookkeeping into the shared array —
    /// deterministic band order, identical totals to the serial
    /// per-co recording.
    fn record_lanes(&mut self) {
        for b in 0..self.bands.len() {
            for lane in 0..self.layer.parallel {
                let (ops, cyc) = (self.bands[b].lane_ops[lane],
                                  self.bands[b].lane_cycles[lane]);
                self.array.record(lane, ops, cyc);
            }
        }
    }

    /// Run one timestep of one frame (allocating wrapper around
    /// [`ConvEngine::run_timestep_into`]).
    pub fn run_timestep(&mut self, input: &SpikeFrame,
                        off_chip_input: bool) -> (SpikeFrame, ConvRunReport) {
        let mut out = SpikeFrame::zeros(self.layer.out_h(),
                                        self.layer.out_w(), self.layer.co);
        let rep = self.run_timestep_into(input, off_chip_input, &mut out);
        (out, rep)
    }

    /// Run all `timesteps` of one frame (same input each step — direct
    /// encoding upstream) into the caller-owned `out` frame, merging
    /// reports. Zero heap allocations in steady state on the serial
    /// path; multi-band engines spawn one scoped worker per band per
    /// frame (the whole timestep loop runs inside the worker).
    pub fn run_frame_into(&mut self, input: &SpikeFrame,
                          off_chip_input: bool, out: &mut SpikeFrame)
                          -> ConvRunReport {
        self.neuron.reset();
        if self.bands.len() > 1 {
            let l = &self.layer;
            assert_eq!((input.h, input.w, input.c),
                       (l.in_h, l.in_w, l.ci),
                       "input shape mismatch for {:?}", l.mode);
            out.reset(l.out_h(), l.out_w(), l.co);
            let field_cycles = self.field_cycles();
            let incremental = self.incremental;
            let timesteps = self.timesteps;
            self.run_bands(input, off_chip_input, field_cycles,
                           incremental, timesteps);
            let mut rep = ConvRunReport::default();
            for band in &mut self.bands {
                let step = std::mem::take(&mut band.step);
                rep.merge(&step);
                out.or_rows_from(&band.out, band.y0);
            }
            self.record_lanes();
            return rep;
        }
        let mut merged = ConvRunReport::default();
        for _ in 0..self.timesteps {
            let rep = self.run_timestep_into(input, off_chip_input, out);
            merged.merge(&rep);
        }
        merged
    }

    /// Run all `timesteps` of one frame (allocating wrapper around
    /// [`ConvEngine::run_frame_into`]).
    pub fn run_frame(&mut self, input: &SpikeFrame, off_chip_input: bool)
                     -> (SpikeFrame, ConvRunReport) {
        let mut out = SpikeFrame::zeros(self.layer.out_h(),
                                        self.layer.out_w(), self.layer.co);
        let rep = self.run_frame_into(input, off_chip_input, &mut out);
        (out, rep)
    }

    // ---- row-granular streaming (inter-layer pipeline executor) ----
    //
    // Three modes, picked by configuration:
    // * T = 1, one band — true row streaming: output row `oy` is
    //   computed the moment input row `oy + Kh - 1 - pad` lands
    //   (paper SectionIV-E: the next layer starts once Kh rows are
    //   buffered). Writes the executor's `out` frame directly.
    // * T = 1, multi band — band streaming: each intra-frame band runs
    //   as soon as its input rows are all in, emitting `[y0, y1)` at
    //   once (the PR-4 band charge rule keeps reports bit-identical).
    // * T > 1 — whole-frame fallback in `stream_finish`: every
    //   timestep re-reads the full input, so there is nothing to
    //   overlap at row granularity.
    //
    // Every charge (line-buffer ingest, window reads, weight reads,
    // fires, cycle adds) happens through the same `Band::prime` /
    // `Band::compute_row` bodies the serial schedule runs, only
    // interleaved differently in time — counters and cycles are
    // order-independent sums, so streamed reports are bit-identical.

    /// Arm a new streamed frame.
    pub(crate) fn stream_begin(&mut self, off_chip: bool) {
        self.neuron.reset();
        for band in self.bands.iter_mut() {
            band.clear_run_state();
        }
        self.stream = StreamState { off_chip, ..StreamState::default() };
    }

    /// Input rows `0..=y` are valid; compute whatever became ready
    /// into `out` (already reset to the output shape by the caller).
    /// Returns the completed output-row prefix.
    pub(crate) fn stream_row(&mut self, input: &SpikeFrame, y: usize,
                             out: &mut SpikeFrame) -> usize {
        let l = &self.layer;
        assert_eq!((input.h, input.w, input.c), (l.in_h, l.in_w, l.ci),
                   "input shape mismatch for {:?}", l.mode);
        if self.timesteps > 1 {
            return 0; // frame mode: all work happens in stream_finish
        }
        let last = y + 1 == l.in_h;
        let field_cycles = self.field_cycles();
        let incremental = self.incremental;
        let ho = l.out_h();
        let Self { layer, weights, neuron, bands, stream, .. } = self;

        if bands.len() > 1 {
            // Band mode: run each band once its input rows are all in.
            let wo_co = layer.out_w() * layer.co;
            while stream.next_band < bands.len() {
                let band = &mut bands[stream.next_band];
                // Highest padded row the band ingests; its input row is
                // `need - pad` (past-the-frame rows are zero padding,
                // complete only once the whole frame is in).
                let need = band.y1 - 1 + layer.kh - 1;
                let ready = last
                    || (need >= layer.pad
                        && need - layer.pad <= y
                        && need - layer.pad < layer.in_h)
                    || need < layer.pad;
                if !ready {
                    break;
                }
                let mut nb =
                    neuron.band(band.y0 * wo_co, band.y1 * wo_co);
                band.run(layer, weights, &mut nb, input,
                         stream.off_chip, field_cycles, incremental,
                         None);
                out.or_rows_from(&band.out, band.y0);
                stream.next_band += 1;
            }
            return match stream.next_band {
                0 => 0,
                n => bands[n - 1].y1,
            };
        }

        // Row mode: the single band writes the executor's frame
        // directly. Output row `oy` needs input rows up to
        // `oy + kh - 1 - pad`; the last input row releases the
        // remaining (bottom-padding) rows.
        let ready = if last {
            ho
        } else {
            (y + layer.pad + 2).saturating_sub(layer.kh).min(ho)
        };
        if stream.next_oy >= ready {
            return stream.next_oy;
        }
        let band = &mut bands[0];
        if !stream.primed {
            band.prime(layer, input, stream.off_chip);
            stream.primed = true;
        }
        let mut nb = neuron.band_all();
        for oy in stream.next_oy..ready {
            band.compute_row(layer, weights, &mut nb, input,
                             stream.off_chip, field_cycles, incremental,
                             oy, Some(&mut *out));
        }
        stream.next_oy = ready;
        ready
    }

    /// Every input row has been presented; complete the frame and
    /// return the merged report — bit-identical to
    /// [`ConvEngine::run_frame_into`] on the same input.
    pub(crate) fn stream_finish(&mut self, input: &SpikeFrame,
                                out: &mut SpikeFrame) -> ConvRunReport {
        if self.timesteps > 1 {
            // Frame fallback: the timestep replay loop re-reads the
            // fully staged input (resets `out` itself).
            return self.run_frame_into(input, self.stream.off_chip, out);
        }
        // Defensive tail: complete any remainder as if the last input
        // row just landed (no-op when the executor presented them all).
        self.stream_row(input, self.layer.in_h - 1, out);
        let mut rep = ConvRunReport::default();
        if self.bands.len() > 1 {
            for band in &mut self.bands {
                rep.merge(&std::mem::take(&mut band.step));
            }
        } else {
            let band = &mut self.bands[0];
            // Spike count once per frame, after the last row — the
            // same point the serial schedule charges it.
            band.step.out_spikes += out.count() as u64;
            rep = std::mem::take(&mut band.step);
        }
        self.record_lanes();
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ConvLayer, ConvMode};
    use crate::dataflow::{conv_latency, ConvLatencyParams};
    use crate::util::rng::Rng;

    fn layer(mode: ConvMode, parallel: usize) -> ConvLayer {
        let (ci, co) = match mode {
            ConvMode::Depthwise => (6, 6),
            _ => (6, 8),
        };
        let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
        ConvLayer {
            mode,
            in_h: 10,
            in_w: 10,
            ci,
            co,
            kh: k,
            kw: k,
            pad: k / 2,
            encoder: false,
            parallel,
        }
    }

    /// Reference conv + IF in plain rust (mirrors kernels/ref.py).
    fn ref_conv_if(input: &SpikeFrame, l: &ConvLayer, w: &ConvWeights)
                   -> SpikeFrame {
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut out = SpikeFrame::zeros(ho, wo, l.co);
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..l.co {
                    let mut acc: i64 = 0;
                    match l.mode {
                        ConvMode::Standard | ConvMode::Depthwise => {
                            for r in 0..l.kh {
                                for c in 0..l.kw {
                                    let iy = oy as isize + r as isize
                                        - l.pad as isize;
                                    let ix = ox as isize + c as isize
                                        - l.pad as isize;
                                    if iy < 0 || ix < 0
                                        || iy >= l.in_h as isize
                                        || ix >= l.in_w as isize {
                                        continue;
                                    }
                                    let (iy, ix) = (iy as usize, ix as usize);
                                    match l.mode {
                                        ConvMode::Standard => {
                                            for ci in 0..l.ci {
                                                if input.get(iy, ix, ci) {
                                                    acc += w.taps_of(co, ci)
                                                        [r * l.kw + c]
                                                        as i64;
                                                }
                                            }
                                        }
                                        _ => {
                                            if input.get(iy, ix, co) {
                                                acc += w.taps_of(co, 0)
                                                    [r * l.kw + c]
                                                    as i64;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        ConvMode::Pointwise => {
                            for ci in 0..l.ci {
                                if input.get(oy, ox, ci) {
                                    acc += w.taps_of(co, ci)[0] as i64;
                                }
                            }
                        }
                    }
                    let v = acc as f32 * w.scale + w.bias[co];
                    if v >= w.vth {
                        out.set(oy, ox, co);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn standard_engine_matches_reference() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 3);
        let mut rng = Rng::new(1);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, rep) = eng.run_frame(&input, true);
        assert_eq!(got, want);
        assert!(rep.cycles > 0 && rep.ops > 0);
    }

    #[test]
    fn depthwise_engine_matches_reference() {
        let l = layer(ConvMode::Depthwise, 1);
        let w = ConvWeights::random(&l, 5);
        let mut rng = Rng::new(2);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    #[test]
    fn pointwise_engine_matches_reference() {
        let l = layer(ConvMode::Pointwise, 2);
        let w = ConvWeights::random(&l, 7);
        let mut rng = Rng::new(3);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    /// The word-parallel backend matches the reference semantics and
    /// the accurate backend's full report on every conv mode.
    #[test]
    fn word_parallel_backend_is_bit_exact() {
        for mode in [ConvMode::Standard, ConvMode::Depthwise,
                     ConvMode::Pointwise] {
            let l = layer(mode, 2);
            let w = ConvWeights::random(&l, 31);
            let mut rng = Rng::new(9);
            let input = SpikeFrame::random(10, 10, 6, 0.35, &mut rng);
            let want = ref_conv_if(&input, &l, &w);
            let mut acc = ConvEngine::new(
                l.clone(), w.clone(), ConvLatencyParams::optimized(), 1);
            let mut wp = ConvEngine::with_backend(
                l, w, ConvLatencyParams::optimized(), 1,
                BackendKind::WordParallel);
            let (got_a, rep_a) = acc.run_frame(&input, true);
            let (got_w, rep_w) = wp.run_frame(&input, true);
            assert_eq!(got_w, want, "{mode:?}");
            assert_eq!(got_a, got_w, "{mode:?}");
            assert_eq!(rep_a, rep_w, "{mode:?} reports diverge");
        }
    }

    /// The incremental sliding-window protocol equals the full-repack
    /// fallback bit-for-bit: spikes AND reports, every mode x backend.
    #[test]
    fn incremental_window_matches_begin_field_fallback() {
        for mode in [ConvMode::Standard, ConvMode::Depthwise,
                     ConvMode::Pointwise] {
            for kind in [BackendKind::Accurate, BackendKind::WordParallel,
                         BackendKind::Sparse] {
                let l = layer(mode, 2);
                let w = ConvWeights::random(&l, 41);
                let mut rng = Rng::new(13);
                let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
                let mut inc = ConvEngine::with_backend(
                    l.clone(), w.clone(), ConvLatencyParams::optimized(),
                    1, kind);
                let mut fb = ConvEngine::with_backend(
                    l, w, ConvLatencyParams::optimized(), 1, kind)
                    .with_incremental(false);
                let (out_i, rep_i) = inc.run_frame(&input, true);
                let (out_f, rep_f) = fb.run_frame(&input, true);
                assert_eq!(out_i, out_f, "{mode:?} {kind}");
                assert_eq!(rep_i, rep_f, "{mode:?} {kind}");
            }
        }
    }

    /// Intra-frame row bands are bit-exact against the serial run:
    /// same spikes, same cycles/ops/traffic (merged deterministically),
    /// every mode x backend x band count.
    #[test]
    fn intra_parallel_bands_are_bit_exact() {
        for mode in [ConvMode::Standard, ConvMode::Depthwise,
                     ConvMode::Pointwise] {
            for kind in [BackendKind::Accurate, BackendKind::WordParallel,
                         BackendKind::Sparse] {
                for (bands, timesteps) in [(2, 1), (4, 1), (3, 2), (16, 1)]
                {
                    let l = layer(mode, 2);
                    let w = ConvWeights::random(&l, 47);
                    let mut rng = Rng::new(15);
                    let input =
                        SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
                    let mut serial = ConvEngine::with_backend(
                        l.clone(), w.clone(),
                        ConvLatencyParams::optimized(), timesteps, kind);
                    let mut banded = ConvEngine::with_backend(
                        l, w, ConvLatencyParams::optimized(), timesteps,
                        kind)
                        .with_intra_parallel(bands);
                    let (out_s, rep_s) = serial.run_frame(&input, true);
                    let (out_b, rep_b) = banded.run_frame(&input, true);
                    assert_eq!(out_s, out_b,
                               "{mode:?} {kind} bands={bands}");
                    assert_eq!(rep_s, rep_b,
                               "{mode:?} {kind} bands={bands}");
                    assert_eq!(serial.array.total_ops(),
                               banded.array.total_ops());
                }
            }
        }
    }

    #[test]
    fn cycles_match_analytical_model() {
        for parallel in [1, 2, 4] {
            let l = layer(ConvMode::Standard, parallel);
            let w = ConvWeights::random(&l, 11);
            let timing = ConvLatencyParams::optimized();
            let analytical = conv_latency(&l, &timing);
            let mut eng = ConvEngine::new(l, w, timing, 1);
            let mut rng = Rng::new(4);
            let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
            let (_, rep) = eng.run_frame(&input, true);
            let err = (rep.cycles as f64 - analytical as f64).abs()
                / analytical as f64;
            assert!(err < 0.05,
                    "p={parallel}: engine {} vs model {analytical}",
                    rep.cycles);
        }
    }

    #[test]
    fn parallelism_reduces_cycles() {
        let mut rng = Rng::new(5);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut cycles = Vec::new();
        for p in [1, 2, 4] {
            let l = layer(ConvMode::Standard, p);
            let w = ConvWeights::random(&l, 13);
            let mut eng =
                ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
            let (_, rep) = eng.run_frame(&input, true);
            cycles.push(rep.cycles);
        }
        assert!(cycles[0] > cycles[1] && cycles[1] > cycles[2],
                "{cycles:?}");
        let ratio = cycles[0] as f64 / cycles[2] as f64;
        assert!(ratio > 3.0, "4x lanes gave only {ratio}x");
    }

    #[test]
    fn parallelism_preserves_function() {
        let mut rng = Rng::new(6);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l1 = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l1, 17);
        let mut e1 =
            ConvEngine::new(l1, w.clone(), ConvLatencyParams::optimized(), 1);
        let (out1, _) = e1.run_frame(&input, true);
        let l4 = layer(ConvMode::Standard, 4);
        let mut e4 =
            ConvEngine::new(l4, w, ConvLatencyParams::optimized(), 1);
        let (out4, _) = e4.run_frame(&input, true);
        assert_eq!(out1, out4);
    }

    #[test]
    fn t1_has_zero_vmem_traffic_t2_does_not() {
        let mut rng = Rng::new(7);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 19);
        let mut e1 = ConvEngine::new(l.clone(), w.clone(),
                                     ConvLatencyParams::optimized(), 1);
        let (_, r1) = e1.run_frame(&input, true);
        assert_eq!(r1.counters.total_of_kind(DataKind::Vmem), 0);
        assert_eq!(e1.vmem_bytes(), 0);

        let mut e2 = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 2);
        let (_, r2) = e2.run_frame(&input, true);
        assert!(r2.counters.total_of_kind(DataKind::Vmem) > 0);
        assert!(e2.vmem_bytes() > 0);
        // Two timesteps => ~2x cycles and ~2x ops.
        assert!((r2.cycles as f64 / r1.cycles as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn input_vector_fetched_once_per_pixel() {
        // Table III: off-chip input reads = Hi*Wi (padded rows included
        // as zero vectors are on-chip constants; we count ingested rows).
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 23);
        let mut rng = Rng::new(8);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (_, rep) = eng.run_frame(&input, true);
        let dram_reads =
            rep.counters.reads_of(MemLevel::Dram, DataKind::InputSpike);
        // Padded geometry: (Hi+2p) rows of (Wi+2p) vectors exist, but
        // only Kh + (Ho-1) rows enter the buffer.
        let rows_ingested = (l_kh() + (10 - 1)) as u64;
        assert_eq!(dram_reads, rows_ingested * 12);
        fn l_kh() -> usize { 3 }
    }

    /// Band charging: the banded run's ingest traffic equals the
    /// serial run's exactly (each padded row charged once globally).
    #[test]
    fn band_ingest_traffic_matches_serial() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 27);
        let mut rng = Rng::new(10);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut serial = ConvEngine::new(
            l.clone(), w.clone(), ConvLatencyParams::optimized(), 1);
        let mut banded = ConvEngine::new(
            l, w, ConvLatencyParams::optimized(), 1)
            .with_intra_parallel(4);
        let (_, rs) = serial.run_frame(&input, true);
        let (_, rb) = banded.run_frame(&input, true);
        assert_eq!(
            rs.counters.reads_of(MemLevel::Dram, DataKind::InputSpike),
            rb.counters.reads_of(MemLevel::Dram, DataKind::InputSpike));
        assert_eq!(
            rs.counters.writes_of(MemLevel::Bram, DataKind::InputSpike),
            rb.counters.writes_of(MemLevel::Bram, DataKind::InputSpike));
    }

    #[test]
    fn band_ranges_cover_and_clamp() {
        assert_eq!(band_ranges(10, 1), vec![(0, 10)]);
        assert_eq!(band_ranges(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(band_ranges(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(band_ranges(1, 0), vec![(0, 1)]);
    }

    #[test]
    fn taps_of_matches_tap_major_mirror() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 29);
        for co in 0..l.co {
            let tm = w.taps_tm(co);
            for ci in 0..l.ci {
                let row = w.taps_of(co, ci);
                assert_eq!(row.len(), l.kh * l.kw);
                for (t, &v) in row.iter().enumerate() {
                    assert_eq!(v, tm[t * l.ci + ci], "co={co} ci={ci} t={t}");
                }
            }
        }
    }
}
