//! Cycle-level OS-dataflow convolution layer engine (paper Fig. 6).
//!
//! Walks receptive fields through the line buffer, drives the PE array
//! per output channel (grouped by the layer's parallel factor), fires
//! neurons, and emits the output spike frame — while counting cycles,
//! memory accesses, and synaptic ops.  The cycle count realises
//! Eq. (12); the integration tests cross-check it against the
//! analytical `dataflow::latency` model, and the functional output is
//! bit-exact against the python L1/L2 semantics.

use crate::arch::{ConvLayer, ConvMode};
use crate::codec::{SpikeFrame, SpikeVector};
use crate::dataflow::ConvLatencyParams;

use super::array::PeArray;
use super::linebuf::{padded_rows, LineBuffer};
use super::memory::{AccessCounter, DataKind, MemLevel};
use super::neuron::NeuronUnit;

/// int8 weights of one conv layer, laid out `[co][ci][tap]`
/// (depthwise: `[c][0][tap]`; pointwise: `[co][ci][0]`).
#[derive(Debug, Clone)]
pub struct ConvWeights {
    pub scale: f32,
    pub bias: Vec<f32>,
    pub vth: f32,
    taps: Vec<i8>,
    /// Tap-major mirror `[co][tap][ci]` — the hot-path layout
    /// (`PeArray::process_field` walks active channels per tap; §Perf).
    taps_tm: Vec<i8>,
    ci: usize,
    ntaps: usize,
}

impl ConvWeights {
    /// Build from a flat `[co][ci][tap]` int8 array.
    pub fn new(layer: &ConvLayer, taps: Vec<i8>, scale: f32, bias: Vec<f32>,
               vth: f32) -> Self {
        let ci_eff = match layer.mode {
            ConvMode::Depthwise => 1,
            _ => layer.ci,
        };
        let ntaps = match layer.mode {
            ConvMode::Pointwise => 1,
            _ => layer.kh * layer.kw,
        };
        assert_eq!(taps.len(), layer.co * ci_eff * ntaps,
                   "weight tap count mismatch");
        assert_eq!(bias.len(), layer.co);
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self { scale, bias, vth, taps, taps_tm, ci: ci_eff, ntaps }
    }

    fn to_tap_major(taps: &[i8], co: usize, ci: usize, ntaps: usize)
                    -> Vec<i8> {
        let mut tm = vec![0i8; taps.len()];
        for o in 0..co {
            for c in 0..ci {
                for t in 0..ntaps {
                    tm[(o * ntaps + t) * ci + c] =
                        taps[(o * ci + c) * ntaps + t];
                }
            }
        }
        tm
    }

    /// Deterministic random weights (benches / hardware-only runs —
    /// cycle counts do not depend on weight values).
    pub fn random(layer: &ConvLayer, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let ci_eff = if layer.mode == ConvMode::Depthwise { 1 } else { layer.ci };
        let ntaps = if layer.mode == ConvMode::Pointwise {
            1
        } else {
            layer.kh * layer.kw
        };
        let n = layer.co * ci_eff * ntaps;
        let taps: Vec<i8> = (0..n).map(|_| rng.int8()).collect();
        // Scale/vth chosen so ~half the psums cross threshold.
        let fanin = (ci_eff * ntaps) as f32;
        let taps_tm = Self::to_tap_major(&taps, layer.co, ci_eff, ntaps);
        Self {
            scale: 1.0 / 127.0 / fanin.sqrt(),
            bias: vec![0.0; layer.co],
            vth: 0.05,
            taps,
            taps_tm,
            ci: ci_eff,
            ntaps,
        }
    }

    /// Tap-major taps of output channel `co` (hot-path layout).
    #[inline]
    pub fn taps_tm(&self, co: usize) -> &[i8] {
        let n = self.ci * self.ntaps;
        &self.taps_tm[co * n..(co + 1) * n]
    }

    /// Input channels walked per output channel (1 for depthwise).
    pub fn n_ci(&self) -> usize {
        self.ci
    }

    /// Taps of output channel `co`, as `[ci][tap]` slices.
    pub fn of_channel(&self, co: usize) -> Vec<Vec<i8>> {
        let base = co * self.ci * self.ntaps;
        (0..self.ci)
            .map(|ci| {
                let s = base + ci * self.ntaps;
                self.taps[s..s + self.ntaps].to_vec()
            })
            .collect()
    }
}

/// Per-run report of the engine.
#[derive(Debug, Clone, Default)]
pub struct ConvRunReport {
    pub cycles: u64,
    pub ops: u64,
    pub out_spikes: u64,
    pub counters: AccessCounter,
}

/// The engine itself. One instance per conv layer of the pipeline.
pub struct ConvEngine {
    pub layer: ConvLayer,
    pub weights: ConvWeights,
    pub timing: ConvLatencyParams,
    pub array: PeArray,
    pub neuron: NeuronUnit,
    timesteps: usize,
}

impl ConvEngine {
    pub fn new(layer: ConvLayer, weights: ConvWeights,
               timing: ConvLatencyParams, timesteps: usize) -> Self {
        let n_neurons = layer.out_h() * layer.out_w() * layer.co;
        let neuron = NeuronUnit::new(
            weights.vth,
            weights.scale,
            weights.bias.clone(),
            n_neurons,
            timesteps,
        );
        let array = PeArray::for_layer(&layer);
        Self { layer, weights, timing, array, neuron, timesteps }
    }

    /// Architectural Vmem buffer size (18-bit potentials — the BRAM18
    /// word width; see `arch::ConvLayer::vmem_bytes`). The simulator
    /// stores f32 internally for convenience; what the FPGA provisions
    /// is the 18-bit figure, so that is what we report.
    pub fn vmem_bytes(&self) -> usize {
        if self.neuron.vmem_bytes() == 0 {
            0
        } else {
            self.layer.vmem_bytes()
        }
    }

    /// Run one timestep of one frame. `off_chip_input` marks whether
    /// the input arrives from DRAM (first layer) or an on-chip FIFO.
    pub fn run_timestep(&mut self, input: &SpikeFrame,
                        off_chip_input: bool) -> (SpikeFrame, ConvRunReport) {
        let l = &self.layer;
        assert_eq!((input.h, input.w, input.c), (l.in_h, l.in_w, l.ci),
                   "input shape mismatch for {:?}", l.mode);
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut out = SpikeFrame::zeros(ho, wo, l.co);
        let mut rep = ConvRunReport::default();
        let ops_before = self.array.total_ops();

        let rows = padded_rows(input, l.pad);
        let wi_pad = l.in_w + 2 * l.pad;
        let mut lb = LineBuffer::new(l.kh, wi_pad, l.ci);
        let mut row_iter = rows.into_iter();
        // Prime the line buffer with the first Kh rows.
        for _ in 0..l.kh {
            lb.push_row(row_iter.next().expect("input taller than kernel"),
                        &mut rep.counters, off_chip_input);
        }

        let t_rw = self.timing.t_rw;
        let t_pe = self.timing.t_pe;
        let groups = l.co.div_ceil(l.parallel);

        let n_ci = self.weights.n_ci();
        // Reused active-spike list: one decode per receptive field,
        // shared across the whole Co walk (§Perf iteration 2).
        let mut active: Vec<(u16, u16)> = Vec::with_capacity(
            l.kh * l.kw * l.ci.min(u16::MAX as usize));
        let standard = l.mode == ConvMode::Standard;
        for oy in 0..ho {
            if oy > 0 {
                // Shift one new input row in (overlapped with compute —
                // the fill pipeline of Fig. 7a; no cycle charge here).
                lb.push_row(row_iter.next().expect("row count"),
                            &mut rep.counters, off_chip_input);
            }
            let full_rows = lb.resident_rows();
            let mut wrows: Vec<&[SpikeVector]> =
                Vec::with_capacity(l.kh);
            for ox in 0..wo {
                lb.count_window_read(l.kw, &mut rep.counters);
                // Zero-copy window: Kh sub-slices at this x offset.
                wrows.clear();
                for fr in &full_rows {
                    wrows.push(&fr[ox..ox + l.kw]);
                }
                if standard {
                    active.clear();
                    for (r, row) in wrows.iter().enumerate() {
                        for c in 0..l.kw {
                            let tap = (r * l.kw + c) as u16;
                            for ci in row[c].iter_active() {
                                active.push((tap, ci as u16));
                            }
                        }
                    }
                }
                // Output channels in groups of `parallel` lanes; lanes
                // run concurrently so the group costs one lane's time.
                for g in 0..groups {
                    let mut group_cycles = 0u64;
                    for lane in 0..l.parallel {
                        let co = g * l.parallel + lane;
                        if co >= l.co {
                            break;
                        }
                        // Weight-buffer reads: one vector per input
                        // channel walked (hidden or not, still traffic).
                        rep.counters.read(MemLevel::Bram, DataKind::Weight,
                                          n_ci as u64);
                        let fr = if standard {
                            self.array.process_field_active(
                                lane, &active, self.weights.taps_tm(co),
                                n_ci, t_rw, t_pe)
                        } else {
                            self.array.process_field(
                                lane, &wrows, self.weights.taps_tm(co),
                                n_ci, co, t_rw, t_pe)
                        };
                        group_cycles = group_cycles.max(fr.cycles);
                        let idx = (oy * wo + ox) * l.co + co;
                        if self.neuron.fire(idx, co, fr.psum,
                                            &mut rep.counters) {
                            out.set(oy, ox, co);
                        }
                    }
                    rep.cycles += group_cycles;
                }
                rep.counters.write(MemLevel::Bram, DataKind::OutputSpike, 1);
            }
        }
        rep.ops = self.array.total_ops() - ops_before;
        rep.out_spikes = out.count() as u64;
        (out, rep)
    }

    /// Run all `timesteps` of one frame (same input each step — direct
    /// encoding upstream), merging reports.
    pub fn run_frame(&mut self, input: &SpikeFrame, off_chip_input: bool)
                     -> (SpikeFrame, ConvRunReport) {
        self.neuron.reset();
        let mut merged = ConvRunReport::default();
        let mut last_out = None;
        for _ in 0..self.timesteps {
            let (out, rep) = self.run_timestep(input, off_chip_input);
            merged.cycles += rep.cycles;
            merged.ops += rep.ops;
            merged.out_spikes += rep.out_spikes;
            merged.counters.merge(&rep.counters);
            last_out = Some(out);
        }
        (last_out.expect("timesteps >= 1"), merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ConvLayer, ConvMode};
    use crate::dataflow::{conv_latency, ConvLatencyParams};
    use crate::util::rng::Rng;

    fn layer(mode: ConvMode, parallel: usize) -> ConvLayer {
        let (ci, co) = match mode {
            ConvMode::Depthwise => (6, 6),
            _ => (6, 8),
        };
        let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
        ConvLayer {
            mode,
            in_h: 10,
            in_w: 10,
            ci,
            co,
            kh: k,
            kw: k,
            pad: k / 2,
            encoder: false,
            parallel,
        }
    }

    /// Reference conv + IF in plain rust (mirrors kernels/ref.py).
    fn ref_conv_if(input: &SpikeFrame, l: &ConvLayer, w: &ConvWeights)
                   -> SpikeFrame {
        let (ho, wo) = (l.out_h(), l.out_w());
        let mut out = SpikeFrame::zeros(ho, wo, l.co);
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..l.co {
                    let taps = w.of_channel(co);
                    let mut acc: i64 = 0;
                    match l.mode {
                        ConvMode::Standard | ConvMode::Depthwise => {
                            for r in 0..l.kh {
                                for c in 0..l.kw {
                                    let iy = oy as isize + r as isize
                                        - l.pad as isize;
                                    let ix = ox as isize + c as isize
                                        - l.pad as isize;
                                    if iy < 0 || ix < 0
                                        || iy >= l.in_h as isize
                                        || ix >= l.in_w as isize {
                                        continue;
                                    }
                                    let (iy, ix) = (iy as usize, ix as usize);
                                    match l.mode {
                                        ConvMode::Standard => {
                                            for ci in 0..l.ci {
                                                if input.get(iy, ix, ci) {
                                                    acc += taps[ci]
                                                        [r * l.kw + c]
                                                        as i64;
                                                }
                                            }
                                        }
                                        _ => {
                                            if input.get(iy, ix, co) {
                                                acc += taps[0][r * l.kw + c]
                                                    as i64;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        ConvMode::Pointwise => {
                            for ci in 0..l.ci {
                                if input.get(oy, ox, ci) {
                                    acc += taps[ci][0] as i64;
                                }
                            }
                        }
                    }
                    let v = acc as f32 * w.scale + w.bias[co];
                    if v >= w.vth {
                        out.set(oy, ox, co);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn standard_engine_matches_reference() {
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 3);
        let mut rng = Rng::new(1);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, rep) = eng.run_frame(&input, true);
        assert_eq!(got, want);
        assert!(rep.cycles > 0 && rep.ops > 0);
    }

    #[test]
    fn depthwise_engine_matches_reference() {
        let l = layer(ConvMode::Depthwise, 1);
        let w = ConvWeights::random(&l, 5);
        let mut rng = Rng::new(2);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    #[test]
    fn pointwise_engine_matches_reference() {
        let l = layer(ConvMode::Pointwise, 2);
        let w = ConvWeights::random(&l, 7);
        let mut rng = Rng::new(3);
        let input = SpikeFrame::random(10, 10, 6, 0.4, &mut rng);
        let want = ref_conv_if(&input, &l, &w);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (got, _) = eng.run_frame(&input, true);
        assert_eq!(got, want);
    }

    #[test]
    fn cycles_match_analytical_model() {
        for parallel in [1, 2, 4] {
            let l = layer(ConvMode::Standard, parallel);
            let w = ConvWeights::random(&l, 11);
            let timing = ConvLatencyParams::optimized();
            let analytical = conv_latency(&l, &timing);
            let mut eng = ConvEngine::new(l, w, timing, 1);
            let mut rng = Rng::new(4);
            let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
            let (_, rep) = eng.run_frame(&input, true);
            let err = (rep.cycles as f64 - analytical as f64).abs()
                / analytical as f64;
            assert!(err < 0.05,
                    "p={parallel}: engine {} vs model {analytical}",
                    rep.cycles);
        }
    }

    #[test]
    fn parallelism_reduces_cycles() {
        let mut rng = Rng::new(5);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut cycles = Vec::new();
        for p in [1, 2, 4] {
            let l = layer(ConvMode::Standard, p);
            let w = ConvWeights::random(&l, 13);
            let mut eng =
                ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
            let (_, rep) = eng.run_frame(&input, true);
            cycles.push(rep.cycles);
        }
        assert!(cycles[0] > cycles[1] && cycles[1] > cycles[2],
                "{cycles:?}");
        let ratio = cycles[0] as f64 / cycles[2] as f64;
        assert!(ratio > 3.0, "4x lanes gave only {ratio}x");
    }

    #[test]
    fn parallelism_preserves_function() {
        let mut rng = Rng::new(6);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l1 = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l1, 17);
        let mut e1 =
            ConvEngine::new(l1, w.clone(), ConvLatencyParams::optimized(), 1);
        let (out1, _) = e1.run_frame(&input, true);
        let l4 = layer(ConvMode::Standard, 4);
        let mut e4 =
            ConvEngine::new(l4, w, ConvLatencyParams::optimized(), 1);
        let (out4, _) = e4.run_frame(&input, true);
        assert_eq!(out1, out4);
    }

    #[test]
    fn t1_has_zero_vmem_traffic_t2_does_not() {
        let mut rng = Rng::new(7);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 19);
        let mut e1 = ConvEngine::new(l.clone(), w.clone(),
                                     ConvLatencyParams::optimized(), 1);
        let (_, r1) = e1.run_frame(&input, true);
        assert_eq!(r1.counters.total_of_kind(DataKind::Vmem), 0);
        assert_eq!(e1.vmem_bytes(), 0);

        let mut e2 = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 2);
        let (_, r2) = e2.run_frame(&input, true);
        assert!(r2.counters.total_of_kind(DataKind::Vmem) > 0);
        assert!(e2.vmem_bytes() > 0);
        // Two timesteps => ~2x cycles and ~2x ops.
        assert!((r2.cycles as f64 / r1.cycles as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn input_vector_fetched_once_per_pixel() {
        // Table III: off-chip input reads = Hi*Wi (padded rows included
        // as zero vectors are on-chip constants; we count pushed rows).
        let l = layer(ConvMode::Standard, 1);
        let w = ConvWeights::random(&l, 23);
        let mut rng = Rng::new(8);
        let input = SpikeFrame::random(10, 10, 6, 0.3, &mut rng);
        let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
        let (_, rep) = eng.run_frame(&input, true);
        let dram_reads =
            rep.counters.reads_of(MemLevel::Dram, DataKind::InputSpike);
        // Padded geometry: (Hi+2p) rows of (Wi+2p) vectors pushed, but
        // only Kh + (Ho-1) rows enter the buffer.
        let rows_pushed = (l_kh() + (10 - 1)) as u64;
        assert_eq!(dram_reads, rows_pushed * 12);
        fn l_kh() -> usize { 3 }
    }
}
