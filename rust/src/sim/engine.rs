//! The unified per-layer engine abstraction.
//!
//! The paper's accelerator is one parameterized machine: a multi-mode
//! PE array whose engines are configured per layer and composed into a
//! layer-wise pipeline (Fig. 5/9). This module is that machine's
//! programmable interface on the simulator side:
//!
//! * [`LayerEngine`] — the trait every hardware layer engine
//!   implements ([`ConvEngine`], [`PoolEngine`], [`FcEngine`], and the
//!   weight-stationary baseline [`WsEngine`]). The coordinator's
//!   pipeline holds `Vec<Box<dyn LayerEngine>>`, so a new layer kind
//!   is one trait impl plus one arm in [`engine_for_layer`] — not a
//!   cross-module edit.
//! * [`LayerStep`] — the uniform per-frame cost report (cycles, ops,
//!   output spikes, memory traffic) every engine produces. The conv,
//!   FC, and pool engines all report through this one type.
//! * [`LayerWeights`] — the per-layer weight source consumed when
//!   engines are built from a network spec (deterministic-random or
//!   real quantised artifact tensors).
//! * [`build_engines`] / [`engine_for_layer`] — the single place a
//!   [`crate::arch::Layer`] maps to its hardware engine.
//!
//! Construction normally happens through the `session` facade
//! (`sti_snn::session::Session`); this layer exists so benches and
//! tests can also drive individual engines through the exact code path
//! the pipeline uses.

use std::sync::Arc;

use crate::arch::{Layer, NetworkSpec};
use crate::codec::{EventCodec, SpikeFrame};
use crate::dataflow::ConvLatencyParams;
use crate::telemetry::TraceSink;

use super::backend::BackendKind;
use super::conv_engine::{ConvEngine, ConvWeights};
use super::fc_engine::FcEngine;
use super::memory::AccessCounter;
use super::pool_engine::PoolEngine;
use super::ws_engine::WsEngine;

/// Uniform per-frame cost report of one [`LayerEngine`] invocation.
///
/// One type for every engine kind (conv / pool / FC / WS baseline):
/// architectural cycles, spike-gated synaptic ops, output spike count,
/// and the per-level/per-kind memory traffic. Counters are
/// weight- and compute-backend-independent (see `sim::backend`), so
/// two engines configured identically produce identical `LayerStep`s.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerStep {
    /// Architectural cycles of the step (all configured timesteps).
    pub cycles: u64,
    /// Spike-gated synaptic accumulates performed.
    pub ops: u64,
    /// Spikes in the output frame (0 for the classifier head).
    pub out_spikes: u64,
    /// Memory traffic by level and data kind.
    pub counters: AccessCounter,
}

impl LayerStep {
    /// Merge another step's costs into this one (multi-timestep /
    /// multi-layer aggregation).
    pub fn merge(&mut self, other: &LayerStep) {
        self.cycles += other.cycles;
        self.ops += other.ops;
        self.out_spikes += other.out_spikes;
        self.counters.merge(&other.counters);
    }
}

/// What a layer engine hands to the next pipeline stage.
pub enum LayerOutput {
    /// A spike frame for the next engine.
    Frame(SpikeFrame),
    /// Terminal classifier output: argmax class + accumulated logits.
    Classified { class: usize, logits: Vec<f32> },
}

/// Outcome of a zero-copy [`LayerEngine::process_frame_into`] step.
pub enum LayerResult {
    /// The output spike frame was written into the caller's buffer.
    Frame,
    /// Terminal classifier output: argmax class + accumulated logits
    /// (the caller's buffer is untouched).
    Classified { class: usize, logits: Vec<f32> },
}

/// One pipeline stage of the accelerator: a hardware engine that
/// consumes a spike frame and produces the next activation (or the
/// classification) while accounting its architectural cost.
///
/// Implementors: [`ConvEngine`] (OS dataflow, all three conv modes),
/// [`PoolEngine`] (2x2 OR pooling), [`FcEngine`] (classifier head),
/// and [`WsEngine`] (the weight-stationary Table I baseline). Engines
/// are `Send` so replica pools can move pipelines across worker
/// threads.
pub trait LayerEngine: Send {
    /// Engine kind for report labels ("conv", "pool", "fc", "ws").
    fn kind(&self) -> &'static str;

    /// Label suffix appended after the layer index (conv mode).
    fn label_detail(&self) -> String {
        String::new()
    }

    /// Output frame shape `(h, w, c)`; `None` for classifier heads
    /// whose result is logits, not a frame. The streamed executor
    /// sizes inter-layer row channels and staging buffers from this.
    fn out_shape(&self) -> Option<(usize, usize, usize)>;

    /// Row-granular entry point, part 1: arm the engine for a new
    /// streamed frame. `off_chip_input` marks whether the input
    /// arrives from DRAM (first pipeline layer) or an on-chip FIFO.
    /// The caller has already `reset` the `out` buffer it will pass to
    /// [`LayerEngine::process_row_into`] to [`LayerEngine::out_shape`].
    fn begin_frame(&mut self, off_chip_input: bool);

    /// Row-granular entry point, part 2: input rows `0..=y` of
    /// `input` are now valid; compute every output row that became
    /// ready and write it into `out`. Returns the completed-output-row
    /// prefix length (monotone non-decreasing across calls) so the
    /// streamed executor knows which rows it may forward downstream.
    /// Engines that only compute at frame granularity return 0 and do
    /// all the work in [`LayerEngine::finish_frame`].
    fn process_row_into(&mut self, input: &SpikeFrame, y: usize,
                        out: &mut SpikeFrame) -> usize;

    /// Row-granular entry point, part 3: every input row has been
    /// presented; complete the frame (remaining rows, timestep
    /// replays, classifier readout) and return the result plus the
    /// full architectural cost of the frame — bit-identical to what
    /// [`LayerEngine::process_frame_into`] reports for the same input.
    fn finish_frame(&mut self, input: &SpikeFrame, out: &mut SpikeFrame)
                    -> (LayerResult, LayerStep);

    /// Run all configured timesteps of one frame, writing the output
    /// frame (if any) into the caller-owned `out` buffer — the
    /// zero-allocation hot path the serial pipeline drives (§Perf).
    ///
    /// Provided as a trivial driver loop over the row-granular entry
    /// points; engines with a faster whole-frame schedule (the conv
    /// engine's intra-frame band threads) override it.
    fn process_frame_into(&mut self, input: &SpikeFrame,
                          off_chip_input: bool, out: &mut SpikeFrame)
                          -> (LayerResult, LayerStep) {
        if let Some((h, w, c)) = self.out_shape() {
            out.reset(h, w, c);
        }
        self.begin_frame(off_chip_input);
        for y in 0..input.h {
            self.process_row_into(input, y, out);
        }
        self.finish_frame(input, out)
    }

    /// Allocating convenience wrapper around
    /// [`LayerEngine::process_frame_into`].
    fn process_frame(&mut self, input: &SpikeFrame, off_chip_input: bool)
                     -> (LayerOutput, LayerStep) {
        let mut out = SpikeFrame::zeros(0, 0, 0);
        let (res, step) =
            self.process_frame_into(input, off_chip_input, &mut out);
        let output = match res {
            LayerResult::Frame => LayerOutput::Frame(out),
            LayerResult::Classified { class, logits } => {
                LayerOutput::Classified { class, logits }
            }
        };
        (output, step)
    }

    /// Reset cross-frame state (membrane potentials). Engines are
    /// frame-stateless by default.
    fn reset(&mut self) {}

    /// Architectural Vmem buffer bytes this engine provisions
    /// (0 at T = 1 — the paper's Fig. 11 saving).
    fn vmem_bytes(&self) -> usize {
        0
    }

    /// Event codec of this engine's input link, when the inter-layer
    /// stream is spike-event encoded (conv layers). The pipeline uses
    /// it for compression-ratio accounting.
    fn event_codec(&self) -> Option<EventCodec> {
        None
    }

    /// Install (or clear, with `None`) the telemetry span recorder.
    /// Engines with internal span sites override this (the conv
    /// engine records band prime/row spans); the default is a no-op —
    /// tracing never changes what an engine computes or reports.
    fn set_trace(&mut self, _trace: Option<Arc<TraceSink>>) {}
}

impl LayerEngine for ConvEngine {
    fn kind(&self) -> &'static str {
        "conv"
    }

    fn label_detail(&self) -> String {
        format!(":{:?}", self.layer.mode)
    }

    fn out_shape(&self) -> Option<(usize, usize, usize)> {
        Some((self.layer.out_h(), self.layer.out_w(), self.layer.co))
    }

    fn begin_frame(&mut self, off_chip_input: bool) {
        self.stream_begin(off_chip_input);
    }

    fn process_row_into(&mut self, input: &SpikeFrame, y: usize,
                        out: &mut SpikeFrame) -> usize {
        self.stream_row(input, y, out)
    }

    fn finish_frame(&mut self, input: &SpikeFrame, out: &mut SpikeFrame)
                    -> (LayerResult, LayerStep) {
        (LayerResult::Frame, self.stream_finish(input, out))
    }

    /// Whole-frame override: the engine-owned schedule (one pass, or
    /// scoped threads across intra-frame bands) — not the row loop.
    fn process_frame_into(&mut self, input: &SpikeFrame,
                          off_chip_input: bool, out: &mut SpikeFrame)
                          -> (LayerResult, LayerStep) {
        let step = self.run_frame_into(input, off_chip_input, out);
        (LayerResult::Frame, step)
    }

    fn reset(&mut self) {
        self.neuron.reset();
    }

    fn vmem_bytes(&self) -> usize {
        ConvEngine::vmem_bytes(self)
    }

    fn event_codec(&self) -> Option<EventCodec> {
        Some(EventCodec::new(self.layer.in_h, self.layer.in_w,
                             self.layer.ci))
    }

    fn set_trace(&mut self, trace: Option<Arc<TraceSink>>) {
        self.set_trace_sink(trace);
    }
}

impl LayerEngine for PoolEngine {
    fn kind(&self) -> &'static str {
        "pool"
    }

    fn out_shape(&self) -> Option<(usize, usize, usize)> {
        Some((self.in_h / 2, self.in_w / 2, self.c))
    }

    fn begin_frame(&mut self, _off_chip_input: bool) {
        self.stream_begin();
    }

    fn process_row_into(&mut self, input: &SpikeFrame, y: usize,
                        out: &mut SpikeFrame) -> usize {
        // Every odd input row completes one pooled output row; the
        // charge order per row matches the whole-frame pass exactly.
        self.stream_row(input, y, out)
    }

    fn finish_frame(&mut self, _input: &SpikeFrame, out: &mut SpikeFrame)
                    -> (LayerResult, LayerStep) {
        // The pooling pass repeats per timestep (same OR result); the
        // traffic is charged once — the registers hold the window.
        (LayerResult::Frame, self.stream_finish(out))
    }
}

impl LayerEngine for FcEngine {
    fn kind(&self) -> &'static str {
        "fc"
    }

    fn out_shape(&self) -> Option<(usize, usize, usize)> {
        None // classifier head: logits, not a frame
    }

    fn begin_frame(&mut self, _off_chip_input: bool) {}

    fn process_row_into(&mut self, input: &SpikeFrame, y: usize,
                        _out: &mut SpikeFrame) -> usize {
        // Consume upstream rows as they land: stage into the
        // engine-owned flatten scratch; no output rows to report.
        self.stage_row(input, y);
        0
    }

    fn finish_frame(&mut self, _input: &SpikeFrame, _out: &mut SpikeFrame)
                    -> (LayerResult, LayerStep) {
        // At T > 1 the same final spike map replays per timestep
        // (upstream already accumulated) — SDT readout over the staged
        // scratch.
        let (class, logits, step) = self.classify_flat();
        (LayerResult::Classified { class, logits }, step)
    }
}

impl LayerEngine for WsEngine {
    fn kind(&self) -> &'static str {
        "ws"
    }

    fn label_detail(&self) -> String {
        format!(":{:?}", self.layer().mode)
    }

    fn out_shape(&self) -> Option<(usize, usize, usize)> {
        let l = self.layer();
        Some((l.out_h(), l.out_w(), l.co))
    }

    fn begin_frame(&mut self, _off_chip_input: bool) {}

    fn process_row_into(&mut self, _input: &SpikeFrame, _y: usize,
                        _out: &mut SpikeFrame) -> usize {
        // The WS baseline computes at frame granularity (its Table I
        // access pattern is a whole-frame rewrite); rows pass through
        // and the work happens in `finish_frame`.
        0
    }

    fn finish_frame(&mut self, input: &SpikeFrame, out: &mut SpikeFrame)
                    -> (LayerResult, LayerStep) {
        // WS charges its own (Table I) traffic pattern regardless of
        // where the input comes from.
        let step = self.run_frame_into(input, out);
        (LayerResult::Frame, step)
    }

    fn reset(&mut self) {
        WsEngine::reset(self);
    }
}

/// Per-layer weight source for engine construction.
///
/// The session facade resolves its weight policy
/// (`sti_snn::session::Weights`) into one of these per accelerated
/// layer; artifacts produce them via
/// [`crate::model::Artifact::layer_weights`].
#[derive(Clone)]
pub enum LayerWeights {
    /// Deterministic random weights (hardware-only experiments —
    /// cycle and traffic counts are weight-independent).
    Random {
        /// PRNG seed for this layer's taps.
        seed: u64,
    },
    /// Real quantised conv weights from `artifacts/`.
    Conv(ConvWeights),
    /// Real quantised classifier weights from `artifacts/`.
    Fc {
        /// Row-major `[n_in][n_out]` int8 weights.
        weights: Vec<i8>,
        /// Dequantisation scale.
        scale: f32,
        /// Per-output bias.
        bias: Vec<f32>,
    },
}

/// Construction knobs shared by every engine builder.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Per-stage cycle costs of the conv latency model (Eq. 12).
    pub timing: ConvLatencyParams,
    /// Inference timesteps (T = 1 is the paper's headline mode).
    pub timesteps: usize,
    /// Functional compute backend (bit-exact across kinds).
    pub backend: BackendKind,
    /// Intra-frame row bands per conv engine (host-side parallelism;
    /// reports are band-invariant — 1 = serial).
    pub intra_parallel: usize,
}

/// Build the engine for one accelerated layer — the single place a
/// layer kind maps to hardware. Pool layers take no weights; conv and
/// FC layers require a matching [`LayerWeights`] source.
pub fn engine_for_layer(layer: &Layer, weights: Option<LayerWeights>,
                        cfg: &EngineConfig)
                        -> anyhow::Result<Box<dyn LayerEngine>> {
    match layer {
        Layer::Conv(c) => {
            let w = match weights {
                Some(LayerWeights::Random { seed }) => {
                    ConvWeights::random(c, seed)
                }
                Some(LayerWeights::Conv(w)) => w,
                Some(LayerWeights::Fc { .. }) => {
                    anyhow::bail!("expected conv weights, got fc")
                }
                None => anyhow::bail!("conv layer needs weights"),
            };
            Ok(Box::new(
                ConvEngine::with_backend(c.clone(), w, cfg.timing,
                                         cfg.timesteps, cfg.backend)
                    .with_intra_parallel(cfg.intra_parallel),
            ))
        }
        Layer::Pool { in_h, in_w, c } => {
            anyhow::ensure!(weights.is_none(),
                            "pool layers take no weights");
            Ok(Box::new(PoolEngine::new(*in_h, *in_w, *c)
                .with_timesteps(cfg.timesteps)))
        }
        Layer::Fc { n_in, n_out } => {
            let eng = match weights {
                Some(LayerWeights::Random { seed }) => {
                    FcEngine::random(*n_in, *n_out, seed)
                }
                Some(LayerWeights::Fc { weights, scale, bias }) => {
                    FcEngine::new(*n_in, *n_out, weights, scale, bias)
                }
                Some(LayerWeights::Conv(_)) => {
                    anyhow::bail!("expected fc weights, got conv")
                }
                None => anyhow::bail!("fc layer needs weights"),
            };
            Ok(Box::new(eng
                .with_backend(cfg.backend)
                .with_timesteps(cfg.timesteps)))
        }
    }
}

/// Build the engine chain for every accelerated layer of `net`.
/// `sources` supplies weights per conv/FC layer in order (encoder and
/// pool layers take none); the count must match exactly.
pub fn build_engines(net: &NetworkSpec, cfg: &EngineConfig,
                     sources: Vec<LayerWeights>)
                     -> anyhow::Result<Vec<Box<dyn LayerEngine>>> {
    let mut srcs = sources;
    srcs.reverse(); // pop from the front
    let mut engines = Vec::new();
    for layer in &net.layers {
        match layer {
            Layer::Conv(c) if c.encoder => {
                // Encoder runs off-accelerator (host / L2 artifact).
                continue;
            }
            Layer::Pool { .. } => {
                engines.push(engine_for_layer(layer, None, cfg)?);
            }
            _ => {
                let w = srcs.pop().ok_or_else(|| {
                    anyhow::anyhow!("missing weights for layer {layer:?}")
                })?;
                engines.push(engine_for_layer(layer, Some(w), cfg)?);
            }
        }
    }
    if !srcs.is_empty() {
        anyhow::bail!("{} unused layer weight sources", srcs.len());
    }
    Ok(engines)
}

/// Deterministic per-layer random weight sources for `net`: layer `i`
/// (over weight-taking layers, in order) gets seed `base_seed + i`.
pub fn random_sources(net: &NetworkSpec, base_seed: u64)
                      -> Vec<LayerWeights> {
    let n = net
        .layers
        .iter()
        .filter(|l| match l {
            Layer::Conv(c) => !c.encoder,
            Layer::Pool { .. } => false,
            Layer::Fc { .. } => true,
        })
        .count();
    (0..n)
        .map(|i| LayerWeights::Random { seed: base_seed + i as u64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::scnn3;
    use crate::util::rng::Rng;

    fn cfg() -> EngineConfig {
        EngineConfig {
            timing: ConvLatencyParams::optimized(),
            timesteps: 1,
            backend: BackendKind::Accurate,
            intra_parallel: 1,
        }
    }

    #[test]
    fn build_engines_covers_all_accel_layers() {
        let net = scnn3();
        let engines =
            build_engines(&net, &cfg(), random_sources(&net, 1000))
                .unwrap();
        // scnn3: encoder skipped; conv, pool, conv, pool, fc = 5.
        assert_eq!(engines.len(), 5);
        let kinds: Vec<&str> = engines.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, vec!["conv", "pool", "conv", "pool", "fc"]);
    }

    #[test]
    fn source_count_mismatch_is_an_error() {
        let net = scnn3();
        assert!(build_engines(&net, &cfg(),
                              vec![LayerWeights::Random { seed: 1 }])
            .is_err());
        let too_many: Vec<LayerWeights> = (0..9)
            .map(|s| LayerWeights::Random { seed: s })
            .collect();
        assert!(build_engines(&net, &cfg(), too_many).is_err());
    }

    /// Trait dispatch produces the same frames and reports as calling
    /// the concrete engine directly.
    #[test]
    fn trait_dispatch_matches_concrete_conv_engine() {
        let net = scnn3();
        let c = net.accel_convs()[0].clone();
        let w = ConvWeights::random(&c, 7);
        let mut rng = Rng::new(3);
        let input = SpikeFrame::random(c.in_h, c.in_w, c.ci, 0.2, &mut rng);

        let mut direct = ConvEngine::with_backend(
            c.clone(), w.clone(), ConvLatencyParams::optimized(), 1,
            BackendKind::Accurate);
        let (want_out, want_rep) = direct.run_frame(&input, true);

        let mut boxed: Box<dyn LayerEngine> = Box::new(
            ConvEngine::with_backend(c, w, ConvLatencyParams::optimized(),
                                     1, BackendKind::Accurate));
        let (out, step) = boxed.process_frame(&input, true);
        match out {
            LayerOutput::Frame(f) => assert_eq!(f, want_out),
            _ => panic!("conv engine must emit a frame"),
        }
        assert_eq!(step, want_rep);
        assert!(boxed.event_codec().is_some());
    }

    /// The WS baseline runs through the same trait surface the
    /// pipeline uses, agreeing functionally with the OS engine while
    /// paying psum traffic OS avoids (Table I).
    #[test]
    fn ws_engine_runs_through_the_trait() {
        use crate::sim::memory::DataKind;
        let net = scnn3();
        let c = net.accel_convs()[0].clone();
        let w = ConvWeights::random(&c, 9);
        let mut rng = Rng::new(4);
        let input = SpikeFrame::random(c.in_h, c.in_w, c.ci, 0.2, &mut rng);

        let mut os: Box<dyn LayerEngine> = Box::new(ConvEngine::new(
            c.clone(), w.clone(), ConvLatencyParams::optimized(), 1));
        let mut ws: Box<dyn LayerEngine> =
            Box::new(WsEngine::new(c, w, 1));
        assert_eq!(ws.kind(), "ws");
        let (os_out, os_step) = os.process_frame(&input, true);
        let (ws_out, ws_step) = ws.process_frame(&input, true);
        match (os_out, ws_out) {
            (LayerOutput::Frame(a), LayerOutput::Frame(b)) => {
                assert_eq!(a, b)
            }
            _ => panic!("conv engines must emit frames"),
        }
        assert_eq!(
            os_step.counters.total_of_kind(DataKind::PartialSum), 0);
        assert!(
            ws_step.counters.total_of_kind(DataKind::PartialSum) > 0);
        assert!(ws_step.cycles > os_step.cycles);
    }
}
