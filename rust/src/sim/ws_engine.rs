//! Weight-stationary baseline engine (Table I comparison).
//!
//! A counting model of a WS conv layer: weights pinned in PEs, input
//! spikes re-streamed per (ci, co) pair, partial sums spilled to and
//! re-fetched from the psum buffer for every input channel — the
//! traffic pattern whose cost motivates the paper's OS choice
//! (SectionII-C).  Functional output uses the same semantics as the OS
//! engine (convolution is dataflow-invariant); only traffic and cycle
//! accounting differ.

use crate::arch::ConvLayer;
use crate::codec::SpikeFrame;
use crate::dataflow::ws_access;

use super::conv_engine::{ConvEngine, ConvRunReport, ConvWeights};
use super::memory::{AccessCounter, DataKind, MemLevel};

pub struct WsEngine {
    inner: ConvEngine,
    timesteps: usize,
}

impl WsEngine {
    pub fn new(layer: ConvLayer, weights: ConvWeights,
               timesteps: usize) -> Self {
        let timing = crate::dataflow::ConvLatencyParams::optimized();
        Self {
            inner: ConvEngine::new(layer, weights, timing, timesteps),
            timesteps: timesteps.max(1),
        }
    }

    /// The conv layer this engine models.
    pub fn layer(&self) -> &ConvLayer {
        &self.inner.layer
    }

    /// Reset cross-frame membrane state (delegates to the OS core).
    pub fn reset(&mut self) {
        self.inner.neuron.reset();
    }

    /// Run one frame under WS accounting.
    pub fn run_frame(&mut self, input: &SpikeFrame)
                     -> (SpikeFrame, ConvRunReport) {
        let mut out = SpikeFrame::zeros(self.inner.layer.out_h(),
                                        self.inner.layer.out_w(),
                                        self.inner.layer.co);
        let rep = self.run_frame_into(input, &mut out);
        (out, rep)
    }

    /// Run one frame under WS accounting into the caller-owned `out`
    /// frame (the zero-allocation trait path).
    pub fn run_frame_into(&mut self, input: &SpikeFrame,
                          out: &mut SpikeFrame) -> ConvRunReport {
        // Functional result: identical to OS (dataflow changes traffic,
        // not math).
        let os_rep = self.inner.run_frame_into(input, true, out);

        // Replace the traffic with the WS pattern from Table I.
        let l = &self.inner.layer;
        let a = ws_access(l, self.timesteps() as u64);
        let mut counters = AccessCounter::new();
        counters.read(MemLevel::Bram, DataKind::InputSpike, a.input_spikes);
        counters.read(MemLevel::Bram, DataKind::Weight, a.weights);
        // WS psums: half reads, half writes of the spill traffic.
        counters.read(MemLevel::Bram, DataKind::PartialSum,
                      a.partial_sums / 2);
        counters.write(MemLevel::Bram, DataKind::PartialSum,
                       a.partial_sums - a.partial_sums / 2);

        // WS cycles: the psum spill serialises on the buffer port —
        // one extra cycle per psum access on top of the compute walk.
        let cycles = os_rep.cycles + a.partial_sums;

        ConvRunReport {
            cycles,
            ops: os_rep.ops,
            out_spikes: os_rep.out_spikes,
            counters,
        }
    }

    fn timesteps(&self) -> usize {
        self.timesteps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ConvMode;
    use crate::util::rng::Rng;

    fn layer() -> ConvLayer {
        ConvLayer {
            mode: ConvMode::Standard,
            in_h: 8,
            in_w: 8,
            ci: 4,
            co: 6,
            kh: 3,
            kw: 3,
            pad: 1,
            encoder: false,
            parallel: 1,
        }
    }

    #[test]
    fn ws_and_os_agree_functionally() {
        let l = layer();
        let w = ConvWeights::random(&l, 1);
        let mut rng = Rng::new(2);
        let input = SpikeFrame::random(8, 8, 4, 0.3, &mut rng);
        let mut os = ConvEngine::new(
            l.clone(), w.clone(),
            crate::dataflow::ConvLatencyParams::optimized(), 1);
        let (os_out, _) = os.run_frame(&input, true);
        let mut ws = WsEngine::new(l, w, 1);
        let (ws_out, _) = ws.run_frame(&input);
        assert_eq!(os_out, ws_out);
    }

    #[test]
    fn ws_pays_psum_traffic_at_t1() {
        let l = layer();
        let w = ConvWeights::random(&l, 3);
        let mut rng = Rng::new(4);
        let input = SpikeFrame::random(8, 8, 4, 0.3, &mut rng);
        let mut ws = WsEngine::new(l, w, 1);
        let (_, rep) = ws.run_frame(&input);
        // Table I WS psums at T=1: Ci*Co*Wo*Ho > 0 — the OS engine's is 0.
        assert_eq!(rep.counters.total_of_kind(DataKind::PartialSum),
                   4 * 6 * 8 * 8);
    }

    #[test]
    fn ws_slower_than_os() {
        let l = layer();
        let w = ConvWeights::random(&l, 5);
        let mut rng = Rng::new(6);
        let input = SpikeFrame::random(8, 8, 4, 0.3, &mut rng);
        let mut os = ConvEngine::new(
            l.clone(), w.clone(),
            crate::dataflow::ConvLatencyParams::optimized(), 1);
        let (_, os_rep) = os.run_frame(&input, true);
        let mut ws = WsEngine::new(l, w, 1);
        let (_, ws_rep) = ws.run_frame(&input);
        assert!(ws_rep.cycles > os_rep.cycles);
    }
}
