//! Multi-mode processing element (paper Fig. 8).
//!
//! One PE owns one kernel tap position. Per input channel it receives
//! (spike bit, int8 weight) and, depending on mode:
//!
//! * **Standard** (Fig. 8b): accumulates the weight into its psum
//!   register iff the spike bit is set; after all input channels the
//!   psum is offloaded to the spike-generation adder tree (`ctrl1`).
//! * **Depthwise** (Fig. 8c): no cross-channel accumulation — each
//!   channel's tap product is emitted directly (accumulation happens
//!   across taps in the adder tree instead).
//! * **Pointwise** (Fig. 8d): single-tap; the PE's accumulated psum is
//!   thresholded directly with no adder tree.
//!
//! In multi-timestep mode the PE seeds its accumulator from the saved
//! membrane potential and hands the updated value back (the Vmem-buffer
//! round trip that T = 1 eliminates).
//!
//! This model is the semantic ground truth for the functional compute
//! backends in [`super::backend`]: any backend's field psum / op count
//! must equal stepping these PEs one (spike, weight) pair at a time —
//! pinned by the array unit tests and `tests/prop_backend.rs`.

use crate::arch::ConvMode;

/// PE accumulator precision: 32-bit signed, matching the RTL's
/// worst-case `Ci*Kh*Kw*127` growth.
pub type Acc = i32;

#[derive(Debug, Clone)]
pub struct Pe {
    pub mode: ConvMode,
    psum: Acc,
    /// Spike-gated accumulates performed (for ops accounting).
    pub ops: u64,
    /// Cycles the PE was busy.
    pub busy_cycles: u64,
}

impl Pe {
    pub fn new(mode: ConvMode) -> Self {
        Self { mode, psum: 0, ops: 0, busy_cycles: 0 }
    }

    /// Begin a new output pixel; in multi-timestep mode `seed` is the
    /// saved membrane potential (always 0 in standard OS accumulation —
    /// the neuron module owns vmem across timesteps).
    pub fn start(&mut self, seed: Acc) {
        self.psum = seed;
    }

    /// One (spike, weight) step. Returns the depthwise pass-through
    /// value when in depthwise mode (caller routes it to the tree).
    #[inline]
    pub fn step(&mut self, spike: bool, weight: i8) -> Option<Acc> {
        self.busy_cycles += 1;
        match self.mode {
            ConvMode::Standard | ConvMode::Pointwise => {
                if spike {
                    self.psum += weight as Acc;
                    self.ops += 1;
                }
                None
            }
            ConvMode::Depthwise => {
                // Fig. 8c: output the loaded weight iff a spike arrived.
                if spike {
                    self.ops += 1;
                    Some(weight as Acc)
                } else {
                    Some(0)
                }
            }
        }
    }

    /// Offload the accumulated psum (ctrl1) and clear the register.
    pub fn drain(&mut self) -> Acc {
        let v = self.psum;
        self.psum = 0;
        v
    }

    /// Observe without clearing (multi-timestep save path).
    pub fn peek(&self) -> Acc {
        self.psum
    }
}

/// Psum adder tree (the spike-generation module's combiner, Fig. 8a).
/// Returns (sum, tree latency in cycles = ceil(log2(n)) for n > 1).
pub fn adder_tree(psums: &[Acc]) -> (Acc, u64) {
    let sum = psums.iter().copied().sum();
    (sum, adder_tree_latency(psums.len()))
}

/// Latency of an n-input adder tree: ceil(log2(n)) cycles (0 for n<=1).
pub fn adder_tree_latency(n: usize) -> u64 {
    let n = n.max(1) as u64;
    if n <= 1 { 0 } else { 64 - (n - 1).leading_zeros() as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_mode_gates_on_spike() {
        let mut pe = Pe::new(ConvMode::Standard);
        pe.start(0);
        pe.step(true, 5);
        pe.step(false, 100); // gated off
        pe.step(true, -3);
        assert_eq!(pe.drain(), 2);
        assert_eq!(pe.ops, 2);
        assert_eq!(pe.drain(), 0); // cleared
    }

    #[test]
    fn depthwise_mode_passes_weight_through() {
        let mut pe = Pe::new(ConvMode::Depthwise);
        pe.start(0);
        assert_eq!(pe.step(true, 7), Some(7));
        assert_eq!(pe.step(false, 7), Some(0));
        // No internal accumulation in depthwise mode.
        assert_eq!(pe.peek(), 0);
    }

    #[test]
    fn pointwise_accumulates_like_standard() {
        let mut pe = Pe::new(ConvMode::Pointwise);
        pe.start(0);
        for w in [1i8, 2, 3] {
            pe.step(true, w);
        }
        assert_eq!(pe.drain(), 6);
    }

    #[test]
    fn multi_timestep_seed() {
        let mut pe = Pe::new(ConvMode::Standard);
        pe.start(10); // saved membrane potential
        pe.step(true, 1);
        assert_eq!(pe.peek(), 11);
    }

    #[test]
    fn adder_tree_sums_and_latency() {
        let (s, lat) = adder_tree(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(s, 45);
        assert_eq!(lat, 4); // ceil(log2(9)) = 4
        let (s, lat) = adder_tree(&[42]);
        assert_eq!((s, lat), (42, 0));
    }

    #[test]
    fn accumulator_handles_negative_weights() {
        let mut pe = Pe::new(ConvMode::Standard);
        pe.start(0);
        for _ in 0..1000 {
            pe.step(true, -127);
        }
        assert_eq!(pe.drain(), -127_000);
    }
}
