//! Line buffer: `Kh` chained FIFOs of spike vectors (paper Fig. 7a).
//!
//! The FIFOs are arranged tail-to-head: pushing a new pixel's spike
//! vector into row 0 shifts the column history upward, so after priming,
//! reading the heads of all `Kh` rows yields the `Kh x 1` column of the
//! current receptive field.  Each FIFO has depth `Wi` (one image row)
//! and width `Ci` bits (one spike vector) — exactly the paper's sizing.
//!
//! The conv engine walks receptive fields through [`LineBuffer::window`]
//! which also counts the BRAM traffic the structure implies: each input
//! vector is **written once** on fill (the single off-chip fetch of
//! Table III) and **read `Kw`** times per row it participates in from
//! on-chip FIFOs.

use crate::codec::{SpikeFrame, SpikeVector};

use super::memory::{AccessCounter, DataKind, MemLevel};

#[derive(Debug, Clone)]
pub struct LineBuffer {
    pub kh: usize,
    pub wi: usize,
    pub ci: usize,
    /// rows[r] = the r-th most recent image row (r = 0 newest).
    rows: Vec<Vec<SpikeVector>>,
    /// Number of image rows pushed so far.
    filled: usize,
}

impl LineBuffer {
    pub fn new(kh: usize, wi: usize, ci: usize) -> Self {
        Self {
            kh,
            wi,
            ci,
            rows: (0..kh).map(|_| Vec::with_capacity(wi)).collect(),
            filled: 0,
        }
    }

    /// Capacity in bits: `Kh * Wi * Ci` (the Fig. 7a sizing rule).
    pub fn capacity_bits(&self) -> usize {
        self.kh * self.wi * self.ci
    }

    /// Push one full image row of spike vectors (the fill from the
    /// previous layer / DRAM). Counts one off-chip read + one BRAM
    /// write per vector. Rows shift tail-to-head: the oldest falls off.
    pub fn push_row(&mut self, row: Vec<SpikeVector>,
                    counters: &mut AccessCounter, off_chip: bool) {
        assert_eq!(row.len(), self.wi, "row width mismatch");
        for v in &row {
            assert_eq!(v.channels, self.ci, "channel width mismatch");
        }
        counters.read(
            if off_chip { MemLevel::Dram } else { MemLevel::Bram },
            DataKind::InputSpike,
            self.wi as u64,
        );
        counters.write(MemLevel::Bram, DataKind::InputSpike, self.wi as u64);
        self.rows.rotate_right(1);
        self.rows[0] = row;
        self.filled += 1;
    }

    /// True when `Kh` rows are resident (the array can start).
    pub fn primed(&self) -> bool {
        self.filled >= self.kh
    }

    /// Borrow the `Kh` resident rows bottom-up (index 0 = top of the
    /// receptive field) for zero-copy window slicing (§Perf hot path).
    /// Traffic is accounted separately via [`Self::count_window_read`].
    pub fn resident_rows(&self) -> Vec<&[SpikeVector]> {
        debug_assert!(self.primed());
        (0..self.kh)
            .map(|r| self.rows[self.kh - 1 - r].as_slice())
            .collect()
    }

    /// Account the BRAM reads of one `Kh x Kw` window fetch.
    pub fn count_window_read(&self, kw: usize,
                             counters: &mut AccessCounter) {
        counters.read(MemLevel::Bram, DataKind::InputSpike,
                      (self.kh * kw) as u64);
    }

    /// The `Kh x Kw` window of spike vectors whose top-left input column
    /// is `x0` (0-based within the padded row). Counts `Kh*Kw` BRAM
    /// reads — the on-chip reuse traffic.
    pub fn window(&self, x0: usize, kw: usize,
                  counters: &mut AccessCounter) -> Vec<Vec<&SpikeVector>> {
        debug_assert!(self.primed());
        debug_assert!(x0 + kw <= self.wi);
        counters.read(MemLevel::Bram, DataKind::InputSpike,
                      (self.kh * kw) as u64);
        // rows[0] is the newest = bottom of the receptive field.
        (0..self.kh)
            .map(|r| {
                let row = &self.rows[self.kh - 1 - r];
                (x0..x0 + kw).map(|x| &row[x]).collect()
            })
            .collect()
    }
}

/// Build the padded spike-vector rows of a frame (zero padding).
pub fn padded_rows(frame: &SpikeFrame, pad: usize) -> Vec<Vec<SpikeVector>> {
    let wi = frame.w + 2 * pad;
    let mut rows = Vec::with_capacity(frame.h + 2 * pad);
    let zero_row =
        || (0..wi).map(|_| SpikeVector::zeros(frame.c)).collect::<Vec<_>>();
    for _ in 0..pad {
        rows.push(zero_row());
    }
    for y in 0..frame.h {
        let mut row = Vec::with_capacity(wi);
        for _ in 0..pad {
            row.push(SpikeVector::zeros(frame.c));
        }
        for x in 0..frame.w {
            row.push(frame.vector(y, x));
        }
        for _ in 0..pad {
            row.push(SpikeVector::zeros(frame.c));
        }
        rows.push(row);
    }
    for _ in 0..pad {
        rows.push(zero_row());
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sizing_rule() {
        let lb = LineBuffer::new(3, 28, 16);
        assert_eq!(lb.capacity_bits(), 3 * 28 * 16);
    }

    #[test]
    fn priming_and_window() {
        let mut rng = Rng::new(5);
        let f = SpikeFrame::random(4, 4, 2, 0.5, &mut rng);
        let rows = padded_rows(&f, 0);
        let mut lb = LineBuffer::new(3, 4, 2);
        let mut ctr = AccessCounter::new();
        lb.push_row(rows[0].clone(), &mut ctr, true);
        assert!(!lb.primed());
        lb.push_row(rows[1].clone(), &mut ctr, true);
        lb.push_row(rows[2].clone(), &mut ctr, true);
        assert!(lb.primed());
        let win = lb.window(1, 3, &mut ctr);
        // Window row r must equal image row r (rows 0..2), cols 1..3.
        for (r, wrow) in win.iter().enumerate() {
            for (c, v) in wrow.iter().enumerate() {
                assert_eq!(**v, f.vector(r, 1 + c), "mismatch at {r},{c}");
            }
        }
    }

    #[test]
    fn window_shifts_with_new_rows() {
        let mut rng = Rng::new(6);
        let f = SpikeFrame::random(5, 3, 1, 0.5, &mut rng);
        let rows = padded_rows(&f, 0);
        let mut lb = LineBuffer::new(3, 3, 1);
        let mut ctr = AccessCounter::new();
        for r in rows.iter().take(4) {
            lb.push_row(r.clone(), &mut ctr, true);
        }
        // After 4 pushes the window covers image rows 1..3.
        let win = lb.window(0, 3, &mut ctr);
        assert_eq!(*win[0][0], f.vector(1, 0));
        assert_eq!(*win[2][2], f.vector(3, 2));
    }

    #[test]
    fn traffic_accounting() {
        let mut lb = LineBuffer::new(3, 8, 4);
        let mut ctr = AccessCounter::new();
        for _ in 0..3 {
            let row = (0..8).map(|_| SpikeVector::zeros(4)).collect();
            lb.push_row(row, &mut ctr, true);
        }
        // 3 rows x 8 vectors: one DRAM read + one BRAM write each.
        assert_eq!(ctr.reads_of(MemLevel::Dram, DataKind::InputSpike), 24);
        assert_eq!(ctr.writes_of(MemLevel::Bram, DataKind::InputSpike), 24);
        lb.window(0, 3, &mut ctr);
        assert_eq!(ctr.reads_of(MemLevel::Bram, DataKind::InputSpike), 9);
    }

    #[test]
    fn padded_rows_geometry() {
        let f = SpikeFrame::zeros(4, 6, 3);
        let rows = padded_rows(&f, 1);
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[0].len(), 8);
    }
}
