//! Line buffer: `Kh` chained FIFOs of spike vectors (paper Fig. 7a).
//!
//! The FIFOs are arranged tail-to-head: ingesting a new image row
//! shifts the row history upward, so after priming, the `Kh` resident
//! rows are the rows of the current receptive field.  Each FIFO has
//! depth `Wi` (one padded image row) and width `Ci` bits (one spike
//! vector) — exactly the paper's sizing.
//!
//! The buffer is an engine-owned **workspace**: all `Kh x Wi` vectors
//! are allocated once at construction and refilled in place via
//! word-level extraction from the input frame
//! ([`crate::codec::SpikeFrame::vector_into`]), so steady-state frame
//! processing performs zero heap allocations (§Perf; pinned by
//! `tests/alloc_budget.rs`).  Zero padding is materialised during
//! ingest — there is no separately allocated padded-row copy of the
//! input.
//!
//! Traffic accounting mirrors the hardware: each input vector is
//! **written once** on fill (the single off-chip fetch of Table III)
//! and **read `Kw`** times per row it participates in from on-chip
//! FIFOs ([`LineBuffer::count_window_read`]).

use crate::codec::{SpikeFrame, SpikeVector};

use super::memory::{AccessCounter, DataKind, MemLevel};

#[derive(Debug, Clone)]
pub struct LineBuffer {
    pub kh: usize,
    pub wi: usize,
    pub ci: usize,
    /// Ring of `kh` padded rows; `rows[(head + r) % kh]` is field row
    /// `r` (0 = top of the receptive field = oldest resident row).
    rows: Vec<Vec<SpikeVector>>,
    head: usize,
    /// Number of image rows ingested since the last [`Self::reset`].
    filled: usize,
}

impl LineBuffer {
    pub fn new(kh: usize, wi: usize, ci: usize) -> Self {
        Self {
            kh,
            wi,
            ci,
            rows: (0..kh)
                .map(|_| (0..wi).map(|_| SpikeVector::zeros(ci)).collect())
                .collect(),
            head: 0,
            filled: 0,
        }
    }

    /// Capacity in bits: `Kh * Wi * Ci` (the Fig. 7a sizing rule).
    pub fn capacity_bits(&self) -> usize {
        self.kh * self.wi * self.ci
    }

    /// Start a new frame: forget the resident rows (buffers stay
    /// allocated — every vector is overwritten on ingest).
    pub fn reset(&mut self) {
        self.head = 0;
        self.filled = 0;
    }

    /// Ingest one padded row: padded row index `py` maps to frame row
    /// `py - pad` (rows and columns outside the frame are zero
    /// vectors).  The oldest resident row is overwritten in place.
    ///
    /// When `charge` is set, counts one off-chip (or on-chip, per
    /// `off_chip`) read plus one BRAM write per vector — exactly the
    /// fill traffic the serial row schedule implies.  Intra-frame
    /// bands re-ingest the `Kh - 1` rows they share with the previous
    /// band with `charge = false`, so each padded row is charged once
    /// across bands and reports stay bit-identical to the serial run.
    pub fn ingest_row(&mut self, frame: &SpikeFrame, py: isize, pad: usize,
                      counters: &mut AccessCounter, off_chip: bool,
                      charge: bool) {
        debug_assert_eq!(frame.c, self.ci, "channel width mismatch");
        debug_assert_eq!(frame.w + 2 * pad, self.wi, "row width mismatch");
        if charge {
            counters.read(
                if off_chip { MemLevel::Dram } else { MemLevel::Bram },
                DataKind::InputSpike,
                self.wi as u64,
            );
            counters.write(MemLevel::Bram, DataKind::InputSpike,
                           self.wi as u64);
        }
        let slot = if self.filled < self.kh {
            self.filled
        } else {
            let s = self.head;
            self.head = (self.head + 1) % self.kh;
            s
        };
        self.filled += 1;
        let y = py - pad as isize;
        let row = &mut self.rows[slot];
        if y < 0 || y >= frame.h as isize {
            for v in row.iter_mut() {
                v.clear();
            }
            return;
        }
        let y = y as usize;
        for (x, v) in row.iter_mut().enumerate() {
            let fx = x as isize - pad as isize;
            if fx < 0 || fx >= frame.w as isize {
                v.clear();
            } else {
                frame.vector_into(y, fx as usize, v);
            }
        }
    }

    /// True when `Kh` rows are resident (the array can start).
    pub fn primed(&self) -> bool {
        self.filled >= self.kh
    }

    /// Field row `r` (0 = top of the receptive field), full padded row.
    #[inline]
    pub fn row(&self, r: usize) -> &[SpikeVector] {
        debug_assert!(self.primed());
        &self.rows[(self.head + r) % self.kh]
    }

    /// The window vector at field row `r`, padded column `x`.
    #[inline]
    pub fn at(&self, r: usize, x: usize) -> &SpikeVector {
        &self.row(r)[x]
    }

    /// Account the BRAM reads of one `Kh x Kw` window fetch.
    pub fn count_window_read(&self, kw: usize,
                             counters: &mut AccessCounter) {
        counters.read(MemLevel::Bram, DataKind::InputSpike,
                      (self.kh * kw) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ingest(lb: &mut LineBuffer, f: &SpikeFrame, py: usize, pad: usize,
              ctr: &mut AccessCounter) {
        lb.ingest_row(f, py as isize, pad, ctr, true, true);
    }

    #[test]
    fn sizing_rule() {
        let lb = LineBuffer::new(3, 28, 16);
        assert_eq!(lb.capacity_bits(), 3 * 28 * 16);
    }

    #[test]
    fn priming_and_window() {
        let mut rng = Rng::new(5);
        let f = SpikeFrame::random(4, 4, 2, 0.5, &mut rng);
        let mut lb = LineBuffer::new(3, 4, 2);
        let mut ctr = AccessCounter::new();
        ingest(&mut lb, &f, 0, 0, &mut ctr);
        assert!(!lb.primed());
        ingest(&mut lb, &f, 1, 0, &mut ctr);
        ingest(&mut lb, &f, 2, 0, &mut ctr);
        assert!(lb.primed());
        // Field row r must equal image row r (rows 0..2), cols 1..3.
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(*lb.at(r, 1 + c), f.vector(r, 1 + c),
                           "mismatch at {r},{c}");
            }
        }
    }

    #[test]
    fn window_shifts_with_new_rows() {
        let mut rng = Rng::new(6);
        let f = SpikeFrame::random(5, 3, 1, 0.5, &mut rng);
        let mut lb = LineBuffer::new(3, 3, 1);
        let mut ctr = AccessCounter::new();
        for py in 0..4 {
            ingest(&mut lb, &f, py, 0, &mut ctr);
        }
        // After 4 ingests the window covers image rows 1..3.
        assert_eq!(*lb.at(0, 0), f.vector(1, 0));
        assert_eq!(*lb.at(2, 2), f.vector(3, 2));
    }

    #[test]
    fn padding_rows_and_columns_are_zero() {
        let mut rng = Rng::new(9);
        let f = SpikeFrame::random(4, 4, 3, 0.9, &mut rng);
        let mut lb = LineBuffer::new(3, 6, 3);
        let mut ctr = AccessCounter::new();
        // Padded rows 0..3 with pad = 1: row 0 is the zero pad row.
        for py in 0..3 {
            ingest(&mut lb, &f, py, 1, &mut ctr);
        }
        for x in 0..6 {
            assert!(lb.at(0, x).is_empty(), "pad row not zero at {x}");
        }
        // Field row 1 = image row 0, shifted one column right.
        assert!(lb.at(1, 0).is_empty());
        assert_eq!(*lb.at(1, 1), f.vector(0, 0));
        assert!(lb.at(1, 5).is_empty());
    }

    #[test]
    fn traffic_accounting() {
        let f = SpikeFrame::zeros(3, 8, 4);
        let mut lb = LineBuffer::new(3, 8, 4);
        let mut ctr = AccessCounter::new();
        for py in 0..3 {
            ingest(&mut lb, &f, py, 0, &mut ctr);
        }
        // 3 rows x 8 vectors: one DRAM read + one BRAM write each.
        assert_eq!(ctr.reads_of(MemLevel::Dram, DataKind::InputSpike), 24);
        assert_eq!(ctr.writes_of(MemLevel::Bram, DataKind::InputSpike), 24);
        lb.count_window_read(3, &mut ctr);
        assert_eq!(ctr.reads_of(MemLevel::Bram, DataKind::InputSpike), 9);
        // Uncharged ingest (band-overlap refill) moves no counters.
        lb.ingest_row(&f, 0, 0, &mut ctr, true, false);
        assert_eq!(ctr.reads_of(MemLevel::Dram, DataKind::InputSpike), 24);
    }

    #[test]
    fn reset_forgets_rows_without_reallocating() {
        let mut rng = Rng::new(12);
        let f = SpikeFrame::random(4, 4, 2, 0.5, &mut rng);
        let mut lb = LineBuffer::new(3, 4, 2);
        let mut ctr = AccessCounter::new();
        for py in 0..3 {
            ingest(&mut lb, &f, py, 0, &mut ctr);
        }
        lb.reset();
        assert!(!lb.primed());
        for py in 1..4 {
            ingest(&mut lb, &f, py, 0, &mut ctr);
        }
        assert_eq!(*lb.at(0, 0), f.vector(1, 0));
    }
}
