//! Neuron module: threshold compare, fire, reset, Vmem buffer (Fig. 5).
//!
//! The accelerator uses IF neurons (paper Table V).  At T = 1 the psum
//! is compared against the threshold and discarded — no membrane
//! potential ever leaves the PE/adder-tree datapath.  At T > 1 the
//! updated potential must round-trip through the on-chip **Vmem
//! buffer** every timestep: this module owns that buffer and counts its
//! traffic (the cost Fig. 11 quantifies).
//!
//! Numerics: PEs accumulate int8 weights into i32; the threshold check
//! dequantises with the layer scale and adds the (float) bias:
//! `acc*scale + bias >= vth` — bit-identical to the L2 fake-quant graph.

use super::memory::{AccessCounter, DataKind, MemLevel};
use super::pe::Acc;

/// Per-layer neuron unit.
#[derive(Debug, Clone)]
pub struct NeuronUnit {
    pub vth: f32,
    pub scale: f32,
    pub bias: Vec<f32>,
    /// Membrane potentials (Ho*Wo*Co), allocated only when T > 1.
    vmem: Option<Vec<f32>>,
    n_neurons: usize,
}

impl NeuronUnit {
    pub fn new(vth: f32, scale: f32, bias: Vec<f32>, n_neurons: usize,
               timesteps: usize) -> Self {
        Self {
            vth,
            scale,
            bias,
            vmem: if timesteps > 1 {
                Some(vec![0.0; n_neurons])
            } else {
                None
            },
            n_neurons,
        }
    }

    /// Bytes of Vmem buffer this unit allocates (0 at T = 1 — Fig. 11).
    pub fn vmem_bytes(&self) -> usize {
        self.vmem.as_ref().map_or(0, |v| v.len() * 4)
    }

    /// Process one neuron's psum: integrate (+saved vmem), compare,
    /// fire, reset. `idx` is the flat (y*Wo + x)*Co + co index; `co`
    /// selects the bias lane. Returns the spike bit.
    #[inline]
    pub fn fire(&mut self, idx: usize, co: usize, psum: Acc,
                counters: &mut AccessCounter) -> bool {
        debug_assert!(idx < self.n_neurons);
        let current = psum as f32 * self.scale + self.bias[co];
        match self.vmem.as_mut() {
            None => {
                // T = 1: threshold on the live accumulator; no storage.
                current >= self.vth
            }
            Some(vm) => {
                // T > 1: read-modify-write the Vmem buffer (BRAM).
                counters.read(MemLevel::Bram, DataKind::Vmem, 1);
                let v = vm[idx] + current;
                let spike = v >= self.vth;
                vm[idx] = if spike { 0.0 } else { v };
                counters.write(MemLevel::Bram, DataKind::Vmem, 1);
                spike
            }
        }
    }

    /// Clear state between frames (potentials reset per input frame).
    pub fn reset(&mut self) {
        if let Some(vm) = self.vmem.as_mut() {
            vm.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// One band view covering every neuron (the serial path).
    pub fn band_all(&mut self) -> NeuronBand<'_> {
        NeuronBand {
            vth: self.vth,
            scale: self.scale,
            bias: &self.bias,
            vmem: self.vmem.as_deref_mut(),
            base: 0,
        }
    }

    /// One band view over global neuron indices `[start, end)` — the
    /// streaming executor's per-band view when bands run one at a time
    /// inside a layer worker (no concurrent split needed, so ranges
    /// need not tile the layer the way [`NeuronUnit::bands`] requires).
    pub fn band(&mut self, start: usize, end: usize) -> NeuronBand<'_> {
        assert!(start <= end && end <= self.n_neurons,
                "band out of range");
        NeuronBand {
            vth: self.vth,
            scale: self.scale,
            bias: &self.bias,
            vmem: self.vmem.as_deref_mut().map(|v| &mut v[start..end]),
            base: start,
        }
    }

    /// Split into per-band views over contiguous `[start, end)` global
    /// neuron index ranges (ascending, disjoint, starting at 0). Each
    /// band gets its own slice of the Vmem buffer, so intra-frame row
    /// bands can fire neurons from scoped worker threads without
    /// sharing mutable state.
    pub fn bands<'a>(&'a mut self, ranges: &[(usize, usize)])
                     -> Vec<NeuronBand<'a>> {
        let mut out = Vec::with_capacity(ranges.len());
        let mut vm_rest = self.vmem.as_deref_mut();
        let mut offset = 0usize;
        for &(start, end) in ranges {
            assert_eq!(start, offset, "bands must be contiguous");
            assert!(end >= start && end <= self.n_neurons,
                    "band out of range");
            let vmem = match vm_rest.take() {
                None => None,
                Some(r) => {
                    let (a, b) = r.split_at_mut(end - start);
                    vm_rest = Some(b);
                    Some(a)
                }
            };
            out.push(NeuronBand {
                vth: self.vth,
                scale: self.scale,
                bias: &self.bias,
                vmem,
                base: start,
            });
            offset = end;
        }
        out
    }
}

/// A view over one contiguous band of a layer's neurons — the unit of
/// intra-frame row parallelism. Bands hold disjoint Vmem slices, so
/// scoped worker threads fire neurons concurrently while traffic is
/// accounted per band (and merged deterministically).
pub struct NeuronBand<'a> {
    vth: f32,
    scale: f32,
    bias: &'a [f32],
    vmem: Option<&'a mut [f32]>,
    /// Global neuron index of this band's first Vmem slot.
    base: usize,
}

impl NeuronBand<'_> {
    /// Process one neuron's psum (global flat index `idx`): integrate,
    /// compare, fire, reset — identical semantics and Vmem traffic to
    /// [`NeuronUnit::fire`].
    #[inline]
    pub fn fire(&mut self, idx: usize, co: usize, psum: Acc,
                counters: &mut AccessCounter) -> bool {
        let current = psum as f32 * self.scale + self.bias[co];
        match self.vmem.as_deref_mut() {
            None => {
                // T = 1: threshold on the live accumulator; no storage.
                current >= self.vth
            }
            Some(vm) => {
                // T > 1: read-modify-write the Vmem buffer (BRAM).
                counters.read(MemLevel::Bram, DataKind::Vmem, 1);
                let v = vm[idx - self.base] + current;
                let spike = v >= self.vth;
                vm[idx - self.base] = if spike { 0.0 } else { v };
                counters.write(MemLevel::Bram, DataKind::Vmem, 1);
                spike
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(t: usize) -> NeuronUnit {
        NeuronUnit::new(1.0, 0.1, vec![0.0; 4], 16, t)
    }

    #[test]
    fn t1_no_vmem_allocated() {
        assert_eq!(unit(1).vmem_bytes(), 0);
        assert_eq!(unit(2).vmem_bytes(), 64);
    }

    #[test]
    fn t1_threshold_fire() {
        let mut n = unit(1);
        let mut c = AccessCounter::new();
        assert!(n.fire(0, 0, 10, &mut c)); // 10*0.1 = 1.0 >= 1.0
        assert!(!n.fire(0, 0, 9, &mut c)); // 0.9 < 1.0
        // T = 1 must generate zero vmem traffic.
        assert_eq!(c.total_of_kind(DataKind::Vmem), 0);
    }

    #[test]
    fn t2_accumulates_across_timesteps() {
        let mut n = unit(2);
        let mut c = AccessCounter::new();
        assert!(!n.fire(3, 0, 6, &mut c)); // v = 0.6
        assert!(n.fire(3, 0, 6, &mut c));  // v = 1.2 -> fire
        assert!(!n.fire(3, 0, 6, &mut c)); // reset to 0, v = 0.6
        // Each fire() at T>1 is one read + one write of the buffer.
        assert_eq!(c.reads_of(MemLevel::Bram, DataKind::Vmem), 3);
        assert_eq!(c.writes_of(MemLevel::Bram, DataKind::Vmem), 3);
    }

    #[test]
    fn bias_lane_applied() {
        let mut n = NeuronUnit::new(1.0, 0.1, vec![0.0, 100.0], 4, 1);
        let mut c = AccessCounter::new();
        assert!(!n.fire(0, 0, 0, &mut c));
        assert!(n.fire(1, 1, 0, &mut c)); // bias lane 1 pushes over vth
    }

    /// Band views reproduce the unit's semantics and traffic on the
    /// T > 1 (Vmem) path, with disjoint slices per band.
    #[test]
    fn bands_split_vmem_and_match_unit() {
        let mut whole = unit(2);
        let mut split = unit(2);
        let mut c_whole = AccessCounter::new();
        let mut c_split = AccessCounter::new();
        let want: Vec<bool> =
            (0..16).map(|i| whole.fire(i, i % 4, 6, &mut c_whole)).collect();
        let mut got = Vec::new();
        {
            let mut bands = split.bands(&[(0, 5), (5, 12), (12, 16)]);
            for (b, (s, e)) in [(0, (0, 5)), (1, (5, 12)), (2, (12, 16))] {
                for i in s..e {
                    got.push(bands[b].fire(i, i % 4, 6, &mut c_split));
                }
            }
        }
        assert_eq!(got, want);
        assert_eq!(c_whole, c_split);
    }

    #[test]
    fn reset_clears_potentials() {
        let mut n = unit(2);
        let mut c = AccessCounter::new();
        n.fire(0, 0, 6, &mut c);
        n.reset();
        // After reset the same sub-threshold input does not fire.
        assert!(!n.fire(0, 0, 6, &mut c));
    }
}
