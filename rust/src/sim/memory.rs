//! Memory hierarchy model: access counters per level and data kind.
//!
//! The paper's energy argument is a *traffic* argument (SectionII-C): what
//! matters is how many times each datum crosses each memory boundary.
//! Every engine in the simulator routes its accesses through an
//! [`AccessCounter`] so Tables I/III and Fig. 11 fall out of the run.
//!
//! The counter is a fixed `[MemLevel x DataKind]` array: a counter
//! touch in the innermost engine loop is one add into a 15-slot array
//! instead of a `BTreeMap` entry lookup (an allocation + tree walk per
//! touch; §Perf hot path).

/// Memory level crossed by an access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemLevel {
    /// Off-chip DDR4 (frames in/out, streaming weights for huge nets).
    Dram,
    /// On-chip BRAM (weight buffer, line buffer, Vmem buffer, FIFOs).
    Bram,
    /// PE-internal registers (membrane potential during OS accumulate).
    Reg,
}

impl MemLevel {
    /// Every level, in reporting order.
    pub const ALL: [MemLevel; 3] =
        [MemLevel::Dram, MemLevel::Bram, MemLevel::Reg];

    #[inline]
    fn index(self) -> usize {
        match self {
            MemLevel::Dram => 0,
            MemLevel::Bram => 1,
            MemLevel::Reg => 2,
        }
    }
}

/// What kind of datum the access moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DataKind {
    InputSpike,
    Weight,
    PartialSum,
    Vmem,
    OutputSpike,
}

impl DataKind {
    /// Every kind, in reporting order.
    pub const ALL: [DataKind; 5] = [
        DataKind::InputSpike,
        DataKind::Weight,
        DataKind::PartialSum,
        DataKind::Vmem,
        DataKind::OutputSpike,
    ];

    #[inline]
    fn index(self) -> usize {
        match self {
            DataKind::InputSpike => 0,
            DataKind::Weight => 1,
            DataKind::PartialSum => 2,
            DataKind::Vmem => 3,
            DataKind::OutputSpike => 4,
        }
    }
}

const SLOTS: usize = MemLevel::ALL.len() * DataKind::ALL.len();

#[inline]
fn slot(level: MemLevel, kind: DataKind) -> usize {
    level.index() * DataKind::ALL.len() + kind.index()
}

/// Read/write counts keyed by (level, kind) — fixed-slot arrays with
/// the same accessor surface the old map-backed counter had.
#[derive(Debug, Clone, PartialEq)]
pub struct AccessCounter {
    reads: [u64; SLOTS],
    writes: [u64; SLOTS],
}

impl Default for AccessCounter {
    fn default() -> Self {
        Self { reads: [0; SLOTS], writes: [0; SLOTS] }
    }
}

impl AccessCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn read(&mut self, level: MemLevel, kind: DataKind, n: u64) {
        self.reads[slot(level, kind)] += n;
    }

    #[inline]
    pub fn write(&mut self, level: MemLevel, kind: DataKind, n: u64) {
        self.writes[slot(level, kind)] += n;
    }

    pub fn reads_of(&self, level: MemLevel, kind: DataKind) -> u64 {
        self.reads[slot(level, kind)]
    }

    pub fn writes_of(&self, level: MemLevel, kind: DataKind) -> u64 {
        self.writes[slot(level, kind)]
    }

    /// Total accesses (reads + writes) of a kind across all levels.
    pub fn total_of_kind(&self, kind: DataKind) -> u64 {
        MemLevel::ALL
            .into_iter()
            .map(|l| self.reads[slot(l, kind)] + self.writes[slot(l, kind)])
            .sum()
    }

    /// Total accesses at a level.
    pub fn total_at_level(&self, level: MemLevel) -> u64 {
        DataKind::ALL
            .into_iter()
            .map(|k| {
                self.reads[slot(level, k)] + self.writes[slot(level, k)]
            })
            .sum()
    }

    pub fn merge(&mut self, other: &AccessCounter) {
        for i in 0..SLOTS {
            self.reads[i] += other.reads[i];
            self.writes[i] += other.writes[i];
        }
    }

    /// Iterate every `(level, kind, reads, writes)` slot (zeros
    /// included) in deterministic reporting order.
    pub fn iter(&self)
                -> impl Iterator<Item = (MemLevel, DataKind, u64, u64)> + '_
    {
        MemLevel::ALL.into_iter().flat_map(move |l| {
            DataKind::ALL.into_iter().map(move |k| {
                (l, k, self.reads[slot(l, k)], self.writes[slot(l, k)])
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut c = AccessCounter::new();
        c.read(MemLevel::Bram, DataKind::Weight, 10);
        c.read(MemLevel::Bram, DataKind::Weight, 5);
        c.write(MemLevel::Dram, DataKind::Vmem, 3);
        assert_eq!(c.reads_of(MemLevel::Bram, DataKind::Weight), 15);
        assert_eq!(c.writes_of(MemLevel::Dram, DataKind::Vmem), 3);
        assert_eq!(c.total_of_kind(DataKind::Weight), 15);
        assert_eq!(c.total_at_level(MemLevel::Dram), 3);
    }

    #[test]
    fn merge_sums() {
        let mut a = AccessCounter::new();
        a.read(MemLevel::Reg, DataKind::PartialSum, 7);
        let mut b = AccessCounter::new();
        b.read(MemLevel::Reg, DataKind::PartialSum, 5);
        b.write(MemLevel::Bram, DataKind::InputSpike, 1);
        a.merge(&b);
        assert_eq!(a.reads_of(MemLevel::Reg, DataKind::PartialSum), 12);
        assert_eq!(a.writes_of(MemLevel::Bram, DataKind::InputSpike), 1);
    }

    #[test]
    fn iter_covers_every_slot_in_order() {
        let mut c = AccessCounter::new();
        c.read(MemLevel::Dram, DataKind::InputSpike, 2);
        c.write(MemLevel::Reg, DataKind::OutputSpike, 9);
        let all: Vec<_> = c.iter().collect();
        assert_eq!(all.len(), SLOTS);
        assert_eq!(all[0], (MemLevel::Dram, DataKind::InputSpike, 2, 0));
        assert_eq!(all[SLOTS - 1],
                   (MemLevel::Reg, DataKind::OutputSpike, 0, 9));
        let total_r: u64 = all.iter().map(|(_, _, r, _)| r).sum();
        let total_w: u64 = all.iter().map(|(_, _, _, w)| w).sum();
        assert_eq!((total_r, total_w), (2, 9));
    }
}
