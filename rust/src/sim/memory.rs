//! Memory hierarchy model: access counters per level and data kind.
//!
//! The paper's energy argument is a *traffic* argument (SectionII-C): what
//! matters is how many times each datum crosses each memory boundary.
//! Every engine in the simulator routes its accesses through an
//! [`AccessCounter`] so Tables I/III and Fig. 11 fall out of the run.

use std::collections::BTreeMap;

/// Memory level crossed by an access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemLevel {
    /// Off-chip DDR4 (frames in/out, streaming weights for huge nets).
    Dram,
    /// On-chip BRAM (weight buffer, line buffer, Vmem buffer, FIFOs).
    Bram,
    /// PE-internal registers (membrane potential during OS accumulate).
    Reg,
}

/// What kind of datum the access moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DataKind {
    InputSpike,
    Weight,
    PartialSum,
    Vmem,
    OutputSpike,
}

/// Read/write counts keyed by (level, kind).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AccessCounter {
    pub reads: BTreeMap<(MemLevel, DataKind), u64>,
    pub writes: BTreeMap<(MemLevel, DataKind), u64>,
}

impl AccessCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn read(&mut self, level: MemLevel, kind: DataKind, n: u64) {
        *self.reads.entry((level, kind)).or_insert(0) += n;
    }

    #[inline]
    pub fn write(&mut self, level: MemLevel, kind: DataKind, n: u64) {
        *self.writes.entry((level, kind)).or_insert(0) += n;
    }

    pub fn reads_of(&self, level: MemLevel, kind: DataKind) -> u64 {
        self.reads.get(&(level, kind)).copied().unwrap_or(0)
    }

    pub fn writes_of(&self, level: MemLevel, kind: DataKind) -> u64 {
        self.writes.get(&(level, kind)).copied().unwrap_or(0)
    }

    /// Total accesses (reads + writes) of a kind across all levels.
    pub fn total_of_kind(&self, kind: DataKind) -> u64 {
        let r: u64 = self
            .reads
            .iter()
            .filter(|((_, k), _)| *k == kind)
            .map(|(_, v)| v)
            .sum();
        let w: u64 = self
            .writes
            .iter()
            .filter(|((_, k), _)| *k == kind)
            .map(|(_, v)| v)
            .sum();
        r + w
    }

    /// Total accesses at a level.
    pub fn total_at_level(&self, level: MemLevel) -> u64 {
        let r: u64 = self
            .reads
            .iter()
            .filter(|((l, _), _)| *l == level)
            .map(|(_, v)| v)
            .sum();
        let w: u64 = self
            .writes
            .iter()
            .filter(|((l, _), _)| *l == level)
            .map(|(_, v)| v)
            .sum();
        r + w
    }

    pub fn merge(&mut self, other: &AccessCounter) {
        for (k, v) in &other.reads {
            *self.reads.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &other.writes {
            *self.writes.entry(*k).or_insert(0) += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut c = AccessCounter::new();
        c.read(MemLevel::Bram, DataKind::Weight, 10);
        c.read(MemLevel::Bram, DataKind::Weight, 5);
        c.write(MemLevel::Dram, DataKind::Vmem, 3);
        assert_eq!(c.reads_of(MemLevel::Bram, DataKind::Weight), 15);
        assert_eq!(c.writes_of(MemLevel::Dram, DataKind::Vmem), 3);
        assert_eq!(c.total_of_kind(DataKind::Weight), 15);
        assert_eq!(c.total_at_level(MemLevel::Dram), 3);
    }

    #[test]
    fn merge_sums() {
        let mut a = AccessCounter::new();
        a.read(MemLevel::Reg, DataKind::PartialSum, 7);
        let mut b = AccessCounter::new();
        b.read(MemLevel::Reg, DataKind::PartialSum, 5);
        b.write(MemLevel::Bram, DataKind::InputSpike, 1);
        a.merge(&b);
        assert_eq!(a.reads_of(MemLevel::Reg, DataKind::PartialSum), 12);
        assert_eq!(a.writes_of(MemLevel::Bram, DataKind::InputSpike), 1);
    }
}
