//! Cycle-level simulator of the STI-SNN accelerator microarchitecture.
//!
//! This is the DESIGN.md substitution for the paper's ZCU102 FPGA: the
//! same microarchitecture (multi-mode PE array, line buffer, neuron
//! unit, OS dataflow, layer-wise pipeline) expressed as a simulator
//! whose **counters** (cycles, memory accesses, energy, resources) are
//! the quantities the paper's evaluation reports.
//!
//! Functional behaviour (which spikes come out) is bit-exact against
//! the L1/L2 reference semantics — validated by `rust/tests/` against
//! vectors exported from python.
//!
//! Every per-layer engine implements the [`engine::LayerEngine`]
//! trait; the coordinator's pipeline and the session facade compose
//! engines exclusively through it.

pub mod array;
pub mod backend;
pub mod conv_engine;
pub mod energy;
pub mod engine;
pub mod fc_engine;
pub mod fifo;
pub mod linebuf;
pub mod memory;
pub mod neuron;
pub mod pe;
pub mod pool_engine;
pub mod resources;
pub mod ws_engine;

pub use backend::BackendKind;
pub use conv_engine::ConvEngine;
pub use energy::{EnergyModel, EnergyReport};
pub use engine::{LayerEngine, LayerOutput, LayerResult, LayerStep,
                 LayerWeights};
pub use fc_engine::FcEngine;
pub use memory::{AccessCounter, DataKind, MemLevel};
pub use pool_engine::PoolEngine;
pub use resources::{ResourceModel, ResourceReport, Zcu102};
pub use ws_engine::WsEngine;

/// Design clock of the paper's implementation (Table V): 200 MHz.
pub const CLK_HZ: f64 = 200e6;

/// Cycles -> milliseconds at the design clock.
pub fn cycles_to_ms(cycles: u64) -> f64 {
    cycles as f64 / CLK_HZ * 1e3
}

/// Cycles -> seconds at the design clock.
pub fn cycles_to_s(cycles: u64) -> f64 {
    cycles as f64 / CLK_HZ
}
