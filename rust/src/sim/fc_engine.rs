//! Fully-connected (classifier) engine.
//!
//! The head consumes the flattened, channel-sorted spike vector of the
//! final feature map and accumulates int8 weight rows for active
//! inputs — a gather-accumulate, which is exactly how the FPGA
//! implements it (weights fetched only for spiking inputs: the
//! event-driven win). Output neurons never fire; the i32 accumulators
//! (dequantised + bias) are the logits.

use crate::codec::SpikeFrame;

use super::memory::{AccessCounter, DataKind, MemLevel};

#[derive(Debug, Clone, Default)]
pub struct FcRunReport {
    pub cycles: u64,
    pub ops: u64,
    pub counters: AccessCounter,
}

pub struct FcEngine {
    pub n_in: usize,
    pub n_out: usize,
    pub scale: f32,
    /// Row-major `[n_in][n_out]` int8.
    weights: Vec<i8>,
    pub bias: Vec<f32>,
}

impl FcEngine {
    pub fn new(n_in: usize, n_out: usize, weights: Vec<i8>, scale: f32,
               bias: Vec<f32>) -> Self {
        assert_eq!(weights.len(), n_in * n_out);
        assert_eq!(bias.len(), n_out);
        Self { n_in, n_out, scale, weights, bias }
    }

    pub fn random(n_in: usize, n_out: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let weights = (0..n_in * n_out).map(|_| rng.int8()).collect();
        Self {
            n_in,
            n_out,
            scale: 1.0 / 127.0 / (n_in as f32).sqrt(),
            weights,
            bias: vec![0.0; n_out],
        }
    }

    /// Flatten a (H, W, C) spike frame in channel-last order — must
    /// match python's `act.reshape(-1)` on (H, W, C).
    pub fn flatten(frame: &SpikeFrame) -> Vec<bool> {
        let mut out = Vec::with_capacity(frame.h * frame.w * frame.c);
        for y in 0..frame.h {
            for x in 0..frame.w {
                for ch in 0..frame.c {
                    out.push(frame.get(y, x, ch));
                }
            }
        }
        out
    }

    /// One timestep: returns logits. Event-driven: only active inputs
    /// cost weight fetches + accumulates.
    pub fn run(&self, spikes: &[bool]) -> (Vec<f32>, FcRunReport) {
        assert_eq!(spikes.len(), self.n_in);
        let mut acc = vec![0i64; self.n_out];
        let mut rep = FcRunReport::default();
        for (i, &s) in spikes.iter().enumerate() {
            rep.cycles += 1; // input scan
            if !s {
                continue;
            }
            let row = &self.weights[i * self.n_out..(i + 1) * self.n_out];
            rep.counters.read(MemLevel::Bram, DataKind::Weight, 1);
            for (o, &w) in row.iter().enumerate() {
                acc[o] += w as i64;
            }
            rep.ops += self.n_out as u64;
        }
        let logits: Vec<f32> = acc
            .iter()
            .zip(&self.bias)
            .map(|(&a, &b)| a as f32 * self.scale + b)
            .collect();
        rep.counters.write(MemLevel::Bram, DataKind::OutputSpike,
                           self.n_out as u64);
        (logits, rep)
    }

    /// Accumulate logits across timesteps then argmax (SDT readout).
    pub fn classify(&self, frames: &[Vec<bool>]) -> (usize, FcRunReport) {
        let mut total = vec![0f32; self.n_out];
        let mut rep = FcRunReport::default();
        for f in frames {
            let (l, r) = self.run(f);
            for (t, v) in total.iter_mut().zip(&l) {
                *t += v;
            }
            rep.cycles += r.cycles;
            rep.ops += r.ops;
            rep.counters.merge(&r.counters);
        }
        let arg = total
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        (arg, rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_spike_selects_row() {
        let mut w = vec![0i8; 4 * 3];
        w[1 * 3..2 * 3].copy_from_slice(&[1, 2, 3]);
        let fc = FcEngine::new(4, 3, w, 1.0, vec![0.0; 3]);
        let mut spikes = vec![false; 4];
        spikes[1] = true;
        let (logits, rep) = fc.run(&spikes);
        assert_eq!(logits, vec![1.0, 2.0, 3.0]);
        assert_eq!(rep.ops, 3);
    }

    #[test]
    fn no_spikes_costs_no_weight_reads() {
        let fc = FcEngine::random(16, 4, 1);
        let (logits, rep) = fc.run(&vec![false; 16]);
        assert!(logits.iter().all(|&l| l == 0.0));
        assert_eq!(rep.counters.reads_of(MemLevel::Bram, DataKind::Weight), 0);
        assert_eq!(rep.ops, 0);
        assert_eq!(rep.cycles, 16); // scan still happens
    }

    #[test]
    fn classify_accumulates_timesteps() {
        let mut w = vec![0i8; 2 * 2];
        w[0] = 10; // input 0 votes class 0
        w[3] = 6;  // input 1 votes class 1
        let fc = FcEngine::new(2, 2, w, 1.0, vec![0.0; 2]);
        // Two timesteps of input-1 spikes beat one of input-0.
        let (cls, _) = fc.classify(&[
            vec![true, false],
            vec![false, true],
            vec![false, true],
        ]);
        assert_eq!(cls, 1);
    }

    #[test]
    fn flatten_is_channel_last() {
        let mut f = SpikeFrame::zeros(2, 2, 3);
        f.set(0, 1, 2); // flat index (0*2+1)*3 + 2 = 5
        let flat = FcEngine::flatten(&f);
        assert!(flat[5]);
        assert_eq!(flat.iter().filter(|&&b| b).count(), 1);
    }
}
