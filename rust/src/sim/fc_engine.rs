//! Fully-connected (classifier) engine.
//!
//! The head consumes the flattened, channel-sorted spike vector of the
//! final feature map and accumulates int8 weight rows for active
//! inputs — a gather-accumulate, which is exactly how the FPGA
//! implements it (weights fetched only for spiking inputs: the
//! event-driven win). Output neurons never fire; the i32 accumulators
//! (dequantised + bias) are the logits.
//!
//! Like the conv engine, the functional accumulate is delegated to a
//! [`FcCompute`](super::backend::FcCompute) backend (event-driven row
//! gather or word-parallel bit-plane popcount); reports are identical
//! across backends because cycles / ops / weight traffic depend only
//! on the spike pattern.

use crate::codec::SpikeFrame;

use super::backend::{fc_backend, BackendKind, FcCompute};
use super::memory::{DataKind, MemLevel};

/// Per-run report — the unified
/// [`LayerStep`](super::engine::LayerStep) every layer engine shares
/// (`out_spikes` stays 0: output neurons never fire).
pub type FcRunReport = super::engine::LayerStep;

pub struct FcEngine {
    pub n_in: usize,
    pub n_out: usize,
    pub scale: f32,
    /// Row-major `[n_in][n_out]` int8.
    weights: Vec<i8>,
    pub bias: Vec<f32>,
    backend: Box<dyn FcCompute>,
    timesteps: usize,
    /// Reusable flatten buffer (the zero-allocation serving path never
    /// rebuilds the packed input vector; §Perf).
    flat: Vec<bool>,
    /// Reusable per-class accumulators.
    acc: Vec<i64>,
}

impl FcEngine {
    pub fn new(n_in: usize, n_out: usize, weights: Vec<i8>, scale: f32,
               bias: Vec<f32>) -> Self {
        assert_eq!(weights.len(), n_in * n_out);
        assert_eq!(bias.len(), n_out);
        let backend = fc_backend(BackendKind::Accurate, n_in, n_out,
                                 &weights);
        Self {
            n_in,
            n_out,
            scale,
            weights,
            bias,
            backend,
            timesteps: 1,
            flat: vec![false; n_in],
            acc: vec![0; n_out],
        }
    }

    /// Configure the SDT-readout timestep count (the final spike map
    /// replays per timestep when the trait runs the engine).
    pub fn with_timesteps(mut self, timesteps: usize) -> Self {
        self.timesteps = timesteps.max(1);
        self
    }

    /// Configured inference timesteps.
    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    pub fn random(n_in: usize, n_out: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let weights: Vec<i8> =
            (0..n_in * n_out).map(|_| rng.int8()).collect();
        Self::new(n_in, n_out, weights,
                  1.0 / 127.0 / (n_in as f32).sqrt(), vec![0.0; n_out])
    }

    /// Swap the functional compute backend (bit-exact across kinds).
    pub fn with_backend(mut self, kind: BackendKind) -> Self {
        self.backend = fc_backend(kind, self.n_in, self.n_out,
                                  &self.weights);
        self
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Flatten a (H, W, C) spike frame in channel-last order — must
    /// match python's `act.reshape(-1)` on (H, W, C).
    pub fn flatten(frame: &SpikeFrame) -> Vec<bool> {
        let mut out = Vec::with_capacity(frame.h * frame.w * frame.c);
        for y in 0..frame.h {
            for x in 0..frame.w {
                for ch in 0..frame.c {
                    out.push(frame.get(y, x, ch));
                }
            }
        }
        out
    }

    /// One timestep: returns logits. Event-driven: only active inputs
    /// cost weight fetches + accumulates.
    pub fn run(&mut self, spikes: &[bool]) -> (Vec<f32>, FcRunReport) {
        assert_eq!(spikes.len(), self.n_in);
        let mut acc = vec![0i64; self.n_out];
        let mut rep = FcRunReport::default();
        let active = self.backend.accumulate(spikes, &self.weights,
                                             self.n_out, &mut acc);
        // Architectural accounting — identical for every backend: the
        // input scan costs one cycle per input; each active input costs
        // one weight-row fetch and n_out accumulates.
        rep.cycles = self.n_in as u64;
        rep.ops = active * self.n_out as u64;
        if active > 0 {
            rep.counters.read(MemLevel::Bram, DataKind::Weight, active);
        }
        let logits: Vec<f32> = acc
            .iter()
            .zip(&self.bias)
            .map(|(&a, &b)| a as f32 * self.scale + b)
            .collect();
        rep.counters.write(MemLevel::Bram, DataKind::OutputSpike,
                           self.n_out as u64);
        (logits, rep)
    }

    /// Classify one frame with the SDT readout (the same final spike
    /// map replays per timestep — upstream already accumulated):
    /// argmax class, accumulated logits, merged report. Flattens into
    /// engine-owned scratch, so the serving hot path performs no
    /// per-frame flatten/replay allocations (the returned logits
    /// vector aside). Bit-identical — spikes, logits, and report — to
    /// [`FcEngine::flatten`] + [`FcEngine::classify_full`] over
    /// `timesteps` copies.
    pub fn classify_frame(&mut self, frame: &SpikeFrame)
                          -> (usize, Vec<f32>, FcRunReport) {
        for y in 0..frame.h {
            self.stage_row(frame, y);
        }
        self.classify_flat()
    }

    /// Row-granular streaming: stage input row `y` into the
    /// engine-owned flatten scratch (channel-last order, matching
    /// [`FcEngine::flatten`]). The inter-layer streaming executor
    /// calls this as upstream rows land, then
    /// [`FcEngine::classify_flat`] once the frame is complete.
    pub fn stage_row(&mut self, frame: &SpikeFrame, y: usize) {
        assert_eq!(frame.h * frame.w * frame.c, self.n_in);
        let mut i = y * frame.w * frame.c;
        for x in 0..frame.w {
            for ch in 0..frame.c {
                self.flat[i] = frame.get(y, x, ch);
                i += 1;
            }
        }
    }

    /// Classify the staged flatten scratch — the SDT-readout tail of
    /// [`FcEngine::classify_frame`], exposed for the streaming path.
    pub fn classify_flat(&mut self) -> (usize, Vec<f32>, FcRunReport) {
        let (n_in, n_out, scale) = (self.n_in, self.n_out, self.scale);
        let mut total = vec![0f32; n_out];
        let mut rep = FcRunReport::default();
        for _ in 0..self.timesteps {
            for a in self.acc.iter_mut() {
                *a = 0;
            }
            let active = {
                let Self { backend, weights, flat, acc, .. } = &mut *self;
                backend.accumulate(flat.as_slice(), weights.as_slice(),
                                   n_out, acc.as_mut_slice())
            };
            rep.cycles += n_in as u64;
            rep.ops += active * n_out as u64;
            if active > 0 {
                rep.counters.read(MemLevel::Bram, DataKind::Weight,
                                  active);
            }
            for ((t, &a), &b) in total
                .iter_mut()
                .zip(self.acc.iter())
                .zip(self.bias.iter())
            {
                *t += a as f32 * scale + b;
            }
            rep.counters.write(MemLevel::Bram, DataKind::OutputSpike,
                               n_out as u64);
        }
        let arg = total
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        (arg, total, rep)
    }

    /// Accumulate logits across timesteps (SDT readout): returns the
    /// argmax class, the accumulated logits, and the merged report.
    pub fn classify_full(&mut self, frames: &[Vec<bool>])
                         -> (usize, Vec<f32>, FcRunReport) {
        let mut total = vec![0f32; self.n_out];
        let mut rep = FcRunReport::default();
        for f in frames {
            let (l, r) = self.run(f);
            for (t, v) in total.iter_mut().zip(&l) {
                *t += v;
            }
            rep.cycles += r.cycles;
            rep.ops += r.ops;
            rep.counters.merge(&r.counters);
        }
        let arg = total
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        (arg, total, rep)
    }

    /// Accumulate logits across timesteps then argmax (SDT readout).
    pub fn classify(&mut self, frames: &[Vec<bool>])
                    -> (usize, FcRunReport) {
        let (arg, _, rep) = self.classify_full(frames);
        (arg, rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_spike_selects_row() {
        let mut w = vec![0i8; 4 * 3];
        w[1 * 3..2 * 3].copy_from_slice(&[1, 2, 3]);
        let mut fc = FcEngine::new(4, 3, w, 1.0, vec![0.0; 3]);
        let mut spikes = vec![false; 4];
        spikes[1] = true;
        let (logits, rep) = fc.run(&spikes);
        assert_eq!(logits, vec![1.0, 2.0, 3.0]);
        assert_eq!(rep.ops, 3);
    }

    #[test]
    fn no_spikes_costs_no_weight_reads() {
        let mut fc = FcEngine::random(16, 4, 1);
        let (logits, rep) = fc.run(&vec![false; 16]);
        assert!(logits.iter().all(|&l| l == 0.0));
        assert_eq!(rep.counters.reads_of(MemLevel::Bram, DataKind::Weight), 0);
        assert_eq!(rep.ops, 0);
        assert_eq!(rep.cycles, 16); // scan still happens
    }

    #[test]
    fn classify_accumulates_timesteps() {
        let mut w = vec![0i8; 2 * 2];
        w[0] = 10; // input 0 votes class 0
        w[3] = 6;  // input 1 votes class 1
        let mut fc = FcEngine::new(2, 2, w, 1.0, vec![0.0; 2]);
        // Two timesteps of input-1 spikes beat one of input-0.
        let (cls, _) = fc.classify(&[
            vec![true, false],
            vec![false, true],
            vec![false, true],
        ]);
        assert_eq!(cls, 1);
    }

    #[test]
    fn flatten_is_channel_last() {
        let mut f = SpikeFrame::zeros(2, 2, 3);
        f.set(0, 1, 2); // flat index (0*2+1)*3 + 2 = 5
        let flat = FcEngine::flatten(&f);
        assert!(flat[5]);
        assert_eq!(flat.iter().filter(|&&b| b).count(), 1);
    }

    /// The zero-alloc classify_frame path equals flatten +
    /// classify_full over replayed timesteps — logits AND report.
    #[test]
    fn classify_frame_matches_classify_full() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for timesteps in [1usize, 3] {
            let frame = SpikeFrame::random(3, 4, 5, 0.35, &mut rng);
            let mut a = FcEngine::random(60, 7, 9)
                .with_timesteps(timesteps);
            let mut b = FcEngine::random(60, 7, 9)
                .with_timesteps(timesteps);
            let flat = FcEngine::flatten(&frame);
            let reps: Vec<Vec<bool>> =
                (0..timesteps).map(|_| flat.clone()).collect();
            let (cls_a, logits_a, rep_a) = a.classify_full(&reps);
            let (cls_b, logits_b, rep_b) = b.classify_frame(&frame);
            assert_eq!(cls_a, cls_b, "T={timesteps}");
            assert_eq!(logits_a, logits_b, "T={timesteps}");
            assert_eq!(rep_a, rep_b, "T={timesteps}");
        }
    }

    /// Both backends produce identical logits + identical reports on
    /// random weights and spike patterns.
    #[test]
    fn word_parallel_fc_matches_accurate() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for trial in 0..10 {
            let n_in = 1 + rng.below(300);
            let n_out = 1 + rng.below(12);
            let mut acc_fc = FcEngine::random(n_in, n_out, 100 + trial);
            let mut wp_fc = FcEngine::random(n_in, n_out, 100 + trial)
                .with_backend(BackendKind::WordParallel);
            let spikes: Vec<bool> =
                (0..n_in).map(|_| rng.bernoulli(0.3)).collect();
            let (la, ra) = acc_fc.run(&spikes);
            let (lw, rw) = wp_fc.run(&spikes);
            assert_eq!(la, lw, "trial {trial}");
            assert_eq!(ra, rw, "trial {trial}");
        }
    }
}
