//! Pooling layer engine: 2x2 stride-2 logical-OR on spike vectors,
//! staged through the line buffer + register pair (paper Fig. 7b).

use crate::codec::{SpikeFrame, SpikeVector};

use super::memory::{DataKind, MemLevel};

/// Per-run report — the unified
/// [`LayerStep`](super::engine::LayerStep) every layer engine shares
/// (`ops` and `out_spikes` stay 0 here: OR gates are not synaptic ops).
pub type PoolRunReport = super::engine::LayerStep;

pub struct PoolEngine {
    pub in_h: usize,
    pub in_w: usize,
    pub c: usize,
    timesteps: usize,
    /// Reusable OR-reduce register (the Fig. 7b register pair — and
    /// the zero-allocation hot path's only scratch).
    acc: SpikeVector,
    /// Streamed-frame cost accumulator (row-granular entry points).
    step: PoolRunReport,
}

impl PoolEngine {
    pub fn new(in_h: usize, in_w: usize, c: usize) -> Self {
        assert!(in_h % 2 == 0 && in_w % 2 == 0,
                "OR pooling needs even dimensions");
        Self {
            in_h,
            in_w,
            c,
            timesteps: 1,
            acc: SpikeVector::zeros(c),
            step: PoolRunReport::default(),
        }
    }

    /// Configure the inference timestep count (the pooling pass
    /// repeats per timestep in the pipeline's cycle accounting).
    pub fn with_timesteps(mut self, timesteps: usize) -> Self {
        self.timesteps = timesteps.max(1);
        self
    }

    /// Configured inference timesteps.
    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    pub fn run(&mut self, input: &SpikeFrame)
               -> (SpikeFrame, PoolRunReport) {
        let mut out =
            SpikeFrame::zeros(self.in_h / 2, self.in_w / 2, self.c);
        let rep = self.run_into(input, &mut out);
        (out, rep)
    }

    /// Pool into the caller-owned `out` frame (reshaped as needed) —
    /// the zero-allocation hot path.
    pub fn run_into(&mut self, input: &SpikeFrame, out: &mut SpikeFrame)
                    -> PoolRunReport {
        assert_eq!((input.h, input.w, input.c),
                   (self.in_h, self.in_w, self.c));
        let (ho, wo) = (self.in_h / 2, self.in_w / 2);
        out.reset(ho, wo, self.c);
        let mut rep = PoolRunReport::default();
        for oy in 0..ho {
            Self::pool_row(&mut self.acc, wo, input, oy, out, &mut rep);
        }
        rep
    }

    /// One output row of the 2x2 OR pool — shared by the whole-frame
    /// pass and the row-granular streaming path (identical charge
    /// order, so the streamed report is bit-identical).
    fn pool_row(acc: &mut SpikeVector, wo: usize, input: &SpikeFrame,
                oy: usize, out: &mut SpikeFrame,
                rep: &mut PoolRunReport) {
        for ox in 0..wo {
            // Fig. 7b: four vector reads, OR reduce, one write —
            // word-level, into the reusable register.
            input.vector_into(2 * oy, 2 * ox, acc);
            input.or_vector_into(2 * oy, 2 * ox + 1, acc);
            input.or_vector_into(2 * oy + 1, 2 * ox, acc);
            input.or_vector_into(2 * oy + 1, 2 * ox + 1, acc);
            rep.counters.read(MemLevel::Bram, DataKind::InputSpike, 4);
            out.set_vector(oy, ox, acc);
            rep.counters.write(MemLevel::Bram, DataKind::OutputSpike, 1);
            rep.cycles += 1; // one output vector per cycle
        }
    }

    /// Row-granular streaming, part 1: arm a new frame.
    pub(crate) fn stream_begin(&mut self) {
        self.step = PoolRunReport::default();
    }

    /// Row-granular streaming, part 2: input row `y` is in; every odd
    /// row completes one output row. Returns the completed output-row
    /// prefix.
    pub(crate) fn stream_row(&mut self, input: &SpikeFrame, y: usize,
                             out: &mut SpikeFrame) -> usize {
        assert_eq!((input.h, input.w, input.c),
                   (self.in_h, self.in_w, self.c));
        if y % 2 == 1 {
            Self::pool_row(&mut self.acc, self.in_w / 2, input, y / 2,
                           out, &mut self.step);
        }
        (y + 1) / 2
    }

    /// Row-granular streaming, part 3: the timestep replay multiplier
    /// and spike count, exactly as the whole-frame path reports them.
    pub(crate) fn stream_finish(&mut self, out: &SpikeFrame)
                                -> PoolRunReport {
        let mut rep = std::mem::take(&mut self.step);
        rep.cycles *= self.timesteps as u64;
        rep.out_spikes = out.count() as u64;
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn or_pooling_semantics() {
        let mut f = SpikeFrame::zeros(4, 4, 2);
        f.set(0, 1, 0); // one spike in the top-left window, channel 0
        f.set(3, 3, 1); // one in bottom-right, channel 1
        let (out, _) = PoolEngine::new(4, 4, 2).run(&f);
        assert!(out.get(0, 0, 0));
        assert!(!out.get(0, 0, 1));
        assert!(out.get(1, 1, 1));
        assert_eq!(out.count(), 2);
    }

    #[test]
    fn cycle_count_is_output_pixels() {
        let mut rng = Rng::new(1);
        let f = SpikeFrame::random(8, 8, 4, 0.3, &mut rng);
        let (_, rep) = PoolEngine::new(8, 8, 4).run(&f);
        assert_eq!(rep.cycles, 16);
    }

    #[test]
    fn rate_never_decreases() {
        let mut rng = Rng::new(2);
        let f = SpikeFrame::random(16, 16, 8, 0.2, &mut rng);
        let (out, _) = PoolEngine::new(16, 16, 8).run(&f);
        assert!(out.rate() >= f.rate());
    }

    #[test]
    #[should_panic]
    fn odd_dims_rejected() {
        PoolEngine::new(7, 8, 1);
    }
}
