//! Pareto-frontier extraction over evaluated cost points.
//!
//! Objectives are the minimisation vector of
//! [`CostPoint::objectives`]: pool interval (throughput), per-frame
//! latency, energy per frame, and LUTs. The frontier keeps every
//! non-dominated point, deduplicates identical objective vectors with
//! a deterministic preference order (measured-faster host backend
//! first, then fewer replicas, then lexicographic factors, then
//! backend name), and is itself deterministically ordered — the same
//! inputs always produce the same frontier.

use std::cmp::Ordering;

use super::evaluate::CostPoint;

/// Strict Pareto dominance for minimisation: `a` is no worse anywhere
/// and strictly better somewhere.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Deterministic total order over precomputed objective vectors:
/// objectives lexicographically, then the tie-break preferences
/// documented at module level.
fn order_by(oa: &[f64; 4], ob: &[f64; 4], a: &CostPoint, b: &CostPoint)
            -> Ordering {
    for (x, y) in oa.iter().zip(ob) {
        match x.total_cmp(y) {
            Ordering::Equal => {}
            o => return o,
        }
    }
    let ha = a.host_ns_per_frame.unwrap_or(f64::INFINITY);
    let hb = b.host_ns_per_frame.unwrap_or(f64::INFINITY);
    ha.total_cmp(&hb)
        .then(a.candidate.replicas.cmp(&b.candidate.replicas))
        .then_with(|| a.candidate.factors.cmp(&b.candidate.factors))
        .then_with(|| {
            a.candidate.backend.name().cmp(b.candidate.backend.name())
        })
}

/// Deterministic total order between two points.
fn order(a: &CostPoint, b: &CostPoint) -> Ordering {
    order_by(&a.objectives(), &b.objectives(), a, b)
}

/// Non-dominated subset of `points`, deduplicated and deterministically
/// ordered. Objectives are computed once per point (the scan itself is
/// all-pairs).
pub fn pareto_frontier(points: &[CostPoint]) -> Vec<CostPoint> {
    let objs: Vec<[f64; 4]> = points.iter().map(|p| p.objectives()).collect();
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        order_by(&objs[a], &objs[b], &points[a], &points[b])
    });
    let mut front: Vec<CostPoint> = Vec::new();
    let mut front_objs: Vec<[f64; 4]> = Vec::new();
    'outer: for &i in &idx {
        for j in 0..points.len() {
            if j != i && dominates(&objs[j], &objs[i]) {
                continue 'outer;
            }
        }
        if front_objs.contains(&objs[i]) {
            continue; // duplicate metrics: the preferred variant is
                      // already in (sorted order put it first)
        }
        front.push(points[i].clone());
        front_objs.push(objs[i]);
    }
    front
}

/// Serving choice: the fitting point with the highest pool throughput;
/// ties fall to lower energy, then fewer LUTs, then the deterministic
/// preference order. Evaluated over every point (not just the
/// frontier) so a feasible choice survives even when the unconstrained
/// frontier is dominated by designs that do not fit the device.
pub fn choose(points: &[CostPoint]) -> Option<CostPoint> {
    points
        .iter()
        .filter(|p| p.fits)
        .max_by(|a, b| {
            a.pool_fps
                .total_cmp(&b.pool_fps)
                .then_with(|| {
                    b.energy_per_frame_j.total_cmp(&a.energy_per_frame_j)
                })
                .then_with(|| b.resources.lut.cmp(&a.resources.lut))
                .then_with(|| order(b, a))
        })
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::resources::ResourceReport;
    use crate::sim::BackendKind;

    use crate::dse::space::Candidate;

    fn point(t_max: f64, energy: f64, lut: u64, replicas: usize,
             fits: bool) -> CostPoint {
        CostPoint {
            candidate: Candidate {
                factors: vec![1],
                replicas,
                backend: BackendKind::Accurate,
            },
            t_max_cycles: t_max,
            latency_ms: t_max / 200e3,
            pool_fps: replicas as f64 * 200e6 / t_max,
            energy_per_frame_j: energy,
            power_w: 1.0,
            resources: ResourceReport {
                lut,
                ff: lut,
                bram36: 1.0,
                dsp: 0,
            },
            pes: 9,
            fits,
            host_ns_per_frame: None,
        }
    }

    #[test]
    fn dominance_requires_strict_improvement() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 4.0], &[2.0, 3.0])); // trade-off
    }

    #[test]
    fn frontier_drops_dominated_points() {
        let fast_big = point(100.0, 1e-6, 1000, 1, true);
        let slow_small = point(400.0, 1e-6, 250, 1, true);
        let dominated = point(400.0, 2e-6, 1200, 1, true);
        let front = pareto_frontier(&[
            fast_big.clone(),
            slow_small.clone(),
            dominated,
        ]);
        assert_eq!(front.len(), 2);
        assert!(front.contains(&fast_big));
        assert!(front.contains(&slow_small));
    }

    #[test]
    fn frontier_dedups_identical_metrics() {
        let a = point(100.0, 1e-6, 500, 1, true);
        let b = point(100.0, 1e-6, 500, 1, true);
        assert_eq!(pareto_frontier(&[a, b]).len(), 1);
    }

    #[test]
    fn choose_prefers_throughput_among_fitting_points() {
        let fast = point(100.0, 2e-6, 1000, 1, true);
        let pool = point(100.0, 2e-6, 2000, 4, true); // 4x fps
        let huge = point(50.0, 1e-6, 500, 8, false); // best but no fit
        let chosen = choose(&[fast, pool, huge]).unwrap();
        assert_eq!(chosen.candidate.replicas, 4);
        assert!(chosen.fits);
    }

    #[test]
    fn choose_returns_none_when_nothing_fits() {
        let p = point(100.0, 1e-6, 500, 1, false);
        assert!(choose(&[p]).is_none());
    }
}
