//! Analytical candidate evaluation: one multi-objective cost point per
//! design-space candidate.
//!
//! This module is the single home of the cost math that used to live in
//! `coordinator::scheduler`: per-layer latencies under parallel factors
//! (Eq. 12), PE accounting, and the greedy bottleneck-doubling factor
//! optimiser. The scheduler's public functions are now thin wrappers
//! over these.
//!
//! On top of that, [`Evaluator`] combines the analytical models —
//! `dataflow::latency` (cycles), `dataflow::access` (memory traffic),
//! `sim::energy` (per-event energies + static power) and
//! `sim::resources` (LUT/FF/BRAM area) — into a [`CostPoint`] per
//! [`Candidate`], with the [`Calibration`] correction factors fitted
//! from real simulator probes applied to every term.

use crate::arch::{Layer, NetworkSpec};
use crate::dataflow::latency::layer_latency;
use crate::dataflow::{conv_latency, conv_mode_access, ConvLatencyParams};
use crate::sim::energy::EnergyModel;
use crate::sim::resources::{ResourceModel, ResourceReport};
use crate::sim::CLK_HZ;

use super::calibrate::Calibration;
use super::space::Candidate;

// ---------------------------------------------------------------------------
// Parallel-factor schedules (migrated from coordinator::scheduler)
// ---------------------------------------------------------------------------

/// A chosen per-layer parallel-factor schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleChoice {
    pub factors: Vec<usize>,
    pub pes: usize,
    /// Pipeline interval (cycles) under the latency model.
    pub t_max: u64,
    /// Interval before optimisation (all factors 1).
    pub t_max_base: u64,
}

impl ScheduleChoice {
    pub fn speedup(&self) -> f64 {
        self.t_max_base as f64 / self.t_max as f64
    }

    /// Steady-state frames/s of one pipeline at this schedule (Eq. 11,
    /// N -> inf) for a given clock.
    pub fn fps(&self, clk_hz: f64) -> f64 {
        clk_hz / self.t_max as f64
    }
}

/// A schedule replicated across N identical pipeline copies (the
/// serving pool of `coordinator::replica`): replicas trade per-frame
/// latency (fewer lanes per copy) for request throughput (more copies).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicatedSchedule {
    pub replicas: usize,
    pub per_replica: ScheduleChoice,
    /// Total PEs across all replicas.
    pub pes_total: usize,
}

impl ReplicatedSchedule {
    /// Aggregate frames/s of the whole pool at a given clock.
    pub fn pool_fps(&self, clk_hz: f64) -> f64 {
        self.replicas as f64 * self.per_replica.fps(clk_hz)
    }
}

/// Per-conv-layer latencies of a factor assignment (Eq. 12 each).
fn conv_latencies(net: &NetworkSpec, factors: &[usize],
                  timing: &ConvLatencyParams) -> Vec<u64> {
    net.accel_convs()
        .iter()
        .zip(factors)
        .map(|(c, &f)| {
            let mut l = (*c).clone();
            l.parallel = f;
            conv_latency(&l, timing)
        })
        .collect()
}

/// Total PEs of a factor assignment.
fn factors_pes(net: &NetworkSpec, factors: &[usize]) -> usize {
    net.accel_convs()
        .iter()
        .zip(factors)
        .map(|(c, &f)| c.kh * c.kw * f)
        .sum()
}

/// Lexicographic descent key: pipeline interval first, then how many
/// layers sit at it. The second component lets the greedy escape tied
/// bottlenecks (doubling one of two equal layers leaves the max
/// unchanged but is a necessary step of any schedule that beats it).
fn bottleneck_key(lat: &[u64]) -> (u64, usize) {
    let m = *lat.iter().max().unwrap();
    (m, lat.iter().filter(|&&x| x == m).count())
}

/// Greedy bottleneck doubling. Tie moves (doubling one of several
/// layers tied at the interval) are explored because any schedule that
/// beats a tie must upgrade every tied layer — but they are only
/// *committed* if the interval eventually drops: trailing tie moves
/// that never pay off are rolled back so the returned schedule spends
/// no PEs without a latency return. Returns the choice plus the
/// committed trajectory from all-ones to it (the chain doubles as a
/// search-space sample in `dse::space`).
fn greedy_search(net: &NetworkSpec, pe_budget: usize,
                 timing: &ConvLatencyParams)
                 -> (ScheduleChoice, Vec<Vec<usize>>) {
    let convs = net.accel_convs();
    assert!(!convs.is_empty(), "network has no accelerated conv layers");
    let mut factors = vec![1usize; convs.len()];
    let mut chain = vec![factors.clone()];

    let base_lat = conv_latencies(net, &factors, timing);
    let t_max_base = *base_lat.iter().max().unwrap();
    // Chain index of the last state that lowered the interval.
    let mut committed = 0usize;
    let mut best_max = t_max_base;

    loop {
        let lat = conv_latencies(net, &factors, timing);
        let cur = bottleneck_key(&lat);
        // Walk layers from the bottleneck down, doubling the first one
        // that still fits the budget, its channel count, and lane
        // divisibility (Co must split evenly across lanes).
        let mut order: Vec<usize> = (0..factors.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(lat[i]));
        let mut improved = false;
        for &i in &order {
            let c = convs[i];
            let next = factors[i] * 2;
            if next > c.co || c.co % next != 0 {
                continue; // no more even lane splits for this layer
            }
            let mut trial = factors.clone();
            trial[i] = next;
            if factors_pes(net, &trial) > pe_budget {
                continue;
            }
            // Only useful if it improves (interval, #bottlenecks).
            let new_lat = conv_latencies(net, &trial, timing);
            let new_key = bottleneck_key(&new_lat);
            if new_key < cur {
                factors = trial;
                chain.push(factors.clone());
                if new_key.0 < best_max {
                    best_max = new_key.0;
                    committed = chain.len() - 1;
                }
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }

    // Roll back tie moves after the last interval drop.
    chain.truncate(committed + 1);
    let factors = chain.last().unwrap().clone();
    let final_lat = conv_latencies(net, &factors, timing);
    let choice = ScheduleChoice {
        pes: factors_pes(net, &factors),
        t_max: *final_lat.iter().max().unwrap(),
        t_max_base,
        factors,
    };
    (choice, chain)
}

/// Choose per-conv-layer factors under a total-PE budget (greedy
/// steepest descent on the latency model: repeatedly double the
/// bottleneck layer's factor while the budget allows — optimal for
/// this objective because layer latencies are independent and monotone
/// in their own factor). Factors are powers of two that divide each
/// layer's `Co`.
pub fn optimize_factors(net: &NetworkSpec, pe_budget: usize,
                        timing: &ConvLatencyParams) -> ScheduleChoice {
    greedy_search(net, pe_budget, timing).0
}

/// Every factor vector on the greedy optimiser's committed path from
/// all-ones to the budget-optimal point — a monotone latency/PE chain.
pub fn greedy_chain(net: &NetworkSpec, pe_budget: usize,
                    timing: &ConvLatencyParams) -> Vec<Vec<usize>> {
    greedy_search(net, pe_budget, timing).1
}

/// Schedule `replicas` identical copies under one total PE budget.
pub fn optimize_replicated(net: &NetworkSpec, pe_budget: usize,
                           replicas: usize, timing: &ConvLatencyParams)
                           -> ReplicatedSchedule {
    let replicas = replicas.max(1);
    let per_replica = optimize_factors(net, pe_budget / replicas, timing);
    ReplicatedSchedule {
        replicas,
        pes_total: per_replica.pes * replicas,
        per_replica,
    }
}

/// Sweep PE budgets, reporting the latency/PE trade-off curve (the
/// flexibility argument of SectionV-C).
pub fn budget_sweep(net: &NetworkSpec, budgets: &[usize],
                    timing: &ConvLatencyParams) -> Vec<ScheduleChoice> {
    budgets
        .iter()
        .map(|&b| optimize_factors(net, b, timing))
        .collect()
}

// ---------------------------------------------------------------------------
// Multi-objective candidate evaluation
// ---------------------------------------------------------------------------

/// The analytical models + calibration a DSE run evaluates with.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub timing: ConvLatencyParams,
    pub energy: EnergyModel,
    pub resources: ResourceModel,
    pub calibration: Calibration,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            timing: ConvLatencyParams::optimized(),
            energy: EnergyModel::default(),
            resources: ResourceModel::default(),
            calibration: Calibration::identity(),
        }
    }
}

/// One evaluated design point: a candidate plus its predicted latency,
/// throughput, energy, power, and FPGA resource costs.
#[derive(Debug, Clone, PartialEq)]
pub struct CostPoint {
    pub candidate: Candidate,
    /// Calibrated per-replica pipeline interval (cycles, all layers).
    pub t_max_cycles: f64,
    /// Steady-state per-frame latency of one replica (ms).
    pub latency_ms: f64,
    /// Aggregate frames/s of the replica pool at the design clock.
    pub pool_fps: f64,
    /// Calibrated dynamic energy per frame (J).
    pub energy_per_frame_j: f64,
    /// Average power at pool throughput (dynamic + static floor, W).
    pub power_w: f64,
    /// Resources across all replicas.
    pub resources: ResourceReport,
    /// PEs across all replicas.
    pub pes: usize,
    /// Whether the whole pool fits the ZCU102 budget.
    pub fits: bool,
    /// Measured host wall-time per frame for the candidate's compute
    /// backend (ns), when calibration probed it.
    pub host_ns_per_frame: Option<f64>,
}

impl CostPoint {
    /// Minimisation objectives for Pareto pruning:
    /// `[pool interval (cycles/frame at pool level), per-frame latency
    /// (ms), energy/frame (J), LUTs]`.
    pub fn objectives(&self) -> [f64; 4] {
        [
            self.t_max_cycles / self.candidate.replicas as f64,
            self.latency_ms,
            self.energy_per_frame_j,
            self.resources.lut as f64,
        ]
    }
}

/// Evaluates candidates for one network under one cost model.
pub struct Evaluator<'a> {
    net: &'a NetworkSpec,
    model: &'a CostModel,
    timesteps: usize,
}

impl<'a> Evaluator<'a> {
    pub fn new(net: &'a NetworkSpec, model: &'a CostModel,
               timesteps: usize) -> Self {
        Self { net, model, timesteps: timesteps.max(1) }
    }

    /// Evaluate one candidate. Errors only on invalid factor vectors
    /// (wrong count / zero / non-dividing — `arch` validation).
    pub fn evaluate(&self, cand: &Candidate) -> anyhow::Result<CostPoint> {
        let net = self
            .net
            .clone()
            .try_with_parallel_factors(&cand.factors)?;
        let replicas = cand.replicas.max(1);
        let t = self.timesteps as u64;
        let cal = &self.model.calibration;
        let timing = &self.model.timing;

        // Calibrated per-layer cycles (Eq. 12 x per-mode correction for
        // convs; pool/FC latencies are minor and used uncorrected).
        let mut t_max = 0f64;
        for layer in &net.layers {
            let cycles = match layer {
                Layer::Conv(c) if !c.encoder => {
                    conv_latency(c, timing) as f64 * cal.cycle_scale(c.mode)
                }
                Layer::Conv(_) => 0.0,
                other => layer_latency(other, timing) as f64,
            } * t as f64;
            t_max = t_max.max(cycles);
        }

        // Calibrated dynamic energy: theoretical ops scaled by the
        // measured spike activity, plus per-class memory traffic at the
        // Eyeriss-style per-level energies (first conv streams its
        // input from DRAM; everything downstream is on-chip).
        let e = &self.model.energy;
        let mut energy_pj = 0.0;
        let mut first = true;
        for c in net.accel_convs() {
            let a = conv_mode_access(c, t);
            energy_pj +=
                c.ops() as f64 * t as f64 * cal.op_activity * e.pj_per_op;
            let inputs = a.input_spikes as f64;
            if first {
                energy_pj += inputs * cal.input_dram_scale * e.pj_dram;
                first = false;
            }
            energy_pj += inputs * cal.input_bram_scale * e.pj_bram;
            energy_pj += a.weights as f64 * cal.weight_scale * e.pj_bram;
            energy_pj +=
                a.partial_sums as f64 * cal.vmem_scale * e.pj_bram;
            let outputs = (c.out_h() * c.out_w()) as f64 * t as f64;
            energy_pj += outputs * cal.output_scale * e.pj_bram;
        }
        for layer in &net.layers {
            if let Layer::Fc { n_in, n_out } = layer {
                energy_pj += (n_in * n_out) as f64 * t as f64
                    * cal.op_activity
                    * e.pj_per_op;
            }
        }
        let energy_per_frame_j = energy_pj * 1e-12;

        // Resources and power scale with the replica count (each
        // replica is a full copy of the array + buffers).
        let base = self.model.resources.network(&net, self.timesteps);
        let resources = ResourceReport {
            lut: base.lut * replicas as u64,
            ff: base.ff * replicas as u64,
            bram36: base.bram36 * replicas as f64,
            dsp: base.dsp * replicas as u64,
        };
        let pes = net.total_pes() * replicas;
        let pool_fps = replicas as f64 * CLK_HZ / t_max;
        let power_w = e.avg_power(energy_per_frame_j, pool_fps, pes,
                                  resources.bram36);

        Ok(CostPoint {
            host_ns_per_frame: cal.host_ns(cand.backend),
            candidate: cand.clone(),
            t_max_cycles: t_max,
            latency_ms: t_max / CLK_HZ * 1e3,
            pool_fps,
            energy_per_frame_j,
            power_w,
            resources,
            pes,
            fits: resources.fits(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5};
    use crate::sim::BackendKind;

    fn cand(factors: &[usize], replicas: usize) -> Candidate {
        Candidate {
            factors: factors.to_vec(),
            replicas,
            backend: BackendKind::Accurate,
        }
    }

    #[test]
    fn more_lanes_lower_latency_higher_lut() {
        let net = scnn3();
        let model = CostModel::default();
        let ev = Evaluator::new(&net, &model, 1);
        let base = ev.evaluate(&cand(&[1, 1], 1)).unwrap();
        let par = ev.evaluate(&cand(&[4, 2], 1)).unwrap();
        assert!(par.latency_ms < base.latency_ms);
        assert!(par.resources.lut > base.resources.lut);
        // Function-preserving knob: energy per frame is unchanged.
        let de = (par.energy_per_frame_j - base.energy_per_frame_j).abs();
        assert!(de / base.energy_per_frame_j < 1e-9);
    }

    #[test]
    fn replicas_scale_pool_fps_and_resources() {
        let net = scnn3();
        let model = CostModel::default();
        let ev = Evaluator::new(&net, &model, 1);
        let one = ev.evaluate(&cand(&[2, 2], 1)).unwrap();
        let four = ev.evaluate(&cand(&[2, 2], 4)).unwrap();
        assert!((four.pool_fps / one.pool_fps - 4.0).abs() < 1e-9);
        assert_eq!(four.resources.lut, 4 * one.resources.lut);
        assert_eq!(four.pes, 4 * one.pes);
        // Per-replica latency is identical.
        assert!((four.latency_ms - one.latency_ms).abs() < 1e-12);
    }

    #[test]
    fn invalid_factors_are_an_error_not_a_panic() {
        let net = scnn3();
        let model = CostModel::default();
        let ev = Evaluator::new(&net, &model, 1);
        assert!(ev.evaluate(&cand(&[3, 2], 1)).is_err());
        assert!(ev.evaluate(&cand(&[4], 1)).is_err());
    }

    #[test]
    fn evaluator_latency_matches_schedule_choice() {
        // The evaluator (identity calibration) and the migrated greedy
        // agree on the pipeline interval of the same factor profile.
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let choice = optimize_factors(&net, 99, &timing);
        let model = CostModel::default();
        let ev = Evaluator::new(&net, &model, 1);
        let p = ev.evaluate(&cand(&choice.factors, 1)).unwrap();
        // Conv bottleneck dominates every deployed net, so the whole-
        // pipeline interval equals the schedule's conv interval.
        assert!((p.t_max_cycles - choice.t_max as f64).abs() < 1.0,
                "evaluator {} vs schedule {}", p.t_max_cycles,
                choice.t_max);
    }

    #[test]
    fn tied_bottlenecks_roll_back_unpaid_tie_moves() {
        // Two identical convs tie at the interval. With budget for
        // only one doubling the tie move cannot pay off and is rolled
        // back (no PEs spent at speedup 1.0); with budget for both,
        // the interval halves.
        let net = crate::arch::NetBuilder::new("tie", (8, 8, 2))
            .encoder(8, 3)
            .conv(8, 3)
            .conv(8, 3)
            .fc(10)
            .build();
        let timing = ConvLatencyParams::optimized();
        let one = optimize_factors(&net, 27, &timing);
        assert_eq!(one.factors, vec![1, 1]);
        assert_eq!(one.pes, 18);
        assert_eq!(one.speedup(), 1.0);
        let both = optimize_factors(&net, 36, &timing);
        assert_eq!(both.factors, vec![2, 2]);
        assert!(both.t_max < one.t_max);
    }

    #[test]
    fn greedy_chain_starts_at_ones_and_ends_at_choice() {
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let chain = greedy_chain(&net, 99, &timing);
        let choice = optimize_factors(&net, 99, &timing);
        assert_eq!(chain.first().unwrap(), &vec![1, 1, 1, 1]);
        assert_eq!(chain.last().unwrap(), &choice.factors);
        assert!(chain.len() >= 2);
    }
}
