//! Calibration: fit correction factors so the analytical models track
//! the cycle-level simulator's counters.
//!
//! A small number of probe runs through the real `sim` engines (one
//! per accelerated conv layer, per requested backend) yields
//! multiplicative per-term corrections:
//!
//! * **cycles** — per conv mode, `simulated / Eq.(12)`. Standard and
//!   pointwise layers agree with the model exactly; depthwise layers
//!   pay an adder-tree term the closed form omits, which is precisely
//!   the kind of microarchitectural detail calibration recovers.
//! * **accesses** — per traffic class (`input@DRAM`, `input@BRAM`,
//!   weights, Vmem, output spikes), `simulated counter / Table III
//!   prediction`. Line-buffer fills and padded geometry make the raw
//!   vector counts drift from the closed forms; the fitted scales
//!   absorb that.
//! * **op activity** — measured spike-gated accumulates over the
//!   theoretical op count (drives the dynamic-energy term).
//! * **host speed** — wall-clock per probe frame per backend, the
//!   measured input to serving auto-tune's backend choice.
//!
//! Counters are architectural (weight- and backend-independent, pinned
//! by `tests/prop_backend.rs`), so the fit is deterministic; only the
//! host timings vary run to run.

use std::time::Instant;

use crate::arch::{ConvLayer, ConvMode, NetworkSpec};
use crate::codec::SpikeFrame;
use crate::dataflow::{conv_latency, conv_mode_access, ConvLatencyParams};
use crate::sim::conv_engine::{ConvEngine, ConvWeights};
use crate::sim::memory::{DataKind, MemLevel};
use crate::sim::BackendKind;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How the probe runs are generated.
#[derive(Debug, Clone)]
pub struct CalibrationConfig {
    /// Input firing rate of the probe frames.
    pub rate: f64,
    pub seed: u64,
    pub timesteps: usize,
    /// Backends to time on the host (counters come from the first).
    pub backends: Vec<BackendKind>,
    /// Intra-frame row bands the probe engines run with: the fitted
    /// host-ns/frame then reflects the serving configuration's band
    /// count (counter scales are band-invariant).
    pub intra_parallel: usize,
    /// Whether the serving pipeline streams layers concurrently
    /// (inter-layer workers). Pipelined, the steady-state host cost of
    /// a frame is the *bottleneck* layer's time (workers overlap), so
    /// the fit takes the max over probed layers; serial, it is the
    /// sum. Counter scales are schedule-invariant.
    pub pipelined: bool,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        Self {
            // The single source of truth for the probe firing rate:
            // `AutoTuneOptions`, the CLI, benches, and examples all
            // derive their default from here.
            rate: 0.15,
            seed: 42,
            timesteps: 1,
            backends: vec![BackendKind::Accurate, BackendKind::WordParallel,
                           BackendKind::Sparse],
            intra_parallel: 1,
            pipelined: true,
        }
    }
}

/// Fitted correction factors (all multiplicative, identity = 1.0).
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Cycles: simulated / analytical, per conv mode
    /// (Standard, Depthwise, Pointwise).
    pub cycle_scales: [f64; 3],
    /// Off-chip input-vector reads of the first layer vs Table III.
    pub input_dram_scale: f64,
    /// On-chip input-vector traffic (line-buffer fills + window reads)
    /// vs Table III inputs.
    pub input_bram_scale: f64,
    /// Weight-buffer reads vs Table III weights.
    pub weight_scale: f64,
    /// Vmem traffic vs Table III partial sums (1.0 at T = 1).
    pub vmem_scale: f64,
    /// Output-spike writes vs `Ho*Wo*T`.
    pub output_scale: f64,
    /// Measured spike-gated ops / theoretical ops.
    pub op_activity: f64,
    /// Measured host wall-time per probe frame, per backend (ns).
    pub host_ns_per_frame: Vec<(BackendKind, f64)>,
}

fn mode_index(mode: ConvMode) -> usize {
    match mode {
        ConvMode::Standard => 0,
        ConvMode::Depthwise => 1,
        ConvMode::Pointwise => 2,
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 { num / den } else { 1.0 }
}

impl Calibration {
    /// No correction: the analytical models used as-is.
    pub fn identity() -> Self {
        Self {
            cycle_scales: [1.0; 3],
            input_dram_scale: 1.0,
            input_bram_scale: 1.0,
            weight_scale: 1.0,
            vmem_scale: 1.0,
            output_scale: 1.0,
            op_activity: 1.0,
            host_ns_per_frame: Vec::new(),
        }
    }

    pub fn cycle_scale(&self, mode: ConvMode) -> f64 {
        self.cycle_scales[mode_index(mode)]
    }

    /// Measured host time per frame for a backend, if probed.
    pub fn host_ns(&self, backend: BackendKind) -> Option<f64> {
        self.host_ns_per_frame
            .iter()
            .find(|(b, _)| *b == backend)
            .map(|(_, ns)| *ns)
    }

    /// Calibrated cycle prediction for one conv layer, all timesteps.
    pub fn predict_conv_cycles(&self, l: &ConvLayer,
                               timing: &ConvLatencyParams,
                               timesteps: usize) -> f64 {
        conv_latency(l, timing) as f64
            * timesteps as f64
            * self.cycle_scale(l.mode)
    }

    /// Calibrated access-count predictions for one conv layer.
    pub fn predict_access(&self, l: &ConvLayer, timesteps: usize,
                          off_chip_input: bool) -> PredictedAccess {
        let a = conv_mode_access(l, timesteps as u64);
        let inputs = a.input_spikes as f64;
        PredictedAccess {
            input_dram: if off_chip_input {
                inputs * self.input_dram_scale
            } else {
                0.0
            },
            input_bram: inputs * self.input_bram_scale,
            weight: a.weights as f64 * self.weight_scale,
            vmem: a.partial_sums as f64 * self.vmem_scale,
            output: (l.out_h() * l.out_w() * timesteps) as f64
                * self.output_scale,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycle_scale_standard", Json::num(self.cycle_scales[0])),
            ("cycle_scale_depthwise", Json::num(self.cycle_scales[1])),
            ("cycle_scale_pointwise", Json::num(self.cycle_scales[2])),
            ("input_dram_scale", Json::num(self.input_dram_scale)),
            ("input_bram_scale", Json::num(self.input_bram_scale)),
            ("weight_scale", Json::num(self.weight_scale)),
            ("vmem_scale", Json::num(self.vmem_scale)),
            ("output_scale", Json::num(self.output_scale)),
            ("op_activity", Json::num(self.op_activity)),
            ("host_ns_per_frame",
             Json::Arr(self
                 .host_ns_per_frame
                 .iter()
                 .map(|(b, ns)| {
                     Json::obj(vec![
                         ("backend", Json::str(b.name())),
                         ("ns", Json::num(*ns)),
                     ])
                 })
                 .collect())),
        ])
    }
}

/// Calibrated analytical access counts for one layer (fractional —
/// these are fitted predictions, not integer counters).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictedAccess {
    pub input_dram: f64,
    pub input_bram: f64,
    pub weight: f64,
    pub vmem: f64,
    pub output: f64,
}

/// Probe every accelerated conv layer of `net` through the real
/// simulator engines and fit the correction factors.
pub fn calibrate(net: &NetworkSpec, timing: &ConvLatencyParams,
                 cfg: &CalibrationConfig) -> Calibration {
    assert!(!cfg.backends.is_empty(), "calibration needs a backend");
    let timesteps = cfg.timesteps.max(1);
    let t = timesteps as u64;
    let convs = net.accel_convs();

    let mut sim_cycles = [0.0f64; 3];
    let mut ana_cycles = [0.0f64; 3];
    let (mut sim_ops, mut ana_ops) = (0.0f64, 0.0f64);
    let (mut sim_in_dram, mut ana_in_dram) = (0.0f64, 0.0f64);
    let (mut sim_in_bram, mut ana_in_bram) = (0.0f64, 0.0f64);
    let (mut sim_weight, mut ana_weight) = (0.0f64, 0.0f64);
    let (mut sim_vmem, mut ana_vmem) = (0.0f64, 0.0f64);
    let (mut sim_out, mut ana_out) = (0.0f64, 0.0f64);
    let mut host_sum = vec![0.0f64; cfg.backends.len()];
    let mut host_max = vec![0.0f64; cfg.backends.len()];
    let mut probes = 0usize;

    for (i, c) in convs.iter().enumerate() {
        let layer = (*c).clone();
        let mut rng = Rng::new(cfg.seed ^ (0xD5E0 + i as u64));
        let input = SpikeFrame::random(layer.in_h, layer.in_w, layer.ci,
                                       cfg.rate, &mut rng);
        let off_chip = i == 0;
        for (bi, &backend) in cfg.backends.iter().enumerate() {
            let weights = ConvWeights::random(&layer, cfg.seed + i as u64);
            let mut eng = ConvEngine::with_backend(
                layer.clone(), weights, *timing, timesteps, backend)
                .with_intra_parallel(cfg.intra_parallel);
            let t0 = Instant::now();
            let (_, rep) = eng.run_frame(&input, off_chip);
            let ns = t0.elapsed().as_nanos() as f64;
            host_sum[bi] += ns;
            host_max[bi] = host_max[bi].max(ns);
            if bi > 0 {
                continue; // counters are backend-invariant (pinned)
            }
            probes += 1;
            let m = mode_index(layer.mode);
            sim_cycles[m] += rep.cycles as f64;
            ana_cycles[m] += conv_latency(&layer, timing) as f64 * t as f64;
            sim_ops += rep.ops as f64;
            ana_ops += layer.ops() as f64 * t as f64;

            let a = conv_mode_access(&layer, t);
            if off_chip {
                sim_in_dram += rep
                    .counters
                    .reads_of(MemLevel::Dram, DataKind::InputSpike)
                    as f64;
                ana_in_dram += a.input_spikes as f64;
            }
            sim_in_bram += (rep
                .counters
                .reads_of(MemLevel::Bram, DataKind::InputSpike)
                + rep
                    .counters
                    .writes_of(MemLevel::Bram, DataKind::InputSpike))
                as f64;
            ana_in_bram += a.input_spikes as f64;
            sim_weight += rep
                .counters
                .reads_of(MemLevel::Bram, DataKind::Weight)
                as f64;
            ana_weight += a.weights as f64;
            sim_vmem += rep.counters.total_of_kind(DataKind::Vmem) as f64;
            ana_vmem += a.partial_sums as f64;
            sim_out += rep
                .counters
                .writes_of(MemLevel::Bram, DataKind::OutputSpike)
                as f64;
            ana_out += (layer.out_h() * layer.out_w()) as f64 * t as f64;
        }
    }
    assert!(probes > 0, "network has no accelerated conv layers");

    Calibration {
        cycle_scales: [
            ratio(sim_cycles[0], ana_cycles[0]),
            ratio(sim_cycles[1], ana_cycles[1]),
            ratio(sim_cycles[2], ana_cycles[2]),
        ],
        input_dram_scale: ratio(sim_in_dram, ana_in_dram),
        input_bram_scale: ratio(sim_in_bram, ana_in_bram),
        weight_scale: ratio(sim_weight, ana_weight),
        vmem_scale: ratio(sim_vmem, ana_vmem),
        output_scale: ratio(sim_out, ana_out),
        op_activity: ratio(sim_ops, ana_ops),
        // Pipelined serving overlaps layer workers, so the steady
        // state is bottleneck-bound: fit the max over probed layers.
        // Serial serving pays every layer in turn: fit the sum.
        host_ns_per_frame: cfg
            .backends
            .iter()
            .zip(if cfg.pipelined { &host_max } else { &host_sum })
            .map(|(&b, &ns)| (b, ns))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{NetBuilder, vmobilenet};

    fn std_net() -> NetworkSpec {
        NetBuilder::new("cal", (10, 10, 2))
            .encoder(4, 3)
            .conv(8, 3)
            .fc(10)
            .build()
    }

    #[test]
    fn identity_is_one_everywhere() {
        let c = Calibration::identity();
        assert_eq!(c.cycle_scales, [1.0; 3]);
        assert_eq!(c.op_activity, 1.0);
        assert!(c.host_ns(BackendKind::Accurate).is_none());
    }

    #[test]
    fn standard_conv_cycles_need_no_correction() {
        // Eq. (12) matches the engine exactly for standard convs, so
        // the fitted scale must be ~1.
        let cal = calibrate(&std_net(), &ConvLatencyParams::optimized(),
                            &CalibrationConfig::default());
        let s = cal.cycle_scale(ConvMode::Standard);
        assert!((s - 1.0).abs() < 0.02, "standard scale {s}");
        // Weight reads also match Table III exactly.
        assert!((cal.weight_scale - 1.0).abs() < 0.02,
                "weight scale {}", cal.weight_scale);
    }

    #[test]
    fn depthwise_adder_tree_is_recovered_by_calibration() {
        // The closed form omits the depthwise adder-tree term; the
        // engine pays it (9 taps -> +4 cycles on 9), so the fitted
        // scale sits near 13/9.
        let cal = calibrate(&vmobilenet(), &ConvLatencyParams::optimized(),
                            &CalibrationConfig::default());
        let s = cal.cycle_scale(ConvMode::Depthwise);
        assert!(s > 1.2 && s < 1.7, "depthwise scale {s}");
        // Pointwise has no adder tree in either — scale ~1.
        let p = cal.cycle_scale(ConvMode::Pointwise);
        assert!((p - 1.0).abs() < 0.02, "pointwise scale {p}");
    }

    #[test]
    fn op_activity_tracks_input_rate_direction() {
        let timing = ConvLatencyParams::optimized();
        let sparse = calibrate(&std_net(), &timing, &CalibrationConfig {
            rate: 0.05,
            ..Default::default()
        });
        let dense = calibrate(&std_net(), &timing, &CalibrationConfig {
            rate: 0.6,
            ..Default::default()
        });
        assert!(dense.op_activity > sparse.op_activity);
        assert!(sparse.op_activity > 0.0 && dense.op_activity <= 1.01);
    }

    #[test]
    fn host_times_recorded_per_backend() {
        let cal = calibrate(&std_net(), &ConvLatencyParams::optimized(),
                            &CalibrationConfig::default());
        assert_eq!(cal.host_ns_per_frame.len(), 3);
        assert!(cal.host_ns(BackendKind::Accurate).unwrap() > 0.0);
        assert!(cal.host_ns(BackendKind::WordParallel).unwrap() > 0.0);
        assert!(cal.host_ns(BackendKind::Sparse).unwrap() > 0.0);
    }

    /// Intra-frame bands change host timing only: the fitted counter
    /// and cycle scales are identical to the single-band fit, and the
    /// host-ns/frame refit still records every backend.
    #[test]
    fn band_calibration_refits_host_time_with_invariant_scales() {
        let timing = ConvLatencyParams::optimized();
        let base = calibrate(&std_net(), &timing,
                             &CalibrationConfig::default());
        let banded = calibrate(&std_net(), &timing, &CalibrationConfig {
            intra_parallel: 2,
            ..Default::default()
        });
        assert_eq!(base.cycle_scales, banded.cycle_scales);
        assert_eq!(base.input_dram_scale, banded.input_dram_scale);
        assert_eq!(base.input_bram_scale, banded.input_bram_scale);
        assert_eq!(base.weight_scale, banded.weight_scale);
        assert_eq!(base.output_scale, banded.output_scale);
        assert_eq!(base.op_activity, banded.op_activity);
        assert!(banded.host_ns(BackendKind::Accurate).unwrap() > 0.0);
        assert!(banded.host_ns(BackendKind::WordParallel).unwrap() > 0.0);
        assert!(banded.host_ns(BackendKind::Sparse).unwrap() > 0.0);
    }

    #[test]
    fn calibration_is_deterministic_apart_from_host_times() {
        let timing = ConvLatencyParams::optimized();
        let a = calibrate(&std_net(), &timing,
                          &CalibrationConfig::default());
        let b = calibrate(&std_net(), &timing,
                          &CalibrationConfig::default());
        assert_eq!(a.cycle_scales, b.cycle_scales);
        assert_eq!(a.weight_scale, b.weight_scale);
        assert_eq!(a.op_activity, b.op_activity);
    }
}
