//! JSON report of an exploration: search-space summary, fitted
//! calibration, the Pareto frontier, and the chosen serving point.
//!
//! The schema mirrors `util::bench`'s JSON conventions (flat objects,
//! numeric fields in base units) so the `BENCH_dse.json` artifact and
//! `dse_report.json` can be post-processed by the same tooling:
//!
//! ```json
//! {
//!   "model": "scnn3", "pe_budget": 144, "max_replicas": 4,
//!   "timesteps": 1, "candidates": 120, "evaluated": 120,
//!   "calibration": {"cycle_scale_standard": 1.0, ...},
//!   "frontier": [{"factors": [4, 2], "replicas": 1,
//!                 "backend": "word-parallel", "t_max_cycles": ...,
//!                 "latency_ms": ..., "pool_fps": ...,
//!                 "energy_uj_per_frame": ..., "power_w": ...,
//!                 "pes": 54, "lut": ..., "bram36": ..., "fits": true},
//!                ...],
//!   "chosen": { ...same shape... }   // null when nothing fits
//! }
//! ```

use crate::util::json::Json;

use super::evaluate::CostPoint;
use super::space::SearchSpace;
use super::Exploration;

fn point_json(p: &CostPoint) -> Json {
    Json::obj(vec![
        ("factors",
         Json::Arr(p.candidate
             .factors
             .iter()
             .map(|&f| Json::num(f as f64))
             .collect())),
        ("replicas", Json::num(p.candidate.replicas as f64)),
        ("backend", Json::str(p.candidate.backend.name())),
        ("t_max_cycles", Json::num(p.t_max_cycles)),
        ("latency_ms", Json::num(p.latency_ms)),
        ("pool_fps", Json::num(p.pool_fps)),
        ("energy_uj_per_frame", Json::num(p.energy_per_frame_j * 1e6)),
        ("power_w", Json::num(p.power_w)),
        ("pes", Json::num(p.pes as f64)),
        ("lut", Json::num(p.resources.lut as f64)),
        ("bram36", Json::num(p.resources.bram36)),
        ("fits", Json::Bool(p.fits)),
    ])
}

/// Fixed-width frontier table (one header + one line per frontier
/// point), shared by the `explore` subcommand and the examples so the
/// two entry points cannot drift.
pub fn frontier_table(ex: &Exploration) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<16} {:>4} {:>14} {:>10} {:>10} {:>10} {:>8} {:>8} {:>7} \
         {:>5}",
        "factors", "rep", "backend", "t_max ms", "pool FPS", "uJ/frame",
        "power W", "LUT", "BRAM", "fits");
    for p in &ex.frontier {
        let _ = writeln!(
            s,
            "{:<16} {:>4} {:>14} {:>10.3} {:>10.1} {:>10.2} {:>8.2} \
             {:>8} {:>7.1} {:>5}",
            format!("{:?}", p.candidate.factors),
            p.candidate.replicas,
            p.candidate.backend.name(),
            p.latency_ms,
            p.pool_fps,
            p.energy_per_frame_j * 1e6,
            p.power_w,
            p.resources.lut,
            p.resources.bram36,
            p.fits);
    }
    s
}

/// The full report as a JSON value.
pub fn report_json(ex: &Exploration, space: &SearchSpace) -> Json {
    Json::obj(vec![
        ("model", Json::str(&space.net.name)),
        ("pe_budget", Json::num(space.pe_budget as f64)),
        ("max_replicas", Json::num(space.max_replicas as f64)),
        ("timesteps", Json::num(space.timesteps as f64)),
        ("candidates", Json::num(ex.candidates as f64)),
        ("evaluated", Json::num(ex.evaluated as f64)),
        ("calibration", ex.calibration.to_json()),
        ("frontier",
         Json::Arr(ex.frontier.iter().map(point_json).collect())),
        ("chosen",
         ex.chosen.as_ref().map(point_json).unwrap_or(Json::Null)),
    ])
}

/// Write the report to `path` (pretty enough for diffing: one blob,
/// stable key order from the BTreeMap-backed object).
pub fn write_report(path: &str, ex: &Exploration, space: &SearchSpace)
                    -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", report_json(ex, space)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::scnn3;
    use crate::dse::{self, CostModel};

    #[test]
    fn report_roundtrips_and_names_the_chosen_point() {
        let space = dse::SearchSpace::new(scnn3(), 54).with_replicas(2);
        let model = CostModel::default();
        let ex = dse::explore(&space, &model);
        assert!(!ex.frontier.is_empty());
        let j = report_json(&ex, &space);
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re.get("model").and_then(|m| m.as_str()),
                   Some("scnn3"));
        let frontier = re.get("frontier").and_then(|f| f.as_arr()).unwrap();
        assert_eq!(frontier.len(), ex.frontier.len());
        let chosen = re.get("chosen").unwrap();
        assert!(chosen.get("fits").and_then(|f| f.as_bool()).unwrap());
        // Factors in the report stay valid for the model.
        let factors: Vec<usize> = chosen
            .get("factors")
            .and_then(|f| f.as_arr())
            .unwrap()
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        assert!(scnn3().try_with_parallel_factors(&factors).is_ok());
    }

    #[test]
    fn report_writes_to_disk() {
        let space = dse::SearchSpace::new(scnn3(), 36);
        let ex = dse::explore(&space, &CostModel::default());
        let path = std::env::temp_dir().join("sti_dse_report_test.json");
        let path = path.to_str().unwrap().to_string();
        write_report(&path, &ex, &space).unwrap();
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(txt.trim()).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}
