//! Design-space enumeration: the joint space of per-layer parallel
//! factors, replica counts, and functional compute backends under a
//! total PE budget.
//!
//! Constraints are conv-mode-aware through the layer geometry
//! (`arch::ConvMode` determines `Kh*Kw`, the PEs one lane costs —
//! pointwise lanes are 1 PE, standard/depthwise `Kh*Kw`): a factor is
//! admissible when it is a power of two, divides the layer's `Co`
//! (whole-lane replication), and the whole design fits the budget.
//! Replicas split the budget evenly; each replica is a full pipeline
//! copy (`coordinator::replica`).
//!
//! Enumeration is exhaustive (depth-first with suffix-minimum budget
//! pruning) while the space is small; past `max_candidates` factor
//! vectors per replica count it falls back to the greedy optimiser's
//! trajectory (`evaluate::greedy_chain`) — a monotone latency/PE chain
//! that samples the interesting diagonal of the space.

use std::collections::BTreeSet;

use crate::arch::{ConvLayer, NetworkSpec};
use crate::dataflow::ConvLatencyParams;
use crate::sim::BackendKind;

use super::evaluate::greedy_chain;

/// One point of the search space.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Candidate {
    /// Per-accelerated-conv-layer output-channel parallel factors.
    pub factors: Vec<usize>,
    /// Pipeline replicas sharing the PE budget.
    pub replicas: usize,
    /// Functional compute backend (host-side; bit-exact across kinds).
    pub backend: BackendKind,
}

/// Minimum PEs a single pipeline of `net` needs (all factors 1).
pub fn min_pes(net: &NetworkSpec) -> usize {
    net.accel_convs().iter().map(|c| c.kh * c.kw).sum()
}

/// The search space of one network under one PE budget.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub net: NetworkSpec,
    /// Total PE budget across all replicas.
    pub pe_budget: usize,
    /// Largest replica count to consider (>= 1).
    pub max_replicas: usize,
    /// Backends to cross the hardware configurations with.
    pub backends: Vec<BackendKind>,
    pub timesteps: usize,
    /// Cap on exhaustively enumerated factor vectors per replica
    /// count; beyond it the greedy trajectory samples the space.
    pub max_candidates: usize,
}

impl SearchSpace {
    pub fn new(net: NetworkSpec, pe_budget: usize) -> Self {
        Self {
            net,
            pe_budget,
            max_replicas: 1,
            backends: vec![BackendKind::Accurate, BackendKind::WordParallel,
                           BackendKind::Sparse],
            timesteps: 1,
            max_candidates: 2048,
        }
    }

    pub fn with_replicas(mut self, max_replicas: usize) -> Self {
        self.max_replicas = max_replicas.max(1);
        self
    }

    pub fn with_backends(mut self, backends: Vec<BackendKind>) -> Self {
        assert!(!backends.is_empty(), "need at least one backend");
        self.backends = backends;
        self
    }

    pub fn with_timesteps(mut self, timesteps: usize) -> Self {
        self.timesteps = timesteps.max(1);
        self
    }

    pub fn with_max_candidates(mut self, cap: usize) -> Self {
        self.max_candidates = cap.max(1);
        self
    }

    /// Admissible factors for one layer under a per-replica budget:
    /// powers of two dividing `Co` whose lane cost alone fits.
    pub fn factor_options(c: &ConvLayer, budget: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut f = 1usize;
        loop {
            if f > c.co || c.co % f != 0 || c.kh * c.kw * f > budget {
                break;
            }
            out.push(f);
            f *= 2;
        }
        out
    }

    /// Enumerate the whole space, deterministically ordered by
    /// (replicas, factors, backend). `timing` drives the greedy
    /// fallback when the exhaustive product exceeds `max_candidates`.
    pub fn enumerate(&self, timing: &ConvLatencyParams) -> Vec<Candidate> {
        let mut configs: BTreeSet<(usize, Vec<usize>)> = BTreeSet::new();
        for replicas in 1..=self.max_replicas {
            let budget = self.pe_budget / replicas;
            if budget < min_pes(&self.net) {
                continue; // not even unit factors fit this split
            }
            let vecs = exhaustive_factors(&self.net, budget,
                                          self.max_candidates)
                .unwrap_or_else(|| greedy_chain(&self.net, budget, timing));
            for v in vecs {
                configs.insert((replicas, v));
            }
        }
        let mut out = Vec::new();
        for (replicas, factors) in configs {
            for &backend in &self.backends {
                out.push(Candidate {
                    factors: factors.clone(),
                    replicas,
                    backend,
                });
            }
        }
        out
    }
}

/// Exhaustive factor-vector product under a budget, or `None` when it
/// would exceed `cap` vectors (caller falls back to sampling).
fn exhaustive_factors(net: &NetworkSpec, budget: usize, cap: usize)
                      -> Option<Vec<Vec<usize>>> {
    let convs = net.accel_convs();
    let opts: Vec<Vec<usize>> = convs
        .iter()
        .map(|c| SearchSpace::factor_options(c, budget))
        .collect();
    if opts.iter().any(|o| o.is_empty()) {
        return Some(Vec::new());
    }
    // Suffix sums of the minimum (factor 1) PE cost, for pruning.
    let mut tail = vec![0usize; convs.len() + 1];
    for i in (0..convs.len()).rev() {
        tail[i] = tail[i + 1] + convs[i].kh * convs[i].kw;
    }
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(convs.len());
    if dfs(&convs, &opts, &tail, budget, cap, 0, 0, &mut cur, &mut out) {
        Some(out)
    } else {
        None
    }
}

#[allow(clippy::too_many_arguments)]
fn dfs(convs: &[&ConvLayer], opts: &[Vec<usize>], tail: &[usize],
       budget: usize, cap: usize, i: usize, used: usize,
       cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) -> bool {
    if i == convs.len() {
        if out.len() >= cap {
            return false; // over the cap: abandon exhaustive mode
        }
        out.push(cur.clone());
        return true;
    }
    for &f in &opts[i] {
        let pes = convs[i].kh * convs[i].kw * f;
        if used + pes + tail[i + 1] > budget {
            break; // options ascend, so no later f fits either
        }
        cur.push(f);
        let ok = dfs(convs, opts, tail, budget, cap, i + 1, used + pes,
                     cur, out);
        cur.pop();
        if !ok {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5, vmobilenet};

    #[test]
    fn factor_options_divide_co_and_fit_budget() {
        let c = scnn5().accel_convs()[0].clone(); // Co = 128, 3x3
        let opts = SearchSpace::factor_options(&c, 99);
        assert_eq!(opts, vec![1, 2, 4, 8]); // 9*16 = 144 > 99
        let tiny = SearchSpace::factor_options(&c, 8);
        assert!(tiny.is_empty()); // one 3x3 lane needs 9 PEs
    }

    #[test]
    fn enumerate_respects_budget_and_is_deterministic() {
        let space = SearchSpace::new(scnn3(), 54).with_replicas(2);
        let timing = ConvLatencyParams::optimized();
        let cands = space.enumerate(&timing);
        assert!(!cands.is_empty());
        for c in &cands {
            let net = space
                .net
                .clone()
                .try_with_parallel_factors(&c.factors)
                .expect("enumerated factors are valid");
            assert!(net.total_pes() * c.replicas <= 54,
                    "{c:?} blows the budget");
        }
        assert_eq!(cands, space.enumerate(&timing));
    }

    #[test]
    fn backends_cross_every_hardware_config() {
        let space = SearchSpace::new(scnn3(), 36);
        let cands = space.enumerate(&ConvLatencyParams::optimized());
        let n_acc = cands
            .iter()
            .filter(|c| c.backend == BackendKind::Accurate)
            .count();
        assert_eq!(cands.len(), 3 * n_acc);
    }

    #[test]
    fn replica_splits_shrink_the_per_copy_budget() {
        let space = SearchSpace::new(scnn3(), 54).with_replicas(3);
        let cands = space.enumerate(&ConvLatencyParams::optimized());
        // 54 / 3 = 18 < 18-PE minimum? scnn3 needs 2 x 9 = 18, so
        // replicas = 3 is exactly feasible at unit factors only.
        let r3: Vec<_> =
            cands.iter().filter(|c| c.replicas == 3).collect();
        assert!(!r3.is_empty());
        for c in r3 {
            assert_eq!(c.factors, vec![1, 1]);
        }
    }

    #[test]
    fn oversized_space_falls_back_to_greedy_chain() {
        // vMobileNet has 8 accelerated convs — the exhaustive product
        // explodes, so a tiny cap must trigger the trajectory fallback
        // and still produce valid, budget-respecting candidates.
        let net = vmobilenet();
        let budget = min_pes(&net) * 8;
        let space = SearchSpace::new(net, budget)
            .with_max_candidates(4);
        let timing = ConvLatencyParams::optimized();
        let cands = space.enumerate(&timing);
        assert!(!cands.is_empty());
        for c in &cands {
            let net = space
                .net
                .clone()
                .try_with_parallel_factors(&c.factors)
                .expect("fallback factors are valid");
            assert!(net.total_pes() <= budget);
        }
    }

    #[test]
    fn min_pes_matches_unit_factor_design() {
        assert_eq!(min_pes(&scnn3()), 18);
        assert_eq!(min_pes(&scnn5()), 36);
        assert_eq!(min_pes(&vmobilenet()), 40); // 4 x 9 dw + 4 x 1 pw
    }
}
