//! Design-space exploration: calibrated cost models, Pareto search,
//! and auto-tuned serving configurations.
//!
//! STI-SNN's computation array is *parameterized* — PE modes, per-layer
//! intra-layer parallel factors, and inter-layer pipelining are knobs
//! to be tuned per model (paper SectionIV). This subsystem searches the
//! joint space of those knobs plus the serving-side ones (replica
//! count, compute backend) against latency, energy, *and* resource
//! budgets, feeding measured simulator results back into the
//! analytical models:
//!
//! * [`space`] — search-space enumeration under a total PE budget
//!   (dividing power-of-two factors, replica budget splits, backend
//!   cross product), with greedy-trajectory sampling past a size cap.
//! * [`evaluate`] — the analytical evaluator combining
//!   `dataflow::latency`, `dataflow::access`, `sim::energy` and
//!   `sim::resources` into one [`CostPoint`] per candidate. Also the
//!   home of the parallel-factor schedule optimiser that
//!   `coordinator::scheduler` now wraps.
//! * [`calibrate`] — probe the real `sim` engines and fit per-term
//!   correction factors so analytical cycles/accesses track simulated
//!   counters (and measure host speed per backend).
//! * [`pareto`] — latency/energy/resource frontier with dominance
//!   pruning and deterministic tie-breaking, plus the serving choice.
//! * [`report`] — JSON report of the frontier + chosen point
//!   (`dse_report.json`, `BENCH_dse.json`-compatible conventions).
//!
//! End to end: `sti-snn explore` prints and writes the frontier;
//! `sti-snn serve --auto-tune` boots the `ReplicaPool` from the
//! winning point via the session facade
//! (`sti_snn::session::SessionBuilder::auto_tune`).

pub mod calibrate;
pub mod evaluate;
pub mod pareto;
pub mod report;
pub mod space;

use crate::arch::NetworkSpec;
use crate::dataflow::ConvLatencyParams;

pub use calibrate::{calibrate, Calibration, CalibrationConfig};
pub use evaluate::{CostModel, CostPoint, Evaluator};
pub use pareto::{dominates, pareto_frontier};
pub use report::{frontier_table, report_json, write_report};
pub use space::{min_pes, Candidate, SearchSpace};

/// The result of one exploration run.
#[derive(Debug, Clone)]
pub struct Exploration {
    /// Enumerated candidate count.
    pub candidates: usize,
    /// Successfully evaluated count (== candidates unless a factor
    /// vector was rejected by `arch` validation).
    pub evaluated: usize,
    /// Every evaluated cost point, in enumeration order.
    pub points: Vec<CostPoint>,
    /// The non-dominated subset (deterministically ordered).
    pub frontier: Vec<CostPoint>,
    /// Serving choice: best-throughput point that fits the device.
    pub chosen: Option<CostPoint>,
    /// The calibration the evaluator ran with (recorded for the
    /// report).
    pub calibration: Calibration,
}

/// Enumerate, evaluate, and prune a search space under a cost model.
pub fn explore(space: &SearchSpace, model: &CostModel) -> Exploration {
    let cands = space.enumerate(&model.timing);
    let eval = Evaluator::new(&space.net, model, space.timesteps);
    let mut points = Vec::with_capacity(cands.len());
    for c in &cands {
        if let Ok(p) = eval.evaluate(c) {
            points.push(p);
        }
    }
    let frontier = pareto::pareto_frontier(&points);
    let chosen = pareto::choose(&points);
    Exploration {
        candidates: cands.len(),
        evaluated: points.len(),
        points,
        frontier,
        chosen,
        calibration: model.calibration.clone(),
    }
}

/// The `serve --auto-tune` recipe, shared by the CLI, benches, and
/// examples so the measured configuration is exactly the booted one.
#[derive(Debug, Clone)]
pub struct AutoTuneOptions {
    /// Total PE budget; `None` = 8x the net's unit-factor minimum.
    pub pe_budget: Option<usize>,
    /// Largest replica split to consider.
    pub max_replicas: usize,
    pub timesteps: usize,
    /// Calibration probe firing rate.
    pub rate: f64,
    /// Intra-frame row bands the served pipelines will run with; the
    /// calibration probes run the same way so the fitted host-ns/frame
    /// (and thus the chosen backend/replica split) matches what boots.
    pub intra_parallel: usize,
    /// Whether the served pipelines stream layers concurrently
    /// (`PipelineConfig::pipelined`, the default). Drives how the
    /// host-ns/frame fit aggregates per-layer probe times: bottleneck
    /// max when pipelined, sum when serial.
    pub pipelined: bool,
}

impl Default for AutoTuneOptions {
    fn default() -> Self {
        Self {
            pe_budget: None,
            max_replicas: std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
                .clamp(1, 8),
            timesteps: 1,
            rate: CalibrationConfig::default().rate,
            intra_parallel: 1,
            pipelined: true,
        }
    }
}

/// Calibrate against the simulator, explore the space, and return the
/// chosen serving point (plus the full exploration for reporting).
/// Errors when no candidate fits the device.
pub fn auto_tune(net: &NetworkSpec, opts: &AutoTuneOptions)
                 -> anyhow::Result<(CostPoint, Exploration)> {
    let budget = opts.pe_budget.unwrap_or_else(|| 8 * min_pes(net));
    let timing = ConvLatencyParams::optimized();
    let model = CostModel {
        calibration: calibrate(net, &timing, &CalibrationConfig {
            rate: opts.rate,
            timesteps: opts.timesteps,
            intra_parallel: opts.intra_parallel,
            pipelined: opts.pipelined,
            ..Default::default()
        }),
        timing,
        ..CostModel::default()
    };
    let space = SearchSpace::new(net.clone(), budget)
        .with_replicas(opts.max_replicas)
        .with_timesteps(opts.timesteps);
    let ex = explore(&space, &model);
    let chosen = ex.chosen.clone().ok_or_else(|| {
        anyhow::anyhow!(
            "auto-tune: no design point fits a {budget}-PE budget on \
             the ZCU102")
    })?;
    Ok((chosen, ex))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::scnn3;
    use crate::sim::BackendKind;

    #[test]
    fn explore_scnn3_finds_the_paper_profile_on_the_frontier() {
        // With the paper's 54-PE budget, the (4,2) hand profile must be
        // on (or dominated by nothing on) the frontier.
        let space = SearchSpace::new(scnn3(), 54);
        let ex = explore(&space, &CostModel::default());
        assert_eq!(ex.candidates, ex.evaluated);
        assert!(!ex.frontier.is_empty());
        let best_latency = ex
            .frontier
            .iter()
            .map(|p| p.latency_ms)
            .fold(f64::INFINITY, f64::min);
        let hand = ex
            .points
            .iter()
            .find(|p| p.candidate.factors == vec![4, 2]
                  && p.candidate.replicas == 1)
            .expect("(4,2) enumerated");
        assert!(hand.latency_ms <= best_latency * 1.0001,
                "hand profile off the frontier: {} vs {}",
                hand.latency_ms, best_latency);
    }

    #[test]
    fn chosen_point_fits_and_maximises_pool_fps() {
        let space = SearchSpace::new(scnn3(), 144).with_replicas(4);
        let ex = explore(&space, &CostModel::default());
        let chosen = ex.chosen.expect("feasible point exists");
        assert!(chosen.fits);
        for p in ex.points.iter().filter(|p| p.fits) {
            assert!(chosen.pool_fps >= p.pool_fps,
                    "chosen {} beaten by {:?} at {}", chosen.pool_fps,
                    p.candidate, p.pool_fps);
        }
    }

    #[test]
    fn frontier_prefers_measured_faster_backend_on_ties() {
        // With measured host times, equal-hardware candidates keep the
        // faster backend after dedup.
        let model = CostModel {
            calibration: Calibration {
                host_ns_per_frame: vec![
                    (BackendKind::Accurate, 1000.0),
                    (BackendKind::WordParallel, 10.0),
                    (BackendKind::Sparse, 2000.0),
                ],
                ..Calibration::identity()
            },
            ..CostModel::default()
        };
        let space = SearchSpace::new(scnn3(), 36);
        let ex = explore(&space, &model);
        assert!(!ex.frontier.is_empty());
        for p in &ex.frontier {
            assert_eq!(p.candidate.backend, BackendKind::WordParallel);
        }
    }

    #[test]
    fn auto_tune_yields_a_bootable_pool() {
        let net = scnn3();
        let (best, ex) = auto_tune(&net, &AutoTuneOptions {
            max_replicas: 2,
            ..Default::default()
        })
        .unwrap();
        assert!(best.fits);
        assert!(!ex.frontier.is_empty());
        // Measured host times flowed into the chosen point.
        assert!(best.host_ns_per_frame.is_some());
        // The session facade boots the chosen configuration.
        let session = crate::session::Session::builder()
            .network(net)
            .auto_tune(AutoTuneOptions {
                max_replicas: 2,
                ..Default::default()
            })
            .build()
            .unwrap();
        let tuned = session.tuned().expect("auto-tuned session");
        assert!(tuned.fits);
        assert_eq!(session.replicas(), tuned.candidate.replicas);
        assert_eq!(session.backend(), tuned.candidate.backend);
    }
}
