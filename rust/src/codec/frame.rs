//! Dense spike frame: (H, W, C) binary feature map, channel-last.
//!
//! Matches the python/L1 layout (`kernels/ref.py` conventions): channel-
//! last so a pixel's spike vector (all C channels, channel-sorted) is
//! contiguous — the paper's compressed & sorted representation.

use super::SpikeVector;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeFrame {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major (y, x, c) bitset packed into u64 words per pixel would
    /// waste space for small C; we store one bit per (y,x,c) in a flat
    /// bitvec with pixel-major order: index = (y*w + x)*c + ch.
    bits: Vec<u64>,
}

impl SpikeFrame {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, bits: vec![0; (h * w * c).div_ceil(64)] }
    }

    /// Bernoulli(rate) random frame — synthetic workload generator.
    pub fn random(h: usize, w: usize, c: usize, rate: f64,
                  rng: &mut Rng) -> Self {
        let mut f = Self::zeros(h, w, c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if rng.bernoulli(rate) {
                        f.set(y, x, ch);
                    }
                }
            }
        }
        f
    }

    /// Build from f32 {0,1} planes in (H, W, C) order (the python side's
    /// layout; used when loading spike tensors produced by the runtime).
    pub fn from_f32(h: usize, w: usize, c: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), h * w * c);
        let mut f = Self::zeros(h, w, c);
        for (i, &v) in data.iter().enumerate() {
            if v >= 0.5 {
                let ch = i % c;
                let x = (i / c) % w;
                let y = i / (c * w);
                f.set(y, x, ch);
            }
        }
        f
    }

    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.h * self.w * self.c];
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..self.c {
                    if self.get(y, x, ch) {
                        out[(y * self.w + x) * self.c + ch] = 1.0;
                    }
                }
            }
        }
        out
    }

    #[inline]
    fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize) {
        let i = self.idx(y, x, ch);
        self.bits[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        let i = self.idx(y, x, ch);
        (self.bits[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Extract the spike vector (all channels) at one pixel.
    pub fn vector(&self, y: usize, x: usize) -> SpikeVector {
        let mut v = SpikeVector::zeros(self.c);
        for ch in 0..self.c {
            if self.get(y, x, ch) {
                v.set(ch);
            }
        }
        v
    }

    /// Write a spike vector into one pixel.
    pub fn set_vector(&mut self, y: usize, x: usize, v: &SpikeVector) {
        debug_assert_eq!(v.channels, self.c);
        for ch in v.iter_active() {
            self.set(y, x, ch);
        }
    }

    /// Total spike count.
    pub fn count(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Mean firing rate.
    pub fn rate(&self) -> f64 {
        self.count() as f64 / (self.h * self.w * self.c) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut f = SpikeFrame::zeros(4, 5, 3);
        f.set(0, 0, 0);
        f.set(3, 4, 2);
        f.set(1, 2, 1);
        assert!(f.get(0, 0, 0) && f.get(3, 4, 2) && f.get(1, 2, 1));
        assert!(!f.get(0, 0, 1));
        assert_eq!(f.count(), 3);
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(3);
        let f = SpikeFrame::random(6, 7, 5, 0.4, &mut rng);
        let back = SpikeFrame::from_f32(6, 7, 5, &f.to_f32());
        assert_eq!(f, back);
    }

    #[test]
    fn vector_extraction_matches_get() {
        let mut f = SpikeFrame::zeros(2, 2, 70);
        f.set(1, 0, 0);
        f.set(1, 0, 69);
        let v = f.vector(1, 0);
        assert_eq!(v.popcount(), 2);
        assert!(v.get(0) && v.get(69));
        assert!(f.vector(0, 0).is_empty());
    }

    #[test]
    fn random_rate_is_close() {
        let mut rng = Rng::new(11);
        let f = SpikeFrame::random(32, 32, 16, 0.25, &mut rng);
        assert!((f.rate() - 0.25).abs() < 0.03, "rate {}", f.rate());
    }
}
