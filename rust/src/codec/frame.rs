//! Dense spike frame: (H, W, C) binary feature map, channel-last.
//!
//! Matches the python/L1 layout (`kernels/ref.py` conventions): channel-
//! last so a pixel's spike vector (all C channels, channel-sorted) is
//! contiguous — the paper's compressed & sorted representation.

use super::{or_bits, SpikeVector};
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeFrame {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major (y, x, c) bitset packed into u64 words per pixel would
    /// waste space for small C; we store one bit per (y,x,c) in a flat
    /// bitvec with pixel-major order: index = (y*w + x)*c + ch.
    bits: Vec<u64>,
}

impl SpikeFrame {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, bits: vec![0; (h * w * c).div_ceil(64)] }
    }

    /// Bernoulli(rate) random frame — synthetic workload generator.
    pub fn random(h: usize, w: usize, c: usize, rate: f64,
                  rng: &mut Rng) -> Self {
        let mut f = Self::zeros(h, w, c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if rng.bernoulli(rate) {
                        f.set(y, x, ch);
                    }
                }
            }
        }
        f
    }

    /// Build from f32 {0,1} planes in (H, W, C) order (the python side's
    /// layout; used when loading spike tensors produced by the runtime).
    pub fn from_f32(h: usize, w: usize, c: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), h * w * c);
        let mut f = Self::zeros(h, w, c);
        for (i, &v) in data.iter().enumerate() {
            if v >= 0.5 {
                let ch = i % c;
                let x = (i / c) % w;
                let y = i / (c * w);
                f.set(y, x, ch);
            }
        }
        f
    }

    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.h * self.w * self.c];
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..self.c {
                    if self.get(y, x, ch) {
                        out[(y * self.w + x) * self.c + ch] = 1.0;
                    }
                }
            }
        }
        out
    }

    #[inline]
    fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize) {
        let i = self.idx(y, x, ch);
        self.bits[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        let i = self.idx(y, x, ch);
        (self.bits[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Extract the spike vector (all channels) at one pixel.
    pub fn vector(&self, y: usize, x: usize) -> SpikeVector {
        let mut v = SpikeVector::zeros(self.c);
        self.vector_into(y, x, &mut v);
        v
    }

    /// Extract one pixel's spike vector into `v`, overwriting it —
    /// word-level (whole words shifted out of the frame's bitvec), so
    /// row ingest into the line buffer is memcpy-shaped instead of a
    /// bit-by-bit walk (§Perf hot path).
    pub fn vector_into(&self, y: usize, x: usize, v: &mut SpikeVector) {
        debug_assert_eq!(v.channels, self.c);
        self.pixel_words(y, x, v.words_mut(), false);
    }

    /// OR one pixel's spike vector into `v` — the pooling reduce
    /// primitive (Fig. 7b), word-level.
    pub fn or_vector_into(&self, y: usize, x: usize, v: &mut SpikeVector) {
        debug_assert_eq!(v.channels, self.c);
        self.pixel_words(y, x, v.words_mut(), true);
    }

    /// Extract the pixel's `c` bits, LSB-aligned, into `dst` words
    /// (overwrite or OR).
    fn pixel_words(&self, y: usize, x: usize, dst: &mut [u64], or: bool) {
        let pos = (y * self.w + x) * self.c;
        let n = self.c;
        let nw = n.div_ceil(64);
        debug_assert!(dst.len() >= nw);
        for (i, d) in dst.iter_mut().enumerate().take(nw) {
            let bit = pos + i * 64;
            let (word, off) = (bit / 64, bit % 64);
            let mut w = self.bits[word] >> off;
            if off > 0 {
                if let Some(&hi) = self.bits.get(word + 1) {
                    w |= hi << (64 - off);
                }
            }
            let take = (n - i * 64).min(64);
            if take < 64 {
                w &= (1u64 << take) - 1;
            }
            if or {
                *d |= w;
            } else {
                *d = w;
            }
        }
        if !or {
            for d in dst.iter_mut().skip(nw) {
                *d = 0;
            }
        }
    }

    /// True when no channel spikes at `(y, x)` — word-level and
    /// allocation-free (the event-codec stats hot path).
    pub fn pixel_is_empty(&self, y: usize, x: usize) -> bool {
        let start = (y * self.w + x) * self.c;
        let end = start + self.c;
        let (w0, w1) = (start / 64, (end - 1) / 64);
        for w in w0..=w1 {
            let mut word = self.bits[w];
            if w == w0 {
                word &= !0u64 << (start % 64);
            }
            if w == w1 {
                let top = end - w * 64; // in 1..=64
                if top < 64 {
                    word &= (1u64 << top) - 1;
                }
            }
            if word != 0 {
                return false;
            }
        }
        true
    }

    /// Write (OR) a spike vector into one pixel — word-level.
    pub fn set_vector(&mut self, y: usize, x: usize, v: &SpikeVector) {
        debug_assert_eq!(v.channels, self.c);
        let pos = (y * self.w + x) * self.c;
        or_bits(&mut self.bits, pos, v.words(), self.c);
    }

    /// Zero every bit in place (frame reuse across timesteps — the
    /// zero-allocation hot path never rebuilds output frames).
    pub fn clear(&mut self) {
        self.bits.iter_mut().for_each(|w| *w = 0);
    }

    /// Reshape to `(h, w, c)` and zero the contents, reusing the bit
    /// buffer when the word count already matches (it only allocates
    /// on a genuine shape change — i.e. never in steady state).
    pub fn reset(&mut self, h: usize, w: usize, c: usize) {
        let words = (h * w * c).div_ceil(64);
        self.h = h;
        self.w = w;
        self.c = c;
        if self.bits.len() == words {
            self.clear();
        } else {
            self.bits.clear();
            self.bits.resize(words, 0);
        }
    }

    /// OR `src`'s rows into rows `[y0, y0 + src.h)` of `self` — one
    /// word-level pass, used to merge intra-frame band outputs (bands
    /// may share a boundary word, so each writes its own frame and the
    /// coordinator merges deterministically).
    pub fn or_rows_from(&mut self, src: &SpikeFrame, y0: usize) {
        assert_eq!((self.w, self.c), (src.w, src.c), "band shape");
        assert!(y0 + src.h <= self.h, "band rows out of range");
        or_bits(&mut self.bits, y0 * self.w * self.c, &src.bits,
                src.h * src.w * src.c);
    }

    /// Number of `u64` words that carry one row's `w * c` bits
    /// LSB-aligned — the sizing contract for the row buffers that
    /// [`SpikeFrame::row_words_into`] fills (inter-layer streaming
    /// channels size their recycled buffers with this).
    pub fn row_words(&self) -> usize {
        (self.w * self.c).div_ceil(64)
    }

    /// Extract row `y`'s `w * c` bits, LSB-aligned, into `dst`,
    /// overwriting the first [`SpikeFrame::row_words`] words —
    /// allocation-free. The producer side of the streamed inter-layer
    /// row channels (a row is not word-aligned inside the flat bit
    /// buffer, so this is a shifted word walk like `pixel_words`).
    pub fn row_words_into(&self, y: usize, dst: &mut [u64]) {
        let n = self.w * self.c;
        let pos = y * n;
        let nw = n.div_ceil(64);
        debug_assert!(y < self.h);
        debug_assert!(dst.len() >= nw);
        for (i, d) in dst.iter_mut().enumerate().take(nw) {
            let bit = pos + i * 64;
            let (word, off) = (bit / 64, bit % 64);
            let mut w = self.bits[word] >> off;
            if off > 0 {
                if let Some(&hi) = self.bits.get(word + 1) {
                    w |= hi << (64 - off);
                }
            }
            let take = (n - i * 64).min(64);
            if take < 64 {
                w &= (1u64 << take) - 1;
            }
            *d = w;
        }
    }

    /// OR an LSB-aligned row payload (as produced by
    /// [`SpikeFrame::row_words_into`]) into row `y` — the consumer side
    /// of the streamed row channels, staging received rows into the
    /// next layer's input frame.
    pub fn or_row_words(&mut self, y: usize, src: &[u64]) {
        let n = self.w * self.c;
        debug_assert!(y < self.h);
        debug_assert!(src.len() >= n.div_ceil(64));
        or_bits(&mut self.bits, y * n, src, n);
    }

    /// Total spike count.
    pub fn count(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Mean firing rate.
    pub fn rate(&self) -> f64 {
        self.count() as f64 / (self.h * self.w * self.c) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut f = SpikeFrame::zeros(4, 5, 3);
        f.set(0, 0, 0);
        f.set(3, 4, 2);
        f.set(1, 2, 1);
        assert!(f.get(0, 0, 0) && f.get(3, 4, 2) && f.get(1, 2, 1));
        assert!(!f.get(0, 0, 1));
        assert_eq!(f.count(), 3);
    }

    #[test]
    fn row_words_roundtrip_every_row() {
        // Odd w*c so rows straddle word boundaries at every offset.
        let mut rng = Rng::new(41);
        for (w, c) in [(5usize, 3usize), (7, 9), (3, 64), (4, 33)] {
            let src = SpikeFrame::random(6, w, c, 0.4, &mut rng);
            let mut buf = vec![0u64; src.row_words()];
            let mut dst = SpikeFrame::zeros(6, w, c);
            for y in 0..src.h {
                src.row_words_into(y, &mut buf);
                dst.or_row_words(y, &buf);
            }
            assert_eq!(dst, src, "w={w} c={c}");
        }
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(3);
        let f = SpikeFrame::random(6, 7, 5, 0.4, &mut rng);
        let back = SpikeFrame::from_f32(6, 7, 5, &f.to_f32());
        assert_eq!(f, back);
    }

    #[test]
    fn vector_extraction_matches_get() {
        let mut f = SpikeFrame::zeros(2, 2, 70);
        f.set(1, 0, 0);
        f.set(1, 0, 69);
        let v = f.vector(1, 0);
        assert_eq!(v.popcount(), 2);
        assert!(v.get(0) && v.get(69));
        assert!(f.vector(0, 0).is_empty());
    }

    /// Word-level extraction equals the bit-by-bit definition on
    /// channel counts that straddle word boundaries at odd offsets.
    #[test]
    fn vector_into_matches_bitwise_walk() {
        let mut rng = Rng::new(17);
        for c in [1, 3, 63, 64, 65, 130] {
            let f = SpikeFrame::random(3, 5, c, 0.4, &mut rng);
            let mut v = SpikeVector::zeros(c);
            for y in 0..3 {
                for x in 0..5 {
                    f.vector_into(y, x, &mut v);
                    for ch in 0..c {
                        assert_eq!(v.get(ch), f.get(y, x, ch),
                                   "c={c} ({y},{x},{ch})");
                    }
                    // OR variant accumulates instead of overwriting.
                    let before = v.popcount();
                    f.or_vector_into(y, x, &mut v);
                    assert_eq!(v.popcount(), before);
                }
            }
        }
    }

    #[test]
    fn pixel_is_empty_matches_vector() {
        let mut rng = Rng::new(21);
        for c in [1, 5, 64, 100] {
            let f = SpikeFrame::random(4, 6, c, 0.05, &mut rng);
            for y in 0..4 {
                for x in 0..6 {
                    assert_eq!(f.pixel_is_empty(y, x),
                               f.vector(y, x).is_empty(),
                               "c={c} ({y},{x})");
                }
            }
        }
    }

    #[test]
    fn reset_reuses_and_reshapes() {
        let mut f = SpikeFrame::zeros(4, 4, 8);
        f.set(1, 2, 3);
        f.reset(4, 4, 8);
        assert_eq!(f.count(), 0);
        f.reset(2, 2, 3);
        assert_eq!((f.h, f.w, f.c), (2, 2, 3));
        f.set(1, 1, 2);
        assert!(f.get(1, 1, 2));
    }

    #[test]
    fn or_rows_from_places_band_rows() {
        let mut rng = Rng::new(23);
        let full = SpikeFrame::random(6, 4, 3, 0.5, &mut rng);
        // Split into two bands, merge back, expect equality.
        let mut top = SpikeFrame::zeros(2, 4, 3);
        let mut bot = SpikeFrame::zeros(4, 4, 3);
        for y in 0..6 {
            for x in 0..4 {
                for ch in 0..3 {
                    if full.get(y, x, ch) {
                        if y < 2 {
                            top.set(y, x, ch);
                        } else {
                            bot.set(y - 2, x, ch);
                        }
                    }
                }
            }
        }
        let mut merged = SpikeFrame::zeros(6, 4, 3);
        merged.or_rows_from(&top, 0);
        merged.or_rows_from(&bot, 2);
        assert_eq!(merged, full);
    }

    #[test]
    fn random_rate_is_close() {
        let mut rng = Rng::new(11);
        let f = SpikeFrame::random(32, 32, 16, 0.25, &mut rng);
        assert!((f.rate() - 0.25).abs() < 0.03, "rate {}", f.rate());
    }
}
