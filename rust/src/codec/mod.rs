//! Compressed & sorted spike representation + spike-event encoding.
//!
//! Paper SectionIV-C: one **spike vector** per pixel holds the spikes of all
//! `C` channels at that location, in channel order, so a single memory
//! access fetches the whole vector ("compressed and sorted").  Here a
//! spike vector is a bit-packed `Vec<u64>` of `C` bits.
//!
//! Paper SectionIV-E.1: between pipeline stages, sparse frames are encoded as
//! **spike events** of `log2(Hi) + log2(Wi) + Ci` bits — coordinates plus
//! the raw channel vector — and only non-empty pixels are transmitted.
//! `EventCodec` implements that encoding, its decoder, and the
//! bits-on-the-wire accounting used by the interconnect energy model.
//!
//! The [`stream`] submodule extends the same representation to the
//! *ingestion* boundary: sorted DVS-style address events are
//! accumulated straight into word-packed [`SpikeFrame`] windows
//! ([`stream::EventStream`]) — the event-driven serving path that
//! never materialises a dense `f32` image.

pub mod frame;
pub mod stream;

pub use frame::SpikeFrame;
pub use stream::{DvsEvent, EventStream, WindowPolicy};

/// Bit-packed spike vector: one pixel, `C` channels, channel-sorted.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SpikeVector {
    pub channels: usize,
    words: Vec<u64>,
}

impl SpikeVector {
    pub fn zeros(channels: usize) -> Self {
        Self { channels, words: vec![0; channels.div_ceil(64)] }
    }

    pub fn from_bits(bits: &[bool]) -> Self {
        let mut v = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i);
            }
        }
        v
    }

    #[inline]
    pub fn set(&mut self, c: usize) {
        debug_assert!(c < self.channels);
        self.words[c / 64] |= 1 << (c % 64);
    }

    #[inline]
    pub fn get(&self, c: usize) -> bool {
        debug_assert!(c < self.channels);
        (self.words[c / 64] >> (c % 64)) & 1 == 1
    }

    /// Number of active channels (spike count at this pixel).
    pub fn popcount(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Logical OR (the pooling primitive, Fig. 7b).
    pub fn or(&self, other: &SpikeVector) -> SpikeVector {
        debug_assert_eq!(self.channels, other.channels);
        SpikeVector {
            channels: self.channels,
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(a, b)| a | b)
                .collect(),
        }
    }

    /// Iterate active channel indices in sorted order — the "sorted"
    /// property the PE weight-fetch sequencer relies on.
    pub fn iter_active(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut rem = w;
            std::iter::from_fn(move || {
                if rem == 0 {
                    None
                } else {
                    let b = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Raw words (for width accounting / hashing).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable raw words — the word-level ingest path
    /// ([`SpikeFrame::vector_into`] writes whole words instead of
    /// testing bits one by one; §Perf hot path).
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Zero every bit in place (buffer reuse across frames — the
    /// zero-allocation hot path never rebuilds vectors).
    #[inline]
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }
}

/// OR `nbits` bits of `src` (LSB-first words) into `dst` at bit offset
/// `pos`; returns the offset past the written range. Target bits must
/// currently be zero when an overwrite (rather than an OR) is
/// intended. The single word-level bit-packing primitive shared by the
/// frame codec and the word-parallel compute backend.
#[inline]
pub fn or_bits(dst: &mut [u64], mut pos: usize, src: &[u64],
               nbits: usize) -> usize {
    let mut remaining = nbits;
    let mut si = 0;
    while remaining > 0 {
        let take = remaining.min(64);
        let mut w = src[si];
        if take < 64 {
            w &= (1u64 << take) - 1;
        }
        let (word, off) = (pos / 64, pos % 64);
        dst[word] |= w << off;
        if off + take > 64 {
            // off >= 1 here (take <= 64), so the shift is in range.
            dst[word + 1] |= w >> (64 - off);
        }
        pos += take;
        remaining -= take;
        si += 1;
    }
    pos
}

/// One spike event on the inter-layer link: pixel coordinates + the
/// pixel's channel vector.
#[derive(Debug, Clone, PartialEq)]
pub struct SpikeEvent {
    pub y: u16,
    pub x: u16,
    pub vector: SpikeVector,
}

/// Encoder/decoder for the inter-layer event stream (paper SectionIV-E.1).
#[derive(Debug, Clone)]
pub struct EventCodec {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CodecStats {
    /// Pixels with at least one spike (events transmitted).
    pub events: usize,
    /// Total pixels scanned.
    pub pixels: usize,
    /// Bits on the wire with event encoding.
    pub encoded_bits: u64,
    /// Bits a dense (raw bitmap) transfer would need.
    pub dense_bits: u64,
}

impl CodecStats {
    /// Compression ratio dense/encoded (>1 = encoding wins).
    pub fn ratio(&self) -> f64 {
        if self.encoded_bits == 0 {
            f64::INFINITY
        } else {
            self.dense_bits as f64 / self.encoded_bits as f64
        }
    }
}

impl EventCodec {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c }
    }

    /// Bits per event: `log2(Hi) + log2(Wi) + Ci` (paper SectionIV-E.1).
    pub fn bits_per_event(&self) -> u64 {
        (usize::BITS - (self.h - 1).leading_zeros()) as u64
            + (usize::BITS - (self.w - 1).leading_zeros()) as u64
            + self.c as u64
    }

    /// Wire statistics of encoding `frame` — identical numbers to
    /// [`EventCodec::encode`] without materialising the event list
    /// (allocation-free; the pipeline's per-batch ratio accounting).
    pub fn stats(&self, frame: &SpikeFrame) -> CodecStats {
        assert_eq!((frame.h, frame.w, frame.c), (self.h, self.w, self.c));
        let mut events = 0usize;
        for y in 0..self.h {
            for x in 0..self.w {
                if !frame.pixel_is_empty(y, x) {
                    events += 1;
                }
            }
        }
        CodecStats {
            events,
            pixels: self.h * self.w,
            encoded_bits: events as u64 * self.bits_per_event(),
            dense_bits: (self.h * self.w * self.c) as u64,
        }
    }

    /// Encode a frame into its non-empty pixel events (+ wire stats).
    pub fn encode(&self, frame: &SpikeFrame) -> (Vec<SpikeEvent>, CodecStats) {
        assert_eq!((frame.h, frame.w, frame.c), (self.h, self.w, self.c));
        let mut events = Vec::new();
        for y in 0..self.h {
            for x in 0..self.w {
                let v = frame.vector(y, x);
                if !v.is_empty() {
                    events.push(SpikeEvent {
                        y: y as u16,
                        x: x as u16,
                        vector: v,
                    });
                }
            }
        }
        let stats = CodecStats {
            events: events.len(),
            pixels: self.h * self.w,
            encoded_bits: events.len() as u64 * self.bits_per_event(),
            dense_bits: (self.h * self.w * self.c) as u64,
        };
        (events, stats)
    }

    /// Decode events back into a dense frame (the hardware decoder).
    pub fn decode(&self, events: &[SpikeEvent]) -> SpikeFrame {
        let mut f = SpikeFrame::zeros(self.h, self.w, self.c);
        for e in events {
            for ch in e.vector.iter_active() {
                f.set(e.y as usize, e.x as usize, ch);
            }
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn vector_set_get_popcount() {
        let mut v = SpikeVector::zeros(130);
        v.set(0);
        v.set(64);
        v.set(129);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1));
        assert_eq!(v.popcount(), 3);
        assert_eq!(v.iter_active().collect::<Vec<_>>(), vec![0, 64, 129]);
    }

    #[test]
    fn vector_or_is_union() {
        let a = SpikeVector::from_bits(&[true, false, true, false]);
        let b = SpikeVector::from_bits(&[false, false, true, true]);
        let o = a.or(&b);
        assert_eq!(o.iter_active().collect::<Vec<_>>(), vec![0, 2, 3]);
    }

    #[test]
    fn bits_per_event_formula() {
        // 28x28x16: log2(28)->5 bits, log2(28)->5 bits, 16 channel bits.
        let c = EventCodec::new(28, 28, 16);
        assert_eq!(c.bits_per_event(), 5 + 5 + 16);
        // Powers of two need exactly log2 bits.
        let c = EventCodec::new(32, 32, 64);
        assert_eq!(c.bits_per_event(), 5 + 5 + 64);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(42);
        let f = SpikeFrame::random(16, 16, 32, 0.2, &mut rng);
        let codec = EventCodec::new(16, 16, 32);
        let (events, stats) = codec.encode(&f);
        assert_eq!(stats.pixels, 256);
        let back = codec.decode(&events);
        assert_eq!(f, back);
    }

    #[test]
    fn sparse_frames_compress() {
        let mut rng = Rng::new(7);
        let codec = EventCodec::new(32, 32, 64);
        // 5% firing rate: most pixels empty -> encoding wins big.
        let f = SpikeFrame::random(32, 32, 64, 0.002, &mut rng);
        let (_, stats) = codec.encode(&f);
        assert!(stats.ratio() > 2.0, "ratio {}", stats.ratio());
        // Dense frame: encoding loses (coordinate overhead).
        let f = SpikeFrame::random(32, 32, 64, 0.9, &mut rng);
        let (_, stats) = codec.encode(&f);
        assert!(stats.ratio() < 1.0);
    }

    /// The allocation-free stats pass reports exactly what encode
    /// reports.
    #[test]
    fn stats_match_encode() {
        let mut rng = Rng::new(19);
        for (c, rate) in [(3, 0.3), (64, 0.01), (70, 0.2)] {
            let f = SpikeFrame::random(9, 7, c, rate, &mut rng);
            let codec = EventCodec::new(9, 7, c);
            let (_, want) = codec.encode(&f);
            assert_eq!(codec.stats(&f), want, "c={c}");
        }
    }

    #[test]
    fn or_bits_packs_across_word_boundaries() {
        // Three 40-bit chunks: bits straddle the first word boundary.
        let mut dst = vec![0u64; 2];
        let mut pos = 0;
        for k in 0..3u64 {
            let src = [0b1011 | (k << 36)];
            pos = or_bits(&mut dst, pos, &src, 40);
        }
        assert_eq!(pos, 120);
        for k in 0..3 {
            let base = k * 40;
            for (bit, want) in [(0, true), (1, true), (2, false),
                                (3, true)] {
                let p = base + bit;
                let got = (dst[p / 64] >> (p % 64)) & 1 == 1;
                assert_eq!(got, want, "chunk {k} bit {bit}");
            }
        }
    }

    #[test]
    fn empty_frame_zero_events() {
        let f = SpikeFrame::zeros(8, 8, 16);
        let (events, stats) = EventCodec::new(8, 8, 16).encode(&f);
        assert!(events.is_empty());
        assert_eq!(stats.encoded_bits, 0);
    }
}
