//! Streaming event-driven ingestion: sorted address events in,
//! single-timestep [`SpikeFrame`] windows out.
//!
//! The paper's headline claim is *event-driven, single-timestep*
//! inference over the compressed & sorted spike representation
//! (SectionIV-C / SectionIV-E.1) — yet a dense-image serving path has to
//! rate-encode host-side and reconstruct exactly the representation
//! the sensor already produced. This module is the native path: a
//! DVS-style address-event stream `(x, y, c, t)` is accumulated
//! straight into the word-packed [`SpikeFrame`] (single-bit word-level
//! ORs and [`SpikeFrame::set_vector`] for whole-pixel vectors — no
//! dense `f32` decode, no rate encoding) and windowed into
//! single-timestep frames by event count or time horizon.
//!
//! [`EventStream`] is double-buffered and **zero-allocation in steady
//! state**: the accumulating frame and the completed window are two
//! preallocated [`SpikeFrame`]s that swap roles at each window
//! boundary, so a million-event stream touches the allocator exactly
//! twice (at construction).
//!
//! # Event wire/file format
//!
//! One event is a fixed 12-byte little-endian record — the unit of the
//! server's `mode: "events"` binary protocol (`server` module docs)
//! and of the `.aer` files `gen-events` writes and `run --events`
//! reads:
//!
//! ```text
//! offset  size  field
//!      0     2  x            u16 LE, column in [0, W)
//!      2     2  y            u16 LE, row in [0, H)
//!      4     2  c            u16 LE, channel in [0, C)
//!      6     2  reserved     u16 LE, must be 0 (polarity/flags later)
//!      8     4  t            u32 LE, timestamp in microseconds
//! ```
//!
//! Records must be sorted by non-decreasing `t` — the same "sorted"
//! property the PE weight-fetch sequencer relies on for channels
//! applies to the stream in time.
//!
//! ```
//! use sti_snn::codec::stream::{DvsEvent, EventStream, WindowPolicy};
//!
//! let mut s = EventStream::new(4, 4, 2, WindowPolicy::Count(3)).unwrap();
//! for (i, (x, y, c)) in [(0, 0, 0), (1, 2, 1), (3, 3, 0)].iter()
//!     .enumerate()
//! {
//!     let done = s
//!         .push(DvsEvent { x: *x, y: *y, c: *c, t: i as u32 })
//!         .unwrap();
//!     if done {
//!         // Third event completes the window: 3 spikes, bit-packed.
//!         assert_eq!(s.window().count(), 3);
//!         assert!(s.window().get(2, 1, 1));
//!     }
//! }
//! assert_eq!(s.stats().windows, 1);
//! ```

use anyhow::{bail, Result};

use super::{SpikeFrame, SpikeVector};
use crate::util::rng::Rng;

/// One DVS-style address event: a single spike at `(y, x, c)` at time
/// `t` (microseconds). See the module docs for the 12-byte wire record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DvsEvent {
    /// Column, `[0, W)`.
    pub x: u16,
    /// Row, `[0, H)`.
    pub y: u16,
    /// Channel (polarity for 2-channel DVS input), `[0, C)`.
    pub c: u16,
    /// Timestamp in microseconds; streams require non-decreasing `t`.
    pub t: u32,
}

impl DvsEvent {
    /// Size of one little-endian wire record (module docs).
    pub const WIRE_BYTES: usize = 12;

    /// Append this event's 12-byte wire record to `out`.
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.x.to_le_bytes());
        out.extend_from_slice(&self.y.to_le_bytes());
        out.extend_from_slice(&self.c.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&self.t.to_le_bytes());
    }

    /// Parse one wire record (caller supplies exactly
    /// [`DvsEvent::WIRE_BYTES`] bytes).
    pub fn from_wire(b: &[u8]) -> Result<DvsEvent> {
        if b.len() != Self::WIRE_BYTES {
            bail!("event record is {} bytes, expected {}", b.len(),
                  Self::WIRE_BYTES);
        }
        let u16_at = |i: usize| u16::from_le_bytes([b[i], b[i + 1]]);
        if u16_at(6) != 0 {
            bail!("event record reserved field is non-zero");
        }
        Ok(DvsEvent {
            x: u16_at(0),
            y: u16_at(2),
            c: u16_at(4),
            t: u32::from_le_bytes([b[8], b[9], b[10], b[11]]),
        })
    }
}

/// Encode a sorted event slice into its concatenated wire records
/// (the payload format of one binary event batch / an `.aer` file).
pub fn encode_events(events: &[DvsEvent]) -> Vec<u8> {
    let mut out = Vec::with_capacity(events.len() * DvsEvent::WIRE_BYTES);
    for e in events {
        e.write_wire(&mut out);
    }
    out
}

/// Decode concatenated wire records (must be a whole number of
/// 12-byte events).
pub fn decode_events(bytes: &[u8]) -> Result<Vec<DvsEvent>> {
    if bytes.len() % DvsEvent::WIRE_BYTES != 0 {
        bail!("event payload of {} bytes is not a multiple of {}",
              bytes.len(), DvsEvent::WIRE_BYTES);
    }
    bytes
        .chunks_exact(DvsEvent::WIRE_BYTES)
        .map(DvsEvent::from_wire)
        .collect()
}

/// When a window of events closes and becomes one single-timestep
/// frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowPolicy {
    /// Close once the window holds at least `n` events (n > 0).
    /// Single-event pushes close at exactly `n`; a multi-channel
    /// [`EventStream::push_vector`] counts all its active channels at
    /// once and can overshoot. Duplicate events (same pixel +
    /// channel) still count toward `n`.
    Count(usize),
    /// Time horizon: a window opens at its first event's timestamp
    /// `t0` and covers `[t0, t0 + horizon_us)`; the first event at or
    /// past the horizon closes it and opens the next window. Windows
    /// with no events are never emitted — a gap longer than the
    /// horizon simply delays the next window's start.
    TimeUs(u32),
}

impl WindowPolicy {
    /// Parse the CLI/wire syntax: `count:N` or `us:N`.
    pub fn parse(s: &str) -> Option<WindowPolicy> {
        let (kind, n) = s.split_once(':')?;
        match kind {
            "count" => n.parse().ok().filter(|&n| n > 0)
                .map(WindowPolicy::Count),
            "us" => n.parse().ok().filter(|&n| n > 0)
                .map(WindowPolicy::TimeUs),
            _ => None,
        }
    }
}

impl std::fmt::Display for WindowPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WindowPolicy::Count(n) => write!(f, "count:{n}"),
            WindowPolicy::TimeUs(us) => write!(f, "us:{us}"),
        }
    }
}

/// Ingestion counters of one [`EventStream`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// Events accepted (single events; a pushed vector counts its
    /// active channels).
    pub events: u64,
    /// Windows completed (including any final partial window flushed).
    pub windows: u64,
}

/// Accumulates sorted address events into word-packed single-timestep
/// [`SpikeFrame`] windows — the module-level docs describe the policy
/// semantics and the zero-allocation double-buffering.
#[derive(Debug, Clone)]
pub struct EventStream {
    h: usize,
    w: usize,
    c: usize,
    policy: WindowPolicy,
    /// The window currently accumulating.
    frame: SpikeFrame,
    /// The last completed window ([`EventStream::window`]).
    ready: SpikeFrame,
    /// Events in the accumulating window (0 = window not yet open).
    in_window: usize,
    /// First event timestamp of the accumulating window.
    window_start: u32,
    /// Last accepted timestamp (sortedness check).
    last_t: u32,
    stats: StreamStats,
}

impl EventStream {
    /// A stream producing `(h, w, c)` frames under `policy`.
    pub fn new(h: usize, w: usize, c: usize, policy: WindowPolicy)
               -> Result<Self> {
        if h == 0 || w == 0 || c == 0 {
            bail!("event stream shape ({h}, {w}, {c}) has a zero \
                   dimension");
        }
        match policy {
            WindowPolicy::Count(0) => bail!("count window must be > 0"),
            WindowPolicy::TimeUs(0) => bail!("time window must be > 0"),
            _ => {}
        }
        Ok(Self {
            h,
            w,
            c,
            policy,
            frame: SpikeFrame::zeros(h, w, c),
            ready: SpikeFrame::zeros(h, w, c),
            in_window: 0,
            window_start: 0,
            last_t: 0,
            stats: StreamStats::default(),
        })
    }

    /// Frame shape `(h, w, c)` this stream produces.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    pub fn policy(&self) -> WindowPolicy {
        self.policy
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Events in the currently-open (not yet emitted) window.
    pub fn pending_events(&self) -> usize {
        self.in_window
    }

    /// Validate coordinates + timestamp order, close a time window the
    /// event falls past, and account the window bookkeeping. Returns
    /// true when the *previous* window was closed (time policy).
    fn admit(&mut self, x: u16, y: u16, t: u32) -> Result<bool> {
        // Channel range is checked by the callers (it differs between
        // single events and whole vectors).
        if (y as usize) >= self.h || (x as usize) >= self.w {
            bail!("event ({x}, {y}) outside frame {}x{}", self.w, self.h);
        }
        if t < self.last_t {
            bail!("unsorted event stream: t {t} after {}", self.last_t);
        }
        self.last_t = t;
        let mut closed = false;
        if let WindowPolicy::TimeUs(horizon) = self.policy {
            if self.in_window > 0
                && t as u64 >= self.window_start as u64 + horizon as u64
            {
                self.emit();
                closed = true;
            }
        }
        if self.in_window == 0 {
            self.window_start = t;
        }
        Ok(closed)
    }

    /// Swap the accumulating frame into the ready slot and reset.
    fn emit(&mut self) {
        std::mem::swap(&mut self.frame, &mut self.ready);
        self.frame.clear();
        self.in_window = 0;
        self.stats.windows += 1;
    }

    /// Push one event. `Ok(true)` means a window just completed — read
    /// it with [`EventStream::window`] before the next push overwrites
    /// it (under [`WindowPolicy::TimeUs`] the completed window does
    /// NOT contain this event; it opened the next one).
    pub fn push(&mut self, ev: DvsEvent) -> Result<bool> {
        if ev.c as usize >= self.c {
            bail!("event channel {} outside C={}", ev.c, self.c);
        }
        let closed = self.admit(ev.x, ev.y, ev.t)?;
        self.frame.set(ev.y as usize, ev.x as usize, ev.c as usize);
        self.in_window += 1;
        self.stats.events += 1;
        Ok(closed || self.count_done())
    }

    /// Push one whole-pixel spike vector (the inter-layer event
    /// encoding of SectionIV-E.1: coordinates + channel vector) through
    /// the word-level [`SpikeFrame::set_vector`] path. Counts its
    /// active channels as events; an empty vector is rejected.
    pub fn push_vector(&mut self, x: u16, y: u16, v: &SpikeVector, t: u32)
                       -> Result<bool> {
        if v.channels != self.c {
            bail!("vector of {} channels pushed into C={}", v.channels,
                  self.c);
        }
        let spikes = v.popcount();
        if spikes == 0 {
            bail!("empty spike vector at ({x}, {y})");
        }
        let closed = self.admit(x, y, t)?;
        self.frame.set_vector(y as usize, x as usize, v);
        self.in_window += spikes;
        self.stats.events += spikes as u64;
        Ok(closed || self.count_done())
    }

    fn count_done(&mut self) -> bool {
        if let WindowPolicy::Count(n) = self.policy {
            if self.in_window >= n {
                self.emit();
                return true;
            }
        }
        false
    }

    /// The last completed window. Valid after a `push` returned true
    /// or a [`EventStream::flush`] returned `Some`; overwritten when
    /// the next window completes.
    pub fn window(&self) -> &SpikeFrame {
        &self.ready
    }

    /// Close the open partial window, if any (end of stream).
    pub fn flush(&mut self) -> Option<&SpikeFrame> {
        if self.in_window == 0 {
            return None;
        }
        self.emit();
        Some(&self.ready)
    }
}

/// Decompose a dense frame into its sorted single-spike events, all
/// stamped `t` (raster-scan pixel order, channel-sorted within each
/// pixel — the stream-side mirror of [`super::EventCodec::encode`]).
pub fn frame_events(frame: &SpikeFrame, t: u32) -> Vec<DvsEvent> {
    let mut out = Vec::with_capacity(frame.count());
    for y in 0..frame.h {
        for x in 0..frame.w {
            for ch in 0..frame.c {
                if frame.get(y, x, ch) {
                    out.push(DvsEvent {
                        x: x as u16,
                        y: y as u16,
                        c: ch as u16,
                        t,
                    });
                }
            }
        }
    }
    out
}

/// Synthetic DVS-like workload generator (load testing / benches):
/// `windows` windows of Bernoulli(`rate`) activity over an `(h, w, c)`
/// sensor, each spanning `window_us` microseconds, timestamps jittered
/// uniformly inside the window and sorted. The first event of every
/// window is pinned to the window's base timestamp, so streaming with
/// `WindowPolicy::TimeUs(window_us)` reproduces the generator's
/// windows exactly — the property the events==dense tests and the
/// serving benches rely on.
pub fn synth_events(h: usize, w: usize, c: usize, windows: usize,
                    rate: f64, window_us: u32, seed: u64)
                    -> Vec<DvsEvent> {
    assert!(window_us > 0, "window_us must be > 0");
    // Timestamps are u32 µs on the wire: the whole stream must fit.
    assert!(windows as u64 * window_us as u64 <= u32::MAX as u64,
            "windows ({windows}) x window_us ({window_us}) overflows \
             the u32 µs timestamp space");
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for wi in 0..windows {
        let base = wi as u32 * window_us;
        let start = out.len();
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if rng.bernoulli(rate) {
                        let jitter =
                            rng.below(window_us as usize) as u32;
                        out.push(DvsEvent {
                            x: x as u16,
                            y: y as u16,
                            c: ch as u16,
                            t: base + jitter,
                        });
                    }
                }
            }
        }
        let win = &mut out[start..];
        win.sort_by_key(|e| e.t);
        if let Some(first) = win.first_mut() {
            first.t = base;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(x: u16, y: u16, c: u16, t: u32) -> DvsEvent {
        DvsEvent { x, y, c, t }
    }

    #[test]
    fn count_windows_close_exactly() {
        let mut s = EventStream::new(4, 4, 3, WindowPolicy::Count(2))
            .unwrap();
        assert!(!s.push(ev(0, 0, 0, 5)).unwrap());
        assert!(s.push(ev(1, 1, 2, 5)).unwrap());
        let w = s.window();
        assert_eq!(w.count(), 2);
        assert!(w.get(0, 0, 0) && w.get(1, 1, 2));
        // Next window starts clean.
        assert!(!s.push(ev(2, 2, 1, 6)).unwrap());
        assert_eq!(s.pending_events(), 1);
        let f = s.flush().unwrap();
        assert_eq!(f.count(), 1);
        assert!(f.get(2, 2, 1));
        assert_eq!(s.stats(), StreamStats { events: 3, windows: 2 });
        assert!(s.flush().is_none());
    }

    #[test]
    fn time_windows_split_on_horizon() {
        let mut s = EventStream::new(4, 4, 1, WindowPolicy::TimeUs(100))
            .unwrap();
        assert!(!s.push(ev(0, 0, 0, 1000)).unwrap());
        assert!(!s.push(ev(1, 0, 0, 1099)).unwrap()); // inside [1000,1100)
        // 1100 is past the horizon: closes window 1, opens window 2.
        assert!(s.push(ev(2, 0, 0, 1100)).unwrap());
        assert_eq!(s.window().count(), 2);
        assert!(!s.window().get(0, 2, 0), "closing event not in window");
        // A long gap delays the next window rather than emitting empties.
        assert!(s.push(ev(3, 0, 0, 9999)).unwrap());
        assert_eq!(s.window().count(), 1);
        assert!(s.window().get(0, 2, 0));
        assert_eq!(s.flush().unwrap().count(), 1);
        assert_eq!(s.stats().windows, 3);
    }

    #[test]
    fn rejects_unsorted_and_out_of_range() {
        let mut s = EventStream::new(4, 6, 2, WindowPolicy::Count(10))
            .unwrap();
        s.push(ev(0, 0, 0, 100)).unwrap();
        assert!(s.push(ev(0, 0, 0, 99)).is_err(), "unsorted t");
        assert!(s.push(ev(6, 0, 0, 100)).is_err(), "x out of range");
        assert!(s.push(ev(0, 4, 0, 100)).is_err(), "y out of range");
        assert!(s.push(ev(0, 0, 2, 100)).is_err(), "c out of range");
        // Equal timestamps are fine (sorted = non-decreasing).
        assert!(s.push(ev(1, 1, 1, 100)).is_ok());
    }

    #[test]
    fn zero_shapes_and_policies_rejected() {
        assert!(EventStream::new(0, 4, 1, WindowPolicy::Count(1)).is_err());
        assert!(EventStream::new(4, 4, 1, WindowPolicy::Count(0)).is_err());
        assert!(EventStream::new(4, 4, 1, WindowPolicy::TimeUs(0)).is_err());
    }

    #[test]
    fn vector_push_uses_whole_pixel() {
        let mut s = EventStream::new(2, 2, 70, WindowPolicy::Count(3))
            .unwrap();
        let mut v = SpikeVector::zeros(70);
        v.set(0);
        v.set(69);
        assert!(!s.push_vector(1, 0, &v, 10).unwrap());
        assert_eq!(s.pending_events(), 2);
        assert!(s.push(ev(0, 0, 5, 11)).unwrap());
        let w = s.window();
        assert!(w.get(0, 1, 0) && w.get(0, 1, 69) && w.get(0, 0, 5));
        // Mismatched width and empty vectors are protocol errors.
        assert!(s.push_vector(0, 0, &SpikeVector::zeros(8), 12).is_err());
        assert!(s.push_vector(0, 0, &SpikeVector::zeros(70), 12).is_err());
    }

    #[test]
    fn frame_events_roundtrip_through_stream() {
        let mut rng = Rng::new(33);
        let f = SpikeFrame::random(9, 7, 20, 0.15, &mut rng);
        let events = frame_events(&f, 42);
        assert_eq!(events.len(), f.count());
        let mut s =
            EventStream::new(9, 7, 20, WindowPolicy::Count(events.len()))
                .unwrap();
        let mut done = false;
        for e in &events {
            done = s.push(*e).unwrap();
        }
        assert!(done);
        assert_eq!(*s.window(), f);
    }

    #[test]
    fn synth_time_streaming_reproduces_generator_windows() {
        let (h, w, c, n, us) = (8, 8, 2, 5, 1000u32);
        let events = synth_events(h, w, c, n, 0.2, us, 7);
        assert!(!events.is_empty());
        // Sorted overall (windows are consecutive time ranges).
        assert!(events.windows(2).all(|p| p[0].t <= p[1].t));
        let mut s =
            EventStream::new(h, w, c, WindowPolicy::TimeUs(us)).unwrap();
        let mut windows = 0;
        let mut spikes = 0;
        for e in &events {
            if s.push(*e).unwrap() {
                windows += 1;
                spikes += s.window().count();
            }
        }
        if let Some(f) = s.flush() {
            windows += 1;
            spikes += f.count();
        }
        assert_eq!(windows, n, "one stream window per generator window");
        // Spikes <= events (duplicates OR into the same bit).
        assert!(spikes as u64 <= s.stats().events);
        assert_eq!(s.stats().events, events.len() as u64);
    }

    #[test]
    fn wire_roundtrip() {
        let events = synth_events(16, 16, 2, 2, 0.1, 500, 3);
        let bytes = encode_events(&events);
        assert_eq!(bytes.len(), events.len() * DvsEvent::WIRE_BYTES);
        assert_eq!(decode_events(&bytes).unwrap(), events);
        // Truncated payloads and reserved-field garbage are rejected.
        assert!(decode_events(&bytes[..bytes.len() - 1]).is_err());
        let mut bad = bytes.clone();
        bad[6] = 1;
        assert!(decode_events(&bad).is_err());
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(WindowPolicy::parse("count:64"),
                   Some(WindowPolicy::Count(64)));
        assert_eq!(WindowPolicy::parse("us:1000"),
                   Some(WindowPolicy::TimeUs(1000)));
        assert_eq!(WindowPolicy::parse("count:0"), None);
        assert_eq!(WindowPolicy::parse("ms:5"), None);
        assert_eq!(WindowPolicy::parse("count"), None);
        for p in [WindowPolicy::Count(8), WindowPolicy::TimeUs(250)] {
            assert_eq!(WindowPolicy::parse(&p.to_string()), Some(p));
        }
    }
}
