//! Hardware architecture description: layers, networks, design points.
//!
//! This is the shared vocabulary between the python compile path
//! (`model.spec_dicts` -> `artifacts/<model>/net.json`), the analytical
//! dataflow models (`crate::dataflow`), the cycle-level simulator
//! (`crate::sim`) and the streaming coordinator (`crate::coordinator`).
//!
//! Terminology follows the paper: `Ci/Co` input/output channels,
//! `Hi/Wi/Ho/Wo` feature-map sizes, `Kh/Kw` kernel sizes, `T` inference
//! timesteps, and per-conv-layer **parallel factors** for output-channel
//! parallelism (SectionIV-E.2).

use crate::util::json::Json;

/// Convolution mode of the multi-mode PE (paper Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvMode {
    /// Standard convolution: accumulate across input channels (Fig. 8b).
    Standard,
    /// Depthwise: per-channel taps, no cross-channel accumulation (8c).
    Depthwise,
    /// Pointwise 1x1: no psum adder tree, direct threshold (8d).
    Pointwise,
}

/// One layer of the network, with its input geometry resolved.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv(ConvLayer),
    /// 2x2 stride-2 OR pooling (Fig. 7b).
    Pool { in_h: usize, in_w: usize, c: usize },
    /// Classifier head; output neurons do not fire.
    Fc { n_in: usize, n_out: usize },
}

/// Geometry + mode of one convolutional layer.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvLayer {
    pub mode: ConvMode,
    pub in_h: usize,
    pub in_w: usize,
    pub ci: usize,
    pub co: usize,
    pub kh: usize,
    pub kw: usize,
    pub pad: usize,
    /// Spike-encoding layer: receives the analog frame, runs *outside*
    /// the accelerator (paper SectionV-A: "the first convolution layer is
    /// used for spike encoding, with the encoded spikes serving as the
    /// input to the accelerator"). Excluded from ops/latency accounting.
    pub encoder: bool,
    /// Output-channel parallel factor (SectionIV-E.2); 1 = no parallelism.
    pub parallel: usize,
}

impl ConvLayer {
    pub fn out_h(&self) -> usize {
        self.in_h + 2 * self.pad - self.kh + 1
    }

    pub fn out_w(&self) -> usize {
        self.in_w + 2 * self.pad - self.kw + 1
    }

    /// Synaptic operations (accumulates) per timestep — the paper's "OPs"
    /// (Table IV: GOPS = kFPS x MOPs with MOPs = per-frame accumulates).
    pub fn ops(&self) -> u64 {
        let (ho, wo) = (self.out_h() as u64, self.out_w() as u64);
        match self.mode {
            ConvMode::Standard => {
                ho * wo * self.co as u64 * self.ci as u64
                    * (self.kh * self.kw) as u64
            }
            ConvMode::Depthwise => {
                ho * wo * self.co as u64 * (self.kh * self.kw) as u64
            }
            ConvMode::Pointwise => ho * wo * self.co as u64 * self.ci as u64,
        }
    }

    /// Number of PEs this layer's compute array instantiates:
    /// `Kh*Kw` per output-channel lane (paper SectionIV-B).
    pub fn pes(&self) -> usize {
        self.kh * self.kw * self.parallel
    }

    /// int8 weight footprint in bytes.
    pub fn weight_bytes(&self) -> usize {
        match self.mode {
            ConvMode::Standard => self.kh * self.kw * self.ci * self.co,
            ConvMode::Depthwise => self.kh * self.kw * self.co,
            ConvMode::Pointwise => self.ci * self.co,
        }
    }

    /// Membrane-potential buffer bytes needed when T > 1 (eliminated at
    /// T = 1 — the paper's headline storage saving, Fig. 11).
    ///
    /// 18-bit fixed-point potentials, one per output pixel: the Xilinx
    /// BRAM18 native word width, and the precision that reproduces the
    /// paper's "126 KB saved" for SCNN5 (55296 neurons x 18 bit
    /// = 124.4 KB).
    pub fn vmem_bytes(&self) -> usize {
        (self.out_h() * self.out_w() * self.co * 18).div_ceil(8)
    }
}

impl Layer {
    pub fn ops(&self) -> u64 {
        match self {
            Layer::Conv(c) if !c.encoder => c.ops(),
            Layer::Conv(_) => 0,
            Layer::Pool { .. } => 0, // OR gates; not counted as synaptic ops
            Layer::Fc { n_in, n_out } => (*n_in * *n_out) as u64,
        }
    }

    pub fn out_shape(&self) -> (usize, usize, usize) {
        match self {
            Layer::Conv(c) => (c.out_h(), c.out_w(), c.co),
            Layer::Pool { in_h, in_w, c } => (in_h / 2, in_w / 2, *c),
            Layer::Fc { n_out, .. } => (1, 1, *n_out),
        }
    }

    pub fn in_shape(&self) -> (usize, usize, usize) {
        match self {
            Layer::Conv(c) => (c.in_h, c.in_w, c.ci),
            Layer::Pool { in_h, in_w, c } => (*in_h, *in_w, *c),
            Layer::Fc { n_in, .. } => (1, 1, *n_in),
        }
    }
}

/// A full network bound to an input geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    pub input: (usize, usize, usize),
    pub layers: Vec<Layer>,
}

impl NetworkSpec {
    /// Total accelerator ops per frame per timestep (encoder excluded).
    pub fn ops_per_frame(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    /// Total PE count across conv layers (the streaming architecture
    /// instantiates every layer's array; paper Table V "PE Array Size").
    pub fn total_pes(&self) -> usize {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(c) if !c.encoder => Some(c.pes()),
                _ => None,
            })
            .sum()
    }

    /// `(H, W, C)` of the frames the accelerator ingests: the input
    /// shape of the first non-encoder layer (i.e. post-encoder), or
    /// the network input when nothing is accelerated. The single home
    /// of this walk — the pipeline, the session, and the CLI event
    /// generator all derive their frame shapes from it.
    pub fn accel_input_shape(&self) -> (usize, usize, usize) {
        for l in &self.layers {
            match l {
                Layer::Conv(c) if c.encoder => continue,
                other => return other.in_shape(),
            }
        }
        self.input
    }

    /// Conv layers that run on the accelerator (encoder excluded),
    /// in order — the unit of per-layer parallel-factor assignment.
    pub fn accel_convs(&self) -> Vec<&ConvLayer> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(c) if !c.encoder => Some(c),
                _ => None,
            })
            .collect()
    }

    /// Validating parallel-factor assignment. A factor is rejected when
    /// it is zero, exceeds the layer's `Co`, or does not divide `Co`
    /// (the RTL replicates whole output-channel lanes, so `Co` must
    /// split evenly across them); the count must match the accelerated
    /// conv-layer count. PE budgets are a property of the whole design,
    /// not one assignment — check them with [`Self::check_pe_budget`].
    pub fn try_with_parallel_factors(mut self, factors: &[usize])
                                     -> anyhow::Result<Self> {
        let n_convs = self
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::Conv(c) if !c.encoder))
            .count();
        if factors.len() != n_convs {
            anyhow::bail!(
                "parallel factor count {} != conv layer count {n_convs}",
                factors.len());
        }
        let mut it = factors.iter();
        for l in self.layers.iter_mut() {
            if let Layer::Conv(c) = l {
                if !c.encoder {
                    let f = *it.next().expect("count checked above");
                    if f == 0 {
                        anyhow::bail!("parallel factor 0 (Co = {})", c.co);
                    }
                    if f > c.co {
                        anyhow::bail!(
                            "parallel factor {f} exceeds Co = {}", c.co);
                    }
                    if c.co % f != 0 {
                        anyhow::bail!(
                            "parallel factor {f} does not divide Co = {}",
                            c.co);
                    }
                    c.parallel = f;
                }
            }
        }
        Ok(self)
    }

    /// Error when the design's total PE count exceeds a budget (the
    /// constraint the `dse` search space and scheduler enforce).
    pub fn check_pe_budget(&self, pe_budget: usize) -> anyhow::Result<()> {
        let pes = self.total_pes();
        if pes > pe_budget {
            anyhow::bail!("design needs {pes} PEs, budget is {pe_budget}");
        }
        Ok(())
    }

    /// Total Vmem buffer bytes at the given timestep count (0 at T = 1).
    pub fn vmem_bytes(&self, timesteps: usize) -> usize {
        if timesteps <= 1 {
            return 0;
        }
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(c) if !c.encoder => Some(c.vmem_bytes()),
                _ => None,
            })
            .sum()
    }

    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv(c) => c.weight_bytes(),
                Layer::Fc { n_in, n_out } => n_in * n_out,
                Layer::Pool { .. } => 0,
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Builders + the paper's three deployed models (SectionV-A)
// ---------------------------------------------------------------------------

/// Incremental network builder tracking feature-map geometry.
pub struct NetBuilder {
    name: String,
    input: (usize, usize, usize),
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<Layer>,
}

impl NetBuilder {
    pub fn new(name: &str, input: (usize, usize, usize)) -> Self {
        Self {
            name: name.to_string(),
            input,
            h: input.0,
            w: input.1,
            c: input.2,
            layers: Vec::new(),
        }
    }

    fn push_conv(mut self, mode: ConvMode, co: usize, k: usize, pad: usize,
                 encoder: bool) -> Self {
        let l = ConvLayer {
            mode,
            in_h: self.h,
            in_w: self.w,
            ci: self.c,
            co,
            kh: k,
            kw: k,
            pad,
            encoder,
            parallel: 1,
        };
        self.h = l.out_h();
        self.w = l.out_w();
        self.c = co;
        self.layers.push(Layer::Conv(l));
        self
    }

    /// Standard conv co filters of k x k ('same' padding for odd k).
    pub fn conv(self, co: usize, k: usize) -> Self {
        self.push_conv(ConvMode::Standard, co, k, k / 2, false)
    }

    /// Spike-encoding conv (runs off-accelerator).
    pub fn encoder(self, co: usize, k: usize) -> Self {
        self.push_conv(ConvMode::Standard, co, k, k / 2, true)
    }

    pub fn dwconv(self, k: usize) -> Self {
        let c = self.c;
        self.push_conv(ConvMode::Depthwise, c, k, k / 2, false)
    }

    pub fn pwconv(self, co: usize) -> Self {
        self.push_conv(ConvMode::Pointwise, co, 1, 0, false)
    }

    pub fn pool(mut self) -> Self {
        self.layers.push(Layer::Pool { in_h: self.h, in_w: self.w, c: self.c });
        self.h /= 2;
        self.w /= 2;
        self
    }

    pub fn fc(mut self, n_out: usize) -> Self {
        let n_in = self.h * self.w * self.c;
        self.layers.push(Layer::Fc { n_in, n_out });
        self
    }

    pub fn build(self) -> NetworkSpec {
        NetworkSpec { name: self.name, input: self.input, layers: self.layers }
    }
}

/// SCNN3 (MNIST): `28x28 16c3-32c3-p2-32c3-p2-fc`.
pub fn scnn3() -> NetworkSpec {
    NetBuilder::new("scnn3", (28, 28, 1))
        .encoder(16, 3)
        .conv(32, 3)
        .pool()
        .conv(32, 3)
        .pool()
        .fc(10)
        .build()
}

/// SCNN5 (CIFAR10): `32x32 64c3-p2-128c3-p2-256c3-p2-256c3-p2-512c3-p2-fc`.
pub fn scnn5() -> NetworkSpec {
    NetBuilder::new("scnn5", (32, 32, 3))
        .encoder(64, 3)
        .pool()
        .conv(128, 3)
        .pool()
        .conv(256, 3)
        .pool()
        .conv(256, 3)
        .pool()
        .conv(512, 3)
        .pool()
        .fc(10)
        .build()
}

/// vMobileNet (MNIST): `28x28 16c3-16dwc3/32c1-32dwc3/64c1-64dwc3/64c1-
/// 64dwc3/128c1-fc` (pooling after blocks 1 and 3 — DESIGN.md note).
pub fn vmobilenet() -> NetworkSpec {
    NetBuilder::new("vmobilenet", (28, 28, 1))
        .encoder(16, 3)
        .dwconv(3)
        .pwconv(32)
        .pool()
        .dwconv(3)
        .pwconv(64)
        .dwconv(3)
        .pwconv(64)
        .pool()
        .dwconv(3)
        .pwconv(128)
        .fc(10)
        .build()
}

pub fn by_name(name: &str) -> Option<NetworkSpec> {
    match name {
        "scnn3" => Some(scnn3()),
        "scnn5" => Some(scnn5()),
        "vmobilenet" => Some(vmobilenet()),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// net.json interchange (written by python/compile/aot.py)
// ---------------------------------------------------------------------------

impl NetworkSpec {
    /// Parse the `net.json` emitted by the compile path.
    pub fn from_json(j: &Json) -> anyhow::Result<NetworkSpec> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("net")
            .to_string();
        let input = j
            .get("input")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("net.json: missing input"))?;
        let input = (
            input[0].as_usize().unwrap_or(0),
            input[1].as_usize().unwrap_or(0),
            input[2].as_usize().unwrap_or(0),
        );
        let mut layers = Vec::new();
        for l in j
            .get("layers")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("net.json: missing layers"))?
        {
            let kind = l.get("kind").and_then(|v| v.as_str()).unwrap_or("");
            let g = |k: &str| l.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            match kind {
                "conv" | "dwconv" | "pwconv" => {
                    let mode = match kind {
                        "conv" => ConvMode::Standard,
                        "dwconv" => ConvMode::Depthwise,
                        _ => ConvMode::Pointwise,
                    };
                    layers.push(Layer::Conv(ConvLayer {
                        mode,
                        in_h: g("in_h"),
                        in_w: g("in_w"),
                        ci: g("in_c"),
                        co: g("co"),
                        kh: g("k").max(1),
                        kw: g("k").max(1),
                        pad: g("pad"),
                        encoder: l
                            .get("encoder")
                            .and_then(|v| v.as_bool())
                            .unwrap_or(false),
                        parallel: 1,
                    }));
                }
                "pool" => layers.push(Layer::Pool {
                    in_h: g("in_h"),
                    in_w: g("in_w"),
                    c: g("in_c"),
                }),
                "fc" => layers.push(Layer::Fc {
                    n_in: g("in_h") * g("in_w") * g("in_c"),
                    n_out: g("out"),
                }),
                "residual" => {
                    // Residual blocks are a training-side construct; the
                    // deployed nets (scnn3/scnn5/vmobilenet) do not use
                    // them. Map to two standard convs for accounting.
                    let (h, w, ci, co) = (g("in_h"), g("in_w"), g("in_c"),
                                          g("co"));
                    for (a, b) in [(ci, co), (co, co)] {
                        layers.push(Layer::Conv(ConvLayer {
                            mode: ConvMode::Standard,
                            in_h: h,
                            in_w: w,
                            ci: a,
                            co: b,
                            kh: 3,
                            kw: 3,
                            pad: 1,
                            encoder: false,
                            parallel: 1,
                        }));
                    }
                }
                other => anyhow::bail!("net.json: unknown layer kind {other}"),
            }
        }
        Ok(NetworkSpec { name, input, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scnn3_geometry() {
        let n = scnn3();
        assert_eq!(n.layers.len(), 6);
        // Encoder 16c3 on 28x28 keeps size; pools halve twice -> 7x7x32.
        let shapes: Vec<_> = n.layers.iter().map(|l| l.out_shape()).collect();
        assert_eq!(shapes[0], (28, 28, 16));
        assert_eq!(shapes[4], (7, 7, 32));
        assert_eq!(shapes[5], (1, 1, 10));
    }

    /// Ops budgets must land on the paper's Table IV MOPs to a few %:
    /// SCNN3 5.43 MOPs, SCNN5 51.9 MOPs, vMobileNet 2.59 MOPs.
    #[test]
    fn ops_match_paper_table4() {
        let scnn3_mops = scnn3().ops_per_frame() as f64 / 1e6;
        assert!((scnn3_mops - 5.43).abs() < 0.3, "scnn3 {scnn3_mops}");
        let scnn5_mops = scnn5().ops_per_frame() as f64 / 1e6;
        assert!((scnn5_mops - 51.9).abs() < 2.0, "scnn5 {scnn5_mops}");
        let vm_mops = vmobilenet().ops_per_frame() as f64 / 1e6;
        assert!((vm_mops - 2.59).abs() < 0.6, "vmobilenet {vm_mops}");
    }

    /// Paper Table V: PE array sizes 54 (SCNN3 @ (4,2)), 99 (SCNN5 @
    /// (4,4,2,1)), 40 (vMobileNet, no parallelism).
    #[test]
    fn pe_counts_match_paper_table5() {
        let s3 = scnn3().try_with_parallel_factors(&[4, 2]).unwrap();
        assert_eq!(s3.total_pes(), 54); // 9*4 + 9*2
        let s5 = scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap();
        assert_eq!(s5.total_pes(), 99); // 9*(4+4+2+1)
        let vm = vmobilenet();
        // 4 dw blocks (9 PEs each) + 4 pw blocks (1 PE each) = 40.
        assert_eq!(vm.total_pes(), 40);
    }

    #[test]
    fn vmem_zero_at_t1() {
        let n = scnn5();
        assert_eq!(n.vmem_bytes(1), 0);
        assert!(n.vmem_bytes(2) > 0);
    }

    /// Fig. 11: T=2 needs ~126 KB of membrane-potential storage that
    /// T=1 eliminates (SCNN5, conv2..conv5).
    #[test]
    fn scnn5_vmem_saving_is_about_126kb() {
        let kb = scnn5().vmem_bytes(2) as f64 / 1024.0;
        assert!((kb - 126.0).abs() < 40.0, "vmem {kb} KB");
    }

    #[test]
    fn parallel_factor_assignment() {
        let n = scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap();
        let factors: Vec<_> =
            n.accel_convs().iter().map(|c| c.parallel).collect();
        assert_eq!(factors, vec![4, 4, 2, 1]);
    }

    #[test]
    fn wrong_factor_count_is_an_error() {
        assert!(scnn5().try_with_parallel_factors(&[4, 4]).is_err());
    }

    #[test]
    fn non_dividing_factor_is_an_error() {
        // scnn3 convs have Co = 32; 3 does not divide 32.
        assert!(scnn3().try_with_parallel_factors(&[3, 2]).is_err());
    }

    #[test]
    fn try_with_parallel_factors_rejects_bad_input() {
        // Factor that does not divide Co.
        let err = scnn3().try_with_parallel_factors(&[3, 2]).unwrap_err();
        assert!(err.to_string().contains("divide"), "{err}");
        // Factor exceeding Co.
        let err = scnn3().try_with_parallel_factors(&[64, 1]).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
        // Zero factor.
        assert!(scnn3().try_with_parallel_factors(&[0, 1]).is_err());
        // Wrong count.
        assert!(scnn3().try_with_parallel_factors(&[4]).is_err());
        // Valid profile passes through unchanged.
        let net = scnn3().try_with_parallel_factors(&[4, 2]).unwrap();
        assert_eq!(net.total_pes(), 54);
    }

    #[test]
    fn check_pe_budget_enforced() {
        let net = scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap();
        assert!(net.check_pe_budget(99).is_ok());
        assert!(net.check_pe_budget(98).is_err());
    }

    #[test]
    fn json_roundtrip_net() {
        let src = r#"{
          "name": "t", "input": [8, 8, 2],
          "layers": [
            {"kind": "conv", "in_h": 8, "in_w": 8, "in_c": 2, "co": 4,
             "k": 3, "pad": 1, "encoder": true},
            {"kind": "pool", "in_h": 8, "in_w": 8, "in_c": 4},
            {"kind": "fc", "in_h": 4, "in_w": 4, "in_c": 4, "out": 10}
          ]}"#;
        let net = NetworkSpec::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.layers[2].out_shape(), (1, 1, 10));
    }

    #[test]
    fn dwconv_preserves_channels_pwconv_changes() {
        let n = vmobilenet();
        let convs = n.accel_convs();
        assert_eq!(convs[0].mode, ConvMode::Depthwise);
        assert_eq!(convs[0].ci, convs[0].co);
        assert_eq!(convs[1].mode, ConvMode::Pointwise);
        assert_eq!(convs[1].co, 32);
    }
}
