//! Micro-bench harness (criterion is not vendored).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`BenchSet`]: warm-up, then timed iterations with median/mean/min
//! reporting. Good enough to find regressions and to print the paper's
//! table rows; not a statistics suite.
//!
//! CI hooks (both via environment variables so bench sources stay
//! untouched):
//! * `STI_SNN_BENCH_SMOKE=1` — run exactly one timed iteration per
//!   bench (fast correctness smoke on every push).
//! * `STI_SNN_BENCH_JSON=path.json` — every [`BenchSet`] appends its
//!   results to a JSON array at `path.json` when it is dropped; the CI
//!   workflow uploads the file as the `BENCH_sim.json` artifact.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn per_iter(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// True when `STI_SNN_BENCH_SMOKE` asks for one-iteration bench runs.
pub fn smoke_mode() -> bool {
    std::env::var("STI_SNN_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Time `f`, autotuning iteration count to roughly `target_ms` total.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = if smoke_mode() {
        1
    } else {
        ((target_ms as f64 * 1e6 / once).ceil() as usize).clamp(3, 1000)
    };

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
    };
    println!(
        "{:<48} {:>12}/iter  (mean {:>12}, min {:>12}, n={})",
        res.name,
        fmt_ns(res.median_ns),
        fmt_ns(res.mean_ns),
        fmt_ns(res.min_ns),
        res.iters
    );
    res
}

/// Named group of benches with a header, mirroring criterion's groups.
/// On drop, results are appended to `$STI_SNN_BENCH_JSON` if set.
pub struct BenchSet {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self { title: title.to_string(), results: Vec::new() }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        let r = bench(name, 200, f);
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Register an externally-timed result (throughput-style benches
    /// that cannot be expressed as a repeated closure).
    pub fn add(&mut self, r: BenchResult) -> &BenchResult {
        self.results.push(r);
        self.results.last().unwrap()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("results",
             Json::Arr(self
                 .results
                 .iter()
                 .map(|r| {
                     Json::obj(vec![
                         ("name", Json::str(&r.name)),
                         ("iters", Json::num(r.iters as f64)),
                         ("mean_ns", Json::num(r.mean_ns)),
                         ("median_ns", Json::num(r.median_ns)),
                         ("min_ns", Json::num(r.min_ns)),
                     ])
                 })
                 .collect())),
        ])
    }

    /// Append this set to the JSON array at `path` (read-modify-write;
    /// bench binaries run sequentially so this is race-free in
    /// practice).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut sets: Vec<Json> = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| match j {
                Json::Arr(v) => Some(v),
                _ => None,
            })
            .unwrap_or_default();
        sets.push(self.to_json());
        std::fs::write(path, format!("{}", Json::Arr(sets)))
    }
}

impl Drop for BenchSet {
    fn drop(&mut self) {
        if self.results.is_empty() {
            return;
        }
        if let Ok(path) = std::env::var("STI_SNN_BENCH_JSON") {
            if !path.is_empty() {
                if let Err(e) = self.write_json(&path) {
                    eprintln!("bench json write failed ({path}): {e}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_roundtrips_and_appends() {
        let path = std::env::temp_dir().join("sti_snn_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut s1 = BenchSet::new("set-one");
        s1.add(BenchResult {
            name: "a".into(),
            iters: 3,
            mean_ns: 10.0,
            median_ns: 9.0,
            min_ns: 8.0,
        });
        s1.write_json(&path).unwrap();
        let mut s2 = BenchSet::new("set-two");
        s2.add(BenchResult {
            name: "b".into(),
            iters: 1,
            mean_ns: 5.0,
            median_ns: 5.0,
            min_ns: 5.0,
        });
        s2.write_json(&path).unwrap();

        let txt = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&txt).unwrap();
        let arr = j.as_arr().expect("top-level array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("title").and_then(|t| t.as_str()),
                   Some("set-one"));
        let results = arr[1].get("results").and_then(|r| r.as_arr())
            .unwrap();
        assert_eq!(results[0].get("name").and_then(|n| n.as_str()),
                   Some("b"));
        let _ = std::fs::remove_file(&path);
    }
}
