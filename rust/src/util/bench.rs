//! Micro-bench harness (criterion is not vendored).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`BenchSet`]: warm-up, then timed iterations with median/mean/min
//! reporting. Good enough to find regressions and to print the paper's
//! table rows; not a statistics suite.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn per_iter(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, autotuning iteration count to roughly `target_ms` total.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_ms as f64 * 1e6 / once).ceil() as usize).clamp(3, 1000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        min_ns: samples[0],
    };
    println!(
        "{:<48} {:>12}/iter  (mean {:>12}, min {:>12}, n={})",
        res.name,
        fmt_ns(res.median_ns),
        fmt_ns(res.mean_ns),
        fmt_ns(res.min_ns),
        res.iters
    );
    res
}

/// Named group of benches with a header, mirroring criterion's groups.
pub struct BenchSet {
    pub title: String,
    pub results: Vec<BenchResult>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        Self { title: title.to_string(), results: Vec::new() }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        let r = bench(name, 200, f);
        self.results.push(r);
        self.results.last().unwrap()
    }
}
