//! Deterministic PRNG (SplitMix64) — `rand` is not vendored.
//!
//! Used for synthetic spike frames, weight init in tests/benches, and
//! the property-test harness. Deterministic by construction: the same
//! seed yields the same stream on every platform.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        if n == 0 { return 0; }
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// int8 weight in [-127, 127].
    pub fn int8(&mut self) -> i8 {
        ((self.next_u64() % 255) as i64 - 127) as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_rate_roughly_matches() {
        let mut r = Rng::new(2);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((2_700..3_300).contains(&hits), "hits={hits}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        assert_eq!(r.below(0), 0);
    }
}
