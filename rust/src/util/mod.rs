//! Small std-only utilities replacing crates that are not vendored in
//! this offline environment (serde_json, clap, rand, criterion,
//! proptest). See Cargo.toml for the constraint.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
