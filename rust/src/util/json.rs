//! Minimal JSON parser/serialiser (serde_json is not vendored).
//!
//! Supports the subset this project exchanges with the python compile
//! path (`artifacts/<model>/net.json`) and the TCP server protocol:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": 2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(2.5));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""aA\t""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t"));
    }
}
