//! Tiny CLI argument helper (clap is not vendored).
//!
//! Syntax: `sti-snn <subcommand> [--flag value] [--switch]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key value` or bare `--switch` (next arg missing or
                // itself a flag).
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Parse a flag through an arbitrary converter (used for enum-ish
    /// flags like `--backend`); `None` if the flag is absent, `Err` on
    /// an unparseable value so the caller can report it.
    pub fn get_with<T>(&self, key: &str,
                       parse: impl Fn(&str) -> Option<T>)
                       -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => parse(s)
                .map(Some)
                .ok_or_else(|| format!("invalid value {s:?} for --{key}")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Every flag/switch name the caller passed, in input order.
    pub fn flag_names(&self) -> Vec<&str> {
        self.flags
            .keys()
            .map(|s| s.as_str())
            .chain(self.switches.iter().map(|s| s.as_str()))
            .collect()
    }

    /// Reject flags outside `known`, naming the nearest valid flag in
    /// the error (`unknown flag --replica (did you mean --replicas?)`).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for name in self.flag_names() {
            if known.iter().any(|k| *k == name) {
                continue;
            }
            let nearest = known
                .iter()
                .map(|&k| (edit_distance(name, k), k))
                .min()
                .filter(|(d, k)| *d <= (k.len().max(name.len()) + 1) / 2);
            return Err(match nearest {
                Some((_, k)) => format!(
                    "unknown flag --{name} (did you mean --{k}?)"),
                None => format!("unknown flag --{name}"),
            });
        }
        Ok(())
    }
}

/// Levenshtein distance (small strings — the flag vocabulary).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table4 --model scnn5 --frames 16 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table4"));
        assert_eq!(a.get("model"), Some("scnn5"));
        assert_eq!(a.get_usize("frames", 0), 16);
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("frames", 8), 8);
        assert_eq!(a.get_str("model", "scnn3"), "scnn3");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn positional_args() {
        let a = parse("run file1 file2 --x 1");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn unknown_flags_suggest_the_nearest_valid_one() {
        let known = &["backend", "replicas", "auto-tune", "max-batch"];
        let a = parse("serve --replica 4");
        let err = a.check_known(known).unwrap_err();
        assert!(err.contains("--replica") && err.contains("--replicas"),
                "{err}");
        let a = parse("serve --auto-tun");
        let err = a.check_known(known).unwrap_err();
        assert!(err.contains("--auto-tune"), "{err}");
        // Valid flags pass; hopeless typos get no bogus suggestion.
        assert!(parse("serve --backend wp --auto-tune")
            .check_known(known)
            .is_ok());
        let err = parse("serve --zzzzqqqq 1").check_known(known)
            .unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("replica", "replicas"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn typed_flag_helpers() {
        let a = parse("serve --max-wait-ms 7 --backend wp");
        assert_eq!(a.get_u64("max-wait-ms", 5), 7);
        assert_eq!(a.get_u64("missing", 5), 5);
        let parse_ab = |s: &str| match s {
            "wp" => Some(1u8),
            "acc" => Some(0),
            _ => None,
        };
        assert_eq!(a.get_with("backend", parse_ab), Ok(Some(1)));
        assert_eq!(a.get_with("missing", parse_ab), Ok(None));
        let b = parse("serve --backend gpu");
        assert!(b.get_with("backend", parse_ab).is_err());
    }
}
