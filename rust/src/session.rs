//! The session facade: one construction API for the whole stack.
//!
//! The accelerator is one parameterized machine — network spec,
//! per-layer parallel factors, timesteps, compute backend, replica
//! count — but it used to be assembled by hand at every call site.
//! [`Session`] is the single front door: the CLI, the TCP server, the
//! DSE auto-tuner, the benches and the examples all construct the
//! simulator stack through [`Session::builder`].
//!
//! ```
//! use sti_snn::codec::SpikeFrame;
//! use sti_snn::session::{Session, Weights};
//! use sti_snn::util::rng::Rng;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = Session::builder()
//!     .model("scnn3")
//!     .weights(Weights::Random { seed: 1000 })
//!     .parallel_factors(&[4, 2])
//!     .build()?;
//! let (h, w, c) = session.input_shape();
//! let mut rng = Rng::new(7);
//! let frames = vec![SpikeFrame::random(h, w, c, 0.2, &mut rng)];
//! let report = session.infer_batch(&frames);
//! assert_eq!(report.predictions.len(), 1);
//! println!("{:.0} FPS, {:.2} W", report.fps_steady, report.power_w);
//! # Ok(())
//! # }
//! ```
//!
//! Event-driven ingestion — the paper's native workload shape — skips
//! the dense image entirely: sorted DVS-style address events are
//! windowed into single-timestep frames by [`crate::codec::stream`]
//! and classified per window:
//!
//! ```
//! use sti_snn::codec::stream::{synth_events, WindowPolicy};
//! use sti_snn::session::Session;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = Session::builder().model("scnn3").build()?;
//! let (h, w, c) = session.input_shape();
//! let events = synth_events(h, w, c, 2, 0.05, 1000, 7);
//! let out = session.infer_events(&events,
//!                                WindowPolicy::TimeUs(1000))?;
//! assert_eq!(out.windows.len(), 2);
//! # Ok(())
//! # }
//! ```
//!
//! What the builder unifies:
//!
//! * **weights** — [`Weights::Random`] (deterministic, for hardware
//!   experiments) or [`Weights::Artifact`] (trained int8 tensors from
//!   `artifacts/<model>/`).
//! * **design point** — `parallel_factors`, `timesteps`, `pipelined`,
//!   compute `backend`, and energy/resource models.
//! * **host parallelism** — `intra_parallel` (row bands inside one
//!   frame, bit-exact) alongside `replicas` (whole-frame replicas).
//! * **serving shape** — `replicas` (N-pipeline pool behind one
//!   queue), the queue's batching policy, and `queue_capacity` (the
//!   bound behind event-streaming backpressure).
//! * **auto-tuning** — `auto_tune` runs the `dse` calibrate→explore
//!   recipe at build time and boots the winning configuration;
//!   explicit `replicas`/`backend`/`parallel_factors` settings pin
//!   their dimension of the search.
//!
//! A session offers synchronous [`Session::infer`] /
//! [`Session::infer_batch`] (returning the unified [`Report`]) and
//! asynchronous [`Session::submit`] through the replica pool; event
//! workloads enter through [`Session::infer_events`] (synchronous) or
//! [`Session::submit_events`] (pooled, with explicit backpressure via
//! `queue_capacity`); and [`Session::serve`] exposes the stack over
//! TCP (paper Fig. 10) in both the dense JSON and the binary events
//! protocol.

use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::arch::{self, Layer, NetworkSpec};
use crate::autotune::{OnlineTuner, PoolRecipe, RetuneLog, RetunePolicy,
                      RetuneSummary};
use crate::codec::stream::{DvsEvent, EventStream, StreamStats,
                           WindowPolicy};
use crate::codec::SpikeFrame;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig,
                                   PipelineReport};
use crate::coordinator::replica::{PoolResult, PoolSupervision,
                                  RebuildFn, ReplicaPool};
use crate::dataflow::ConvLatencyParams;
use crate::dse;
use crate::metrics::{LatencySummary, PerfRow, PoolMetrics};
use crate::model::Artifact;
use crate::server::{Backend, Server};
use crate::sim::engine::{random_sources, LayerWeights};
use crate::sim::fifo::ChannelSnapshot;
use crate::supervise::{FaultHooks, FaultPlan, RestartPolicy,
                       SuperviseSnapshot, SuperviseStats,
                       WatchdogPolicy};
use crate::telemetry::{TraceSink, WorkloadObserver, WorkloadSnapshot};
use crate::sim::{AccessCounter, BackendKind, EnergyModel, EnergyReport,
                 ResourceModel, ResourceReport, CLK_HZ};

/// Default base seed for [`Weights::Random`] — layer `i` draws from
/// `seed + i`, matching the historical hardware-experiment wiring.
pub const DEFAULT_WEIGHT_SEED: u64 = 1000;

/// Where a session's layer weights come from.
#[derive(Debug, Clone)]
pub enum Weights {
    /// Deterministic random weights (cycle and traffic counts are
    /// weight-independent): layer `i` uses seed `seed + i`.
    Random {
        /// Base PRNG seed.
        seed: u64,
    },
    /// Trained + quantised tensors from an artifact directory
    /// (`net.json` + `weights.bin`, produced by `make artifacts`).
    /// Also supplies the network spec when none is set explicitly.
    Artifact(PathBuf),
}

impl Default for Weights {
    fn default() -> Self {
        Weights::Random { seed: DEFAULT_WEIGHT_SEED }
    }
}

/// One synchronous inference result.
#[derive(Debug, Clone)]
pub struct Inference {
    /// Request id (pool submissions number them; direct runs use 0).
    pub id: u64,
    /// Classifier argmax.
    pub class: usize,
    /// Accumulated classifier logits.
    pub logits: Vec<f32>,
    /// Which replica served the request (0 for direct runs).
    pub replica: usize,
    /// End-to-end latency in µs (0 for direct runs).
    pub latency_us: u64,
}

impl Inference {
    fn from_pool(r: PoolResult) -> Result<Self> {
        if let Some(e) = r.error {
            anyhow::bail!("{e}");
        }
        let class = r.prediction.ok_or_else(|| {
            anyhow::anyhow!("network has no classifier head")
        })?;
        Ok(Self {
            id: r.id,
            class,
            logits: r.logits,
            replica: r.replica,
            latency_us: r.latency_us,
        })
    }
}

/// Result of [`Session::infer_events`]: per-window classifications in
/// window order, plus the ingestion counters.
#[derive(Debug)]
pub struct EventInference {
    /// One [`Inference`] per completed window (including the flushed
    /// trailing partial window, if any).
    pub windows: Vec<Inference>,
    /// Events accepted / windows formed by the stream.
    pub stats: StreamStats,
}

/// Result of [`Session::submit_events`]: receivers for the windows
/// accepted by the pool, in window order, plus backpressure accounting.
#[derive(Debug, Default)]
pub struct EventSubmission {
    /// One receiver per window the pool accepted.
    pub receivers: Vec<Receiver<PoolResult>>,
    /// Windows shed because the bounded queue was full
    /// ([`SessionBuilder::queue_capacity`]).
    pub shed: u64,
    /// Events accepted / windows formed by the stream.
    pub stats: StreamStats,
}

/// The unified session report: cycles, memory traffic, energy,
/// resources, and throughput of one batch — everything the paper's
/// Table IV / Table V / Fig. 11 / Fig. 12 artifacts need, in one type.
#[derive(Debug, Clone)]
pub struct Report {
    /// Frames in the batch.
    pub frames: u64,
    /// Per-layer report labels (`conv0:Standard`, `pool1`, ...).
    pub layer_names: Vec<String>,
    /// Per-layer cycles for ONE frame (all timesteps).
    pub layer_cycles: Vec<u64>,
    /// Per-layer dynamic energy for ONE frame.
    pub layer_energy: Vec<EnergyReport>,
    /// Per-layer Vmem buffer bytes (0 at T = 1 — Fig. 11).
    pub layer_vmem_bytes: Vec<usize>,
    /// Inter-layer event-stream compression ratios.
    pub codec_ratios: Vec<f64>,
    /// Pipeline interval = max layer cycles (Eq. 11 asymptote).
    pub t_max: u64,
    /// Sum of per-layer cycles (unpipelined frame latency).
    pub t_sum: u64,
    /// Total cycles for the batch under the configured mode.
    pub total_cycles: u64,
    /// Measured spike-gated synaptic ops per frame.
    pub ops_per_frame: u64,
    /// Theoretical synaptic ops per frame (the paper's "MOPs").
    pub theoretical_ops_per_frame: u64,
    /// Aggregated memory traffic (whole batch).
    pub counters: AccessCounter,
    /// Design resource utilisation (Table V model).
    pub resources: ResourceReport,
    /// PE count of the design.
    pub pes: usize,
    /// Classifier argmax per frame.
    pub predictions: Vec<usize>,
    /// Accumulated classifier logits per frame.
    pub logits: Vec<Vec<f32>>,
    /// Steady-state throughput: one frame per `t_max` (Eq. 11).
    pub fps_steady: f64,
    /// Throughput of this finite batch (includes the pipeline fill).
    pub fps_batch: f64,
    /// Batch latency per frame in ms.
    pub latency_ms_per_frame: f64,
    /// Dynamic energy per frame in joules.
    pub energy_per_frame_j: f64,
    /// Average power (static + dynamic) at steady-state FPS, watts.
    pub power_w: f64,
    /// Throughput in GOPS at steady state (kFPS x MOPs).
    pub gops: f64,
    /// Efficiency, GOPS per watt.
    pub gops_per_w: f64,
    /// The paper's headline metric: GOPS / W / PE.
    pub gops_per_w_per_pe: f64,
    /// Per-link row-channel counters from the streamed schedule (link
    /// `i` connects layer `i` to `i + 1`; empty on the serial
    /// schedule). Host-timing-dependent — excluded from bit-exact
    /// report comparisons.
    pub channel_stats: Vec<ChannelSnapshot>,
}

impl Report {
    fn from_pipeline(rep: &PipelineReport, net: &NetworkSpec,
                     config: &PipelineConfig) -> Self {
        let fps_steady = if rep.t_max > 0 {
            CLK_HZ / rep.t_max as f64
        } else {
            0.0
        };
        let energy_per_frame_j = rep.dynamic_energy_per_frame_j();
        let power_w = config.energy.avg_power(
            energy_per_frame_j, fps_steady, rep.pes,
            rep.resources.bram36);
        let theoretical = net.ops_per_frame();
        let gops = fps_steady * theoretical as f64 / 1e9;
        let gops_per_w = if power_w > 0.0 { gops / power_w } else { 0.0 };
        Self {
            frames: rep.frames,
            layer_names: rep.layer_names.clone(),
            layer_cycles: rep.layer_cycles.clone(),
            layer_energy: rep.layer_energy.clone(),
            layer_vmem_bytes: rep.layer_vmem_bytes.clone(),
            codec_ratios: rep.codec_ratios.clone(),
            t_max: rep.t_max,
            t_sum: rep.t_sum,
            total_cycles: rep.total_cycles,
            ops_per_frame: rep.ops_per_frame,
            theoretical_ops_per_frame: theoretical,
            counters: rep.counters.clone(),
            resources: rep.resources,
            pes: rep.pes,
            predictions: rep.predictions.clone(),
            logits: rep.logits.clone(),
            fps_steady,
            fps_batch: rep.fps(),
            latency_ms_per_frame: rep.latency_ms_per_frame(),
            energy_per_frame_j,
            power_w,
            gops,
            gops_per_w,
            gops_per_w_per_pe: gops_per_w / rep.pes.max(1) as f64,
            channel_stats: rep.channel_stats.clone(),
        }
    }

    /// Render this report as a paper-style Table IV row.
    pub fn perf_row(&self, name: &str) -> PerfRow {
        PerfRow::new(name, self.t_max as f64,
                     self.theoretical_ops_per_frame, self.power_w,
                     self.pes.max(1))
    }
}

/// Fluent builder for [`Session`] — see the module docs for the knob
/// inventory. Every setter is optional; `build` validates the
/// combination.
#[derive(Debug, Clone, Default)]
pub struct SessionBuilder {
    net: Option<NetworkSpec>,
    model: Option<String>,
    weights: Option<Weights>,
    backend: Option<BackendKind>,
    timing: Option<ConvLatencyParams>,
    timesteps: Option<usize>,
    pipelined: Option<bool>,
    energy: Option<EnergyModel>,
    resources: Option<ResourceModel>,
    parallel_factors: Option<Vec<usize>>,
    replicas: Option<usize>,
    intra_parallel: Option<usize>,
    auto_tune: Option<dse::AutoTuneOptions>,
    max_batch: Option<usize>,
    max_wait: Option<Duration>,
    queue_cap: Option<usize>,
    trace: Option<Arc<TraceSink>>,
    online_tune: Option<RetunePolicy>,
    retune_log: Option<PathBuf>,
    watchdog: Option<WatchdogPolicy>,
    restart: Option<RestartPolicy>,
    chaos: Option<FaultPlan>,
}

impl SessionBuilder {
    /// Use an explicit network spec (wins over `model`).
    pub fn network(mut self, net: NetworkSpec) -> Self {
        self.net = Some(net);
        self
    }

    /// Use a named built-in model (`scnn3` / `scnn5` / `vmobilenet`).
    pub fn model(mut self, name: &str) -> Self {
        self.model = Some(name.to_string());
        self
    }

    /// Weight source (default: deterministic random, seed
    /// [`DEFAULT_WEIGHT_SEED`]).
    pub fn weights(mut self, weights: Weights) -> Self {
        self.weights = Some(weights);
        self
    }

    /// Functional compute backend (default `accurate`; explicitly
    /// setting one also pins the backend against `auto_tune`). All
    /// kinds are bit-exact with identical reports; `sparse` is the
    /// density-sensitive fast path (occupancy skipping + the
    /// weight-stationary row batching behind
    /// [`Session::infer_batch`]).
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Conv latency-model timing parameters (default
    /// `ConvLatencyParams::optimized()`).
    pub fn timing(mut self, timing: ConvLatencyParams) -> Self {
        self.timing = Some(timing);
        self
    }

    /// Inference timesteps (default 1 — the paper's headline mode).
    pub fn timesteps(mut self, timesteps: usize) -> Self {
        self.timesteps = Some(timesteps.max(1));
        self
    }

    /// Layer-wise pipelining on (default) or off. The single knob for
    /// inter-layer parallelism: it selects both the Eq. (10) cycle
    /// accounting AND the execution schedule — on, frames stream
    /// through one worker per layer connected by bounded row channels;
    /// off, layers run serially per frame. Reports are bit-identical
    /// either way (pinned by `tests/prop_session.rs`); only host
    /// wall-clock changes. Composes with [`SessionBuilder::intra_parallel`]
    /// (bands within a layer worker) for rows x layers parallelism.
    pub fn pipelined(mut self, pipelined: bool) -> Self {
        self.pipelined = Some(pipelined);
        self
    }

    /// Energy model override.
    pub fn energy(mut self, energy: EnergyModel) -> Self {
        self.energy = Some(energy);
        self
    }

    /// Resource model override.
    pub fn resources(mut self, resources: ResourceModel) -> Self {
        self.resources = Some(resources);
        self
    }

    /// Per-conv-layer output-channel parallel factors (validated at
    /// build; with `auto_tune`, pins the factor dimension of the
    /// search so the measured point matches what boots).
    pub fn parallel_factors(mut self, factors: &[usize]) -> Self {
        self.parallel_factors = Some(factors.to_vec());
        self
    }

    /// Pipeline replica count for the pool / serving paths (default 1;
    /// explicitly setting it also pins the `auto_tune` search to that
    /// split).
    pub fn replicas(mut self, replicas: usize) -> Self {
        self.replicas = Some(replicas.max(1));
        self
    }

    /// Intra-frame parallelism: split each conv layer's output rows
    /// into `n` bands processed by scoped worker threads (default 1).
    /// Host-side speed only — spikes, cycles, ops, and access
    /// counters are architectural and band-invariant (pinned by
    /// `tests/prop_session.rs`). Orthogonal to `replicas` (which
    /// parallelises across frames, not within one).
    pub fn intra_parallel(mut self, n: usize) -> Self {
        self.intra_parallel = Some(n.max(1));
        self
    }

    /// Run design-space exploration at build time and boot the winning
    /// configuration (factors, replica count, compute backend).
    /// Explicit `replicas` / `backend` / `parallel_factors` settings
    /// pin their dimension of the search.
    pub fn auto_tune(mut self, opts: dse::AutoTuneOptions) -> Self {
        self.auto_tune = Some(opts);
        self
    }

    /// Batching policy of the shared work queue (pool + serving).
    pub fn queue(mut self, max_batch: usize, max_wait: Duration) -> Self {
        self.max_batch = Some(max_batch.max(1));
        self.max_wait = Some(max_wait);
        self
    }

    /// Attach a [`TraceSink`]: every pipeline built from this session
    /// (the primary, pool replicas, and serving backends) records
    /// frame / layer / band / backpressure spans into it. Export with
    /// [`TraceSink::to_chrome_json`]. Tracing never changes the
    /// architectural report (pinned by `tests/prop_telemetry.rs`);
    /// without a sink the span sites are a single `Option` check.
    pub fn trace(mut self, sink: Arc<TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Bound the shared work queue's depth (pool + serving; 0 =
    /// unbounded, the default). With a bound, event-streaming paths
    /// ([`Session::submit_events`], the server's events mode) shed
    /// windows explicitly when the queue is full instead of queueing
    /// without limit.
    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    /// Keep tuning while serving: spawn an [`OnlineTuner`] alongside
    /// the replica pool that periodically re-runs the calibrated DSE
    /// against the *measured* workload and hot-swaps the pool's
    /// generation when the policy's hysteresis/cooldown gate clears
    /// (see the [`crate::autotune`] module docs). The search spans the
    /// `auto_tune` options when those are set, or their defaults
    /// otherwise. Takes effect on the pooled paths
    /// ([`Session::start_pool`] / [`Session::submit`] /
    /// [`Session::serve`]).
    pub fn online_tune(mut self, policy: RetunePolicy) -> Self {
        self.online_tune = Some(policy);
        self
    }

    /// Write the retune event log ([`RetuneLog::to_json`]) to this
    /// path when the session shuts down or serving ends.
    pub fn retune_log(mut self, path: impl Into<PathBuf>) -> Self {
        self.retune_log = Some(path.into());
        self
    }

    /// Arm a watchdog over the streamed executor: a frame that
    /// overruns `policy.deadline` tears the worker pipeline down and
    /// (when `policy.retry_serial`) recovers the batch bit-exactly on
    /// the serial schedule. Without one (the default), streamed waits
    /// are plain blocking operations with zero overhead.
    pub fn watchdog(mut self, policy: WatchdogPolicy) -> Self {
        self.watchdog = Some(policy);
        self
    }

    /// Restart budget for supervised replica workers (default:
    /// [`RestartPolicy::default`] — 3 restarts per 30 s rolling
    /// window, exponential backoff). Workers that exhaust it retire;
    /// a pool whose replicas all retire degrades to explicit error
    /// replies instead of hanging.
    pub fn restart_policy(mut self, policy: RestartPolicy) -> Self {
        self.restart = Some(policy);
        self
    }

    /// Run under a deterministic fault-injection plan (chaos testing):
    /// the seeded schedule of panics, channel stalls, slow replicas,
    /// and dropped replies is consumed one-shot as the session serves.
    /// Production sessions leave this unset — every fault hook is an
    /// `Option` that stays `None`.
    pub fn chaos(mut self, plan: FaultPlan) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// Validate the configuration and construct the session.
    pub fn build(self) -> Result<Session> {
        // Weight source first: an artifact can supply the network.
        let weights = self.weights.unwrap_or_default();
        let artifact = match &weights {
            Weights::Artifact(dir) => Some(Artifact::load(dir)?),
            Weights::Random { .. } => None,
        };

        let explicit_net = self.net.is_some() || self.model.is_some();
        let mut net = if let Some(n) = self.net {
            n
        } else if let Some(name) = &self.model {
            arch::by_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown model {name} (scnn3 | scnn5 | \
                                 vmobilenet)")
            })?
        } else if let Some(a) = &artifact {
            a.net.clone()
        } else {
            anyhow::bail!("Session::builder(): set .network(..), \
                           .model(..), or .weights(Weights::Artifact(..))");
        };
        if explicit_net {
            if let Some(a) = &artifact {
                // Artifact tensors are shaped for the artifact's net;
                // a mismatched explicit spec would index them out of
                // bounds (or silently compute garbage).
                check_artifact_net(&net, &a.net)?;
            }
        }

        let timesteps = self
            .timesteps
            .or_else(|| artifact.as_ref().map(|a| a.timesteps.max(1)))
            .unwrap_or(1);
        let mut backend = self.backend.unwrap_or_default();
        let mut replicas = self.replicas.unwrap_or(1);

        // Resolve the design point: auto-tune, then explicit overrides.
        let mut tuned = None;
        let mut tune_opts = None;
        if let Some(opts) = &self.auto_tune {
            let mut opts = opts.clone();
            opts.timesteps = timesteps;
            // Probe with the band count and pipelining mode the
            // session will serve with, so the fitted host-ns/frame
            // matches what boots.
            opts.intra_parallel = self.intra_parallel.unwrap_or(1);
            opts.pipelined = self.pipelined.unwrap_or(true);
            if let Some(r) = self.replicas {
                opts.max_replicas = r;
            }
            let (mut best, ex) = dse::auto_tune(&net, &opts)?;
            // Explicit replicas / parallel_factors pin their dimension
            // of the search, so the chosen point (and its measured
            // FPS/power) matches exactly what boots.
            let pinned_r = self.replicas;
            let pinned_f = self.parallel_factors.as_deref();
            if pinned_r.is_some() || pinned_f.is_some() {
                let pinned: Vec<dse::CostPoint> = ex
                    .points
                    .iter()
                    .filter(|p| pinned_r
                        .map_or(true, |r| p.candidate.replicas == r))
                    .filter(|p| pinned_f
                        .map_or(true,
                                |f| p.candidate.factors.as_slice() == f))
                    .cloned()
                    .collect();
                best = dse::pareto::choose(&pinned).ok_or_else(|| {
                    anyhow::anyhow!(
                        "auto-tune: no fitting design point matches the \
                         pinned configuration (replicas {pinned_r:?}, \
                         factors {pinned_f:?})")
                })?;
            }
            if let Some(b) = self.backend {
                // Explicit backend only swaps the host compute path —
                // hardware metrics are backend-invariant.
                best.candidate.backend = b;
            }
            backend = best.candidate.backend;
            replicas = best.candidate.replicas;
            net = net.try_with_parallel_factors(&best.candidate.factors)?;
            tuned = Some(best);
            tune_opts = Some(opts);
        } else if let Some(f) = &self.parallel_factors {
            net = net.try_with_parallel_factors(f)?;
        }

        let supervise = Arc::new(SuperviseStats::default());
        let faults =
            self.chaos.map(|p| Arc::new(FaultHooks::from_plan(p)));
        let config = PipelineConfig {
            timesteps,
            timing: self.timing
                .unwrap_or_else(ConvLatencyParams::optimized),
            pipelined: self.pipelined.unwrap_or(true),
            energy: self.energy.unwrap_or_default(),
            resources: self.resources.unwrap_or_default(),
            backend,
            intra_parallel: self.intra_parallel.unwrap_or(1),
            trace: self.trace.clone(),
            watchdog: self.watchdog,
            faults: faults.clone(),
            supervise: Some(supervise.clone()),
            ..PipelineConfig::default()
        };

        let sources: Vec<LayerWeights> = match (&weights, &artifact) {
            (Weights::Random { seed }, _) => random_sources(&net, *seed),
            (Weights::Artifact(_), Some(a)) => a.layer_weights()?,
            (Weights::Artifact(_), None) => unreachable!(),
        };

        let pipeline =
            Pipeline::new(net.clone(), config.clone(), sources.clone())?;
        Ok(Session {
            net,
            config,
            sources,
            replicas,
            max_batch: self.max_batch.unwrap_or(16),
            max_wait: self.max_wait.unwrap_or(Duration::from_millis(5)),
            queue_cap: self.queue_cap.unwrap_or(0),
            tuned,
            tune_opts,
            pipeline,
            pool: None,
            observer: Arc::new(WorkloadObserver::new()),
            online_policy: self.online_tune,
            retune_log_path: self.retune_log,
            tuner: None,
            supervise,
            faults,
            restart: self.restart.unwrap_or_default(),
        })
    }
}

/// One coherent snapshot of a session's runtime telemetry — see
/// [`Session::telemetry`]. Everything here is host-side observation;
/// none of it feeds back into the architectural model.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySnapshot {
    /// Rolling per-layer spike density and arrival-rate statistics
    /// from the observed workload (ROADMAP item 5 feedstock).
    pub workload: WorkloadSnapshot,
    /// Latency percentiles over the pool's sliding reservoir, when
    /// the replica pool is running.
    pub latency: Option<LatencySummary>,
    /// Frames waiting in the shared work queue, when the pool is
    /// running.
    pub queue_depth: Option<usize>,
    /// Online-tuner counters (swaps, generation, evaluations), when
    /// [`SessionBuilder::online_tune`] spawned a controller.
    pub retune: Option<RetuneSummary>,
    /// Supervision counters: replica restarts/retirements, watchdog
    /// fires, retune rollbacks, tuner restarts.
    pub supervise: SuperviseSnapshot,
}

/// An explicit network spec used with artifact weights must describe
/// the artifact's network (parallel factors aside — those are a
/// design-point knob, not a tensor shape).
fn check_artifact_net(net: &NetworkSpec, art_net: &NetworkSpec)
                      -> Result<()> {
    let compatible = net.input == art_net.input
        && net.layers.len() == art_net.layers.len()
        && net.layers.iter().zip(&art_net.layers).all(|(l, m)| {
            match (l, m) {
                (Layer::Conv(x), Layer::Conv(y)) => {
                    x.mode == y.mode
                        && (x.in_h, x.in_w, x.ci, x.co) ==
                           (y.in_h, y.in_w, y.ci, y.co)
                        && (x.kh, x.kw, x.pad) == (y.kh, y.kw, y.pad)
                        && x.encoder == y.encoder
                }
                (Layer::Pool { .. }, Layer::Pool { .. }) => {
                    l.in_shape() == m.in_shape()
                }
                (Layer::Fc { .. }, Layer::Fc { .. }) => {
                    l.in_shape() == m.in_shape()
                        && l.out_shape() == m.out_shape()
                }
                _ => false,
            }
        });
    anyhow::ensure!(
        compatible,
        "explicit network {:?} does not match the artifact's network \
         {:?}: artifact tensors are shaped for the artifact's layers",
        net.name, art_net.name);
    Ok(())
}

/// A fully-constructed accelerator stack: network + engines +
/// pipeline, with an optional replica pool and TCP serving on top.
/// Build one with [`Session::builder`].
pub struct Session {
    net: NetworkSpec,
    config: PipelineConfig,
    sources: Vec<LayerWeights>,
    replicas: usize,
    max_batch: usize,
    max_wait: Duration,
    queue_cap: usize,
    tuned: Option<dse::CostPoint>,
    /// The (adjusted) options `auto_tune` searched with, kept so the
    /// online tuner re-plans over the same space.
    tune_opts: Option<dse::AutoTuneOptions>,
    pipeline: Pipeline,
    pool: Option<Arc<ReplicaPool>>,
    observer: Arc<WorkloadObserver>,
    online_policy: Option<RetunePolicy>,
    retune_log_path: Option<PathBuf>,
    tuner: Option<OnlineTuner>,
    supervise: Arc<SuperviseStats>,
    faults: Option<Arc<FaultHooks>>,
    restart: RestartPolicy,
}

impl Session {
    /// Start building a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The (possibly factor-assigned) network this session runs.
    pub fn net(&self) -> &NetworkSpec {
        &self.net
    }

    /// The resolved pipeline configuration.
    pub fn config(&self) -> &PipelineConfig {
        &self.config
    }

    /// The resolved functional compute backend.
    pub fn backend(&self) -> BackendKind {
        self.config.backend
    }

    /// Configured replica count (pool / serving parallelism).
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// The design point `auto_tune` chose, when it ran.
    pub fn tuned(&self) -> Option<&dse::CostPoint> {
        self.tuned.as_ref()
    }

    /// Shape of the (post-encoder) spike frames this session expects.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.pipeline.input_shape()
    }

    /// Run a batch of spike frames through the primary pipeline and
    /// return the unified [`Report`].
    ///
    /// With `--backend sparse` this is the weight-stationary fast
    /// path: every conv row of every queued frame stashes its packed
    /// windows and evaluates them in one pass per output channel
    /// (`ConvCompute::field_psums_batch`), so a batch keeps each
    /// layer's weight planes cache-hot across frames instead of
    /// re-streaming them per field. Reports and spikes are
    /// bit-identical to per-frame [`Session::infer`] — the batch only
    /// reorders host-side sums (pinned by `tests/diff_backends.rs`).
    pub fn infer_batch(&mut self, frames: &[SpikeFrame]) -> Report {
        let rep = self.pipeline.run(frames);
        self.observer
            .observe(&rep.layer_names, &rep.codec_ratios, rep.frames);
        Report::from_pipeline(&rep, &self.net, &self.config)
    }

    /// Classify one frame. Routes through the replica pool when more
    /// than one replica is configured; otherwise runs on the primary
    /// pipeline directly.
    pub fn infer(&mut self, frame: SpikeFrame) -> Result<Inference> {
        if self.replicas > 1 {
            self.start_pool()?;
        }
        if let Some(pool) = &self.pool {
            return Inference::from_pool(pool.infer(frame)?);
        }
        let rep = self.pipeline.run(std::slice::from_ref(&frame));
        self.observer
            .observe(&rep.layer_names, &rep.codec_ratios, rep.frames);
        let class = rep.predictions.first().copied().ok_or_else(|| {
            anyhow::anyhow!("network has no classifier head")
        })?;
        Ok(Inference {
            id: 0,
            class,
            logits: rep.logits.first().cloned().unwrap_or_default(),
            replica: 0,
            latency_us: 0,
        })
    }

    /// Enqueue a frame on the replica pool (spawned on first use);
    /// the receiver yields the result when a replica has served it.
    /// Non-blocking — submit many, then collect.
    pub fn submit(&mut self, frame: SpikeFrame)
                  -> Result<Receiver<PoolResult>> {
        self.start_pool()?;
        Ok(self.pool.as_ref().expect("pool started").submit(frame))
    }

    /// An [`EventStream`] shaped for this session's input: sorted
    /// address events in, single-timestep spike frames out.
    pub fn event_stream(&self, policy: WindowPolicy)
                        -> Result<EventStream> {
        let (h, w, c) = self.input_shape();
        EventStream::new(h, w, c, policy)
    }

    /// Classify a sorted event batch window by window (synchronous;
    /// routes through the pool when >1 replica is configured). The
    /// trailing partial window is flushed — streaming callers that
    /// want open windows to stay open should drive an
    /// [`Session::event_stream`] themselves.
    pub fn infer_events(&mut self, events: &[DvsEvent],
                        policy: WindowPolicy) -> Result<EventInference> {
        let mut stream = self.event_stream(policy)?;
        let mut windows = Vec::new();
        for ev in events {
            if stream.push(*ev)? {
                windows.push(self.infer(stream.window().clone())?);
            }
        }
        if let Some(f) = stream.flush() {
            let frame = f.clone();
            windows.push(self.infer(frame)?);
        }
        Ok(EventInference { windows, stats: stream.stats() })
    }

    /// Stream a sorted event batch into the replica pool: windows are
    /// submitted as they complete (non-blocking), with explicit
    /// backpressure when [`SessionBuilder::queue_capacity`] bounds the
    /// queue — full-queue windows are counted in
    /// [`EventSubmission::shed`] rather than queued without limit.
    /// The trailing partial window is flushed.
    pub fn submit_events(&mut self, events: &[DvsEvent],
                         policy: WindowPolicy)
                         -> Result<EventSubmission> {
        self.start_pool()?;
        let mut stream = self.event_stream(policy)?;
        let pool = self.pool.as_ref().expect("pool started");
        let mut sub = EventSubmission::default();
        let submit = |frame: SpikeFrame, sub: &mut EventSubmission| {
            match pool.try_submit(frame) {
                Ok(rx) => sub.receivers.push(rx),
                Err(_) => sub.shed += 1,
            }
        };
        for ev in events {
            if stream.push(*ev)? {
                submit(stream.window().clone(), &mut sub);
            }
        }
        if let Some(f) = stream.flush() {
            let frame = f.clone();
            submit(frame, &mut sub);
        }
        sub.stats = stream.stats();
        Ok(sub)
    }

    /// Spawn the replica pool now (it is otherwise created lazily on
    /// the first [`Session::submit`]) — call before timing submission
    /// throughput so worker startup stays out of the measurement.
    /// The pool gets `replicas` fresh pipelines of its own; the
    /// primary pipeline stays available for [`Session::infer_batch`]
    /// reports, so a pooled session holds `replicas + 1` engine
    /// stacks in total.
    pub fn start_pool(&mut self) -> Result<()> {
        if self.pool.is_none() {
            let pipes = self.build_pipelines(self.replicas)?;
            self.pool = Some(Arc::new(ReplicaPool::with_supervision(
                pipes, self.max_batch, self.max_wait, self.queue_cap,
                Some(self.observer.clone()), self.supervision())));
        }
        if self.tuner.is_none() {
            if let Some(policy) = self.online_policy.clone() {
                let pool = self.pool.clone().expect("pool started");
                self.tuner = Some(OnlineTuner::spawn(
                    self.recipe(), pool, self.observer.clone(),
                    self.boot_candidate(), policy,
                    self.resolved_tune_opts()));
            }
        }
        Ok(())
    }

    /// The supervision wiring every pool generation inherits: the
    /// session's restart budget, fault hooks (chaos runs only), shared
    /// counters, and a rebuild factory that reconstructs a replica's
    /// pipeline bit-identically after a panic (same net, config, and
    /// weight sources).
    fn supervision(&self) -> PoolSupervision {
        let net = self.net.clone();
        let config = self.config.clone();
        let sources = self.sources.clone();
        let rebuild: RebuildFn = Arc::new(move |_replica| {
            Pipeline::new(net.clone(), config.clone(), sources.clone())
                .ok()
        });
        PoolSupervision {
            policy: self.restart,
            hooks: self.faults.clone(),
            rebuild: Some(rebuild),
            stats: self.supervise.clone(),
        }
    }

    /// Shared supervision counters (replica restarts, watchdog fires,
    /// retune rollbacks, ...) ticked by every component this session
    /// builds.
    pub fn supervise_stats(&self) -> Arc<SuperviseStats> {
        self.supervise.clone()
    }

    /// The fault-injection hooks, when the session runs under a
    /// [`SessionBuilder::chaos`] plan.
    pub fn fault_hooks(&self) -> Option<Arc<FaultHooks>> {
        self.faults.clone()
    }

    /// Replicas of the pool's serving generation still alive (not
    /// retired by the supervisor), when the pool is running.
    pub fn alive_replicas(&self) -> Option<usize> {
        self.pool.as_ref().map(|p| p.alive_replicas())
    }

    /// The rebuild recipe the online tuner constructs replacement
    /// generations from: this session's un-pinned net, config, and
    /// weight sources.
    fn recipe(&self) -> PoolRecipe {
        PoolRecipe {
            base_net: self.net.clone(),
            config: self.config.clone(),
            sources: self.sources.clone(),
        }
    }

    /// The design point currently booted, as a search-space candidate.
    fn boot_candidate(&self) -> dse::Candidate {
        dse::Candidate {
            factors: self
                .net
                .accel_convs()
                .iter()
                .map(|c| c.parallel)
                .collect(),
            replicas: self.replicas,
            backend: self.config.backend,
        }
    }

    /// The search-space options the online tuner re-plans over: the
    /// boot `auto_tune` options when those ran, else defaults aligned
    /// with this session's serving shape.
    fn resolved_tune_opts(&self) -> dse::AutoTuneOptions {
        self.tune_opts.clone().unwrap_or_else(|| {
            let d = dse::AutoTuneOptions::default();
            dse::AutoTuneOptions {
                max_replicas: d.max_replicas.max(self.replicas),
                timesteps: self.config.timesteps,
                intra_parallel: self.config.intra_parallel,
                pipelined: self.config.pipelined,
                ..d
            }
        })
    }

    /// Stop the online tuner (if running) and hand back its log.
    fn stop_tuner(&mut self) -> Option<Arc<RetuneLog>> {
        let tuner = self.tuner.take()?;
        let log = tuner.log();
        tuner.stop();
        Some(log)
    }

    /// Write the retune log where the builder asked for it.
    fn write_retune_log(&self, log: &Option<Arc<RetuneLog>>) {
        if let (Some(path), Some(log)) = (&self.retune_log_path, log) {
            let _ = std::fs::write(path, format!("{}\n", log.to_json()));
        }
    }

    /// Retire the pool: the tuner (the only other long-lived holder)
    /// must already be stopped, so the unwrap normally succeeds and
    /// joins inline; any stray holder falls back to drop-retirement.
    fn retire_pool(pool: Arc<ReplicaPool>) {
        match Arc::try_unwrap(pool) {
            Ok(p) => p.shutdown(),
            Err(p) => drop(p),
        }
    }

    /// The online tuner's shared log (swap events, counters, the
    /// calibration baseline), when [`SessionBuilder::online_tune`]
    /// spawned one and the pool has started.
    pub fn retune_log(&self) -> Option<Arc<RetuneLog>> {
        self.tuner.as_ref().map(|t| t.log())
    }

    /// Pool generation currently serving (0 at boot, +1 per completed
    /// online-retune swap), when the pool is running.
    pub fn pool_generation(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| p.generation())
    }

    /// Per-replica serving counters, when the pool is running.
    pub fn pool_metrics(&self) -> Option<Arc<PoolMetrics>> {
        self.pool.as_ref().map(|p| p.metrics())
    }

    /// The session's workload observer: rolling per-layer spike
    /// density and inter-arrival statistics recorded on every direct
    /// and served inference.
    pub fn workload(&self) -> &Arc<WorkloadObserver> {
        &self.observer
    }

    /// One coherent telemetry snapshot: observed workload statistics
    /// plus, when the replica pool is running, latency percentiles
    /// and the current work-queue depth.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            workload: self.observer.snapshot(),
            latency: self
                .pool
                .as_ref()
                .map(|p| p.metrics().latency_summary()),
            queue_depth: self.pool.as_ref().map(|p| p.queue_len()),
            retune: self.tuner.as_ref().map(|t| t.log().summary()),
            supervise: self.supervise.snapshot(),
        }
    }

    /// Stop the online tuner and the replica pool (drains queued
    /// work), write the retune log if one was requested, and drop the
    /// session.
    pub fn shutdown(mut self) {
        let log = self.stop_tuner();
        self.write_retune_log(&log);
        if let Some(pool) = self.pool.take() {
            Self::retire_pool(pool);
        }
    }

    /// Serve this session's stack over TCP (paper Fig. 10). Two
    /// protocols on one port: newline-JSON dense images
    /// (threshold-encoded to the pipeline's post-encoder input shape)
    /// and, per connection via `{"cmd": "events"}`, the binary
    /// event-streaming protocol that feeds [`EventStream`] windows
    /// straight to the pipeline (see the `server` module docs for the
    /// byte layout). Blocks until a `shutdown` command arrives;
    /// `on_bound` receives the bound address (port 0 => ephemeral,
    /// for tests).
    pub fn serve(mut self, addr: &str,
                 on_bound: impl FnOnce(std::net::SocketAddr))
                 -> Result<()> {
        if self.online_policy.is_some() {
            // Online tuning serves through the swappable pool; the
            // plain path owns its replicas directly.
            return self.serve_online(addr, on_bound);
        }
        if let Some(pool) = self.pool.take() {
            // The server owns its replicas; don't double-run the pool.
            Self::retire_pool(pool);
        }
        let shape = self.pipeline.input_shape();
        let extra = self.build_pipelines(self.replicas - 1)?;
        let sup = self.supervise.clone();
        let obs = self.observer;
        let mut backends = Vec::with_capacity(self.replicas);
        backends.push(FrameBackend {
            pipe: self.pipeline,
            shape,
            observer: obs.clone(),
        });
        for pipe in extra {
            backends.push(FrameBackend {
                pipe,
                shape,
                observer: obs.clone(),
            });
        }
        let pooled = backends.len() > 1;
        let server = Server::with_backends(backends)
            .with_queue(self.max_batch, self.max_wait)
            .with_queue_capacity(self.queue_cap)
            .with_workload(obs)
            .with_supervise(sup);
        if pooled {
            server.serve_pool(addr, on_bound)
        } else {
            server.serve(addr, on_bound)
        }
    }

    /// The `--online-tune` serving path: requests flow through the
    /// replica pool (server workers forward into its shared queue)
    /// while the [`OnlineTuner`] hot-swaps generations underneath —
    /// connections never notice a swap. Worker count covers the
    /// largest replica split the tuner may choose, so a post-swap
    /// wider pool is not starved by too few forwarders.
    fn serve_online(mut self, addr: &str,
                    on_bound: impl FnOnce(std::net::SocketAddr))
                    -> Result<()> {
        self.start_pool()?;
        let pool = self.pool.clone().expect("pool started");
        let shape = self.pipeline.input_shape();
        let workers = self
            .replicas
            .max(self.resolved_tune_opts().max_replicas)
            .max(1);
        let backends: Vec<PoolBackend> = (0..workers)
            .map(|_| PoolBackend { pool: pool.clone(), shape })
            .collect();
        drop(pool);
        let retune =
            self.tuner.as_ref().map(|t| t.log()).unwrap_or_default();
        let server = Server::with_backends(backends)
            .with_queue(self.max_batch, self.max_wait)
            .with_queue_capacity(self.queue_cap)
            .with_workload(self.observer.clone())
            .with_retune(retune)
            .with_supervise(self.supervise.clone());
        let result = if workers > 1 {
            server.serve_pool(addr, on_bound)
        } else {
            server.serve(addr, on_bound)
        };
        let log = self.stop_tuner();
        self.write_retune_log(&log);
        if let Some(pool) = self.pool.take() {
            Self::retire_pool(pool);
        }
        result
    }

    /// Move the primary pipeline out of the session (for callers that
    /// embed it in a custom serving backend, e.g. the PJRT-reference
    /// path). The tuner and pool, if any, are stopped.
    pub fn into_pipeline(mut self) -> Pipeline {
        let log = self.stop_tuner();
        self.write_retune_log(&log);
        if let Some(pool) = self.pool.take() {
            Self::retire_pool(pool);
        }
        self.pipeline
    }

    /// Fresh pipeline replicas from this session's recipe (same net,
    /// config, and weight sources — bit-identical behaviour).
    fn build_pipelines(&self, n: usize) -> Result<Vec<Pipeline>> {
        (0..n)
            .map(|_| {
                Pipeline::new(self.net.clone(), self.config.clone(),
                              self.sources.clone())
            })
            .collect()
    }
}

/// Serving backend over a simulator pipeline. Dense images are
/// threshold-encoded (at 0.5) to the pipeline's post-encoder input
/// shape; spike frames from the events protocol enter as-is — no
/// dense decode anywhere on that path. `Send`, so the replica pool
/// can spread copies across worker threads.
struct FrameBackend {
    pipe: Pipeline,
    shape: (usize, usize, usize),
    observer: Arc<WorkloadObserver>,
}

impl Backend for FrameBackend {
    fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
        let (h, w, c) = self.shape;
        let frame = SpikeFrame::from_f32(h, w, c, image);
        self.infer_frame(&frame)
    }

    fn input_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    fn infer_frame(&mut self, frame: &SpikeFrame)
                   -> Result<(usize, Vec<f32>)> {
        anyhow::ensure!(
            (frame.h, frame.w, frame.c) == self.shape,
            "frame shape ({}, {}, {}) != session input {:?}",
            frame.h, frame.w, frame.c, self.shape);
        let rep = self.pipe.run(std::slice::from_ref(frame));
        self.observer
            .observe(&rep.layer_names, &rep.codec_ratios, rep.frames);
        let class = *rep
            .predictions
            .first()
            .ok_or_else(|| anyhow::anyhow!("no prediction"))?;
        Ok((class, rep.logits.first().cloned().unwrap_or_default()))
    }

    fn frame_shape(&self) -> Option<(usize, usize, usize)> {
        Some(self.shape)
    }
}

/// Serving backend that forwards into the session's [`ReplicaPool`]
/// instead of owning a pipeline — the `--online-tune` path, where the
/// pool must stay swappable underneath live connections. Blocking
/// per request; the server runs one per worker so forwarders cover
/// the widest replica split the tuner may choose. Workload
/// observation happens inside the pool (once per served frame), not
/// here.
struct PoolBackend {
    pool: Arc<ReplicaPool>,
    shape: (usize, usize, usize),
}

impl Backend for PoolBackend {
    fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
        let (h, w, c) = self.shape;
        let frame = SpikeFrame::from_f32(h, w, c, image);
        self.infer_frame(&frame)
    }

    fn input_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    fn infer_frame(&mut self, frame: &SpikeFrame)
                   -> Result<(usize, Vec<f32>)> {
        anyhow::ensure!(
            (frame.h, frame.w, frame.c) == self.shape,
            "frame shape ({}, {}, {}) != session input {:?}",
            frame.h, frame.w, frame.c, self.shape);
        let r = self.pool.infer(frame.clone())?;
        if let Some(e) = r.error {
            anyhow::bail!("{e}");
        }
        let class = r.prediction.ok_or_else(|| {
            anyhow::anyhow!("no prediction")
        })?;
        Ok((class, r.logits))
    }

    fn frame_shape(&self) -> Option<(usize, usize, usize)> {
        Some(self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn frames(shape: (usize, usize, usize), n: usize, seed: u64)
              -> Vec<SpikeFrame> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                        &mut rng))
            .collect()
    }

    #[test]
    fn builder_requires_a_network_source() {
        assert!(Session::builder().build().is_err());
        assert!(Session::builder().model("no-such-net").build().is_err());
        assert!(Session::builder().model("scnn3").build().is_ok());
    }

    #[test]
    fn infer_batch_reports_unified_metrics() {
        let mut s = Session::builder().model("scnn3").build().unwrap();
        let f = frames(s.input_shape(), 2, 1);
        let rep = s.infer_batch(&f);
        assert_eq!(rep.frames, 2);
        assert_eq!(rep.predictions.len(), 2);
        assert!(rep.t_max > 0);
        assert!(rep.fps_steady > 0.0);
        assert!(rep.power_w > 0.0);
        assert!(rep.gops_per_w_per_pe > 0.0);
        // Table-IV row derives from the same numbers.
        let row = rep.perf_row("test");
        assert!((row.fps - rep.fps_steady).abs() / rep.fps_steady < 1e-9);
    }

    #[test]
    fn parallel_factors_validate_at_build() {
        assert!(Session::builder()
            .model("scnn3")
            .parallel_factors(&[3, 2])
            .build()
            .is_err());
        let s = Session::builder()
            .model("scnn3")
            .parallel_factors(&[4, 2])
            .build()
            .unwrap();
        assert_eq!(s.net().total_pes(), 54);
    }

    #[test]
    fn submit_round_trips_through_the_pool() {
        let mut s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .replicas(2)
            .queue(4, Duration::from_millis(2))
            .build()
            .unwrap();
        let f = frames(s.input_shape(), 4, 2);
        let direct: Vec<usize> = {
            let mut solo = Session::builder()
                .model("scnn3")
                .backend(BackendKind::WordParallel)
                .build()
                .unwrap();
            f.iter()
                .map(|fr| solo.infer(fr.clone()).unwrap().class)
                .collect()
        };
        let rxs: Vec<_> =
            f.iter().map(|fr| s.submit(fr.clone()).unwrap()).collect();
        let got: Vec<usize> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().prediction.unwrap())
            .collect();
        assert_eq!(got, direct);
        assert!(s.pool_metrics().is_some());
        s.shutdown();
    }

    /// A chaos plan wired through the builder: the targeted frame is
    /// answered with an explicit error (never a hang), the worker
    /// restarts under the default budget, and the supervision
    /// counters surface in the telemetry snapshot.
    #[test]
    fn chaos_session_restarts_and_reports() {
        use crate::supervise::{FaultEvent, FaultPlan};
        let plan = FaultPlan::new(
            7, vec![FaultEvent::PanicAt { replica: 0, frame: 0 }]);
        let mut s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .chaos(plan)
            .build()
            .unwrap();
        let f = frames(s.input_shape(), 2, 21);
        s.start_pool().unwrap();
        let first = s.infer(f[0].clone());
        assert!(first.is_err(), "injected panic surfaces as an error");
        let second = s.infer(f[1].clone()).unwrap();
        assert_eq!(second.replica, 0, "restarted worker serves again");
        let t = s.telemetry();
        assert_eq!(t.supervise.replica_restarts, 1);
        assert_eq!(t.supervise.replicas_retired, 0);
        assert_eq!(s.alive_replicas(), Some(1));
        assert_eq!(s.fault_hooks().unwrap().injected(), 1);
        s.shutdown();
    }

    /// An idle watchdog through the builder leaves the unified report
    /// bit-identical and never fires.
    #[test]
    fn watchdog_session_is_bit_exact_when_idle() {
        let mut plain = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .build()
            .unwrap();
        let mut dogged = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .watchdog(WatchdogPolicy::default())
            .build()
            .unwrap();
        let f = frames(plain.input_shape(), 2, 33);
        let a = plain.infer_batch(&f);
        let b = dogged.infer_batch(&f);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(b.channel_stats.len(), b.layer_names.len() - 1,
                   "watchdogged batch still streams");
        assert_eq!(dogged.telemetry().supervise.watchdog_fires, 0);
    }

    /// Event windows classify identically to the same frames fed
    /// densely — the session-level face of the events==dense property
    /// (the full report-pinning version lives in tests/prop_stream.rs).
    #[test]
    fn infer_events_matches_dense_windows() {
        use crate::codec::stream::frame_events;
        let mut s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .build()
            .unwrap();
        let shape = s.input_shape();
        let fs = frames(shape, 3, 9);
        let want: Vec<usize> = fs
            .iter()
            .map(|f| s.infer(f.clone()).unwrap().class)
            .collect();
        // One window per frame: all of a frame's events share one
        // timestamp, one window per 1000 µs.
        let events: Vec<_> = fs
            .iter()
            .enumerate()
            .flat_map(|(i, f)| frame_events(f, i as u32 * 1000))
            .collect();
        let out = s
            .infer_events(&events, WindowPolicy::TimeUs(1000))
            .unwrap();
        let got: Vec<usize> =
            out.windows.iter().map(|i| i.class).collect();
        assert_eq!(got, want);
        assert_eq!(out.stats.windows, 3);
        assert_eq!(out.stats.events, events.len() as u64);
    }

    /// submit_events routes windows through the pool; a bounded queue
    /// sheds explicitly rather than queueing without limit.
    #[test]
    fn submit_events_round_trips_and_bounds() {
        use crate::codec::stream::synth_events;
        let mut s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .replicas(2)
            .queue(4, Duration::from_millis(2))
            .build()
            .unwrap();
        let (h, w, c) = s.input_shape();
        let events = synth_events(h, w, c, 4, 0.1, 1000, 11);
        let sub = s
            .submit_events(&events, WindowPolicy::TimeUs(1000))
            .unwrap();
        assert_eq!(sub.shed, 0, "unbounded queue never sheds");
        assert_eq!(sub.receivers.len(), 4);
        for rx in &sub.receivers {
            assert!(rx.recv().unwrap().prediction.is_some());
        }
        assert_eq!(sub.stats.windows, 4);
        s.shutdown();
    }

    /// The telemetry snapshot tracks observed frames and per-layer
    /// density, and the streamed schedule surfaces its row-channel
    /// counters in the unified report.
    #[test]
    fn telemetry_snapshot_tracks_observed_workload() {
        let mut s = Session::builder().model("scnn3").build().unwrap();
        let f = frames(s.input_shape(), 2, 3);
        let rep = s.infer_batch(&f);
        // Default schedule is pipelined => one link per layer pair.
        assert_eq!(rep.channel_stats.len(), rep.layer_names.len() - 1);
        assert!(rep.channel_stats.iter().all(|c| c.sends == c.recvs));
        let t = s.telemetry();
        assert_eq!(t.workload.frames, 2);
        assert!(!t.workload.layers.is_empty());
        assert!(t.latency.is_none(), "no pool => no latency summary");
        assert!(t.queue_depth.is_none());
    }

    #[test]
    fn serve_round_trips_over_tcp() {
        use crate::server::Client;
        let s = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .build()
            .unwrap();
        let shape = s.input_shape();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            s.serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();
        let mut c = Client::connect(&addr).unwrap();
        let n = shape.0 * shape.1 * shape.2;
        let mut rng = Rng::new(5);
        let image: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let resp = c.infer(1, &image).unwrap();
        assert!(resp.get("class").is_some(), "{resp}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }
}
