//! # STI-SNN — single-timestep-inference SNN accelerator (reproduction)
//!
//! Rust Layer-3 of the three-layer stack (DESIGN.md).
//!
//! **Start here:** [`session`] — the public construction API. A
//! [`session::Session`] assembles the whole stack (network + engines +
//! pipeline + replica pool + TCP serving) through one fluent builder;
//! the CLI, benches, and examples all go through it. The per-layer
//! hardware surface underneath is the [`sim::engine::LayerEngine`]
//! trait.
//!
//! Module map:
//!
//! * [`session`] — the `Session` facade: one builder for sim, serving,
//!   DSE auto-tuning, benches, and examples; unified `Report`.
//! * [`arch`] — network/layer hardware description shared with python.
//! * [`codec`] — compressed & sorted spike vectors + event encoding;
//!   [`codec::stream`] windows sorted DVS-style address events into
//!   single-timestep frames (the event-driven ingestion path).
//! * [`dataflow`] — analytical access-count (Tables I/III) and latency
//!   (Eq. 10-12) models.
//! * [`sim`] — cycle-level simulator of the accelerator (PE array, line
//!   buffer, neuron unit, OS/WS engines, energy & resource models).
//!   [`sim::engine`] defines the `LayerEngine` trait every layer
//!   engine implements; `sim::backend` holds the pluggable functional
//!   compute backends (event-driven `accurate` vs bit-plane popcount
//!   `word-parallel`, bit-exact).
//! * [`coordinator`] — streaming layer-wise pipeline over boxed
//!   `LayerEngine`s, parallel-factor scheduler, frame batching, and
//!   the N-replica serving pool.
//! * [`dse`] — design-space exploration: search-space enumeration,
//!   calibrated analytical evaluation, Pareto frontier + serving
//!   choice, JSON reporting (`explore` / `serve --auto-tune`).
//! * [`autotune`] — online co-optimization: a controller that re-runs
//!   the calibrated DSE against the *measured* workload and hot-swaps
//!   the replica pool through its zero-downtime generation protocol
//!   (`serve --online-tune`), gated by a flap-proof decision policy.
//! * [`runtime`] — PJRT wrapper executing the AOT HLO artifacts
//!   (requires the `pjrt` cargo feature; stubs out otherwise).
//! * [`model`] — artifact loading (net.json + int8 weights) into
//!   `LayerWeights` engine sources.
//! * [`server`] — TCP host interface (paper Fig. 10), single-pipeline
//!   or replica-pool mode; dense newline-JSON plus the length-prefixed
//!   binary events protocol with explicit backpressure;
//!   `Session::serve` fronts it.
//! * [`metrics`] — FPS / GOPS / GOPS/W / GOPS/W/PE accounting plus
//!   per-replica serving counters and the latency reservoir behind
//!   the served p50/p95/p99 numbers.
//! * [`supervise`] — fault tolerance: panic-isolated replica workers
//!   under budgeted-backoff restart ([`supervise::Supervisor`]),
//!   streamed-executor watchdog deadlines with serial-retry
//!   degradation, transactional retune swaps with health-probe
//!   rollback, and the seeded [`supervise::FaultPlan`] chaos harness
//!   (`serve --chaos`).
//! * [`telemetry`] — host-side observability: allocation-bounded
//!   trace spans with Chrome trace-event export (`run --trace`), the
//!   Prometheus-style metrics registry behind the server `metrics`
//!   command, and rolling workload observers (per-layer spike
//!   density with windowed min/max, inter-arrival) feeding the
//!   [`autotune`] controller.

pub mod arch;
pub mod autotune;
pub mod codec;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod session;
pub mod sim;
pub mod supervise;
pub mod telemetry;
pub mod util;

pub use session::{Session, SessionBuilder, Weights};
