//! # STI-SNN — single-timestep-inference SNN accelerator (reproduction)
//!
//! Rust Layer-3 of the three-layer stack (DESIGN.md):
//!
//! * [`arch`] — network/layer hardware description shared with python.
//! * [`codec`] — compressed & sorted spike vectors + event encoding.
//! * [`dataflow`] — analytical access-count (Tables I/III) and latency
//!   (Eq. 10-12) models.
//! * [`sim`] — cycle-level simulator of the accelerator (PE array, line
//!   buffer, neuron unit, OS/WS engines, energy & resource models).
//! * [`coordinator`] — streaming layer-wise pipeline, parallel-factor
//!   scheduler, frame batching.
//! * [`runtime`] — PJRT wrapper executing the AOT HLO artifacts.
//! * [`model`] — artifact loading (net.json + int8 weights).
//! * [`server`] — TCP host interface (paper Fig. 10).
//! * [`metrics`] — FPS / GOPS / GOPS/W / GOPS/W/PE accounting.

pub mod arch;
pub mod codec;
pub mod coordinator;
pub mod dataflow;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
