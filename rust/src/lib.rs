//! # STI-SNN — single-timestep-inference SNN accelerator (reproduction)
//!
//! Rust Layer-3 of the three-layer stack (DESIGN.md):
//!
//! * [`arch`] — network/layer hardware description shared with python.
//! * [`codec`] — compressed & sorted spike vectors + event encoding.
//! * [`dataflow`] — analytical access-count (Tables I/III) and latency
//!   (Eq. 10-12) models.
//! * [`sim`] — cycle-level simulator of the accelerator (PE array, line
//!   buffer, neuron unit, OS/WS engines, energy & resource models) with
//!   pluggable functional compute backends (`sim::backend`: event-driven
//!   `accurate` vs bit-plane popcount `word-parallel`, bit-exact).
//! * [`coordinator`] — streaming layer-wise pipeline, parallel-factor
//!   scheduler, frame batching, and the N-replica serving pool.
//! * [`dse`] — design-space exploration: search-space enumeration,
//!   calibrated analytical evaluation, Pareto frontier + serving
//!   choice, JSON reporting (`explore` / `serve --auto-tune`).
//! * [`runtime`] — PJRT wrapper executing the AOT HLO artifacts
//!   (requires the `pjrt` cargo feature; stubs out otherwise).
//! * [`model`] — artifact loading (net.json + int8 weights).
//! * [`server`] — TCP host interface (paper Fig. 10), single-pipeline
//!   or replica-pool mode.
//! * [`metrics`] — FPS / GOPS / GOPS/W / GOPS/W/PE accounting plus
//!   per-replica serving counters.

pub mod arch;
pub mod codec;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
