//! Performance metrics: FPS, GOPS, power, efficiency (paper Table IV) —
//! plus the serving-side per-replica counters ([`PoolMetrics`]) that
//! the multi-pipeline server and the replica pool aggregate.
//!
//! The paper's metric definitions:
//! * `GOPS = kFPS x MOPs` — synaptic accumulates per second.
//! * `Efficiency = GOPS / W`.
//! * `Efficiency/PE = GOPS / W / PE` — the headline 0.14 (SCNN5) and
//!   0.19 (SCNN3) GOPS/W/PE numbers.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::CLK_HZ;

/// One Table-IV row.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfRow {
    pub name: String,
    pub fps: f64,
    pub mops_per_frame: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub gops_per_w_per_pe: f64,
    pub pes: usize,
}

impl PerfRow {
    /// Derive a row from first principles.
    ///
    /// * `cycles_per_frame` — pipeline interval (Eq. 11 at large N).
    /// * `ops_per_frame` — synaptic accumulates per frame.
    /// * `power_w` — average power from the energy model.
    pub fn new(name: &str, cycles_per_frame: f64, ops_per_frame: u64,
               power_w: f64, pes: usize) -> Self {
        let fps = CLK_HZ / cycles_per_frame;
        let mops = ops_per_frame as f64 / 1e6;
        let gops = fps * mops / 1e3; // kFPS x MOPs
        let gops_per_w = gops / power_w;
        Self {
            name: name.to_string(),
            fps,
            mops_per_frame: mops,
            gops,
            power_w,
            gops_per_w,
            gops_per_w_per_pe: gops_per_w / pes as f64,
            pes,
        }
    }

    pub fn header() -> String {
        format!(
            "{:<22} {:>9} {:>9} {:>9} {:>8} {:>10} {:>12} {:>5}",
            "design", "FPS", "MOPs/frm", "GOPS", "Power W", "GOPS/W",
            "GOPS/W/PE", "PEs"
        )
    }
}

impl std::fmt::Display for PerfRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<22} {:>9.1} {:>9.2} {:>9.2} {:>8.2} {:>10.2} {:>12.3} {:>5}",
            self.name, self.fps, self.mops_per_frame, self.gops,
            self.power_w, self.gops_per_w, self.gops_per_w_per_pe, self.pes
        )
    }
}

// ---------------------------------------------------------------------------
// Serving metrics (multi-pipeline replica pool)
// ---------------------------------------------------------------------------

/// Lock-free counters of one pipeline replica in the serving pool.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Requests completed by this replica.
    pub requests: AtomicU64,
    /// Requests that failed in this replica's backend.
    pub errors: AtomicU64,
    /// Microseconds the replica spent inside the backend.
    pub busy_us: AtomicU64,
    /// Sum of end-to-end request latencies (queue wait + compute), µs.
    pub latency_us: AtomicU64,
}

/// Plain-data snapshot of one replica's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplicaSnapshot {
    pub requests: u64,
    pub errors: u64,
    pub busy_us: u64,
    pub latency_us: u64,
}

/// Aggregated metrics of an N-replica serving pool. Writers update
/// their own replica's atomics; readers snapshot without locking.
#[derive(Debug)]
pub struct PoolMetrics {
    replicas: Vec<ReplicaMetrics>,
}

impl PoolMetrics {
    pub fn new(replicas: usize) -> Self {
        Self {
            replicas: (0..replicas.max(1))
                .map(|_| ReplicaMetrics::default())
                .collect(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Record a completed request on replica `i`.
    pub fn record(&self, i: usize, latency_us: u64, busy_us: u64) {
        let r = &self.replicas[i];
        r.requests.fetch_add(1, Ordering::Relaxed);
        r.latency_us.fetch_add(latency_us, Ordering::Relaxed);
        r.busy_us.fetch_add(busy_us, Ordering::Relaxed);
    }

    /// Record a failed request on replica `i`.
    pub fn record_error(&self, i: usize) {
        self.replicas[i].errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot one replica.
    pub fn replica(&self, i: usize) -> ReplicaSnapshot {
        let r = &self.replicas[i];
        ReplicaSnapshot {
            requests: r.requests.load(Ordering::Relaxed),
            errors: r.errors.load(Ordering::Relaxed),
            busy_us: r.busy_us.load(Ordering::Relaxed),
            latency_us: r.latency_us.load(Ordering::Relaxed),
        }
    }

    /// Snapshot every replica.
    pub fn per_replica(&self) -> Vec<ReplicaSnapshot> {
        (0..self.replicas.len()).map(|i| self.replica(i)).collect()
    }

    /// Pool-wide totals (sum over replicas).
    pub fn totals(&self) -> ReplicaSnapshot {
        let mut t = ReplicaSnapshot::default();
        for s in self.per_replica() {
            t.requests += s.requests;
            t.errors += s.errors;
            t.busy_us += s.busy_us;
            t.latency_us += s.latency_us;
        }
        t
    }
}

/// Published comparison rows (paper Table IV) for printing next to ours.
pub fn sota_rows() -> Vec<PerfRow> {
    let mk = |name: &str, fps: f64, gops: f64, w: f64, pes: usize| PerfRow {
        name: name.to_string(),
        fps,
        mops_per_frame: if fps > 0.0 { gops / fps * 1e3 } else { 0.0 },
        gops,
        power_w: w,
        gops_per_w: gops / w,
        gops_per_w_per_pe: if pes > 0 { gops / w / pes as f64 } else { 0.0 },
        pes,
    };
    vec![
        mk("Fang et al. [38]", 133.0, 0.65, 4.5, 0),
        mk("Ye et al. [39]", 826.4, 5.26, 0.98, 256),
        mk("Ju et al. [40]", 164.0, 2.50, 4.6, 0),
        mk("Cerebron MNIST [41]", 38_500.0, 40.1, 1.4, 256),
        mk("Cerebron CIFAR [41]", 94.0, 44.2, 1.4, 256),
        mk("Firefly SCNN-5 [42]", 2036.0, 265.76, 2.55, 2304),
        mk("Firefly SCNN-7 [42]", 966.0, 274.49, 2.55, 2304),
    ]
}

/// Paper's own result rows (Ours-1..5) for shape comparison.
pub fn paper_ours_rows() -> Vec<(&'static str, f64, f64, f64, f64, f64)> {
    // (name, FPS, GOPS, W, GOPS/W, GOPS/W/PE)
    vec![
        ("Ours-1 SCNN3", 341.3, 1.85, 0.66, 2.79, 0.16),
        ("Ours-2 SCNN3 (4,2)", 1333.0, 7.22, 0.71, 10.15, 0.19),
        ("Ours-3 SCNN5", 99.4, 5.16, 1.34, 3.86, 0.11),
        ("Ours-4 SCNN5 (4,4,2,1)", 397.0, 20.6, 1.53, 13.46, 0.14),
        ("Ours-5 vMobileNet", 290.0, 0.75, 0.74, 1.01, 0.03),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf_row_math() {
        // 200 MHz / 2M cycles = 100 FPS; 50 MOPs -> 5 GOPS; 2 W -> 2.5
        // GOPS/W; 100 PEs -> 0.025 GOPS/W/PE.
        let r = PerfRow::new("x", 2e6, 50_000_000, 2.0, 100);
        assert!((r.fps - 100.0).abs() < 1e-9);
        assert!((r.gops - 5.0).abs() < 1e-9);
        assert!((r.gops_per_w - 2.5).abs() < 1e-9);
        assert!((r.gops_per_w_per_pe - 0.025).abs() < 1e-9);
    }

    #[test]
    fn pool_metrics_aggregate_across_replicas() {
        let m = PoolMetrics::new(3);
        m.record(0, 100, 60);
        m.record(0, 50, 30);
        m.record(2, 10, 5);
        m.record_error(1);
        assert_eq!(m.replica(0).requests, 2);
        assert_eq!(m.replica(0).latency_us, 150);
        assert_eq!(m.replica(1).errors, 1);
        assert_eq!(m.replica(2).busy_us, 5);
        let t = m.totals();
        assert_eq!((t.requests, t.errors, t.latency_us, t.busy_us),
                   (3, 1, 160, 95));
        assert_eq!(m.per_replica().len(), 3);
    }

    #[test]
    fn pool_metrics_shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(PoolMetrics::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record(i, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.totals().requests, 400);
        for i in 0..4 {
            assert_eq!(m.replica(i).requests, 100);
        }
    }

    #[test]
    fn sota_rows_consistent() {
        for r in sota_rows() {
            if r.pes > 0 {
                assert!((r.gops_per_w_per_pe
                    - r.gops / r.power_w / r.pes as f64)
                    .abs() < 1e-9);
            }
        }
    }
}
