//! Performance metrics: FPS, GOPS, power, efficiency (paper Table IV) —
//! plus the serving-side per-replica counters ([`PoolMetrics`]) that
//! the multi-pipeline server and the replica pool aggregate.
//!
//! The paper's metric definitions:
//! * `GOPS = kFPS x MOPs` — synaptic accumulates per second.
//! * `Efficiency = GOPS / W`.
//! * `Efficiency/PE = GOPS / W / PE` — the headline 0.14 (SCNN5) and
//!   0.19 (SCNN3) GOPS/W/PE numbers.
//!
//! Serving latency is tracked two ways: per-replica saturating sums
//! (cheap aggregate bookkeeping that can never wrap) and a pool-wide
//! fixed-size [`LatencyReservoir`] holding the most recent request
//! latencies, from which [`LatencySummary`] derives mean and
//! p50/p95/p99 percentiles — the numbers the server's `stats` command
//! reports.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::CLK_HZ;

/// Lock-free saturating add on an atomic counter (latency sums must
/// clamp at `u64::MAX` instead of wrapping back to small values).
fn saturating_fetch_add(a: &AtomicU64, v: u64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let next = cur.saturating_add(v);
        match a.compare_exchange_weak(cur, next, Ordering::Relaxed,
                                      Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// One Table-IV row.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfRow {
    pub name: String,
    pub fps: f64,
    pub mops_per_frame: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub gops_per_w_per_pe: f64,
    pub pes: usize,
}

impl PerfRow {
    /// Derive a row from first principles.
    ///
    /// * `cycles_per_frame` — pipeline interval (Eq. 11 at large N).
    /// * `ops_per_frame` — synaptic accumulates per frame.
    /// * `power_w` — average power from the energy model.
    pub fn new(name: &str, cycles_per_frame: f64, ops_per_frame: u64,
               power_w: f64, pes: usize) -> Self {
        let fps = CLK_HZ / cycles_per_frame;
        let mops = ops_per_frame as f64 / 1e6;
        let gops = fps * mops / 1e3; // kFPS x MOPs
        let gops_per_w = gops / power_w;
        Self {
            name: name.to_string(),
            fps,
            mops_per_frame: mops,
            gops,
            power_w,
            gops_per_w,
            gops_per_w_per_pe: gops_per_w / pes as f64,
            pes,
        }
    }

    pub fn header() -> String {
        format!(
            "{:<22} {:>9} {:>9} {:>9} {:>8} {:>10} {:>12} {:>5}",
            "design", "FPS", "MOPs/frm", "GOPS", "Power W", "GOPS/W",
            "GOPS/W/PE", "PEs"
        )
    }
}

impl std::fmt::Display for PerfRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<22} {:>9.1} {:>9.2} {:>9.2} {:>8.2} {:>10.2} {:>12.3} {:>5}",
            self.name, self.fps, self.mops_per_frame, self.gops,
            self.power_w, self.gops_per_w, self.gops_per_w_per_pe, self.pes
        )
    }
}

// ---------------------------------------------------------------------------
// Serving metrics (multi-pipeline replica pool)
// ---------------------------------------------------------------------------

/// Lock-free counters of one pipeline replica in the serving pool.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Requests completed by this replica.
    pub requests: AtomicU64,
    /// Requests that failed in this replica's backend.
    pub errors: AtomicU64,
    /// Microseconds the replica spent inside the backend (saturating).
    pub busy_us: AtomicU64,
    /// Sum of end-to-end request latencies (queue wait + compute), µs.
    /// Saturates at `u64::MAX` instead of wrapping; for mean and
    /// percentile latency use [`PoolMetrics::latency_summary`].
    pub latency_us: AtomicU64,
}

/// Plain-data snapshot of one replica's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplicaSnapshot {
    pub requests: u64,
    pub errors: u64,
    pub busy_us: u64,
    pub latency_us: u64,
}

/// Fixed-size ring of the most recent request latencies (lock-free:
/// one atomic slot per sample plus a running write index). Bounded
/// memory no matter how long the server runs, and the source of the
/// mean/percentile numbers in [`LatencySummary`] — replacing the old
/// monotonically-growing latency sum that wrapped after ~584k years of
/// µs... or after one bad clock step.
#[derive(Debug)]
pub struct LatencyReservoir {
    slots: Vec<AtomicU64>,
    /// Total samples ever recorded; `% slots.len()` is the write index.
    count: AtomicU64,
}

/// Default reservoir capacity (samples) used by [`PoolMetrics`].
pub const LATENCY_RESERVOIR_CAP: usize = 1024;

impl LatencyReservoir {
    pub fn new(cap: usize) -> Self {
        Self {
            slots: (0..cap.max(1)).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
        }
    }

    /// Record one request latency (µs; clamped to `u64::MAX - 1`).
    /// Overwrites the oldest sample once the ring is full — the
    /// summary reflects recent traffic.
    pub fn record(&self, latency_us: u64) {
        let i = self.count.fetch_add(1, Ordering::Relaxed) as usize
            % self.slots.len();
        // Samples are stored value+1 so 0 stays the "never written"
        // sentinel: a slot claimed by a concurrent writer that has not
        // stored yet still reads as empty (or as its previous valid
        // sample), never as a spurious 0 µs measurement.
        self.slots[i].store(latency_us.saturating_add(1),
                            Ordering::Relaxed);
    }

    /// Samples ever recorded (not capped at the ring size).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean + nearest-rank percentiles over the resident window.
    pub fn summary(&self) -> LatencySummary {
        let count = self.count();
        let mut v: Vec<u64> = self.slots
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .filter(|&s| s != 0)
            .map(|s| s - 1)
            .collect();
        let resident = v.len();
        if resident == 0 {
            return LatencySummary::default();
        }
        v.sort_unstable();
        // Nearest-rank: percentile q is the ceil(q*n)-th smallest.
        let rank = |q: f64| {
            let k = (q * resident as f64).ceil() as usize;
            v[k.clamp(1, resident) - 1]
        };
        let sum: u128 = v.iter().map(|&x| x as u128).sum();
        LatencySummary {
            count,
            window: resident as u64,
            mean_us: (sum / resident as u128) as u64,
            p50_us: rank(0.50),
            p95_us: rank(0.95),
            p99_us: rank(0.99),
            max_us: v[resident - 1],
        }
    }
}

/// Snapshot of the latency reservoir: mean + nearest-rank percentiles
/// over the most recent [`LatencySummary::window`] requests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencySummary {
    /// Requests ever recorded.
    pub count: u64,
    /// Samples the statistics below are computed over (ring residency).
    pub window: u64,
    pub mean_us: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// Aggregated metrics of an N-replica serving pool. Writers update
/// their own replica's atomics; readers snapshot without locking.
#[derive(Debug)]
pub struct PoolMetrics {
    replicas: Vec<ReplicaMetrics>,
    latency: LatencyReservoir,
}

impl PoolMetrics {
    pub fn new(replicas: usize) -> Self {
        Self {
            replicas: (0..replicas.max(1))
                .map(|_| ReplicaMetrics::default())
                .collect(),
            latency: LatencyReservoir::new(LATENCY_RESERVOIR_CAP),
        }
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Record a completed request on replica `i`.
    pub fn record(&self, i: usize, latency_us: u64, busy_us: u64) {
        let r = &self.replicas[i];
        r.requests.fetch_add(1, Ordering::Relaxed);
        saturating_fetch_add(&r.latency_us, latency_us);
        saturating_fetch_add(&r.busy_us, busy_us);
        self.latency.record(latency_us);
    }

    /// Pool-wide mean + percentile latency over recent requests.
    pub fn latency_summary(&self) -> LatencySummary {
        self.latency.summary()
    }

    /// Record a failed request on replica `i`.
    pub fn record_error(&self, i: usize) {
        self.replicas[i].errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot one replica.
    pub fn replica(&self, i: usize) -> ReplicaSnapshot {
        let r = &self.replicas[i];
        ReplicaSnapshot {
            requests: r.requests.load(Ordering::Relaxed),
            errors: r.errors.load(Ordering::Relaxed),
            busy_us: r.busy_us.load(Ordering::Relaxed),
            latency_us: r.latency_us.load(Ordering::Relaxed),
        }
    }

    /// Snapshot every replica.
    pub fn per_replica(&self) -> Vec<ReplicaSnapshot> {
        (0..self.replicas.len()).map(|i| self.replica(i)).collect()
    }

    /// Pool-wide totals (sum over replicas; time sums saturate).
    pub fn totals(&self) -> ReplicaSnapshot {
        let mut t = ReplicaSnapshot::default();
        for s in self.per_replica() {
            t.requests += s.requests;
            t.errors += s.errors;
            t.busy_us = t.busy_us.saturating_add(s.busy_us);
            t.latency_us = t.latency_us.saturating_add(s.latency_us);
        }
        t
    }
}

/// Published comparison rows (paper Table IV) for printing next to ours.
pub fn sota_rows() -> Vec<PerfRow> {
    let mk = |name: &str, fps: f64, gops: f64, w: f64, pes: usize| PerfRow {
        name: name.to_string(),
        fps,
        mops_per_frame: if fps > 0.0 { gops / fps * 1e3 } else { 0.0 },
        gops,
        power_w: w,
        gops_per_w: gops / w,
        gops_per_w_per_pe: if pes > 0 { gops / w / pes as f64 } else { 0.0 },
        pes,
    };
    vec![
        mk("Fang et al. [38]", 133.0, 0.65, 4.5, 0),
        mk("Ye et al. [39]", 826.4, 5.26, 0.98, 256),
        mk("Ju et al. [40]", 164.0, 2.50, 4.6, 0),
        mk("Cerebron MNIST [41]", 38_500.0, 40.1, 1.4, 256),
        mk("Cerebron CIFAR [41]", 94.0, 44.2, 1.4, 256),
        mk("Firefly SCNN-5 [42]", 2036.0, 265.76, 2.55, 2304),
        mk("Firefly SCNN-7 [42]", 966.0, 274.49, 2.55, 2304),
    ]
}

/// Paper's own result rows (Ours-1..5) for shape comparison.
pub fn paper_ours_rows() -> Vec<(&'static str, f64, f64, f64, f64, f64)> {
    // (name, FPS, GOPS, W, GOPS/W, GOPS/W/PE)
    vec![
        ("Ours-1 SCNN3", 341.3, 1.85, 0.66, 2.79, 0.16),
        ("Ours-2 SCNN3 (4,2)", 1333.0, 7.22, 0.71, 10.15, 0.19),
        ("Ours-3 SCNN5", 99.4, 5.16, 1.34, 3.86, 0.11),
        ("Ours-4 SCNN5 (4,4,2,1)", 397.0, 20.6, 1.53, 13.46, 0.14),
        ("Ours-5 vMobileNet", 290.0, 0.75, 0.74, 1.01, 0.03),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf_row_math() {
        // 200 MHz / 2M cycles = 100 FPS; 50 MOPs -> 5 GOPS; 2 W -> 2.5
        // GOPS/W; 100 PEs -> 0.025 GOPS/W/PE.
        let r = PerfRow::new("x", 2e6, 50_000_000, 2.0, 100);
        assert!((r.fps - 100.0).abs() < 1e-9);
        assert!((r.gops - 5.0).abs() < 1e-9);
        assert!((r.gops_per_w - 2.5).abs() < 1e-9);
        assert!((r.gops_per_w_per_pe - 0.025).abs() < 1e-9);
    }

    #[test]
    fn pool_metrics_aggregate_across_replicas() {
        let m = PoolMetrics::new(3);
        m.record(0, 100, 60);
        m.record(0, 50, 30);
        m.record(2, 10, 5);
        m.record_error(1);
        assert_eq!(m.replica(0).requests, 2);
        assert_eq!(m.replica(0).latency_us, 150);
        assert_eq!(m.replica(1).errors, 1);
        assert_eq!(m.replica(2).busy_us, 5);
        let t = m.totals();
        assert_eq!((t.requests, t.errors, t.latency_us, t.busy_us),
                   (3, 1, 160, 95));
        assert_eq!(m.per_replica().len(), 3);
    }

    #[test]
    fn pool_metrics_shared_across_threads() {
        use std::sync::Arc;
        let m = Arc::new(PoolMetrics::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record(i, 1, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.totals().requests, 400);
        for i in 0..4 {
            assert_eq!(m.replica(i).requests, 100);
        }
    }

    /// Satellite fix: latency aggregates saturate instead of wrapping,
    /// and mean/percentiles come from the reservoir.
    #[test]
    fn latency_sums_saturate_instead_of_wrapping() {
        let m = PoolMetrics::new(1);
        m.record(0, u64::MAX - 10, u64::MAX - 10);
        m.record(0, 100, 100);
        let t = m.totals();
        assert_eq!(t.latency_us, u64::MAX, "sum clamped, not wrapped");
        assert_eq!(t.busy_us, u64::MAX);
        assert_eq!(t.requests, 2);
    }

    #[test]
    fn latency_reservoir_percentiles_nearest_rank() {
        let r = LatencyReservoir::new(256);
        // 1..=100 µs in shuffled-ish order: percentiles are exact.
        for i in (1..=100u64).rev() {
            r.record(i);
        }
        let s = r.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.window, 100);
        assert_eq!(s.mean_us, 50); // 5050/100 truncated
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn latency_reservoir_keeps_recent_window() {
        let r = LatencyReservoir::new(4);
        for v in [1000, 1000, 1000, 1000, 1, 2, 3, 4] {
            r.record(v);
        }
        let s = r.summary();
        // The four old 1000s were overwritten by the recent 1..4.
        assert_eq!(s.count, 8);
        assert_eq!(s.window, 4);
        assert_eq!(s.max_us, 4);
        assert_eq!(s.p50_us, 2);
        let empty = LatencyReservoir::new(8).summary();
        assert_eq!(empty, LatencySummary::default());
    }

    /// Edge cases around the reservoir boundaries: a lone sample is
    /// every percentile, filling exactly to capacity keeps all
    /// samples, and one more wraps onto the oldest slot only.
    #[test]
    fn latency_reservoir_single_sample_and_exact_capacity_wrap() {
        let r = LatencyReservoir::new(8);
        r.record(7);
        let s = r.summary();
        assert_eq!((s.count, s.window), (1, 1));
        assert_eq!((s.mean_us, s.p50_us, s.p95_us, s.p99_us, s.max_us),
                   (7, 7, 7, 7, 7));

        // Exactly capacity: nothing overwritten yet.
        let r = LatencyReservoir::new(4);
        for v in [10, 20, 30, 40] {
            r.record(v);
        }
        let s = r.summary();
        assert_eq!((s.count, s.window), (4, 4));
        assert_eq!((s.p50_us, s.max_us), (20, 40));
        // One past capacity wraps onto the oldest sample (10).
        r.record(50);
        let s = r.summary();
        assert_eq!((s.count, s.window), (5, 4));
        assert_eq!((s.p50_us, s.max_us), (30, 50));
    }

    /// Values at the top of the u64 range: `record` clamps at
    /// `u64::MAX - 1` (the +1 storage sentinel must not wrap to the
    /// "empty" 0), and the u128 mean cannot overflow.
    #[test]
    fn latency_reservoir_saturates_near_u64_max() {
        let r = LatencyReservoir::new(4);
        r.record(u64::MAX);
        r.record(u64::MAX - 1);
        let s = r.summary();
        assert_eq!(s.window, 2);
        assert_eq!(s.max_us, u64::MAX - 1, "clamped by the sentinel");
        assert_eq!(s.p99_us, u64::MAX - 1);
        assert_eq!(s.mean_us, u64::MAX - 1, "mean summed in u128");
    }

    /// A zero-capacity request still yields a usable (1-slot) ring,
    /// and an empty ring summarises to the default.
    #[test]
    fn latency_reservoir_zero_capacity_and_empty() {
        let r = LatencyReservoir::new(0);
        assert_eq!(r.summary(), LatencySummary::default());
        r.record(5);
        r.record(9);
        let s = r.summary();
        assert_eq!((s.count, s.window), (2, 1));
        assert_eq!(s.max_us, 9, "1-slot ring keeps the latest");
    }

    #[test]
    fn pool_metrics_expose_latency_summary() {
        let m = PoolMetrics::new(2);
        m.record(0, 10, 5);
        m.record(1, 30, 5);
        let s = m.latency_summary();
        assert_eq!(s.count, 2);
        assert_eq!(s.mean_us, 20);
        assert_eq!(s.max_us, 30);
    }

    #[test]
    fn sota_rows_consistent() {
        for r in sota_rows() {
            if r.pes > 0 {
                assert!((r.gops_per_w_per_pe
                    - r.gops / r.power_w / r.pes as f64)
                    .abs() < 1e-9);
            }
        }
    }
}
