//! The online tuner: a background controller that closes the
//! observe → re-evaluate → decide → swap loop over a live
//! [`ReplicaPool`].
//!
//! The controller owns nothing it serves with: the pool keeps serving
//! while the controller sleeps, plans, and decides; only a go-decision
//! touches it, through the pool's zero-downtime generation swap. Every
//! swap is recorded as a [`RetuneEvent`] in the shared [`RetuneLog`],
//! which also keeps the boot calibration + reference density — the
//! exact inputs needed to reproduce any logged decision offline
//! (`tests/online_tune.rs` replays them through
//! [`super::measure::plan`] and asserts the same choice).
//!
//! # Supervision
//!
//! Swaps are *transactional*: before the pool sees a new generation,
//! its first pipeline serves one synthetic health-probe frame whose
//! logits must be bit-identical to the offline reference (all
//! candidates are bit-exact by the factor/backend-invariance
//! contract, so any divergence — or a panic — means a broken build).
//! A failed probe rolls the retune back: the pool keeps serving the
//! old generation (`pool_generation` unchanged) and a `rolled_back`
//! [`RetuneEvent`] is recorded. The control loop itself runs under
//! `catch_unwind` with a budgeted [`RestartPolicy`] — a tuner panic
//! never takes the serving path down.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::arch::NetworkSpec;
use crate::codec::SpikeFrame;
use crate::coordinator::pipeline::{Pipeline, PipelineConfig};
use crate::coordinator::replica::ReplicaPool;
use crate::dataflow::ConvLatencyParams;
use crate::dse::{calibrate, AutoTuneOptions, Calibration,
                 CalibrationConfig, Candidate};
use crate::sim::engine::LayerWeights;
use crate::supervise::{panic_message, FaultHooks, RestartPolicy,
                       Supervisor, Verdict};
use crate::telemetry::{WorkloadObserver, WorkloadSnapshot};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::measure::{effective_fps, plan, MeasuredWorkload};
use super::policy::{Decision, Observation, PolicyState, RetunePolicy};

/// Everything needed to build a fresh replica set for any candidate:
/// the un-pinned network, the serving pipeline config, and the weight
/// sources. Factors and backend are the candidate's; everything else
/// (weights, timesteps, schedule, tracing) is carried over, so a swap
/// changes the design point and nothing else — predictions stay
/// bit-identical by the backend/factor-invariance contract.
#[derive(Clone)]
pub struct PoolRecipe {
    pub base_net: NetworkSpec,
    pub config: PipelineConfig,
    pub sources: Vec<LayerWeights>,
}

impl PoolRecipe {
    /// Build `candidate.replicas` pipelines at the candidate's factors
    /// and backend.
    pub fn build(&self, candidate: &Candidate)
                 -> anyhow::Result<Vec<Pipeline>> {
        let net = self
            .base_net
            .clone()
            .try_with_parallel_factors(&candidate.factors)?;
        let mut config = self.config.clone();
        config.backend = candidate.backend;
        (0..candidate.replicas.max(1))
            .map(|_| {
                Pipeline::new(net.clone(), config.clone(),
                              self.sources.clone())
            })
            .collect()
    }

    /// The boot probe's density in the observer's units: run one
    /// synthetic frame at the calibration firing rate through this
    /// recipe and average its per-layer codec ratios. This anchors the
    /// measured-density ratio of
    /// [`super::measure::measured_calibration`] — both sides of the
    /// ratio are codec ratios, so the units cancel. Deterministic
    /// (fixed seed, architectural counters).
    pub fn reference_density(&self, rate: f64) -> anyhow::Result<f64> {
        let mut pipe = Pipeline::new(self.base_net.clone(),
                                     self.config.clone(),
                                     self.sources.clone())?;
        let (h, w, c) = pipe.input_shape();
        let mut rng = Rng::new(CalibrationConfig::default().seed);
        let frame = SpikeFrame::random(h, w, c, rate, &mut rng);
        let rep = pipe.run(std::slice::from_ref(&frame));
        if rep.codec_ratios.is_empty() {
            return Ok(0.0);
        }
        Ok(rep.codec_ratios.iter().sum::<f64>()
           / rep.codec_ratios.len() as f64)
    }
}

/// A swap outcome: the candidate generation went live.
pub const OUTCOME_SWAPPED: &str = "swapped";
/// A swap outcome: the candidate failed its health probe (wrong
/// logits or a panic) and the pool kept the serving generation.
pub const OUTCOME_ROLLED_BACK: &str = "rolled_back";

/// One attempted generation swap, with everything needed to audit it.
#[derive(Debug, Clone)]
pub struct RetuneEvent {
    /// µs since the controller started.
    pub at_us: u64,
    /// Pool generation index after the attempt ([`OUTCOME_SWAPPED`]:
    /// the new generation; [`OUTCOME_ROLLED_BACK`]: unchanged).
    pub generation: u64,
    /// [`OUTCOME_SWAPPED`] or [`OUTCOME_ROLLED_BACK`].
    pub outcome: &'static str,
    /// The configuration that was serving.
    pub from: Candidate,
    /// The configuration now serving.
    pub to: Candidate,
    /// Relative throughput gain the policy cleared.
    pub predicted_gain: f64,
    /// In-flight jobs the old generation drained during the swap.
    pub drained: usize,
    /// The reduced workload the decision was made on.
    pub measured: MeasuredWorkload,
    /// The full observer snapshot behind it (replay input).
    pub snapshot: WorkloadSnapshot,
}

fn candidate_json(c: &Candidate) -> Json {
    Json::obj(vec![
        ("factors",
         Json::Arr(c.factors.iter().map(|&f| Json::num(f as f64))
                   .collect())),
        ("replicas", Json::num(c.replicas as f64)),
        ("backend", Json::str(c.backend.name())),
    ])
}

impl RetuneEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_us", Json::num(self.at_us as f64)),
            ("generation", Json::num(self.generation as f64)),
            ("outcome", Json::str(self.outcome)),
            ("from", candidate_json(&self.from)),
            ("to", candidate_json(&self.to)),
            ("predicted_gain", Json::num(self.predicted_gain)),
            ("drained", Json::num(self.drained as f64)),
            ("measured_frames", Json::num(self.measured.frames as f64)),
            ("measured_rate_fps", Json::num(self.measured.rate_fps)),
            ("measured_mean_density",
             Json::num(self.measured.mean_density)),
            ("measured_density_spread",
             Json::num(self.measured.density_spread)),
        ])
    }
}

/// Compact retune counters for `Session::telemetry()` and the metrics
/// endpoint.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RetuneSummary {
    /// Completed generation swaps.
    pub retunes: u64,
    /// Current pool generation (0 = boot).
    pub generation: u64,
    /// Re-planning passes the controller has run (swapped or held).
    pub evaluations: u64,
    /// Swaps rolled back after a failed health probe.
    pub rollbacks: u64,
    /// Predicted gain of the most recent swap, if any.
    pub last_gain: Option<f64>,
}

/// The boot-time model anchor recorded for offline replay.
#[derive(Debug, Clone)]
pub struct RetuneBaseline {
    pub calibration: Calibration,
    pub reference_density: f64,
}

/// Shared, thread-safe record of everything the controller did.
/// Events are capped (oldest dropped) so a long-lived server cannot
/// grow without bound; the counters never reset.
#[derive(Default)]
pub struct RetuneLog {
    retunes: AtomicU64,
    generation: AtomicU64,
    evaluations: AtomicU64,
    rollbacks: AtomicU64,
    events: Mutex<Vec<RetuneEvent>>,
    baseline: Mutex<Option<RetuneBaseline>>,
}

/// Events kept in the in-memory log.
const EVENT_CAP: usize = 64;

impl RetuneLog {
    pub fn new() -> Self {
        Self::default()
    }

    fn record(&self, event: RetuneEvent) {
        if event.outcome == OUTCOME_ROLLED_BACK {
            // A rollback is not a retune: the generation counter and
            // the swap tally describe the *serving* configuration.
            self.rollbacks.fetch_add(1, Ordering::Relaxed);
        } else {
            self.retunes.fetch_add(1, Ordering::Relaxed);
            self.generation.store(event.generation, Ordering::Relaxed);
        }
        let mut ev =
            self.events.lock().unwrap_or_else(|e| e.into_inner());
        if ev.len() == EVENT_CAP {
            ev.remove(0);
        }
        ev.push(event);
    }

    fn note_evaluation(&self) {
        self.evaluations.fetch_add(1, Ordering::Relaxed);
    }

    fn set_baseline(&self, baseline: RetuneBaseline) {
        *self.baseline.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(baseline);
    }

    /// Completed generation swaps.
    pub fn retunes(&self) -> u64 {
        self.retunes.load(Ordering::Relaxed)
    }

    /// Current pool generation the log has seen.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Swaps rolled back after a failed health probe.
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks.load(Ordering::Relaxed)
    }

    /// The recent swap events (up to the cap, oldest first).
    pub fn events(&self) -> Vec<RetuneEvent> {
        self.events.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// The boot calibration + reference density the controller plans
    /// with, once it has finished calibrating.
    pub fn baseline(&self) -> Option<RetuneBaseline> {
        self.baseline
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    pub fn summary(&self) -> RetuneSummary {
        RetuneSummary {
            retunes: self.retunes(),
            generation: self.generation(),
            evaluations: self.evaluations.load(Ordering::Relaxed),
            rollbacks: self.rollbacks(),
            last_gain: self
                .events
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .last()
                .map(|e| e.predicted_gain),
        }
    }

    /// The whole log as JSON (the `--retune-log` artifact): counters,
    /// the baseline calibration, and the retained events.
    pub fn to_json(&self) -> Json {
        let s = self.summary();
        let mut fields = vec![
            ("retunes", Json::num(s.retunes as f64)),
            ("generation", Json::num(s.generation as f64)),
            ("evaluations", Json::num(s.evaluations as f64)),
            ("rollbacks", Json::num(s.rollbacks as f64)),
            ("events",
             Json::Arr(self.events().iter().map(|e| e.to_json())
                       .collect())),
        ];
        if let Some(b) = self.baseline() {
            fields.push(("reference_density",
                         Json::num(b.reference_density)));
            fields.push(("calibration", b.calibration.to_json()));
        }
        Json::obj(fields)
    }
}

/// The background controller. Spawn with [`OnlineTuner::spawn`]; it
/// re-plans every `policy.interval` until stopped (or dropped).
pub struct OnlineTuner {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    log: Arc<RetuneLog>,
}

impl OnlineTuner {
    /// Start the control loop over a live pool. `boot` is the
    /// candidate the pool is currently serving; `opts` spans the same
    /// search space the boot tune used (or would have). The first
    /// loop iteration calibrates the baseline cost model — the one
    /// simulator-probing step; every later tick is pure math over the
    /// observer snapshot.
    ///
    /// The loop is supervised: a panic restarts it under the pool's
    /// budgeted [`RestartPolicy`] defaults (counted in the pool's
    /// `tuner_restarts`); past the budget the tuner retires and the
    /// pool keeps serving its current generation.
    pub fn spawn(recipe: PoolRecipe, pool: Arc<ReplicaPool>,
                 observer: Arc<WorkloadObserver>, boot: Candidate,
                 policy: RetunePolicy, opts: AutoTuneOptions) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let log = Arc::new(RetuneLog::new());
        let handle = {
            let stop = stop.clone();
            let log = log.clone();
            std::thread::spawn(move || {
                let supervisor =
                    Supervisor::new(RestartPolicy::default(), 1);
                let stats = pool.supervise_stats();
                loop {
                    let ran = catch_unwind(AssertUnwindSafe(|| {
                        control_loop(recipe.clone(), pool.clone(),
                                     observer.clone(), boot.clone(),
                                     policy.clone(), opts.clone(),
                                     stop.clone(), log.clone());
                    }));
                    match ran {
                        Ok(()) => break, // clean exit (stop / no work)
                        Err(_) if stop.load(Ordering::SeqCst) => break,
                        Err(_) => match supervisor.decide(0) {
                            Verdict::Restart { delay } => {
                                stats
                                    .tuner_restarts
                                    .fetch_add(1, Ordering::SeqCst);
                                std::thread::sleep(delay);
                            }
                            Verdict::Retire => break,
                        },
                    }
                }
            })
        };
        Self { stop: stop.clone(), handle: Some(handle), log }
    }

    /// The shared log (counters, events, baseline).
    pub fn log(&self) -> Arc<RetuneLog> {
        self.log.clone()
    }

    /// Stop the control loop and join it. The pool is left serving
    /// whatever generation is active.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for OnlineTuner {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Interruptible sleep: `interval` in small slices, bailing on stop.
fn nap(interval: Duration, stop: &AtomicBool) -> bool {
    let slice = Duration::from_millis(10);
    let mut left = interval;
    while left > Duration::ZERO {
        if stop.load(Ordering::SeqCst) {
            return false;
        }
        let step = left.min(slice);
        std::thread::sleep(step);
        left -= step;
    }
    !stop.load(Ordering::SeqCst)
}

#[allow(clippy::too_many_arguments)]
fn control_loop(recipe: PoolRecipe, pool: Arc<ReplicaPool>,
                observer: Arc<WorkloadObserver>, boot: Candidate,
                policy: RetunePolicy, opts: AutoTuneOptions,
                stop: Arc<AtomicBool>, log: Arc<RetuneLog>) {
    // One-time baseline: calibrate the cost model on the booted
    // configuration (the same probes `dse::auto_tune` runs) and anchor
    // the density units.
    let epoch = Instant::now();
    let boot_net = match recipe
        .base_net
        .clone()
        .try_with_parallel_factors(&boot.factors)
    {
        Ok(n) => n,
        Err(_) => return, // unbuildable boot candidate: nothing to do
    };
    let timing = ConvLatencyParams::optimized();
    let base_cal = calibrate(&boot_net, &timing, &CalibrationConfig {
        rate: opts.rate,
        timesteps: opts.timesteps,
        intra_parallel: opts.intra_parallel,
        pipelined: opts.pipelined,
        ..Default::default()
    });
    let Ok(reference_density) = recipe.reference_density(opts.rate)
    else {
        return;
    };
    log.set_baseline(RetuneBaseline {
        calibration: base_cal.clone(),
        reference_density,
    });
    // Offline reference for the health probe: one synthetic frame and
    // its logits under the boot build. Every candidate is bit-exact
    // by construction, so a candidate that disagrees is broken.
    let Ok((probe_frame, probe_logits)) = probe_reference(&recipe,
                                                          opts.rate)
    else {
        return;
    };
    let hooks = pool.fault_hooks();
    let sup_stats = pool.supervise_stats();

    let mut state = PolicyState::default();
    let mut current = boot;
    while nap(policy.interval, &stop) {
        let snapshot = observer.snapshot();
        // Cheap pre-guard: don't explore the space before enough
        // traffic has been observed to plan from.
        if snapshot.frames.saturating_sub(state.frames_at_last_swap)
            < policy.min_frames
        {
            continue;
        }
        let Ok(Some(p)) = plan(&recipe.base_net, &opts, &base_cal,
                               reference_density, &current,
                               policy.headroom, &snapshot)
        else {
            continue;
        };
        log.note_evaluation();
        let now_us = epoch.elapsed().as_micros() as u64;
        let obs = Observation {
            now_us,
            frames: snapshot.frames,
            density_spread: p.measured.density_spread,
            same_config: p.chosen.candidate == current,
            current_fps: effective_fps(&p.current),
            candidate_fps: effective_fps(&p.chosen),
        };
        let Decision::Swap { gain } = policy.decide(&state, &obs) else {
            continue;
        };
        let Ok(mut pipelines) = recipe.build(&p.chosen.candidate) else {
            continue; // unbuildable candidate: keep serving
        };
        // Transactional gate: probe the candidate BEFORE the pool
        // sees it, so a rollback is simply "don't swap".
        if let Err(why) = health_probe(&mut pipelines[0], &probe_frame,
                                       &probe_logits, hooks.as_deref())
        {
            sup_stats.retune_rollbacks.fetch_add(1, Ordering::SeqCst);
            // The policy state still records the attempt so a broken
            // candidate cannot make the tuner re-probe every tick.
            state.record_swap(now_us, snapshot.frames);
            log.record(RetuneEvent {
                at_us: now_us,
                generation: pool.generation(),
                outcome: OUTCOME_ROLLED_BACK,
                from: current.clone(),
                to: p.chosen.candidate.clone(),
                predicted_gain: gain,
                drained: 0,
                measured: p.measured.clone(),
                snapshot,
            });
            let _ = why; // cause is visible through the event log
            continue;
        }
        let stats = pool.swap(pipelines);
        state.record_swap(now_us, snapshot.frames);
        let to = p.chosen.candidate.clone();
        log.record(RetuneEvent {
            at_us: now_us,
            generation: stats.generation,
            outcome: OUTCOME_SWAPPED,
            from: std::mem::replace(&mut current, to.clone()),
            to,
            predicted_gain: gain,
            drained: stats.drained,
            measured: p.measured.clone(),
            snapshot,
        });
    }
}

/// Build the health-probe reference: a synthetic frame at the serving
/// rate and its logits under the *boot* recipe (deterministic seed;
/// bit-exact against every candidate by the invariance contract).
fn probe_reference(recipe: &PoolRecipe, rate: f64)
                   -> anyhow::Result<(SpikeFrame, Vec<f32>)> {
    let mut pipe = Pipeline::new(recipe.base_net.clone(),
                                 recipe.config.clone(),
                                 recipe.sources.clone())?;
    let (h, w, c) = pipe.input_shape();
    let mut rng = Rng::new(CalibrationConfig::default().seed ^ 0xBEEF);
    let frame = SpikeFrame::random(h, w, c, rate, &mut rng);
    let rep = pipe.run(std::slice::from_ref(&frame));
    let logits = rep
        .logits
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("probe produced no logits"))?;
    Ok((frame, logits))
}

/// Serve the probe frame on the candidate's first pipeline, catching
/// panics (including the chaos harness's injected probe kill) and
/// comparing logits bit-exactly against the offline reference.
fn health_probe(pipe: &mut Pipeline, frame: &SpikeFrame,
                want: &[f32], hooks: Option<&FaultHooks>)
                -> Result<(), String> {
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if hooks.is_some_and(|h| h.probe_panic()) {
            panic!("injected fault: panic_at probe (mid-swap kill)");
        }
        pipe.run(std::slice::from_ref(frame))
    }));
    match outcome {
        Err(payload) => Err(format!("health probe panicked: {}",
                                    panic_message(payload.as_ref()))),
        Ok(rep) if rep.logits.first().map(Vec::as_slice) == Some(want)
        => Ok(()),
        Ok(_) => Err("health-probe logits diverged from the offline \
                      reference"
            .to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::sim::BackendKind;

    fn recipe() -> PoolRecipe {
        let net = arch::scnn3();
        let sources =
            crate::sim::engine::random_sources(&net, 1000);
        PoolRecipe {
            base_net: net,
            config: PipelineConfig::default(),
            sources,
        }
    }

    #[test]
    fn recipe_builds_any_candidate_bit_identically() {
        let r = recipe();
        let cand = Candidate {
            factors: vec![4, 2],
            replicas: 2,
            backend: BackendKind::WordParallel,
        };
        let mut pipes = r.build(&cand).unwrap();
        assert_eq!(pipes.len(), 2);
        let (h, w, c) = pipes[0].input_shape();
        let mut rng = Rng::new(3);
        let frame = SpikeFrame::random(h, w, c, 0.2, &mut rng);
        let a = pipes[0].run(std::slice::from_ref(&frame));
        let b = pipes[1].run(std::slice::from_ref(&frame));
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.logits, b.logits);
        // Different backend, same predictions (the swap contract).
        let mut acc = r
            .build(&Candidate { backend: BackendKind::Accurate, ..cand })
            .unwrap();
        let c = acc[0].run(std::slice::from_ref(&frame));
        assert_eq!(a.predictions, c.predictions);
        assert_eq!(a.logits, c.logits);
        // Invalid factors error instead of panicking.
        assert!(r
            .build(&Candidate {
                factors: vec![3],
                replicas: 1,
                backend: BackendKind::Accurate,
            })
            .is_err());
    }

    #[test]
    fn reference_density_is_deterministic_and_positive() {
        let r = recipe();
        let a = r.reference_density(0.15).unwrap();
        let b = r.reference_density(0.15).unwrap();
        assert_eq!(a, b);
        assert!(a > 0.0);
        // Denser probes measure denser reference traffic.
        let dense = r.reference_density(0.9).unwrap();
        assert!(dense > a);
    }

    #[test]
    fn log_caps_events_and_summarises() {
        let log = RetuneLog::new();
        assert_eq!(log.summary(), RetuneSummary::default());
        let snap = WorkloadSnapshot::default();
        let m = MeasuredWorkload {
            frames: 1,
            rate_fps: 0.0,
            mean_density: 0.1,
            density_spread: 0.0,
        };
        let cand = |r: usize| Candidate {
            factors: vec![1, 1],
            replicas: r,
            backend: BackendKind::Accurate,
        };
        for i in 0..(EVENT_CAP as u64 + 8) {
            log.record(RetuneEvent {
                at_us: i,
                generation: i + 1,
                outcome: OUTCOME_SWAPPED,
                from: cand(1),
                to: cand(2),
                predicted_gain: 0.5,
                drained: 0,
                measured: m.clone(),
                snapshot: snap.clone(),
            });
        }
        let s = log.summary();
        assert_eq!(s.retunes, EVENT_CAP as u64 + 8);
        assert_eq!(s.generation, EVENT_CAP as u64 + 8);
        assert_eq!(s.rollbacks, 0);
        assert_eq!(s.last_gain, Some(0.5));
        let events = log.events();
        assert_eq!(events.len(), EVENT_CAP);
        assert_eq!(events.last().unwrap().at_us, EVENT_CAP as u64 + 7);
        // JSON renders and round-trips through the parser.
        let j = format!("{}", log.to_json());
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("retunes").and_then(Json::as_f64),
                   Some((EVENT_CAP + 8) as f64));
    }

    /// A rolled-back event counts in `rollbacks` only: retunes and the
    /// generation stay pinned to the serving configuration.
    #[test]
    fn rolled_back_events_do_not_advance_the_generation() {
        let log = RetuneLog::new();
        let cand = |r: usize| Candidate {
            factors: vec![1, 1],
            replicas: r,
            backend: BackendKind::Accurate,
        };
        log.record(RetuneEvent {
            at_us: 1,
            generation: 0,
            outcome: OUTCOME_ROLLED_BACK,
            from: cand(1),
            to: cand(2),
            predicted_gain: 0.4,
            drained: 0,
            measured: MeasuredWorkload {
                frames: 1,
                rate_fps: 0.0,
                mean_density: 0.1,
                density_spread: 0.0,
            },
            snapshot: WorkloadSnapshot::default(),
        });
        let s = log.summary();
        assert_eq!(s.retunes, 0);
        assert_eq!(s.generation, 0);
        assert_eq!(s.rollbacks, 1);
        assert_eq!(log.events().len(), 1);
        let j = format!("{}", log.to_json());
        assert!(j.contains("rolled_back"));
    }

    /// The probe reference is deterministic, and `health_probe`
    /// accepts a bit-identical rebuild, rejects diverging logits, and
    /// converts an injected probe panic into a rollback error.
    #[test]
    fn health_probe_accepts_exact_and_rejects_divergence() {
        let r = recipe();
        let (frame, want) = probe_reference(&r, 0.2).unwrap();
        let (_, again) = probe_reference(&r, 0.2).unwrap();
        assert_eq!(want, again);

        // A candidate at different factors/backend still passes.
        let cand = Candidate {
            factors: vec![4, 2],
            replicas: 1,
            backend: BackendKind::WordParallel,
        };
        let mut pipes = r.build(&cand).unwrap();
        assert!(health_probe(&mut pipes[0], &frame, &want, None)
            .is_ok());

        // Diverging logits roll back.
        let mut wrong = want.clone();
        wrong[0] += 1.0;
        let err = health_probe(&mut pipes[0], &frame, &wrong, None)
            .unwrap_err();
        assert!(err.contains("diverged"), "{err}");

        // An injected mid-swap kill is caught, not propagated.
        use crate::supervise::{FaultEvent, FaultPlan, REPLICA_PROBE};
        let hooks = FaultHooks::from_plan(FaultPlan::new(
            1,
            vec![FaultEvent::PanicAt { replica: REPLICA_PROBE,
                                       frame: 0 }],
        ));
        let err = health_probe(&mut pipes[0], &frame, &want,
                               Some(&hooks))
            .unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        // One-shot: a second probe on the same hooks passes.
        assert!(health_probe(&mut pipes[0], &frame, &want,
                             Some(&hooks))
            .is_ok());
    }
}
