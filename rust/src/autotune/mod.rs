//! Online co-optimization: live-traffic DSE with zero-downtime pool
//! hot-swap (ROADMAP item 5).
//!
//! A configuration tuned at boot is tuned for the *probe* workload:
//! `dse::calibrate` measures op activity and host speed on synthetic
//! frames at one firing rate, and the chosen design point inherits
//! those assumptions. Real traffic drifts — sparser or denser events,
//! faster or slower arrivals — and the serving point that was optimal
//! at boot stops being optimal. This subsystem closes the loop the
//! co-design thesis asks for:
//!
//! ```text
//!        observe                re-evaluate              decide
//!  ┌──────────────────┐   ┌──────────────────────┐   ┌───────────┐
//!  │ WorkloadObserver │──▶│ measured Calibration │──▶│ Retune-   │
//!  │ density min/max/ │   │ -> dse::explore over │   │ Policy    │
//!  │ EWMA, rate_fps   │   │ the live search space│   │ hysteresis│
//!  └──────────────────┘   └──────────────────────┘   │ cooldown  │
//!            ▲                                       │ min-frames│
//!            │ per-frame codec ratios                └─────┬─────┘
//!            │                                      swap   │ hold
//!  ┌─────────┴────────┐                                    ▼
//!  │   ReplicaPool    │◀──────────── build new generation, │
//!  │ (generation N)   │   redirect, drain, retire old ◀────┘
//!  └──────────────────┘
//! ```
//!
//! * [`policy`] — the pure decision function: hysteresis margin,
//!   cooldown, min-frames-observed and bimodal-workload guards, so the
//!   controller cannot flap between near-equal points.
//! * [`measure`] — measured-workload re-calibration: the boot
//!   [`Calibration`](crate::dse::Calibration) re-scaled by observed
//!   spike density, and the rate-aware serving choice over the
//!   re-evaluated space. Pure functions of their inputs, so the
//!   controller's choice is reproducible offline from a logged
//!   snapshot (pinned by `tests/online_tune.rs`).
//! * [`controller`] — the [`OnlineTuner`] thread gluing them to a live
//!   [`ReplicaPool`](crate::coordinator::replica::ReplicaPool): every
//!   interval it snapshots the observer, re-plans, asks the policy,
//!   and on a go-decision performs the build → redirect → drain →
//!   retire generation swap. Every swap is a [`RetuneEvent`] in the
//!   shared [`RetuneLog`], surfaced in `Session::telemetry()` and as
//!   `sti_retune_total` / `sti_retune_generation` on the metrics
//!   endpoint.
//!
//! Entry points: `Session::builder().online_tune(policy)` or
//! `sti-snn serve --online-tune`.

pub mod controller;
pub mod measure;
pub mod policy;

pub use controller::{OnlineTuner, PoolRecipe, RetuneEvent, RetuneLog,
                     RetuneSummary};
pub use measure::{choose_for_rate, effective_fps, measured_calibration,
                  plan, MeasuredWorkload, Plan};
pub use policy::{Decision, HoldReason, Observation, PolicyState,
                 RetunePolicy};
