//! The retune decision policy: when is a measured-workload re-plan
//! allowed to actually swap the serving pool?
//!
//! A generation swap is cheap but not free (the old generation drains,
//! replicas rebuild), and the measured workload is noisy. Without
//! damping, two design points whose predicted throughput differs by
//! less than the measurement noise would make the controller flap
//! between them forever. [`RetunePolicy::decide`] is the pure gate —
//! no clocks, no I/O, logical time in — so the no-oscillation
//! guarantee is testable exhaustively (`tests/prop_autotune.rs`):
//!
//! * **hysteresis** — the candidate must beat the serving point by a
//!   relative margin, not just beat it.
//! * **cooldown** — a minimum wall-time between swaps, so even a
//!   workload that alternates across the margin cannot thrash.
//! * **min frames** — enough traffic must have been observed since
//!   the last swap for the EWMAs to mean anything.
//! * **bimodal guard** — a wide windowed density spread
//!   ([`LayerWorkload::density_spread`](crate::telemetry::LayerWorkload::density_spread))
//!   means the EWMA sits between two modes neither of which it
//!   represents; the policy holds rather than tune for a fiction.

use std::time::Duration;

/// Damping knobs of the online tuner. Defaults are conservative — a
/// production pool should re-tune on the minutes scale, not thrash on
/// the seconds scale; tests dial everything down.
#[derive(Debug, Clone)]
pub struct RetunePolicy {
    /// How often the controller wakes to observe and re-plan.
    pub interval: Duration,
    /// Frames that must be observed since the last swap before the
    /// next one (EWMA warm-up guard).
    pub min_frames: u64,
    /// Relative throughput gain the candidate must offer over the
    /// serving point (0.10 = 10% better or stay put).
    pub hysteresis: f64,
    /// Minimum wall-time between swaps.
    pub cooldown: Duration,
    /// Hold when the windowed per-layer density spread exceeds this
    /// (bimodal traffic — the EWMA is not a workload).
    pub max_density_spread: f64,
    /// Throughput headroom the chosen point must have over the
    /// measured arrival rate (1.25 = provision for 25% above the
    /// observed rate) — see [`super::measure::choose_for_rate`].
    pub headroom: f64,
}

impl Default for RetunePolicy {
    fn default() -> Self {
        Self {
            interval: Duration::from_secs(2),
            min_frames: 32,
            hysteresis: 0.10,
            cooldown: Duration::from_secs(10),
            max_density_spread: 0.35,
            headroom: 1.25,
        }
    }
}

/// What the controller remembers between decisions.
#[derive(Debug, Clone, Default)]
pub struct PolicyState {
    /// Logical time of the last swap (µs), `None` before the first.
    pub last_swap_us: Option<u64>,
    /// Total frames observed at the last swap.
    pub frames_at_last_swap: u64,
}

impl PolicyState {
    pub fn record_swap(&mut self, now_us: u64, frames: u64) {
        self.last_swap_us = Some(now_us);
        self.frames_at_last_swap = frames;
    }
}

/// One decision's inputs, all pre-measured by the caller (the policy
/// itself never looks at a clock or a pool).
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Logical now (µs since the controller started).
    pub now_us: u64,
    /// Total frames observed so far.
    pub frames: u64,
    /// Max windowed per-layer density spread of the snapshot.
    pub density_spread: f64,
    /// The re-plan chose the configuration already serving.
    pub same_config: bool,
    /// Effective frames/s of the serving point under the measured
    /// calibration.
    pub current_fps: f64,
    /// Effective frames/s of the re-planned candidate, same model.
    pub candidate_fps: f64,
}

/// Why a decision held.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HoldReason {
    /// The re-plan agrees with the serving configuration.
    SameConfig,
    /// Not enough frames observed since the last swap.
    InsufficientFrames,
    /// Inside the post-swap cooldown window.
    Cooldown,
    /// Windowed density spread too wide (bimodal traffic).
    Bimodal,
    /// Candidate gain below the hysteresis margin.
    WithinHysteresis,
}

/// The gate's verdict.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Swap generations; `gain` is the predicted relative throughput
    /// improvement that cleared the margin.
    Swap { gain: f64 },
    Hold(HoldReason),
}

impl RetunePolicy {
    /// The pure retune gate. Guards run cheapest-first; only an
    /// observation that clears every one produces a swap.
    pub fn decide(&self, state: &PolicyState, obs: &Observation)
                  -> Decision {
        if obs.same_config {
            return Decision::Hold(HoldReason::SameConfig);
        }
        if obs.frames.saturating_sub(state.frames_at_last_swap)
            < self.min_frames
        {
            return Decision::Hold(HoldReason::InsufficientFrames);
        }
        if let Some(last) = state.last_swap_us {
            let cooldown_us = self.cooldown.as_micros() as u64;
            if obs.now_us.saturating_sub(last) < cooldown_us {
                return Decision::Hold(HoldReason::Cooldown);
            }
        }
        if obs.density_spread > self.max_density_spread {
            return Decision::Hold(HoldReason::Bimodal);
        }
        let gain = if obs.current_fps > 0.0 {
            obs.candidate_fps / obs.current_fps - 1.0
        } else if obs.candidate_fps > 0.0 {
            f64::INFINITY
        } else {
            0.0
        };
        if gain > 0.0 && gain >= self.hysteresis {
            Decision::Swap { gain }
        } else {
            Decision::Hold(HoldReason::WithinHysteresis)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetunePolicy {
        RetunePolicy {
            interval: Duration::from_millis(100),
            min_frames: 10,
            hysteresis: 0.10,
            cooldown: Duration::from_millis(1000),
            max_density_spread: 0.35,
            headroom: 1.25,
        }
    }

    fn obs(now_us: u64, frames: u64, gain: f64) -> Observation {
        Observation {
            now_us,
            frames,
            density_spread: 0.0,
            same_config: false,
            current_fps: 100.0,
            candidate_fps: 100.0 * (1.0 + gain),
        }
    }

    #[test]
    fn guards_fire_in_order() {
        let p = policy();
        let mut state = PolicyState::default();

        let mut same = obs(0, 100, 1.0);
        same.same_config = true;
        assert_eq!(p.decide(&state, &same),
                   Decision::Hold(HoldReason::SameConfig));

        assert_eq!(p.decide(&state, &obs(0, 5, 1.0)),
                   Decision::Hold(HoldReason::InsufficientFrames));

        let mut bimodal = obs(0, 100, 1.0);
        bimodal.density_spread = 0.5;
        assert_eq!(p.decide(&state, &bimodal),
                   Decision::Hold(HoldReason::Bimodal));

        assert_eq!(p.decide(&state, &obs(0, 100, 0.05)),
                   Decision::Hold(HoldReason::WithinHysteresis));

        match p.decide(&state, &obs(0, 100, 0.5)) {
            Decision::Swap { gain } => assert!((gain - 0.5).abs() < 1e-9),
            d => panic!("expected swap, got {d:?}"),
        }

        // After a swap: cooldown and min-frames both re-arm.
        state.record_swap(0, 100);
        assert_eq!(p.decide(&state, &obs(500_000, 200, 0.5)),
                   Decision::Hold(HoldReason::Cooldown));
        assert_eq!(p.decide(&state, &obs(2_000_000, 105, 0.5)),
                   Decision::Hold(HoldReason::InsufficientFrames));
        assert!(matches!(p.decide(&state, &obs(2_000_000, 200, 0.5)),
                         Decision::Swap { .. }));
    }

    #[test]
    fn losing_candidate_never_swaps() {
        let p = policy();
        let state = PolicyState::default();
        // Worse, equal, and marginally-better candidates all hold.
        for gain in [-0.5, 0.0, 0.0999] {
            assert_eq!(p.decide(&state, &obs(0, 100, gain)),
                       Decision::Hold(HoldReason::WithinHysteresis),
                       "gain {gain}");
        }
    }

    #[test]
    fn dead_current_config_swaps_to_anything_live() {
        let p = policy();
        let state = PolicyState::default();
        let o = Observation {
            now_us: 0,
            frames: 100,
            density_spread: 0.0,
            same_config: false,
            current_fps: 0.0,
            candidate_fps: 1.0,
        };
        assert!(matches!(p.decide(&state, &o), Decision::Swap { .. }));
    }
}
