//! Measured-workload re-planning: turn a live telemetry snapshot into
//! a calibrated DSE run and a rate-aware serving choice.
//!
//! The boot-time [`Calibration`] was fitted on synthetic probes at one
//! firing rate. Live traffic has its own density (which moves the
//! spike-gated op activity, and with it dynamic energy and the
//! event-driven backend's host cost) and its own arrival rate (which
//! sets how much throughput the pool actually needs). Everything here
//! is a pure function of its inputs — the same snapshot always
//! re-plans to the same point, so a controller decision can be
//! reproduced offline from the logged snapshot (the acceptance test of
//! `tests/online_tune.rs` does exactly that).

use std::cmp::Ordering;

use crate::arch::NetworkSpec;
use crate::dataflow::ConvLatencyParams;
use crate::dse::{self, Calibration, Candidate, CostModel, CostPoint,
                 Evaluator, SearchSpace};
use crate::sim::BackendKind;
use crate::telemetry::WorkloadSnapshot;

/// The live workload, reduced to what the cost model consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredWorkload {
    /// Frames the snapshot covers.
    pub frames: u64,
    /// Observed arrival rate (frames/s, 0 until two arrivals).
    pub rate_fps: f64,
    /// Mean of the per-layer density EWMAs — the traffic's overall
    /// spike-density level, in codec-ratio units.
    pub mean_density: f64,
    /// Max windowed per-layer density spread — the bimodality signal
    /// the policy guards on.
    pub density_spread: f64,
}

impl MeasuredWorkload {
    /// Reduce an observer snapshot; `None` until at least one frame
    /// has been observed (there is no workload to measure yet).
    pub fn from_snapshot(s: &WorkloadSnapshot) -> Option<Self> {
        if s.frames == 0 || s.layers.is_empty() {
            return None;
        }
        let mean = s.layers.iter().map(|l| l.density_ewma).sum::<f64>()
            / s.layers.len() as f64;
        let spread = s
            .layers
            .iter()
            .map(|l| l.density_spread())
            .fold(0.0, f64::max);
        Some(Self {
            frames: s.frames,
            rate_fps: s.rate_fps,
            mean_density: mean,
            density_spread: spread,
        })
    }
}

/// Re-scale a boot calibration to the measured workload. The density
/// ratio (measured mean vs the boot probe's density in the same
/// codec-ratio units) scales:
///
/// * `op_activity` — spike-gated ops track input density, so dynamic
///   energy follows the live traffic (clamped to the physical `..=1`).
/// * the measured host-ns/frame of the **density-sensitive** backends
///   — the event-driven walk's cost is proportional to spike count,
///   and the sparse backend's occupancy-gated popcount visits only
///   occupied word groups, so both track the live density. The
///   word-parallel backend popcounts dense bit-planes regardless of
///   activity and is the one density-*invariant* kind; its timing
///   stands.
///
/// The ratio is clamped to `[0.25, 4]`: beyond that the linear
/// extrapolation from one probe point is noise, and an EWMA that far
/// out re-calibrates again next tick anyway. Counter scales are
/// architectural (density-independent fits) and pass through.
pub fn measured_calibration(base: &Calibration, reference_density: f64,
                            m: &MeasuredWorkload) -> Calibration {
    let scale = if reference_density > 0.0 && m.mean_density > 0.0 {
        (m.mean_density / reference_density).clamp(0.25, 4.0)
    } else {
        1.0
    };
    let mut cal = base.clone();
    cal.op_activity = (base.op_activity * scale).clamp(1e-6, 1.0);
    cal.host_ns_per_frame = base
        .host_ns_per_frame
        .iter()
        .map(|&(b, ns)| match b {
            BackendKind::Accurate => (b, ns * scale),
            BackendKind::WordParallel => (b, ns),
            BackendKind::Sparse => (b, ns * scale),
        })
        .collect();
    cal
}

/// Frames/s a point can actually serve end to end: the architectural
/// pool rate capped by the measured host rate of its backend across
/// its replicas (a design that simulates fast but computes slow on
/// this host still bottlenecks on the host).
pub fn effective_fps(p: &CostPoint) -> f64 {
    match p.host_ns_per_frame {
        Some(ns) if ns > 0.0 => {
            p.pool_fps.min(p.candidate.replicas as f64 * 1e9 / ns)
        }
        _ => p.pool_fps,
    }
}

/// Deterministic "cheapest adequate point" order: energy first, then
/// LUTs, then the standing tie-break preferences of `dse::pareto`.
fn frugal_order(a: &CostPoint, b: &CostPoint) -> Ordering {
    a.energy_per_frame_j
        .total_cmp(&b.energy_per_frame_j)
        .then(a.resources.lut.cmp(&b.resources.lut))
        .then_with(|| {
            a.host_ns_per_frame
                .unwrap_or(f64::INFINITY)
                .total_cmp(&b.host_ns_per_frame.unwrap_or(f64::INFINITY))
        })
        .then(a.candidate.replicas.cmp(&b.candidate.replicas))
        .then_with(|| a.candidate.factors.cmp(&b.candidate.factors))
        .then_with(|| {
            a.candidate.backend.name().cmp(b.candidate.backend.name())
        })
}

/// Rate-aware serving choice. With a measured arrival rate, pick the
/// *cheapest* fitting point whose [`effective_fps`] covers
/// `need_fps` (rate x policy headroom) — serving a 50 fps workload
/// with the max-throughput design wastes energy for latency nobody
/// asked for. When no rate has been measured, or nothing covers it,
/// fall back to the boot-time rule (max pool throughput that fits,
/// [`dse::pareto::choose`]).
pub fn choose_for_rate(points: &[CostPoint], need_fps: f64)
                       -> Option<CostPoint> {
    if need_fps > 0.0 {
        let best = points
            .iter()
            .filter(|p| p.fits && effective_fps(p) >= need_fps)
            .min_by(|a, b| frugal_order(a, b));
        if let Some(b) = best {
            return Some(b.clone());
        }
    }
    dse::pareto::choose(points)
}

/// One reproducible re-planning result.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The point the measured workload asks for.
    pub chosen: CostPoint,
    /// The serving configuration evaluated under the *same* measured
    /// model — the apples-to-apples comparison the policy gates on.
    pub current: CostPoint,
    pub measured: MeasuredWorkload,
    /// The re-scaled calibration both evaluations used.
    pub calibration: Calibration,
}

/// Re-run the calibrated DSE against a measured snapshot:
/// re-scale the boot calibration, explore the same space the boot
/// tune would, choose rate-aware, and evaluate the serving candidate
/// under the identical model. `Ok(None)` when there is nothing to
/// measure yet or no point fits. Deterministic given its arguments.
pub fn plan(base_net: &NetworkSpec, opts: &dse::AutoTuneOptions,
            base_cal: &Calibration, reference_density: f64,
            current: &Candidate, headroom: f64,
            snapshot: &WorkloadSnapshot) -> anyhow::Result<Option<Plan>> {
    let Some(measured) = MeasuredWorkload::from_snapshot(snapshot) else {
        return Ok(None);
    };
    let calibration =
        measured_calibration(base_cal, reference_density, &measured);
    let budget = opts
        .pe_budget
        .unwrap_or_else(|| 8 * dse::min_pes(base_net));
    let model = CostModel {
        timing: ConvLatencyParams::optimized(),
        calibration: calibration.clone(),
        ..CostModel::default()
    };
    let space = SearchSpace::new(base_net.clone(), budget)
        .with_replicas(opts.max_replicas)
        .with_timesteps(opts.timesteps);
    let ex = dse::explore(&space, &model);
    let need_fps = measured.rate_fps * headroom.max(0.0);
    let Some(chosen) = choose_for_rate(&ex.points, need_fps) else {
        return Ok(None);
    };
    let eval = Evaluator::new(base_net, &model, opts.timesteps);
    let current = eval.evaluate(current)?;
    Ok(Some(Plan { chosen, current, measured, calibration }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::scnn3;
    use crate::telemetry::WorkloadObserver;

    fn snapshot(densities: &[f64]) -> WorkloadSnapshot {
        let obs = WorkloadObserver::new();
        let names: Vec<String> =
            (0..densities.len()).map(|i| format!("l{i}")).collect();
        obs.observe(&names, densities, 1);
        obs.snapshot()
    }

    #[test]
    fn measured_workload_reduces_a_snapshot() {
        assert!(MeasuredWorkload::from_snapshot(
            &WorkloadSnapshot::default()).is_none());
        let m =
            MeasuredWorkload::from_snapshot(&snapshot(&[0.2, 0.4]))
                .unwrap();
        assert_eq!(m.frames, 1);
        assert!((m.mean_density - 0.3).abs() < 1e-9);
        assert_eq!(m.density_spread, 0.0, "single observation window");
    }

    #[test]
    fn calibration_scales_activity_and_density_sensitive_backends() {
        let base = Calibration {
            op_activity: 0.2,
            host_ns_per_frame: vec![
                (BackendKind::Accurate, 1000.0),
                (BackendKind::WordParallel, 500.0),
                (BackendKind::Sparse, 800.0),
            ],
            ..Calibration::identity()
        };
        let m = MeasuredWorkload {
            frames: 10,
            rate_fps: 100.0,
            mean_density: 0.4,
            density_spread: 0.0,
        };
        // Measured density 2x the reference: activity and the
        // density-sensitive host times (event-driven + sparse) double;
        // word-parallel is the invariant one.
        let cal = measured_calibration(&base, 0.2, &m);
        assert!((cal.op_activity - 0.4).abs() < 1e-9);
        assert_eq!(cal.host_ns(BackendKind::Accurate), Some(2000.0));
        assert_eq!(cal.host_ns(BackendKind::WordParallel), Some(500.0));
        assert_eq!(cal.host_ns(BackendKind::Sparse), Some(1600.0));
        // Clamps: a 100x density ratio saturates at 4x, activity at 1.
        let dense = MeasuredWorkload { mean_density: 20.0, ..m.clone() };
        let cal = measured_calibration(&base, 0.2, &dense);
        assert!((cal.op_activity - 0.8).abs() < 1e-9);
        assert_eq!(cal.host_ns(BackendKind::Accurate), Some(4000.0));
        assert_eq!(cal.host_ns(BackendKind::Sparse), Some(3200.0));
    }

    #[test]
    fn choose_for_rate_prefers_cheapest_adequate_point() {
        let model = CostModel::default();
        let net = scnn3();
        let space = SearchSpace::new(net, 144).with_replicas(4);
        let ex = dse::explore(&space, &model);
        // Unconstrained rate: identical to the boot-time choice.
        assert_eq!(choose_for_rate(&ex.points, 0.0),
                   dse::pareto::choose(&ex.points));
        // A modest rate target: the choice covers it, fits, and no
        // other covering point is cheaper under the frugal order.
        let boot = dse::pareto::choose(&ex.points).unwrap();
        let need = effective_fps(&boot) / 10.0;
        let c = choose_for_rate(&ex.points, need).unwrap();
        assert!(c.fits);
        assert!(effective_fps(&c) >= need);
        for p in ex.points.iter().filter(|p| {
            p.fits && effective_fps(p) >= need
        }) {
            assert!(p.energy_per_frame_j >= c.energy_per_frame_j - 1e-12,
                    "cheaper adequate point {:?} not chosen",
                    p.candidate);
        }
        // An impossible rate falls back to max-throughput.
        assert_eq!(choose_for_rate(&ex.points, 1e18),
                   dse::pareto::choose(&ex.points));
    }

    #[test]
    fn plan_is_deterministic_and_evaluates_current_under_same_model() {
        let net = scnn3();
        let opts = dse::AutoTuneOptions {
            pe_budget: Some(72),
            max_replicas: 2,
            ..Default::default()
        };
        let base = Calibration {
            op_activity: 0.15,
            host_ns_per_frame: vec![
                (BackendKind::Accurate, 50_000.0),
                (BackendKind::WordParallel, 10_000.0),
            ],
            ..Calibration::identity()
        };
        let current = Candidate {
            factors: vec![1, 1],
            replicas: 1,
            backend: BackendKind::Accurate,
        };
        let snap = snapshot(&[0.3, 0.3, 0.3, 0.3, 0.3]);
        let a = plan(&net, &opts, &base, 0.15, &current, 1.25, &snap)
            .unwrap()
            .expect("plannable snapshot");
        let b = plan(&net, &opts, &base, 0.15, &current, 1.25, &snap)
            .unwrap()
            .unwrap();
        assert_eq!(a.chosen, b.chosen, "plan must be deterministic");
        assert_eq!(a.current, b.current);
        assert_eq!(a.current.candidate, current);
        // Empty snapshot: nothing to plan from.
        assert!(plan(&net, &opts, &base, 0.15, &current, 1.25,
                     &WorkloadSnapshot::default())
            .unwrap()
            .is_none());
    }
}
