//! Analytical dataflow models: memory-access counts and latency.
//!
//! * [`access`] — Table I (OS vs WS) and Table III (per-conv-mode)
//!   memory-access-count formulas, cross-checked against the cycle-level
//!   simulator's counters by the integration tests.
//! * [`latency`] — the convolution-layer latency model Eq. (12) and the
//!   layer-wise pipeline totals Eq. (10)-(11).

pub mod access;
pub mod latency;

pub use access::{conv_mode_access, os_access, ws_access, AccessCounts};
pub use latency::{conv_latency, pipeline_latency, ConvLatencyParams,
                  PipelineLatency};
