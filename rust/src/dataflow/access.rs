//! Memory-access-count models (paper Table I and Table III).
//!
//! All counts are *element* accesses per frame for a single conv layer,
//! exactly as the paper's SectionII-C analysis: no line buffer, no spike
//! vectors — those optimisations are what Table III then quantifies
//! (vector accesses with the compressed/sorted representation + line
//! buffer caching).

use crate::arch::{ConvLayer, ConvMode};

/// Access counts for one layer under one dataflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessCounts {
    pub input_spikes: u64,
    pub weights: u64,
    pub partial_sums: u64,
}

impl AccessCounts {
    pub fn total(&self) -> u64 {
        self.input_spikes + self.weights + self.partial_sums
    }
}

/// Output-stationary dataflow (paper Table I, OS column).
///
/// * inputs:  `Ci*Kw*Kh*Co*Wo*Ho*T` — every output pixel re-reads its
///   receptive field once per output channel.
/// * weights: `Ci*Kw*Kh*Co*Wo*Ho*T` — weights re-broadcast per pixel.
/// * psums:   `Co*Wo*Ho*(T-1)` — membrane potential leaves the PE only
///   between timesteps; **zero at T = 1** (the paper's key win).
pub fn os_access(l: &ConvLayer, timesteps: u64) -> AccessCounts {
    let (ho, wo) = (l.out_h() as u64, l.out_w() as u64);
    let (ci, co) = (l.ci as u64, l.co as u64);
    let k = (l.kh * l.kw) as u64;
    AccessCounts {
        input_spikes: ci * k * co * wo * ho * timesteps,
        weights: ci * k * co * wo * ho * timesteps,
        partial_sums: co * wo * ho * timesteps.saturating_sub(1),
    }
}

/// Weight-stationary dataflow (paper Table I, WS column).
///
/// * inputs:  `Kw*Kh*Wo*Ho*Ci*Co*T`
/// * weights: `Ci*Kw*Kh*Co*T` — each weight read once per timestep.
/// * psums:   `Ci*Co*Wo*Ho*T` — partial sums spill per input channel.
pub fn ws_access(l: &ConvLayer, timesteps: u64) -> AccessCounts {
    let (ho, wo) = (l.out_h() as u64, l.out_w() as u64);
    let (ci, co) = (l.ci as u64, l.co as u64);
    let k = (l.kh * l.kw) as u64;
    AccessCounts {
        input_spikes: k * wo * ho * ci * co * timesteps,
        weights: ci * k * co * timesteps,
        partial_sums: ci * co * wo * ho * timesteps,
    }
}

/// Optimised OS dataflow with the compressed & sorted spike vectors +
/// line buffer (paper Table III): counts are **vector** accesses.
///
/// * inputs:  `Hi*Wi*T` — each input pixel's spike vector is fetched
///   off-chip exactly once; the line buffer provides all reuse.
/// * weights: standard `Ci*Co*Ho*Wo*T` vector reads (a vector = one
///   Kh*Kw tap set); depthwise `Co*Ho*Wo*T`; pointwise `Ci*Co*Ho*Wo*T`.
/// * psums:   `Co*Ho*Wo*(T-1)` (all modes) — zero at T = 1.
pub fn conv_mode_access(l: &ConvLayer, timesteps: u64) -> AccessCounts {
    let (ho, wo) = (l.out_h() as u64, l.out_w() as u64);
    let (hi, wi) = (l.in_h as u64, l.in_w as u64);
    let (ci, co) = (l.ci as u64, l.co as u64);
    let weights = match l.mode {
        ConvMode::Standard => ci * co * ho * wo * timesteps,
        ConvMode::Depthwise => co * ho * wo * timesteps,
        ConvMode::Pointwise => ci * co * ho * wo * timesteps,
    };
    AccessCounts {
        input_spikes: hi * wi * timesteps,
        weights,
        partial_sums: co * ho * wo * timesteps.saturating_sub(1),
    }
}

/// The paper's SectionIV-C claim: the line buffer + vector representation
/// reduces off-chip input accesses by ~`Ci*Kw*Kh*Co`.
pub fn input_access_reduction(l: &ConvLayer, timesteps: u64) -> f64 {
    let plain = os_access(l, timesteps).input_spikes as f64;
    let cached = conv_mode_access(l, timesteps).input_spikes as f64;
    plain / cached
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn5, ConvLayer, ConvMode};

    fn layer() -> ConvLayer {
        ConvLayer {
            mode: ConvMode::Standard,
            in_h: 16,
            in_w: 16,
            ci: 64,
            co: 128,
            kh: 3,
            kw: 3,
            pad: 1,
            encoder: false,
            parallel: 1,
        }
    }

    #[test]
    fn table1_formulas() {
        let l = layer();
        let t = 4;
        let os = os_access(&l, t);
        let ws = ws_access(&l, t);
        // Inputs identical between OS and WS (same product, Table I).
        assert_eq!(os.input_spikes, ws.input_spikes);
        // OS weight accesses exceed WS by exactly Wo*Ho (SectionII-C).
        assert_eq!(os.weights, ws.weights * 16 * 16);
        // WS psum traffic is Ci x the OS psum traffic scaled by T/(T-1).
        assert_eq!(ws.partial_sums, 64 * 128 * 16 * 16 * t);
        assert_eq!(os.partial_sums, 128 * 16 * 16 * (t - 1));
    }

    #[test]
    fn os_psums_zero_at_t1() {
        let os = os_access(&layer(), 1);
        assert_eq!(os.partial_sums, 0);
        // WS still pays psum traffic at T = 1 — the co-design argument.
        assert!(ws_access(&layer(), 1).partial_sums > 0);
    }

    #[test]
    fn access_scales_linearly_with_t() {
        let l = layer();
        let a1 = os_access(&l, 1);
        let a2 = os_access(&l, 2);
        assert_eq!(a2.input_spikes, 2 * a1.input_spikes);
        assert_eq!(a2.weights, 2 * a1.weights);
    }

    #[test]
    fn table3_line_buffer_reduction() {
        let l = layer();
        // SectionIV-C: reduction ~= Ci*Kw*Kh*Co = 64*9*128.
        let r = input_access_reduction(&l, 1);
        assert!((r - (64.0 * 9.0 * 128.0)).abs() / r < 0.01, "r={r}");
    }

    #[test]
    fn table3_depthwise_weight_reduction() {
        // SectionIV-D: depthwise reduces weight accesses by a factor Ci.
        let mut l = layer();
        let std = conv_mode_access(&l, 1).weights;
        l.mode = ConvMode::Depthwise;
        l.co = l.ci; // depthwise preserves channels
        let dw = conv_mode_access(&l, 1).weights;
        assert_eq!(std / dw, (128 / 64) * 64);
    }

    #[test]
    fn scnn5_all_layers_have_positive_access() {
        for c in scnn5().accel_convs() {
            let a = conv_mode_access(c, 1);
            assert!(a.input_spikes > 0 && a.weights > 0);
            assert_eq!(a.partial_sums, 0);
        }
    }
}
