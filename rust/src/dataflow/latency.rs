//! Convolution-layer latency model and pipeline totals.
//!
//! Paper Eq. (12): `T_ci = Ho*Wo*Co*[Ci*(Trw + Tpe) + Tpes]` cycles for
//! a standard conv layer, where `Trw` is the weight-read time (0 when
//! hidden behind compute, SectionIV-E.2), `Tpe` the per-input-channel
//! accumulate time inside a PE, and `Tpes` the psum adder-tree time.
//! Output-channel parallelism divides the `Co` walk by the layer's
//! parallel factor.
//!
//! Paper Eq. (10)/(11): layer-wise pipelining makes the whole-network
//! latency for N frames `N*T_max + sum(other layers)`, i.e. the average
//! per-frame latency converges to the slowest layer's latency.

use crate::arch::{ConvLayer, ConvMode, Layer, NetworkSpec};

/// Microarchitectural timing knobs for Eq. (12).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvLatencyParams {
    /// Weight-read cycles per input channel; 0 when prefetch hides it.
    pub t_rw: u64,
    /// Accumulate cycles per input channel inside a PE.
    pub t_pe: u64,
    /// Adder-tree cycles to combine the Kh*Kw psums; `None` derives
    /// ceil(log2(Kh*Kw)) from the layer geometry.
    pub t_pes: Option<u64>,
}

impl ConvLatencyParams {
    /// Unoptimised baseline: weight reads exposed, serial psum combine.
    pub fn baseline() -> Self {
        Self { t_rw: 1, t_pe: 1, t_pes: None }
    }

    /// Optimised (SectionIV-E.2): `Trw` hidden, adder tree for psums.
    pub fn optimized() -> Self {
        Self { t_rw: 0, t_pe: 1, t_pes: None }
    }

    fn tpes(&self, l: &ConvLayer) -> u64 {
        self.t_pes.unwrap_or_else(|| {
            let fanin = (l.kh * l.kw).max(2) as u64;
            64 - (fanin - 1).leading_zeros() as u64
        })
    }
}

/// Cycles for one conv layer, one timestep, one frame — Eq. (12) with
/// the layer's output-channel parallel factor applied.
pub fn conv_latency(l: &ConvLayer, p: &ConvLatencyParams) -> u64 {
    let (ho, wo) = (l.out_h() as u64, l.out_w() as u64);
    let co_serial = (l.co as u64).div_ceil(l.parallel as u64);
    match l.mode {
        ConvMode::Standard => {
            ho * wo * co_serial
                * (l.ci as u64 * (p.t_rw + p.t_pe) + self_tpes(l, p))
        }
        // Depthwise: no Ci walk (one channel per PE pass), no adder tree.
        ConvMode::Depthwise => {
            ho * wo * co_serial * ((l.kh * l.kw) as u64 * (p.t_rw + p.t_pe))
        }
        // Pointwise: Ci walk but single-tap, no adder tree (Fig. 8d).
        ConvMode::Pointwise => {
            ho * wo * co_serial * (l.ci as u64 * (p.t_rw + p.t_pe))
        }
    }
}

fn self_tpes(l: &ConvLayer, p: &ConvLatencyParams) -> u64 {
    p.tpes(l)
}

/// Latency for pooling / FC layers (both are minor next to convs):
/// pooling one cycle per output vector; FC one cycle per input with
/// spikes gathered sequentially.
pub fn layer_latency(l: &Layer, p: &ConvLatencyParams) -> u64 {
    match l {
        Layer::Conv(c) if !c.encoder => conv_latency(c, p),
        Layer::Conv(_) => 0,
        Layer::Pool { in_h, in_w, .. } => ((in_h / 2) * (in_w / 2)) as u64,
        Layer::Fc { n_in, .. } => *n_in as u64,
    }
}

/// Pipeline latency summary (Eq. (10)/(11)).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineLatency {
    /// Per-layer cycles (accelerated layers only).
    pub per_layer: Vec<u64>,
    /// Bottleneck (max) layer cycles: the pipeline interval.
    pub t_max: u64,
    /// Sum of all layer cycles: unpipelined per-frame latency.
    pub t_sum: u64,
}

impl PipelineLatency {
    /// Eq. (10): total cycles for N frames through the pipeline.
    pub fn total_cycles(&self, n_frames: u64) -> u64 {
        n_frames * self.t_max + (self.t_sum - self.t_max)
    }

    /// Eq. (11): average per-frame cycles at N frames.
    pub fn avg_cycles(&self, n_frames: u64) -> f64 {
        self.total_cycles(n_frames) as f64 / n_frames as f64
    }

    /// Unpipelined: every frame pays the full sum.
    pub fn unpipelined_cycles(&self, n_frames: u64) -> u64 {
        n_frames * self.t_sum
    }
}

/// Evaluate the latency model over a whole network at `timesteps`.
pub fn pipeline_latency(net: &NetworkSpec, p: &ConvLatencyParams,
                        timesteps: u64) -> PipelineLatency {
    let per_layer: Vec<u64> = net
        .layers
        .iter()
        .map(|l| layer_latency(l, p) * timesteps)
        .collect();
    let t_max = per_layer.iter().copied().max().unwrap_or(0);
    let t_sum = per_layer.iter().sum();
    PipelineLatency { per_layer, t_max, t_sum }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5};

    const CLK_HZ: f64 = 200e6; // ZCU102 design clock (paper Table V)

    fn ms(cycles: u64) -> f64 {
        cycles as f64 / CLK_HZ * 1e3
    }

    /// Paper SectionV-B.2: SCNN5 pipelined-but-unparallelised inference is
    /// ~10.06 ms; our Eq. (12) model must land in that neighbourhood.
    #[test]
    fn scnn5_pipelined_latency_near_paper() {
        let net = scnn5();
        let lat = pipeline_latency(&net, &ConvLatencyParams::optimized(), 1);
        let v = ms(lat.t_max);
        assert!((v - 10.06).abs() / 10.06 < 0.25, "t_max {v} ms");
    }

    /// Paper SectionV-B.2: unpipelined SCNN5 is ~24.95 ms.
    #[test]
    fn scnn5_unpipelined_latency_near_paper() {
        let net = scnn5();
        let lat = pipeline_latency(&net, &ConvLatencyParams::optimized(), 1);
        let v = ms(lat.t_sum);
        assert!((v - 24.95).abs() / 24.95 < 0.25, "t_sum {v} ms");
    }

    /// Paper SectionV-B.2 + Fig. 12: with factors (4,4,2,1) per-frame delay
    /// drops to ~2.52 ms — a ~9.9x improvement over unpipelined.
    #[test]
    fn scnn5_parallel_factors_hit_paper_speedup() {
        let net = scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap();
        let lat = pipeline_latency(&net, &ConvLatencyParams::optimized(), 1);
        let v = ms(lat.t_max);
        assert!((v - 2.52).abs() / 2.52 < 0.3, "parallel t_max {v} ms");
        let unopt = pipeline_latency(&scnn5(),
                                     &ConvLatencyParams::optimized(), 1);
        let speedup = unopt.t_sum as f64 / lat.t_max as f64;
        assert!(speedup > 7.0 && speedup < 13.0, "speedup {speedup}");
    }

    /// Paper Table IV: SCNN3 341.3 FPS unparallelised, 1333 FPS at (4,2).
    #[test]
    fn scnn3_fps_near_paper() {
        let base = pipeline_latency(&scnn3(),
                                    &ConvLatencyParams::optimized(), 1);
        let fps = CLK_HZ / base.t_max as f64;
        assert!((fps - 341.3).abs() / 341.3 < 0.3, "base fps {fps}");

        let par = pipeline_latency(
            &scnn3().try_with_parallel_factors(&[4, 2]).unwrap(),
            &ConvLatencyParams::optimized(), 1);
        let fps = CLK_HZ / par.t_max as f64;
        assert!((fps - 1333.0).abs() / 1333.0 < 0.35, "par fps {fps}");
    }

    #[test]
    fn eq10_eq11_converge_to_tmax() {
        let net = scnn5();
        let lat = pipeline_latency(&net, &ConvLatencyParams::optimized(), 1);
        let avg1 = lat.avg_cycles(1);
        let avg1k = lat.avg_cycles(1000);
        assert!(avg1 > avg1k);
        // As N grows the average approaches T_max (Eq. 11).
        assert!((avg1k - lat.t_max as f64) / (lat.t_max as f64) < 0.01);
    }

    #[test]
    fn latency_scales_with_timesteps() {
        let net = scnn3();
        let p = ConvLatencyParams::optimized();
        let l1 = pipeline_latency(&net, &p, 1);
        let l2 = pipeline_latency(&net, &p, 2);
        assert_eq!(l2.t_max, 2 * l1.t_max);
    }

    #[test]
    fn baseline_params_slower_than_optimized() {
        let net = scnn3();
        let b = pipeline_latency(&net, &ConvLatencyParams::baseline(), 1);
        let o = pipeline_latency(&net, &ConvLatencyParams::optimized(), 1);
        assert!(b.t_max > o.t_max);
    }

    #[test]
    fn parallel_factor_divides_co_walk() {
        // Parallelising only the bottleneck layer moves the bottleneck:
        // conv2 (2.23M cycles) at P=4 drops below conv3 (2.16M), so
        // t_max barely moves — the reason the paper parallelises all
        // four layers with the (4,4,2,1) profile.
        let base = pipeline_latency(&scnn5(),
                                    &ConvLatencyParams::optimized(), 1);
        let only_first = pipeline_latency(
            &scnn5().try_with_parallel_factors(&[4, 1, 1, 1]).unwrap(),
            &ConvLatencyParams::optimized(), 1);
        let r1 = base.t_max as f64 / only_first.t_max as f64;
        assert!(r1 > 1.0 && r1 < 1.5, "bottleneck shifted, ratio {r1}");

        let all = pipeline_latency(
            &scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(),
            &ConvLatencyParams::optimized(), 1);
        let r_all = base.t_max as f64 / all.t_max as f64;
        assert!(r_all > 3.0, "full profile ratio {r_all}");
    }
}
