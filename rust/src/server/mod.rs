//! TCP host interface (paper Fig. 10: the Vitis TCP server that takes
//! images + control from the host and returns results).
//!
//! Protocol: newline-delimited JSON over TCP.
//!
//! Request:  `{"id": 1, "image": [f32...]}`  (H*W*C floats, row-major
//!           channel-last, matching the artifact's input shape) or
//!           `{"cmd": "stats"}` / `{"cmd": "shutdown"}`.
//! Response: `{"id": 1, "class": 3, "logits": [...], "latency_us": 42,
//!           "replica": 0}` or `{"stats": {...}}`.
//!
//! Architecture: connection threads only parse/serialise; inference
//! jobs flow into a shared [`Batcher`] queue drained by the backend
//! worker(s).
//!
//! * [`Server::serve`] — single-pipeline mode: the accept thread owns
//!   the backend exclusively, matching the physical reality of one
//!   accelerator device. Backends need NOT be `Send` here (the PJRT
//!   client's internals are `Rc`-based).
//! * [`Server::serve_pool`] — multi-pipeline mode: N `Send` backend
//!   replicas each drain the shared queue on their own thread, so
//!   request throughput scales with host cores. Per-replica counters
//!   aggregate in [`crate::metrics::PoolMetrics`] and are reported by
//!   the `stats` command.
//!
//! std::net + threads; tokio is not vendored in this environment.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batch::Batcher;
use crate::metrics::PoolMetrics;
use crate::util::json::Json;

/// Inference backend the server fronts: image in, (class, logits) out.
/// Deliberately NOT required to be `Send` — `serve` keeps it on one
/// thread. `serve_pool` additionally requires `Send` backends.
pub trait Backend {
    fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)>;
    fn input_len(&self) -> usize;
}

/// Serving statistics. Request/latency aggregates are derived from the
/// per-replica [`PoolMetrics`] (single source of truth); the only
/// separate counter is for protocol errors that never reach a replica.
#[derive(Debug)]
pub struct ServerStats {
    /// Bad JSON / bad request shape, counted before replica dispatch.
    pub protocol_errors: AtomicU64,
    /// Per-replica counters (one entry in single-pipeline mode).
    pub pool: PoolMetrics,
}

impl ServerStats {
    fn new(replicas: usize) -> Self {
        Self {
            protocol_errors: AtomicU64::new(0),
            pool: PoolMetrics::new(replicas),
        }
    }

    pub fn requests(&self) -> u64 {
        self.pool.totals().requests
    }

    /// Backend errors across replicas + protocol-level errors.
    pub fn errors(&self) -> u64 {
        self.pool.totals().errors
            + self.protocol_errors.load(Ordering::SeqCst)
    }

    pub fn total_latency_us(&self) -> u64 {
        self.pool.totals().latency_us
    }
}

/// How long a connection waits for its queued job's reply before
/// reporting a timeout (bounds client hangs across shutdown races and
/// overload; the error message names both causes).
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// An inference job travelling from a connection thread to a backend.
struct Job {
    id: f64,
    image: Vec<f32>,
    enqueued_at: Instant,
    reply: Sender<Json>,
}

pub struct Server<B: Backend> {
    backends: Vec<B>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
    max_wait: Duration,
}

impl<B: Backend> Server<B> {
    /// Single-pipeline server (the paper's one-accelerator shape).
    pub fn new(backend: B) -> Self {
        Self::with_backends(vec![backend])
    }

    /// Server fronting a pool of backend replicas. All replicas must
    /// answer identically (same model); the pool only adds throughput.
    pub fn with_backends(backends: Vec<B>) -> Self {
        assert!(!backends.is_empty(), "server needs at least one backend");
        let n = backends.len();
        Self {
            backends,
            stats: Arc::new(ServerStats::new(n)),
            shutdown: Arc::new(AtomicBool::new(false)),
            max_batch: 16,
            max_wait: Duration::from_millis(5),
        }
    }

    /// Tune the shared queue's batching policy.
    pub fn with_queue(mut self, max_batch: usize, max_wait: Duration)
                      -> Self {
        assert!(max_batch > 0);
        self.max_batch = max_batch;
        self.max_wait = max_wait;
        self
    }

    pub fn replicas(&self) -> usize {
        self.backends.len()
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    fn bind(&self, addr: &str,
            on_bound: impl FnOnce(std::net::SocketAddr))
            -> Result<TcpListener> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        Ok(listener)
    }

    /// Bind and serve until a shutdown command arrives, draining jobs
    /// on this (backend-owning) thread. `on_bound` receives the bound
    /// address (port 0 => ephemeral, for tests). Uses the first backend
    /// only — use [`Server::serve_pool`] for replica parallelism.
    pub fn serve(mut self, addr: &str,
                 on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = self.bind(addr, on_bound)?;
        let queue: Arc<Batcher<Job>> =
            Arc::new(Batcher::new(self.max_batch, self.max_wait));
        let mut handles = Vec::new();

        while !self.shutdown.load(Ordering::SeqCst) {
            accept_connections(&listener, &queue, &self.stats,
                               &self.shutdown,
                               self.backends[0].input_len(),
                               &mut handles)?;
            // Drain inference jobs on this (backend-owning) thread.
            let batch = queue.try_batch();
            if batch.is_empty() {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            for job in batch {
                handle_job(&mut self.backends[0], 0, job, &self.stats);
            }
        }
        reject_pending(&queue);
        for h in handles {
            let _ = h.join();
        }
        // A connection racing the shutdown flag may have pushed after
        // the first drain; it has exited (or timed out) by now, so one
        // final sweep leaves nothing unanswered.
        reject_pending(&queue);
        Ok(())
    }

    /// Total requests served (stats convenience for tests/benches).
    pub fn requests_served(&self) -> u64 {
        self.stats.requests()
    }
}

impl<B: Backend + Send + 'static> Server<B> {
    /// Bind and serve with every backend replica draining the shared
    /// queue on its own worker thread.
    pub fn serve_pool(mut self, addr: &str,
                      on_bound: impl FnOnce(std::net::SocketAddr))
                      -> Result<()> {
        let listener = self.bind(addr, on_bound)?;
        let queue: Arc<Batcher<Job>> =
            Arc::new(Batcher::new(self.max_batch, self.max_wait));
        let input_len = self.backends[0].input_len();

        let mut workers = Vec::new();
        for (idx, mut backend) in self.backends.drain(..).enumerate() {
            let queue = queue.clone();
            let stats = self.stats.clone();
            let stop = self.shutdown.clone();
            workers.push(std::thread::spawn(move || {
                loop {
                    let batch = queue.next_batch();
                    if batch.is_empty() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                    for job in batch {
                        handle_job(&mut backend, idx, job, &stats);
                    }
                }
            }));
        }

        let mut handles = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) {
            accept_connections(&listener, &queue, &self.stats,
                               &self.shutdown, input_len, &mut handles)?;
            std::thread::sleep(Duration::from_millis(1));
        }
        for w in workers {
            let _ = w.join(); // workers drain the queue before exiting
        }
        reject_pending(&queue);
        for h in handles {
            let _ = h.join();
        }
        // Final sweep for jobs pushed in the shutdown race window (the
        // connection threads have all exited or timed out by now).
        reject_pending(&queue);
        Ok(())
    }
}

/// Accept pending connections (non-blocking listener).
fn accept_connections(
    listener: &TcpListener, queue: &Arc<Batcher<Job>>,
    stats: &Arc<ServerStats>, shutdown: &Arc<AtomicBool>,
    input_len: usize,
    handles: &mut Vec<std::thread::JoinHandle<()>>) -> Result<()> {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let queue = queue.clone();
                let stats = stats.clone();
                let shutdown = shutdown.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = conn_loop(stream, queue, stats, shutdown,
                                      input_len);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Run one job through a backend, updating aggregate + replica stats.
fn handle_job<B: Backend>(backend: &mut B, replica: usize, job: Job,
                          stats: &ServerStats) {
    let t0 = Instant::now();
    let reply = match backend.infer(&job.image) {
        Ok((class, logits)) => {
            let busy_us = t0.elapsed().as_micros() as u64;
            let us = job.enqueued_at.elapsed().as_micros() as u64;
            stats.pool.record(replica, us, busy_us);
            Json::obj(vec![
                ("id", Json::num(job.id)),
                ("class", Json::num(class as f64)),
                ("logits",
                 Json::Arr(logits
                     .iter()
                     .map(|&l| Json::num(l as f64))
                     .collect())),
                ("latency_us", Json::num(us as f64)),
                ("replica", Json::num(replica as f64)),
            ])
        }
        Err(e) => {
            stats.pool.record_error(replica);
            Json::obj(vec![("error", Json::str(&e.to_string()))])
        }
    };
    let _ = job.reply.send(reply);
}

/// Error out whatever is still queued at shutdown.
fn reject_pending(queue: &Batcher<Job>) {
    for job in queue.drain_all() {
        let _ = job.reply.send(Json::obj(vec![(
            "error",
            Json::str("server shutting down"),
        )]));
    }
}

fn stats_json(stats: &ServerStats) -> Json {
    let per: Vec<Json> = stats
        .pool
        .per_replica()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("requests", Json::num(s.requests as f64)),
                ("errors", Json::num(s.errors as f64)),
                ("busy_us", Json::num(s.busy_us as f64)),
                ("latency_us", Json::num(s.latency_us as f64)),
            ])
        })
        .collect();
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("requests", Json::num(stats.requests() as f64)),
            ("errors", Json::num(stats.errors() as f64)),
            ("total_latency_us",
             Json::num(stats.total_latency_us() as f64)),
            ("replicas", Json::Arr(per)),
        ]),
    )])
}

/// Per-connection loop: parse lines, ship jobs, write replies.
fn conn_loop(stream: TcpStream, queue: Arc<Batcher<Job>>,
             stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>,
             input_len: usize) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match Json::parse(line.trim()) {
            Err(e) => Json::obj(vec![("error", Json::str(&e.to_string()))]),
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
                    match cmd {
                        "shutdown" => {
                            shutdown.store(true, Ordering::SeqCst);
                            let r = Json::obj(vec![("ok", Json::Bool(true))]);
                            writeln!(out, "{r}")?;
                            return Ok(());
                        }
                        "stats" => stats_json(&stats),
                        other => Json::obj(vec![(
                            "error",
                            Json::str(&format!("unknown cmd {other}")),
                        )]),
                    }
                } else {
                    match parse_infer(&req, input_len) {
                        Err(msg) => {
                            stats.protocol_errors
                                .fetch_add(1, Ordering::SeqCst);
                            Json::obj(vec![("error", Json::str(&msg))])
                        }
                        Ok((id, image)) => {
                            if shutdown.load(Ordering::SeqCst) {
                                Json::obj(vec![(
                                    "error",
                                    Json::str("server shutting down"),
                                )])
                            } else {
                                let (tx, rx) = channel();
                                queue.push(Job {
                                    id,
                                    image,
                                    enqueued_at: Instant::now(),
                                    reply: tx,
                                });
                                rx.recv_timeout(REPLY_TIMEOUT)
                                    .unwrap_or_else(|_| {
                                        Json::obj(vec![(
                                            "error",
                                            Json::str("request timed out \
                                                       (overloaded or \
                                                       shutting down)"),
                                        )])
                                    })
                            }
                        }
                    }
                }
            }
        };
        writeln!(out, "{reply}")?;
    }
}

fn parse_infer(req: &Json, input_len: usize)
               -> std::result::Result<(f64, Vec<f32>), String> {
    let id = req.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let image: Vec<f32> = match req.get("image").and_then(|v| v.as_arr()) {
        Some(arr) => arr
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect(),
        None => return Err("missing image".to_string()),
    };
    if image.len() != input_len {
        return Err(format!("image len {} != {input_len}", image.len()));
    }
    Ok((id, image))
}

/// Simple blocking client (used by examples + tests).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.stream, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn infer(&mut self, id: u64, image: &[f32]) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("image",
             Json::Arr(image.iter().map(|&x| Json::num(x as f64)).collect())),
        ]);
        self.request(&req)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.request(&Json::obj(vec![("cmd", Json::str("shutdown"))]));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: class = argmax of the 4-pixel image.
    struct Toy;

    impl Backend for Toy {
        fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
            let arg = image
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            Ok((arg, image.to_vec()))
        }

        fn input_len(&self) -> usize {
            4
        }
    }

    #[test]
    fn end_to_end_roundtrip() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap();

        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.infer(7, &[0.1, 0.9, 0.2, 0.3]).unwrap();
        assert_eq!(resp.get("class").unwrap().as_usize(), Some(1));
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(7.0));

        // Wrong image size -> error, server stays up.
        let resp = c.infer(8, &[0.1]).unwrap();
        assert!(resp.get("error").is_some());

        // Stats reflect the traffic.
        let resp = c
            .request(&Json::obj(vec![("cmd", Json::str("stats"))]))
            .unwrap();
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get("errors").unwrap().as_usize(), Some(1));

        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut clients: Vec<_> = (0..4)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let mut img = [0.0f32; 4];
                    img[i % 4] = 1.0;
                    let resp = c.infer(i as u64, &img).unwrap();
                    resp.get("class").unwrap().as_usize().unwrap()
                })
            })
            .collect();
        let results: Vec<usize> =
            clients.drain(..).map(|h| h.join().unwrap()).collect();
        assert_eq!(results, vec![0, 1, 2, 3]);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// Four replicas behind one port: every request answered correctly,
    /// per-replica stats sum to the total, and the stats command
    /// reports one entry per replica.
    #[test]
    fn replica_pool_serves_concurrent_clients() {
        let server =
            Server::with_backends(vec![Toy, Toy, Toy, Toy])
                .with_queue(4, Duration::from_millis(2));
        assert_eq!(server.replicas(), 4);
        let stats = server.stats();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve_pool("127.0.0.1:0",
                              move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut clients: Vec<_> = (0..8u64)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let mut got = Vec::new();
                    for j in 0..4u64 {
                        let mut img = [0.0f32; 4];
                        img[((i + j) % 4) as usize] = 1.0;
                        let resp = c.infer(i * 10 + j, &img).unwrap();
                        got.push((
                            resp.get("class").unwrap().as_usize().unwrap(),
                            ((i + j) % 4) as usize,
                        ));
                    }
                    got
                })
            })
            .collect();
        for c in clients.drain(..) {
            for (got, want) in c.join().unwrap() {
                assert_eq!(got, want);
            }
        }

        let totals = stats.pool.totals();
        assert_eq!(totals.requests, 32);
        assert_eq!(stats.requests(), 32);
        assert_eq!(stats.pool.per_replica().len(), 4);

        let mut c = Client::connect(&addr).unwrap();
        let resp = c
            .request(&Json::obj(vec![("cmd", Json::str("stats"))]))
            .unwrap();
        let replicas = resp
            .get("stats")
            .and_then(|s| s.get("replicas"))
            .and_then(|r| r.as_arr())
            .expect("per-replica stats present");
        assert_eq!(replicas.len(), 4);
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }
}
